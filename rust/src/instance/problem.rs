//! Core problem model: dimensions, per-group buffers, the [`GroupSource`]
//! abstraction and the in-memory [`MaterializedProblem`].

use crate::error::{Error, Result};
use crate::instance::laminar::LaminarProfile;

/// Instance dimensions: `N` groups × `M` items per group × `K` global
/// knapsack constraints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dims {
    /// Number of groups (users), `N`. Billion-scale in production.
    pub n_groups: usize,
    /// Items per group, `M`. Small (≤ ~100).
    pub n_items: usize,
    /// Global knapsack constraints, `K`. Small (≤ ~hundreds).
    pub n_global: usize,
}

impl Dims {
    /// Total number of decision variables `N·M`.
    pub fn n_vars(&self) -> usize {
        self.n_groups * self.n_items
    }
}

/// Cost coefficients for the `M` items of one group.
///
/// * `Dense` — `b_ijk` for all `(j,k)`, row-major `[j][k]`, the paper's
///   "dense global constraints" class.
/// * `Sparse` — each item `j` consumes from exactly one knapsack
///   `knap[j]` at rate `cost[j]` (`b_ijk = 0` elsewhere), the paper's
///   "sparse" class and the precondition of Algorithm 5.
#[derive(Debug, Clone, PartialEq)]
pub enum CostsBuf {
    /// Dense `M×K` block.
    Dense(Vec<f32>),
    /// One (knapsack, cost) pair per item.
    Sparse { knap: Vec<u32>, cost: Vec<f32> },
}

impl CostsBuf {
    /// Allocate a zeroed buffer of the right layout.
    pub fn zeroed(m: usize, k: usize, dense: bool) -> Self {
        if dense {
            CostsBuf::Dense(vec![0.0; m * k])
        } else {
            let _ = k;
            CostsBuf::Sparse { knap: vec![0; m], cost: vec![0.0; m] }
        }
    }

    /// `b_ijk` for this group's item `j`, knapsack `k`.
    #[inline]
    pub fn cost(&self, j: usize, k: usize, n_global: usize) -> f32 {
        match self {
            CostsBuf::Dense(b) => b[j * n_global + k],
            CostsBuf::Sparse { knap, cost } => {
                if knap[j] as usize == k {
                    cost[j]
                } else {
                    0.0
                }
            }
        }
    }

    /// True if this is the dense layout.
    pub fn is_dense(&self) -> bool {
        matches!(self, CostsBuf::Dense(_))
    }
}

/// Reusable per-group scratch buffer filled by [`GroupSource::fill_group`].
/// The map workers allocate one per worker and reuse it across the shard —
/// there is no per-group allocation on the hot path.
#[derive(Debug, Clone)]
pub struct GroupBuf {
    /// `p_ij` for `j ∈ [M]`.
    pub profits: Vec<f32>,
    /// `b_ijk`.
    pub costs: CostsBuf,
}

impl GroupBuf {
    /// Allocate a buffer matching `dims` and layout.
    pub fn new(dims: Dims, dense: bool) -> Self {
        Self {
            profits: vec![0.0; dims.n_items],
            costs: CostsBuf::zeroed(dims.n_items, dims.n_global, dense),
        }
    }

    /// `b_ijk` accessor for the buffered group.
    #[inline]
    pub fn cost(&self, j: usize, k: usize, n_global: usize) -> f32 {
        self.costs.cost(j, k, n_global)
    }
}

/// A source of group data: the solver's view of an instance.
///
/// Implementations must be `Sync` — the MapReduce engine calls
/// `fill_group` concurrently from worker threads, each with its own
/// [`GroupBuf`].
pub trait GroupSource: Sync {
    /// Instance dimensions.
    fn dims(&self) -> Dims;
    /// Whether groups use dense cost blocks (vs sparse one-knapsack items).
    fn is_dense(&self) -> bool;
    /// The shared hierarchical local-constraint profile (paper Def. 2.1).
    fn locals(&self) -> &LaminarProfile;
    /// Global budgets `B_k`, strictly positive.
    fn budgets(&self) -> &[f64];
    /// Write group `i`'s `(p, b)` into `buf`.
    fn fill_group(&self, i: usize, buf: &mut GroupBuf);

    /// Natural work-partition unit of the source, if it has one. Disk- or
    /// network-backed sources (e.g. [`crate::instance::store::MmapProblem`])
    /// return their file-shard size here so the solvers' map shards align
    /// with storage shards — a map worker then touches whole files
    /// (page-cache-friendly) and XLA slab padding never straddles a file
    /// boundary. In-memory sources return `None`.
    fn preferred_shard_size(&self) -> Option<usize> {
        None
    }

    /// On-disk home of the instance, if it has one (a shard-store
    /// directory). The session API ([`crate::solve`]) writes periodic λ
    /// checkpoints next to the data they belong to, so an interrupted
    /// out-of-core solve resumes from the same directory it reads.
    /// In-memory sources return `None`.
    fn store_dir(&self) -> Option<std::path::PathBuf> {
        None
    }

    /// Validate basic invariants; call once before solving.
    fn validate(&self) -> Result<()> {
        let d = self.dims();
        if d.n_groups == 0 || d.n_items == 0 || d.n_global == 0 {
            return Err(Error::InvalidProblem(format!(
                "dimensions must be positive, got N={} M={} K={}",
                d.n_groups, d.n_items, d.n_global
            )));
        }
        if self.budgets().len() != d.n_global {
            return Err(Error::InvalidProblem(format!(
                "expected {} budgets, got {}",
                d.n_global,
                self.budgets().len()
            )));
        }
        if let Some(b) = self.budgets().iter().find(|&&b| !(b > 0.0)) {
            return Err(Error::InvalidProblem(format!("budgets must be strictly positive, got {b}")));
        }
        self.locals().check_items_in_range(d.n_items)?;
        Ok(())
    }
}

/// Fully in-memory instance. Layout is `f32` (the paper's coefficients live
/// in `[0,10]`; accumulation happens in compensated `f64` downstream).
#[derive(Debug, Clone)]
pub struct MaterializedProblem {
    dims: Dims,
    /// `N×M`, row-major.
    profits: Vec<f32>,
    /// Dense: `N×M×K`; Sparse: parallel `knap`/`cost` of `N×M`.
    costs: MaterializedCosts,
    budgets: Vec<f64>,
    locals: LaminarProfile,
}

#[derive(Debug, Clone)]
enum MaterializedCosts {
    Dense(Vec<f32>),
    Sparse { knap: Vec<u32>, cost: Vec<f32> },
}

impl MaterializedProblem {
    /// Zero-initialized dense instance; fill with the `set_*` methods.
    pub fn zeroed_dense(dims: Dims, budgets: Vec<f64>, locals: LaminarProfile) -> Result<Self> {
        let nm = dims
            .n_groups
            .checked_mul(dims.n_items)
            .ok_or_else(|| Error::InvalidProblem("N*M overflows".into()))?;
        let nmk = nm
            .checked_mul(dims.n_global)
            .ok_or_else(|| Error::InvalidProblem("N*M*K overflows".into()))?;
        Ok(Self {
            dims,
            profits: vec![0.0; nm],
            costs: MaterializedCosts::Dense(vec![0.0; nmk]),
            budgets,
            locals,
        })
    }

    /// Zero-initialized sparse instance (every item initially mapped to
    /// knapsack 0 with cost 0).
    pub fn zeroed_sparse(dims: Dims, budgets: Vec<f64>, locals: LaminarProfile) -> Result<Self> {
        let nm = dims
            .n_groups
            .checked_mul(dims.n_items)
            .ok_or_else(|| Error::InvalidProblem("N*M overflows".into()))?;
        Ok(Self {
            dims,
            profits: vec![0.0; nm],
            costs: MaterializedCosts::Sparse { knap: vec![0; nm], cost: vec![0.0; nm] },
            budgets,
            locals,
        })
    }

    /// Materialize any [`GroupSource`] (small instances only: O(N·M·K)).
    pub fn from_source<S: GroupSource + ?Sized>(src: &S) -> Result<Self> {
        let dims = src.dims();
        let mut out = if src.is_dense() {
            Self::zeroed_dense(dims, src.budgets().to_vec(), src.locals().clone())?
        } else {
            Self::zeroed_sparse(dims, src.budgets().to_vec(), src.locals().clone())?
        };
        let mut buf = GroupBuf::new(dims, src.is_dense());
        for i in 0..dims.n_groups {
            src.fill_group(i, &mut buf);
            out.profits[i * dims.n_items..(i + 1) * dims.n_items].copy_from_slice(&buf.profits);
            match (&mut out.costs, &buf.costs) {
                (MaterializedCosts::Dense(dst), CostsBuf::Dense(srcb)) => {
                    let w = dims.n_items * dims.n_global;
                    dst[i * w..(i + 1) * w].copy_from_slice(srcb);
                }
                (MaterializedCosts::Sparse { knap, cost }, CostsBuf::Sparse { knap: kb, cost: cb }) => {
                    knap[i * dims.n_items..(i + 1) * dims.n_items].copy_from_slice(kb);
                    cost[i * dims.n_items..(i + 1) * dims.n_items].copy_from_slice(cb);
                }
                _ => unreachable!("layout fixed by constructor"),
            }
        }
        Ok(out)
    }

    /// Set `p_ij`.
    pub fn set_profit(&mut self, i: usize, j: usize, v: f32) {
        self.profits[i * self.dims.n_items + j] = v;
    }

    /// Set dense `b_ijk`. Panics on a sparse instance.
    pub fn set_cost(&mut self, i: usize, j: usize, k: usize, v: f32) {
        match &mut self.costs {
            MaterializedCosts::Dense(b) => {
                b[(i * self.dims.n_items + j) * self.dims.n_global + k] = v
            }
            _ => panic!("set_cost on sparse instance; use set_sparse_cost"),
        }
    }

    /// Set sparse item mapping: item `j` of group `i` consumes `v` from `knapsack`.
    pub fn set_sparse_cost(&mut self, i: usize, j: usize, knapsack: u32, v: f32) {
        match &mut self.costs {
            MaterializedCosts::Sparse { knap, cost } => {
                let idx = i * self.dims.n_items + j;
                knap[idx] = knapsack;
                cost[idx] = v;
            }
            _ => panic!("set_sparse_cost on dense instance; use set_cost"),
        }
    }

    /// Replace the budget vector.
    pub fn set_budgets(&mut self, budgets: Vec<f64>) {
        self.budgets = budgets;
    }

    /// `p_ij` accessor.
    pub fn profit(&self, i: usize, j: usize) -> f32 {
        self.profits[i * self.dims.n_items + j]
    }

    /// `b_ijk` accessor (works for both layouts).
    pub fn cost(&self, i: usize, j: usize, k: usize) -> f32 {
        match &self.costs {
            MaterializedCosts::Dense(b) => {
                b[(i * self.dims.n_items + j) * self.dims.n_global + k]
            }
            MaterializedCosts::Sparse { knap, cost } => {
                let idx = i * self.dims.n_items + j;
                if knap[idx] as usize == k {
                    cost[idx]
                } else {
                    0.0
                }
            }
        }
    }
}

impl GroupSource for MaterializedProblem {
    fn dims(&self) -> Dims {
        self.dims
    }

    fn is_dense(&self) -> bool {
        matches!(self.costs, MaterializedCosts::Dense(_))
    }

    fn locals(&self) -> &LaminarProfile {
        &self.locals
    }

    fn budgets(&self) -> &[f64] {
        &self.budgets
    }

    fn fill_group(&self, i: usize, buf: &mut GroupBuf) {
        let m = self.dims.n_items;
        buf.profits.copy_from_slice(&self.profits[i * m..(i + 1) * m]);
        match (&self.costs, &mut buf.costs) {
            (MaterializedCosts::Dense(b), CostsBuf::Dense(dst)) => {
                let w = m * self.dims.n_global;
                dst.copy_from_slice(&b[i * w..(i + 1) * w]);
            }
            (MaterializedCosts::Sparse { knap, cost }, CostsBuf::Sparse { knap: dk, cost: dc }) => {
                dk.copy_from_slice(&knap[i * m..(i + 1) * m]);
                dc.copy_from_slice(&cost[i * m..(i + 1) * m]);
            }
            _ => panic!("GroupBuf layout does not match problem layout"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::laminar::LaminarProfile;

    fn dims() -> Dims {
        Dims { n_groups: 3, n_items: 2, n_global: 2 }
    }

    #[test]
    fn dense_roundtrip() {
        let mut p =
            MaterializedProblem::zeroed_dense(dims(), vec![1.0, 1.0], LaminarProfile::single(2, 1))
                .unwrap();
        p.set_profit(1, 0, 3.5);
        p.set_cost(1, 0, 1, 0.25);
        assert_eq!(p.profit(1, 0), 3.5);
        assert_eq!(p.cost(1, 0, 1), 0.25);
        assert_eq!(p.cost(1, 0, 0), 0.0);

        let mut buf = GroupBuf::new(dims(), true);
        p.fill_group(1, &mut buf);
        assert_eq!(buf.profits, vec![3.5, 0.0]);
        assert_eq!(buf.cost(0, 1, 2), 0.25);
        p.validate().unwrap();
    }

    #[test]
    fn sparse_roundtrip() {
        let mut p = MaterializedProblem::zeroed_sparse(
            dims(),
            vec![1.0, 2.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        p.set_sparse_cost(2, 1, 1, 0.75);
        assert_eq!(p.cost(2, 1, 1), 0.75);
        assert_eq!(p.cost(2, 1, 0), 0.0);
        let mut buf = GroupBuf::new(dims(), false);
        p.fill_group(2, &mut buf);
        assert_eq!(buf.cost(1, 1, 2), 0.75);
        assert_eq!(buf.cost(1, 0, 2), 0.0);
    }

    #[test]
    fn validate_rejects_bad_budgets() {
        let p = MaterializedProblem::zeroed_dense(
            dims(),
            vec![1.0, 0.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        assert!(matches!(p.validate(), Err(Error::InvalidProblem(_))));
        let p = MaterializedProblem::zeroed_dense(dims(), vec![1.0], LaminarProfile::single(2, 1))
            .unwrap();
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_dims() {
        let p = MaterializedProblem::zeroed_dense(
            Dims { n_groups: 0, n_items: 2, n_global: 1 },
            vec![1.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        assert!(p.validate().is_err());
    }

    #[test]
    fn from_source_is_identity_for_materialized() {
        let mut p = MaterializedProblem::zeroed_dense(
            dims(),
            vec![1.0, 1.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        p.set_profit(0, 1, 2.0);
        p.set_cost(2, 1, 0, 0.5);
        let q = MaterializedProblem::from_source(&p).unwrap();
        assert_eq!(q.profit(0, 1), 2.0);
        assert_eq!(q.cost(2, 1, 0), 0.5);
    }
}
