//! Core problem model: dimensions, per-group buffers, the [`GroupSource`]
//! abstraction and the in-memory [`MaterializedProblem`].

use crate::error::{Error, Result};
use crate::instance::laminar::LaminarProfile;

/// Instance dimensions: `N` groups × `M` items per group × `K` global
/// knapsack constraints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dims {
    /// Number of groups (users), `N`. Billion-scale in production.
    pub n_groups: usize,
    /// Items per group, `M`. Small (≤ ~100).
    pub n_items: usize,
    /// Global knapsack constraints, `K`. Small (≤ ~hundreds).
    pub n_global: usize,
}

impl Dims {
    /// Total number of decision variables `N·M`.
    pub fn n_vars(&self) -> usize {
        self.n_groups * self.n_items
    }
}

/// Cost coefficients for the `M` items of one group.
///
/// * `Dense` — `b_ijk` for all `(j,k)`, row-major `[j][k]`, the paper's
///   "dense global constraints" class.
/// * `Sparse` — each item `j` consumes from exactly one knapsack
///   `knap[j]` at rate `cost[j]` (`b_ijk = 0` elsewhere), the paper's
///   "sparse" class and the precondition of Algorithm 5.
#[derive(Debug, Clone, PartialEq)]
pub enum CostsBuf {
    /// Dense `M×K` block.
    Dense(Vec<f32>),
    /// One (knapsack, cost) pair per item.
    Sparse { knap: Vec<u32>, cost: Vec<f32> },
}

impl CostsBuf {
    /// Allocate a zeroed buffer of the right layout.
    pub fn zeroed(m: usize, k: usize, dense: bool) -> Self {
        if dense {
            CostsBuf::Dense(vec![0.0; m * k])
        } else {
            let _ = k;
            CostsBuf::Sparse { knap: vec![0; m], cost: vec![0.0; m] }
        }
    }

    /// `b_ijk` for this group's item `j`, knapsack `k`.
    #[inline]
    pub fn cost(&self, j: usize, k: usize, n_global: usize) -> f32 {
        match self {
            CostsBuf::Dense(b) => b[j * n_global + k],
            CostsBuf::Sparse { knap, cost } => {
                if knap[j] as usize == k {
                    cost[j]
                } else {
                    0.0
                }
            }
        }
    }

    /// True if this is the dense layout.
    pub fn is_dense(&self) -> bool {
        matches!(self, CostsBuf::Dense(_))
    }
}

/// Reusable per-group scratch buffer filled by [`GroupSource::fill_group`].
/// The map workers allocate one per worker and reuse it across the shard —
/// there is no per-group allocation on the hot path.
#[derive(Debug, Clone)]
pub struct GroupBuf {
    /// `p_ij` for `j ∈ [M]`.
    pub profits: Vec<f32>,
    /// `b_ijk`.
    pub costs: CostsBuf,
}

impl GroupBuf {
    /// Allocate a buffer matching `dims` and layout.
    pub fn new(dims: Dims, dense: bool) -> Self {
        Self {
            profits: vec![0.0; dims.n_items],
            costs: CostsBuf::zeroed(dims.n_items, dims.n_global, dense),
        }
    }

    /// `b_ijk` accessor for the buffered group.
    #[inline]
    pub fn cost(&self, j: usize, k: usize, n_global: usize) -> f32 {
        self.costs.cost(j, k, n_global)
    }
}

/// Borrowed SoA cost columns for a contiguous run of groups (the block
/// analogue of [`CostsBuf`]).
#[derive(Debug, Clone, Copy)]
pub enum BlockCosts<'a> {
    /// Dense `len×M×K`, row-major `[g][j][k]`.
    Dense(&'a [f32]),
    /// Sparse parallel columns, `len×M` each.
    Sparse {
        /// Knapsack index per item.
        knap: &'a [u32],
        /// Consumption per item.
        cost: &'a [f32],
    },
}

/// One group's borrowed slices inside a [`GroupBlock`] — what the SoA
/// kernels ([`crate::solver::adjusted`], [`crate::solver::candidates`])
/// consume directly, with no per-group copy in between.
#[derive(Debug, Clone, Copy)]
pub struct GroupRow<'a> {
    /// `p_j` for the group's `M` items.
    pub profits: &'a [f32],
    /// `b_jk` in the layout the source stores.
    pub costs: RowCosts<'a>,
}

/// Cost slices of a single group (row view of [`BlockCosts`]).
#[derive(Debug, Clone, Copy)]
pub enum RowCosts<'a> {
    /// Dense `M×K` row-major block.
    Dense(&'a [f32]),
    /// One (knapsack, cost) pair per item.
    Sparse {
        /// Knapsack index per item.
        knap: &'a [u32],
        /// Consumption per item.
        cost: &'a [f32],
    },
}

impl GroupBuf {
    /// Row view of the buffered group (bridges the per-group API into the
    /// SoA kernels).
    #[inline]
    pub fn row(&self) -> GroupRow<'_> {
        GroupRow {
            profits: &self.profits,
            costs: match &self.costs {
                CostsBuf::Dense(b) => RowCosts::Dense(b),
                CostsBuf::Sparse { knap, cost } => RowCosts::Sparse { knap, cost },
            },
        }
    }
}

/// A zero-copy structure-of-arrays view over the contiguous groups
/// `[start, start+len)` — the unit the hot-path map kernels operate on.
/// Served without copying by [`MaterializedProblem`] and the memory-mapped
/// store ([`crate::instance::store::MmapProblem`]); owned-buffer sources
/// (the synthetic generator, any [`GroupSource`] using the default
/// [`GroupSource::fill_block`]) back it with a caller-provided
/// [`BlockBuf`].
#[derive(Debug, Clone, Copy)]
pub struct GroupBlock<'a> {
    start: usize,
    len: usize,
    n_items: usize,
    profits: &'a [f32],
    costs: BlockCosts<'a>,
}

impl<'a> GroupBlock<'a> {
    /// Assemble a block from raw slices; `profits.len()` must be
    /// `len·n_items` and the cost slices must match the layout
    /// (`len·n_items·n_global` dense, `len·n_items` sparse columns).
    pub fn new(
        start: usize,
        n_items: usize,
        n_global: usize,
        profits: &'a [f32],
        costs: BlockCosts<'a>,
    ) -> Self {
        assert!(n_items > 0, "block needs n_items > 0");
        assert_eq!(profits.len() % n_items, 0, "ragged profits slice");
        let len = profits.len() / n_items;
        match &costs {
            BlockCosts::Dense(b) => {
                assert_eq!(b.len(), len * n_items * n_global, "dense cost slice length")
            }
            BlockCosts::Sparse { knap, cost } => {
                assert_eq!(knap.len(), len * n_items, "sparse knap slice length");
                assert_eq!(cost.len(), len * n_items, "sparse cost slice length");
            }
        }
        Self { start, len, n_items, profits, costs }
    }

    /// Global id of the block's first group.
    #[inline]
    pub fn start(&self) -> usize {
        self.start
    }

    /// Number of groups in the block.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the block holds no groups.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Row view of local group `g` (`0 ≤ g < len`).
    #[inline]
    pub fn row(&self, g: usize) -> GroupRow<'a> {
        let m = self.n_items;
        let profits = &self.profits[g * m..(g + 1) * m];
        let costs = match self.costs {
            BlockCosts::Dense(b) => {
                let w = b.len() / self.len;
                RowCosts::Dense(&b[g * w..(g + 1) * w])
            }
            BlockCosts::Sparse { knap, cost } => {
                RowCosts::Sparse { knap: &knap[g * m..(g + 1) * m], cost: &cost[g * m..(g + 1) * m] }
            }
        };
        GroupRow { profits, costs }
    }
}

/// Owned backing storage for [`GroupSource::fill_block`] on sources that
/// cannot serve borrowed views (the synthetic generator, samplers). One
/// lives per map worker and is reused across blocks and rounds — the hot
/// path performs no per-block allocation after warm-up.
#[derive(Debug, Default)]
pub struct BlockBuf {
    /// `len×M` profits, filled by the source.
    pub profits: Vec<f32>,
    /// `len×M×K` dense costs (dense layout only).
    pub dense: Vec<f32>,
    /// `len×M` knapsack indices (sparse layout only).
    pub knap: Vec<u32>,
    /// `len×M` costs (sparse layout only).
    pub cost: Vec<f32>,
    staging: Option<GroupBuf>,
}

impl BlockBuf {
    /// Empty buffer; sized lazily by [`BlockBuf::ensure`].
    pub fn new() -> Self {
        Self::default()
    }

    /// Resize the SoA columns for `len` groups of shape `(m, k)`;
    /// capacity is kept across calls.
    pub fn ensure(&mut self, len: usize, m: usize, k: usize, dense: bool) {
        self.profits.resize(len * m, 0.0);
        if dense {
            self.dense.resize(len * m * k, 0.0);
        } else {
            self.knap.resize(len * m, 0);
            self.cost.resize(len * m, 0.0);
        }
    }

    /// View the filled columns as a [`GroupBlock`] (after
    /// [`BlockBuf::ensure`] + filling).
    pub fn block(&self, start: usize, len: usize, m: usize, k: usize, dense: bool) -> GroupBlock<'_> {
        let costs = if dense {
            BlockCosts::Dense(&self.dense[..len * m * k])
        } else {
            BlockCosts::Sparse { knap: &self.knap[..len * m], cost: &self.cost[..len * m] }
        };
        GroupBlock::new(start, m, k, &self.profits[..len * m], costs)
    }
}

/// Default cap on the number of f32 values a staged (owned) block holds —
/// keeps the per-worker [`BlockBuf`] around 1 MiB so blocks stay
/// cache-resident.
const BLOCK_STAGING_F32: usize = 262_144;

/// A source of group data: the solver's view of an instance.
///
/// Implementations must be `Sync` — the MapReduce engine calls
/// `fill_group` / `fill_block` concurrently from worker threads, each with
/// its own [`GroupBuf`] / [`BlockBuf`].
pub trait GroupSource: Sync {
    /// Instance dimensions.
    fn dims(&self) -> Dims;
    /// Whether groups use dense cost blocks (vs sparse one-knapsack items).
    fn is_dense(&self) -> bool;
    /// The shared hierarchical local-constraint profile (paper Def. 2.1).
    fn locals(&self) -> &LaminarProfile;
    /// Global budgets `B_k`, strictly positive.
    fn budgets(&self) -> &[f64];
    /// Write group `i`'s `(p, b)` into `buf`.
    fn fill_group(&self, i: usize, buf: &mut GroupBuf);

    /// Largest `e ≤ end` such that `[start, e)` can be served as one
    /// [`GroupBlock`] by [`GroupSource::fill_block`]. Zero-copy sources
    /// return the next internal boundary (a storage-shard edge, or `end`
    /// when the data is fully contiguous); the default caps owned staging
    /// at ~1 MiB of coefficients. Callers iterate a shard as
    /// `pos = block_end(pos, shard.end)` steps. Must return `> start`
    /// whenever `start < end`.
    fn block_end(&self, start: usize, end: usize) -> usize {
        let d = self.dims();
        let per_group = if self.is_dense() {
            d.n_items * (d.n_global + 1)
        } else {
            3 * d.n_items
        };
        let cap = (BLOCK_STAGING_F32 / per_group.max(1)).max(1);
        end.min(start + cap)
    }

    /// Serve groups `[start, end)` as one SoA [`GroupBlock`]. `end` must
    /// respect [`GroupSource::block_end`]'s contract. Zero-copy sources
    /// ignore `buf` and return borrowed views of their own storage; the
    /// default implementation stages each group through
    /// [`GroupSource::fill_group`] into `buf` (no allocation after the
    /// first call at a given shape).
    fn fill_block<'a>(&'a self, start: usize, end: usize, buf: &'a mut BlockBuf) -> GroupBlock<'a> {
        let d = self.dims();
        let (m, k) = (d.n_items, d.n_global);
        let dense = self.is_dense();
        let len = end - start;
        buf.ensure(len, m, k, dense);
        let staging_fits = |s: &GroupBuf| {
            s.profits.len() == m
                && match &s.costs {
                    CostsBuf::Dense(b) => dense && b.len() == m * k,
                    CostsBuf::Sparse { knap, .. } => !dense && knap.len() == m,
                }
        };
        let mut staging = match buf.staging.take() {
            Some(s) if staging_fits(&s) => s,
            _ => GroupBuf::new(Dims { n_groups: 1, n_items: m, n_global: k }, dense),
        };
        for g in 0..len {
            self.fill_group(start + g, &mut staging);
            buf.profits[g * m..(g + 1) * m].copy_from_slice(&staging.profits);
            match &staging.costs {
                CostsBuf::Dense(b) => {
                    buf.dense[g * m * k..(g + 1) * m * k].copy_from_slice(b);
                }
                CostsBuf::Sparse { knap, cost } => {
                    buf.knap[g * m..(g + 1) * m].copy_from_slice(knap);
                    buf.cost[g * m..(g + 1) * m].copy_from_slice(cost);
                }
            }
        }
        buf.staging = Some(staging);
        buf.block(start, len, m, k, dense)
    }

    /// Natural work-partition unit of the source, if it has one. Disk- or
    /// network-backed sources (e.g. [`crate::instance::store::MmapProblem`])
    /// return their file-shard size here so the solvers' map shards align
    /// with storage shards — a map worker then touches whole files
    /// (page-cache-friendly) and XLA slab padding never straddles a file
    /// boundary. In-memory sources return `None`.
    fn preferred_shard_size(&self) -> Option<usize> {
        None
    }

    /// On-disk home of the instance, if it has one (a shard-store
    /// directory). The session API ([`crate::solve`]) writes periodic λ
    /// checkpoints next to the data they belong to, so an interrupted
    /// out-of-core solve resumes from the same directory it reads.
    /// In-memory sources return `None`.
    fn store_dir(&self) -> Option<std::path::PathBuf> {
        None
    }

    /// Validate basic invariants; call once before solving.
    fn validate(&self) -> Result<()> {
        let d = self.dims();
        if d.n_groups == 0 || d.n_items == 0 || d.n_global == 0 {
            return Err(Error::InvalidProblem(format!(
                "dimensions must be positive, got N={} M={} K={}",
                d.n_groups, d.n_items, d.n_global
            )));
        }
        if self.budgets().len() != d.n_global {
            return Err(Error::InvalidProblem(format!(
                "expected {} budgets, got {}",
                d.n_global,
                self.budgets().len()
            )));
        }
        if let Some(b) = self.budgets().iter().find(|&&b| !(b > 0.0)) {
            return Err(Error::InvalidProblem(format!("budgets must be strictly positive, got {b}")));
        }
        self.locals().check_items_in_range(d.n_items)?;
        Ok(())
    }
}

/// Stream the groups `[start, end)` of `source` through `f` in ascending
/// id order, pulling zero-copy blocks via [`GroupSource::block_end`] /
/// [`GroupSource::fill_block`] — **the** canonical hot-path loop, shared
/// by every map kernel so the block-clipping contract lives in one place.
/// (A free function rather than a trait method so `dyn GroupSource`
/// sources stream too.)
#[inline]
pub fn for_each_row<S, F>(source: &S, start: usize, end: usize, buf: &mut BlockBuf, mut f: F)
where
    S: GroupSource + ?Sized,
    F: FnMut(usize, GroupRow<'_>),
{
    let mut pos = start;
    while pos < end {
        let bend = source.block_end(pos, end).clamp(pos + 1, end);
        let blk = source.fill_block(pos, bend, buf);
        for g in 0..blk.len() {
            f(blk.start() + g, blk.row(g));
        }
        pos = bend;
    }
}

/// Fully in-memory instance. Layout is `f32` (the paper's coefficients live
/// in `[0,10]`; accumulation happens in compensated `f64` downstream).
#[derive(Debug, Clone)]
pub struct MaterializedProblem {
    dims: Dims,
    /// `N×M`, row-major.
    profits: Vec<f32>,
    /// Dense: `N×M×K`; Sparse: parallel `knap`/`cost` of `N×M`.
    costs: MaterializedCosts,
    budgets: Vec<f64>,
    locals: LaminarProfile,
}

#[derive(Debug, Clone)]
enum MaterializedCosts {
    Dense(Vec<f32>),
    Sparse { knap: Vec<u32>, cost: Vec<f32> },
}

impl MaterializedProblem {
    /// Zero-initialized dense instance; fill with the `set_*` methods.
    pub fn zeroed_dense(dims: Dims, budgets: Vec<f64>, locals: LaminarProfile) -> Result<Self> {
        let nm = dims
            .n_groups
            .checked_mul(dims.n_items)
            .ok_or_else(|| Error::InvalidProblem("N*M overflows".into()))?;
        let nmk = nm
            .checked_mul(dims.n_global)
            .ok_or_else(|| Error::InvalidProblem("N*M*K overflows".into()))?;
        Ok(Self {
            dims,
            profits: vec![0.0; nm],
            costs: MaterializedCosts::Dense(vec![0.0; nmk]),
            budgets,
            locals,
        })
    }

    /// Zero-initialized sparse instance (every item initially mapped to
    /// knapsack 0 with cost 0).
    pub fn zeroed_sparse(dims: Dims, budgets: Vec<f64>, locals: LaminarProfile) -> Result<Self> {
        let nm = dims
            .n_groups
            .checked_mul(dims.n_items)
            .ok_or_else(|| Error::InvalidProblem("N*M overflows".into()))?;
        Ok(Self {
            dims,
            profits: vec![0.0; nm],
            costs: MaterializedCosts::Sparse { knap: vec![0; nm], cost: vec![0.0; nm] },
            budgets,
            locals,
        })
    }

    /// Materialize any [`GroupSource`] (small instances only: O(N·M·K)).
    pub fn from_source<S: GroupSource + ?Sized>(src: &S) -> Result<Self> {
        let dims = src.dims();
        let mut out = if src.is_dense() {
            Self::zeroed_dense(dims, src.budgets().to_vec(), src.locals().clone())?
        } else {
            Self::zeroed_sparse(dims, src.budgets().to_vec(), src.locals().clone())?
        };
        let mut buf = GroupBuf::new(dims, src.is_dense());
        for i in 0..dims.n_groups {
            src.fill_group(i, &mut buf);
            out.profits[i * dims.n_items..(i + 1) * dims.n_items].copy_from_slice(&buf.profits);
            match (&mut out.costs, &buf.costs) {
                (MaterializedCosts::Dense(dst), CostsBuf::Dense(srcb)) => {
                    let w = dims.n_items * dims.n_global;
                    dst[i * w..(i + 1) * w].copy_from_slice(srcb);
                }
                (MaterializedCosts::Sparse { knap, cost }, CostsBuf::Sparse { knap: kb, cost: cb }) => {
                    knap[i * dims.n_items..(i + 1) * dims.n_items].copy_from_slice(kb);
                    cost[i * dims.n_items..(i + 1) * dims.n_items].copy_from_slice(cb);
                }
                _ => unreachable!("layout fixed by constructor"),
            }
        }
        Ok(out)
    }

    /// Set `p_ij`.
    pub fn set_profit(&mut self, i: usize, j: usize, v: f32) {
        self.profits[i * self.dims.n_items + j] = v;
    }

    /// Set dense `b_ijk`. Panics on a sparse instance.
    pub fn set_cost(&mut self, i: usize, j: usize, k: usize, v: f32) {
        match &mut self.costs {
            MaterializedCosts::Dense(b) => {
                b[(i * self.dims.n_items + j) * self.dims.n_global + k] = v
            }
            _ => panic!("set_cost on sparse instance; use set_sparse_cost"),
        }
    }

    /// Set sparse item mapping: item `j` of group `i` consumes `v` from `knapsack`.
    pub fn set_sparse_cost(&mut self, i: usize, j: usize, knapsack: u32, v: f32) {
        match &mut self.costs {
            MaterializedCosts::Sparse { knap, cost } => {
                let idx = i * self.dims.n_items + j;
                knap[idx] = knapsack;
                cost[idx] = v;
            }
            _ => panic!("set_sparse_cost on dense instance; use set_cost"),
        }
    }

    /// Replace the budget vector.
    pub fn set_budgets(&mut self, budgets: Vec<f64>) {
        self.budgets = budgets;
    }

    /// `p_ij` accessor.
    pub fn profit(&self, i: usize, j: usize) -> f32 {
        self.profits[i * self.dims.n_items + j]
    }

    /// `b_ijk` accessor (works for both layouts).
    pub fn cost(&self, i: usize, j: usize, k: usize) -> f32 {
        match &self.costs {
            MaterializedCosts::Dense(b) => {
                b[(i * self.dims.n_items + j) * self.dims.n_global + k]
            }
            MaterializedCosts::Sparse { knap, cost } => {
                let idx = i * self.dims.n_items + j;
                if knap[idx] as usize == k {
                    cost[idx]
                } else {
                    0.0
                }
            }
        }
    }
}

impl GroupSource for MaterializedProblem {
    fn dims(&self) -> Dims {
        self.dims
    }

    fn is_dense(&self) -> bool {
        matches!(self.costs, MaterializedCosts::Dense(_))
    }

    fn locals(&self) -> &LaminarProfile {
        &self.locals
    }

    fn budgets(&self) -> &[f64] {
        &self.budgets
    }

    fn fill_group(&self, i: usize, buf: &mut GroupBuf) {
        let m = self.dims.n_items;
        buf.profits.copy_from_slice(&self.profits[i * m..(i + 1) * m]);
        match (&self.costs, &mut buf.costs) {
            (MaterializedCosts::Dense(b), CostsBuf::Dense(dst)) => {
                let w = m * self.dims.n_global;
                dst.copy_from_slice(&b[i * w..(i + 1) * w]);
            }
            (MaterializedCosts::Sparse { knap, cost }, CostsBuf::Sparse { knap: dk, cost: dc }) => {
                dk.copy_from_slice(&knap[i * m..(i + 1) * m]);
                dc.copy_from_slice(&cost[i * m..(i + 1) * m]);
            }
            _ => panic!("GroupBuf layout does not match problem layout"),
        }
    }

    /// Fully contiguous in memory: any range is one zero-copy block.
    fn block_end(&self, _start: usize, end: usize) -> usize {
        end
    }

    fn fill_block<'a>(&'a self, start: usize, end: usize, _buf: &'a mut BlockBuf) -> GroupBlock<'a> {
        let (m, k) = (self.dims.n_items, self.dims.n_global);
        let costs = match &self.costs {
            MaterializedCosts::Dense(b) => BlockCosts::Dense(&b[start * m * k..end * m * k]),
            MaterializedCosts::Sparse { knap, cost } => BlockCosts::Sparse {
                knap: &knap[start * m..end * m],
                cost: &cost[start * m..end * m],
            },
        };
        GroupBlock::new(start, m, k, &self.profits[start * m..end * m], costs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::laminar::LaminarProfile;

    fn dims() -> Dims {
        Dims { n_groups: 3, n_items: 2, n_global: 2 }
    }

    #[test]
    fn dense_roundtrip() {
        let mut p =
            MaterializedProblem::zeroed_dense(dims(), vec![1.0, 1.0], LaminarProfile::single(2, 1))
                .unwrap();
        p.set_profit(1, 0, 3.5);
        p.set_cost(1, 0, 1, 0.25);
        assert_eq!(p.profit(1, 0), 3.5);
        assert_eq!(p.cost(1, 0, 1), 0.25);
        assert_eq!(p.cost(1, 0, 0), 0.0);

        let mut buf = GroupBuf::new(dims(), true);
        p.fill_group(1, &mut buf);
        assert_eq!(buf.profits, vec![3.5, 0.0]);
        assert_eq!(buf.cost(0, 1, 2), 0.25);
        p.validate().unwrap();
    }

    #[test]
    fn sparse_roundtrip() {
        let mut p = MaterializedProblem::zeroed_sparse(
            dims(),
            vec![1.0, 2.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        p.set_sparse_cost(2, 1, 1, 0.75);
        assert_eq!(p.cost(2, 1, 1), 0.75);
        assert_eq!(p.cost(2, 1, 0), 0.0);
        let mut buf = GroupBuf::new(dims(), false);
        p.fill_group(2, &mut buf);
        assert_eq!(buf.cost(1, 1, 2), 0.75);
        assert_eq!(buf.cost(1, 0, 2), 0.0);
    }

    #[test]
    fn validate_rejects_bad_budgets() {
        let p = MaterializedProblem::zeroed_dense(
            dims(),
            vec![1.0, 0.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        assert!(matches!(p.validate(), Err(Error::InvalidProblem(_))));
        let p = MaterializedProblem::zeroed_dense(dims(), vec![1.0], LaminarProfile::single(2, 1))
            .unwrap();
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_dims() {
        let p = MaterializedProblem::zeroed_dense(
            Dims { n_groups: 0, n_items: 2, n_global: 1 },
            vec![1.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        assert!(p.validate().is_err());
    }

    #[test]
    fn materialized_block_is_zero_copy_and_matches_fill_group() {
        let mut p =
            MaterializedProblem::zeroed_dense(dims(), vec![1.0, 1.0], LaminarProfile::single(2, 1))
                .unwrap();
        p.set_profit(1, 0, 3.5);
        p.set_cost(1, 0, 1, 0.25);
        let mut bb = BlockBuf::new();
        assert_eq!(p.block_end(0, 3), 3);
        let block = p.fill_block(0, 3, &mut bb);
        assert_eq!(block.start(), 0);
        assert_eq!(block.len(), 3);
        // the zero-copy path must not have touched the staging buffer
        assert!(bb.profits.is_empty());
        let mut buf = GroupBuf::new(dims(), true);
        for i in 0..3 {
            p.fill_group(i, &mut buf);
            let row = block.row(i);
            assert_eq!(row.profits, &buf.profits[..]);
            match (row.costs, &buf.costs) {
                (RowCosts::Dense(b), CostsBuf::Dense(g)) => assert_eq!(b, &g[..]),
                _ => panic!("layout mismatch"),
            }
        }
    }

    #[test]
    fn default_fill_block_stages_through_fill_group() {
        // wrapper that hides the optimized overrides, forcing the trait
        // default (the path external sources get)
        struct PerGroup<'a>(&'a MaterializedProblem);
        impl GroupSource for PerGroup<'_> {
            fn dims(&self) -> Dims {
                self.0.dims()
            }
            fn is_dense(&self) -> bool {
                self.0.is_dense()
            }
            fn locals(&self) -> &LaminarProfile {
                self.0.locals()
            }
            fn budgets(&self) -> &[f64] {
                self.0.budgets()
            }
            fn fill_group(&self, i: usize, buf: &mut GroupBuf) {
                self.0.fill_group(i, buf)
            }
        }
        let mut p = MaterializedProblem::zeroed_sparse(
            dims(),
            vec![1.0, 2.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        p.set_sparse_cost(2, 1, 1, 0.75);
        p.set_profit(0, 0, 9.0);
        let w = PerGroup(&p);
        let mut bb = BlockBuf::new();
        let end = w.block_end(1, 3);
        assert!(end > 1 && end <= 3);
        let block = w.fill_block(1, 3, &mut bb);
        assert_eq!(block.start(), 1);
        let mut buf = GroupBuf::new(dims(), false);
        for g in 0..block.len() {
            p.fill_group(1 + g, &mut buf);
            let row = block.row(g);
            assert_eq!(row.profits, &buf.profits[..]);
            match (row.costs, &buf.costs) {
                (RowCosts::Sparse { knap, cost }, CostsBuf::Sparse { knap: gk, cost: gc }) => {
                    assert_eq!(knap, &gk[..]);
                    assert_eq!(cost, &gc[..]);
                }
                _ => panic!("layout mismatch"),
            }
        }
    }

    #[test]
    fn from_source_is_identity_for_materialized() {
        let mut p = MaterializedProblem::zeroed_dense(
            dims(),
            vec![1.0, 1.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        p.set_profit(0, 1, 2.0);
        p.set_cost(2, 1, 0, 0.5);
        let q = MaterializedProblem::from_source(&p).unwrap();
        assert_eq!(q.profit(0, 1), 2.0);
        assert_eq!(q.cost(2, 1, 0), 0.5);
    }
}
