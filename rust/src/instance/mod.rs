//! Problem instances: the generalized knapsack data model (paper §2).
//!
//! The central abstraction is [`GroupSource`]: anything that can produce the
//! per-group data `(p_ij, b_ijk)` for group `i` on demand. Three
//! implementations:
//!
//! * [`problem::MaterializedProblem`] — everything resident in memory
//!   (tests, small experiments, the LP baseline);
//! * [`generator::SyntheticProblem`] — groups derived deterministically from
//!   `(seed, group_id)` and never materialized, which is what lets a single
//!   box exercise hundred-million-group instances the way the paper's
//!   mappers stream them from a distributed store;
//! * [`store::MmapProblem`] — groups memory-mapped from an on-disk columnar
//!   shard store ([`store`]), the out-of-core path for instances bigger
//!   than RAM.
//!
//! Local constraints are *hierarchical* ([`laminar::LaminarProfile`],
//! Definition 2.1): any two index sets are disjoint or nested.

pub mod generator;
pub mod laminar;
pub mod problem;
pub mod shard;
pub mod store;

pub use generator::{CostClass, GeneratorConfig, SyntheticProblem};
pub use laminar::{LaminarProfile, LocalConstraint};
pub use problem::{
    for_each_row, BlockBuf, BlockCosts, CostsBuf, Dims, GroupBlock, GroupBuf, GroupRow,
    GroupSource, MaterializedProblem, RowCosts,
};
pub use shard::{ShardRange, Shards};
pub use store::{MmapProblem, ShardWriter, StagedProblem};
