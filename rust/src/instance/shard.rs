//! Sharding: splitting `N` groups into contiguous ranges for the map phase.
//!
//! Shards are the unit of work stealing in [`crate::mapreduce`] and the unit
//! of batching for the XLA-backed dense map phase (which requires a fixed
//! batch shape — the final partial shard is padded by the runtime).

/// A contiguous range of group ids `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRange {
    /// First group id.
    pub start: usize,
    /// One past the last group id.
    pub end: usize,
}

impl ShardRange {
    /// Number of groups in the shard.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True when the shard is empty.
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }

    /// Iterate group ids.
    pub fn iter(&self) -> std::ops::Range<usize> {
        self.start..self.end
    }
}

/// A partition of `[0, n)` into shards of (at most) `shard_size`.
#[derive(Debug, Clone, Copy)]
pub struct Shards {
    n: usize,
    shard_size: usize,
}

impl Shards {
    /// Partition `n` groups into shards of `shard_size` (last one partial).
    pub fn new(n: usize, shard_size: usize) -> Self {
        assert!(shard_size > 0, "shard_size must be positive");
        Self { n, shard_size }
    }

    /// Choose a shard size giving each worker several shards (load balance)
    /// while keeping shards large enough to amortize dispatch (min 1k
    /// groups, max 1M).
    pub fn for_workers(n: usize, workers: usize) -> Self {
        let target = (n / (workers.max(1) * 8)).clamp(1_024, 1 << 20).min(n.max(1));
        Self::new(n, target)
    }

    /// Plan the map partition for a solve: an explicit `--shard` override
    /// wins; otherwise start from [`Shards::for_workers`] and, when the
    /// source has a natural `unit` (a store's file-shard size), round the
    /// target to a multiple of it so map shards never straddle storage
    /// shards. Units at or above the load-balance target are used as-is —
    /// one map shard per storage shard.
    pub fn plan(n: usize, workers: usize, unit: Option<usize>, explicit: Option<usize>) -> Self {
        if let Some(s) = explicit {
            return Self::new(n, s);
        }
        let base = Self::for_workers(n, workers);
        match unit {
            None | Some(0) => base,
            Some(u) => {
                let mult = (base.shard_size() / u).max(1);
                Self::new(n, (mult * u).min(n.max(1)).max(1))
            }
        }
    }

    /// Number of shards.
    pub fn count(&self) -> usize {
        self.n.div_ceil(self.shard_size)
    }

    /// The `idx`-th shard.
    pub fn get(&self, idx: usize) -> ShardRange {
        let start = idx * self.shard_size;
        ShardRange { start, end: (start + self.shard_size).min(self.n) }
    }

    /// Total groups.
    pub fn n_total(&self) -> usize {
        self.n
    }

    /// Configured shard size.
    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    /// Iterate all shards.
    pub fn iter(&self) -> impl Iterator<Item = ShardRange> + '_ {
        (0..self.count()).map(move |i| self.get(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_groups_exactly_once() {
        let s = Shards::new(1003, 100);
        assert_eq!(s.count(), 11);
        let mut seen = vec![false; 1003];
        for sh in s.iter() {
            for i in sh.iter() {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
        assert_eq!(s.get(10).len(), 3);
    }

    #[test]
    fn exact_division() {
        let s = Shards::new(1000, 100);
        assert_eq!(s.count(), 10);
        assert_eq!(s.get(9), ShardRange { start: 900, end: 1000 });
    }

    #[test]
    fn empty_input() {
        let s = Shards::new(0, 100);
        assert_eq!(s.count(), 0);
        assert!(s.iter().next().is_none());
    }

    #[test]
    fn for_workers_bounds() {
        let s = Shards::for_workers(10_000_000, 8);
        assert!(s.shard_size() >= 1_024);
        assert!(s.shard_size() <= 1 << 20);
        let s = Shards::for_workers(100, 8);
        assert!(s.count() >= 1);
        // tiny n: single shard covering everything
        assert_eq!(s.get(0).len().min(100), s.get(0).len());
    }

    #[test]
    #[should_panic]
    fn zero_shard_size_panics() {
        Shards::new(10, 0);
    }

    #[test]
    fn plan_respects_override_and_unit() {
        // explicit override wins over everything
        assert_eq!(Shards::plan(10_000, 4, Some(128), Some(500)).shard_size(), 500);
        // no unit: same as for_workers
        assert_eq!(
            Shards::plan(1_000_000, 8, None, None).shard_size(),
            Shards::for_workers(1_000_000, 8).shard_size()
        );
        // small unit: target rounded to a multiple of it
        let s = Shards::plan(1_000_000, 8, Some(1000), None);
        assert_eq!(s.shard_size() % 1000, 0);
        assert!(s.shard_size() >= 1000);
        // unit above the load-balance target: one map shard per file shard
        let big = Shards::for_workers(1_000_000, 8).shard_size() * 3;
        assert_eq!(Shards::plan(1_000_000, 8, Some(big), None).shard_size(), big);
        // degenerate inputs stay valid
        assert!(Shards::plan(0, 4, Some(64), None).shard_size() >= 1);
    }
}
