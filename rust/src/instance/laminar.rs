//! Hierarchical (laminar) local constraints — paper Definition 2.1.
//!
//! A family `{S_l}` over the item set `[M]` is *laminar* when any two sets
//! are either disjoint or nested. The paper builds a DAG with an arc
//! `S_l → S_l'` iff `S_l ⊆ S_l'`; traversing it "from the lowest level"
//! (children before parents) is exactly a traversal in non-decreasing set
//! size, which is how [`LaminarProfile`] stores its topological order.

use crate::error::{Error, Result};

/// One local constraint: `Σ_{j∈items} x_ij ≤ cap`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LocalConstraint {
    /// Item indices (within a group), strictly increasing.
    pub items: Vec<u16>,
    /// Capacity `C_l ≥ 1` (paper: strictly positive).
    pub cap: u32,
}

impl LocalConstraint {
    /// Construct with sorted, deduplicated items.
    pub fn new(mut items: Vec<u16>, cap: u32) -> Self {
        items.sort_unstable();
        items.dedup();
        Self { items, cap }
    }
}

/// A validated laminar family plus its topological order. Shared by all
/// groups of an instance (the paper's experiments use one profile per run;
/// per-group profiles just mean constructing several of these).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaminarProfile {
    constraints: Vec<LocalConstraint>,
    /// Indices into `constraints`, children before parents.
    topo: Vec<u32>,
}

impl LaminarProfile {
    /// Build and validate. Rejects empty/zero-cap sets and non-laminar
    /// overlap.
    pub fn new(constraints: Vec<LocalConstraint>) -> Result<Self> {
        for (l, c) in constraints.iter().enumerate() {
            if c.items.is_empty() {
                return Err(Error::InvalidProblem(format!("local constraint {l} has no items")));
            }
            if c.cap == 0 {
                return Err(Error::InvalidProblem(format!(
                    "local constraint {l} has cap 0 (paper requires C_l > 0)"
                )));
            }
        }
        for a in 0..constraints.len() {
            for b in (a + 1)..constraints.len() {
                if !laminar_pair(&constraints[a].items, &constraints[b].items) {
                    return Err(Error::InvalidProblem(format!(
                        "local constraints {a} and {b} overlap without nesting (not laminar)"
                    )));
                }
            }
        }
        // children before parents == ascending set size (ties arbitrary:
        // equal-size sets in a laminar family are disjoint or identical)
        let mut topo: Vec<u32> = (0..constraints.len() as u32).collect();
        topo.sort_by_key(|&l| constraints[l as usize].items.len());
        Ok(Self { constraints, topo })
    }

    /// The paper's `C=[c]` scenario: one constraint over all `m` items.
    pub fn single(m: usize, cap: u32) -> Self {
        Self::new(vec![LocalConstraint::new((0..m as u16).collect(), cap)])
            .expect("single constraint is trivially laminar")
    }

    /// The paper's Fig-1 `C=[2,2,3]` scenario: the item set split into two
    /// halves capped at 2 each, nested under a root capped at 3.
    pub fn scenario_c223(m: usize) -> Self {
        let half = (m / 2) as u16;
        Self::new(vec![
            LocalConstraint::new((0..half).collect(), 2),
            LocalConstraint::new((half..m as u16).collect(), 2),
            LocalConstraint::new((0..m as u16).collect(), 3),
        ])
        .expect("two halves + root is laminar")
    }

    /// A deeper taxonomy used by the marketing example: `levels` of
    /// power-of-two blocks with caps growing by one per level.
    pub fn taxonomy(m: usize, levels: usize) -> Result<Self> {
        let mut cs = Vec::new();
        for lvl in 0..levels {
            let width = m >> (levels - 1 - lvl);
            if width == 0 {
                continue;
            }
            let cap = (lvl + 1) as u32;
            let mut start = 0usize;
            while start < m {
                let end = (start + width).min(m);
                cs.push(LocalConstraint::new((start as u16..end as u16).collect(), cap));
                start = end;
            }
        }
        Self::new(cs)
    }

    /// Constraints in topological (children-first) order.
    pub fn topo_iter(&self) -> impl Iterator<Item = &LocalConstraint> {
        self.topo.iter().map(move |&l| &self.constraints[l as usize])
    }

    /// All constraints, declaration order.
    pub fn constraints(&self) -> &[LocalConstraint] {
        &self.constraints
    }

    /// Number of local constraints `L`.
    pub fn len(&self) -> usize {
        self.constraints.len()
    }

    /// True when no local constraints exist.
    pub fn is_empty(&self) -> bool {
        self.constraints.is_empty()
    }

    /// Upper bound on the number of items a feasible solution can select
    /// out of `m` (used to scale budgets so global constraints bind).
    pub fn max_selected(&self, m: usize) -> usize {
        // greedily: root-most caps dominate; a safe bound is the min over
        // covering constraints of cap, summed over a partition. Compute by
        // DP over the laminar forest: bound(S) = min(cap_S, Σ bound(children)
        // + uncovered items of S).
        let mut bound = vec![0usize; self.constraints.len()];
        let mut covered_by = vec![usize::MAX; m]; // smallest covering set idx
        for &l in &self.topo {
            let c = &self.constraints[l as usize];
            let mut inner = 0usize;
            let mut counted_children = std::collections::HashSet::new();
            for &j in &c.items {
                let owner = covered_by[j as usize];
                if owner == usize::MAX {
                    inner += 1; // item directly under this set
                } else if counted_children.insert(owner) {
                    inner += bound[owner];
                }
            }
            bound[l as usize] = inner.min(c.cap as usize);
            for &j in &c.items {
                covered_by[j as usize] = l as usize;
            }
        }
        // roots: items whose final cover is a root set + uncovered items
        let mut total = 0usize;
        let mut seen_roots = std::collections::HashSet::new();
        for j in 0..m {
            match covered_by[j] {
                usize::MAX => total += 1,
                r => {
                    if seen_roots.insert(r) {
                        total += bound[r];
                    }
                }
            }
        }
        total
    }

    /// Check the solution `x` (0/1 per item) against every local constraint.
    pub fn is_feasible(&self, x: &[u8]) -> bool {
        self.constraints.iter().all(|c| {
            let sel: u32 = c.items.iter().map(|&j| x[j as usize] as u32).sum();
            sel <= c.cap
        })
    }

    /// Validate that all item indices are `< m`.
    pub fn check_items_in_range(&self, m: usize) -> Result<()> {
        for (l, c) in self.constraints.iter().enumerate() {
            if let Some(&j) = c.items.iter().find(|&&j| j as usize >= m) {
                return Err(Error::InvalidProblem(format!(
                    "local constraint {l} references item {j} but M={m}"
                )));
            }
        }
        Ok(())
    }
}

/// True when sorted sets `a`, `b` are disjoint or one contains the other.
fn laminar_pair(a: &[u16], b: &[u16]) -> bool {
    let inter = intersection_size(a, b);
    inter == 0 || inter == a.len() || inter == b.len()
}

fn intersection_size(a: &[u16], b: &[u16]) -> usize {
    let (mut i, mut j, mut n) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_disjoint_and_nested() {
        LaminarProfile::new(vec![
            LocalConstraint::new(vec![0, 1], 1),
            LocalConstraint::new(vec![2, 3], 1),
            LocalConstraint::new(vec![0, 1, 2, 3], 2),
        ])
        .unwrap();
    }

    #[test]
    fn rejects_partial_overlap() {
        let err = LaminarProfile::new(vec![
            LocalConstraint::new(vec![0, 1], 1),
            LocalConstraint::new(vec![1, 2], 1),
        ]);
        assert!(err.is_err());
    }

    #[test]
    fn rejects_zero_cap_and_empty() {
        assert!(LaminarProfile::new(vec![LocalConstraint::new(vec![0], 0)]).is_err());
        assert!(LaminarProfile::new(vec![LocalConstraint::new(vec![], 1)]).is_err());
    }

    #[test]
    fn topo_is_children_first() {
        let p = LaminarProfile::new(vec![
            LocalConstraint::new((0..10).collect(), 3),
            LocalConstraint::new(vec![0, 1, 2], 2),
            LocalConstraint::new(vec![5, 6], 1),
        ])
        .unwrap();
        let sizes: Vec<usize> = p.topo_iter().map(|c| c.items.len()).collect();
        assert_eq!(sizes, vec![2, 3, 10]);
    }

    #[test]
    fn scenario_c223_shape() {
        let p = LaminarProfile::scenario_c223(10);
        assert_eq!(p.len(), 3);
        let caps: Vec<u32> = p.topo_iter().map(|c| c.cap).collect();
        assert_eq!(caps, vec![2, 2, 3]);
        assert_eq!(p.max_selected(10), 3);
    }

    #[test]
    fn single_scenario() {
        let p = LaminarProfile::single(10, 2);
        assert_eq!(p.max_selected(10), 2);
        assert!(p.is_feasible(&[1, 1, 0, 0, 0, 0, 0, 0, 0, 0]));
        assert!(!p.is_feasible(&[1, 1, 1, 0, 0, 0, 0, 0, 0, 0]));
    }

    #[test]
    fn max_selected_with_uncovered_items() {
        // 4 items, only items 0-1 constrained to 1; items 2,3 free
        let p = LaminarProfile::new(vec![LocalConstraint::new(vec![0, 1], 1)]).unwrap();
        assert_eq!(p.max_selected(4), 3);
    }

    #[test]
    fn max_selected_nested_chain() {
        // {0,1} ≤ 2, {0,1,2,3} ≤ 3, {0..6} ≤ 4
        let p = LaminarProfile::new(vec![
            LocalConstraint::new(vec![0, 1], 2),
            LocalConstraint::new(vec![0, 1, 2, 3], 3),
            LocalConstraint::new((0..6).collect(), 4),
        ])
        .unwrap();
        assert_eq!(p.max_selected(6), 4);
    }

    #[test]
    fn taxonomy_is_laminar_and_bounded() {
        let p = LaminarProfile::taxonomy(16, 3).unwrap();
        assert!(p.len() > 3);
        assert!(p.max_selected(16) <= 16);
        p.check_items_in_range(16).unwrap();
        assert!(p.check_items_in_range(8).is_err());
    }

    #[test]
    fn feasibility_checker() {
        let p = LaminarProfile::scenario_c223(6);
        // halves: {0,1,2} cap2, {3,4,5} cap2, root cap3
        assert!(p.is_feasible(&[1, 1, 0, 1, 0, 0]));
        assert!(!p.is_feasible(&[1, 1, 1, 0, 0, 0])); // violates first half
        assert!(!p.is_feasible(&[1, 1, 0, 1, 1, 0])); // violates root
    }
}
