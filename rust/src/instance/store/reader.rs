//! Memory-mapped shard-store reader: [`MmapProblem`], a [`GroupSource`]
//! whose groups live on disk.
//!
//! Opening a store parses the text manifest and the first shard's header;
//! shard *data* is memory-mapped lazily, one file at a time, the first
//! time a map worker touches a group of that shard. After initialization
//! the per-shard `OnceLock` is a plain atomic load, so concurrent workers
//! read disjoint shards with no shared lock and the kernel's page cache
//! decides what stays resident — instances far larger than RAM solve with
//! the working set bounded by the pages the current round touches.
//!
//! On little-endian hosts group data is read in place (no deserialization
//! — the on-disk `f32` arrays *are* the in-memory arrays); big-endian
//! hosts fall back to per-value conversion.

use crate::error::{Error, Result};
use crate::instance::laminar::LaminarProfile;
use crate::instance::problem::{CostsBuf, Dims, GroupBuf, GroupSource};
use crate::instance::store::checksum::xxh64;
use crate::instance::store::format::{
    decode_laminar, shard_file_name, ShardHeader, HEADER_LEN, MANIFEST_FORMAT, MANIFEST_NAME,
};
use crate::instance::store::mmap::{copy_f32_le, copy_u32_le, Mmap};
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

/// One mapped shard file plus its validated header.
struct ShardView {
    map: Mmap,
    hdr: ShardHeader,
}

impl ShardView {
    fn open(path: &Path, expect: &MmapProblem, idx: usize) -> Result<Self> {
        let map = Mmap::open(path)?;
        let what = path.display().to_string();
        let hdr = ShardHeader::decode(map.bytes(), map.len() as u64, &what)?;
        expect.check_shard_header(&hdr, idx, &what)?;
        Ok(Self { map, hdr })
    }

    fn section(&self, range: (u64, u64)) -> &[u8] {
        &self.map.bytes()[range.0 as usize..(range.0 + range.1) as usize]
    }
}

/// An instance solved straight off a shard-store directory.
pub struct MmapProblem {
    dir: PathBuf,
    dims: Dims,
    dense: bool,
    shard_size: usize,
    budgets: Vec<f64>,
    locals: LaminarProfile,
    manifest_hashes: Vec<u64>,
    views: Vec<OnceLock<ShardView>>,
}

impl MmapProblem {
    /// Open a store directory: parse `store.manifest`, map shard 0 for the
    /// laminar profile, and validate every header lazily on first touch.
    /// Shard payloads are *not* checksummed here — use [`open_verified`]
    /// (or [`verify`]) when reading a store of unknown provenance.
    ///
    /// [`open_verified`]: MmapProblem::open_verified
    /// [`verify`]: MmapProblem::verify
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join(MANIFEST_NAME);
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::InvalidProblem(format!(
                "cannot read {} (not a shard store? run `bskp gen --out <dir>` first): {e}",
                manifest_path.display()
            ))
        })?;
        let mut problem = Self::from_manifest(&text, dir, &manifest_path)?;
        // shard 0 carries the laminar profile (every shard is
        // self-contained; they are all identical by construction)
        let v0 = problem.try_view(0)?;
        let locals = decode_laminar(
            v0.section(v0.hdr.laminar),
            &problem.dir.join(shard_file_name(0)).display().to_string(),
        )?;
        problem.locals = locals;
        Ok(problem)
    }

    /// [`open`](MmapProblem::open) plus a full payload-checksum pass over
    /// every shard file.
    pub fn open_verified<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let p = Self::open(dir)?;
        p.verify()?;
        Ok(p)
    }

    fn from_manifest(text: &str, dir: PathBuf, path: &Path) -> Result<Self> {
        let bad =
            |m: String| Error::InvalidProblem(format!("{}: {m}", path.display()));
        let mut layout = None;
        let mut n_groups = None;
        let mut n_items = None;
        let mut n_global = None;
        let mut shard_size = None;
        let mut n_shards = None;
        let mut format_ok = false;
        let mut budgets = Vec::new();
        let mut shards: Vec<(usize, String, u64)> = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split('\t');
            let key = parts.next().unwrap_or_default();
            let mut next = |name: &str| {
                parts
                    .next()
                    .ok_or_else(|| bad(format!("line {}: {key} missing {name}", ln + 1)))
            };
            match key {
                "format" => {
                    let f = next("value")?;
                    if f != MANIFEST_FORMAT {
                        return Err(bad(format!(
                            "unsupported store format {f:?} (want {MANIFEST_FORMAT:?})"
                        )));
                    }
                    format_ok = true;
                }
                "layout" => {
                    layout = Some(match next("value")? {
                        "dense" => true,
                        "sparse" => false,
                        other => return Err(bad(format!("unknown layout {other:?}"))),
                    })
                }
                "n_groups" | "n_items" | "n_global" | "shard_size" | "n_shards" => {
                    let v: usize = next("value")?
                        .parse()
                        .map_err(|_| bad(format!("line {}: bad number for {key}", ln + 1)))?;
                    match key {
                        "n_groups" => n_groups = Some(v),
                        "n_items" => n_items = Some(v),
                        "n_global" => n_global = Some(v),
                        "shard_size" => shard_size = Some(v),
                        _ => n_shards = Some(v),
                    }
                }
                "budget" => {
                    let v: f64 = next("value")?
                        .parse()
                        .map_err(|_| bad(format!("line {}: bad budget", ln + 1)))?;
                    budgets.push(v);
                }
                "shard" => {
                    let idx: usize = next("index")?
                        .parse()
                        .map_err(|_| bad(format!("line {}: bad shard index", ln + 1)))?;
                    let name = next("filename")?.to_string();
                    let hash = u64::from_str_radix(next("hash")?, 16)
                        .map_err(|_| bad(format!("line {}: bad shard hash", ln + 1)))?;
                    shards.push((idx, name, hash));
                }
                other => return Err(bad(format!("line {}: unknown key {other:?}", ln + 1))),
            }
        }
        if !format_ok {
            return Err(bad("missing format declaration".into()));
        }
        let dims = Dims {
            n_groups: n_groups.ok_or_else(|| bad("missing n_groups".into()))?,
            n_items: n_items.ok_or_else(|| bad("missing n_items".into()))?,
            n_global: n_global.ok_or_else(|| bad("missing n_global".into()))?,
        };
        if dims.n_groups == 0 || dims.n_items == 0 || dims.n_global == 0 {
            // the writer refuses to produce such a store; open() relies on
            // shard 0 existing, so reject rather than panic downstream
            return Err(bad(format!(
                "dimensions must be positive, got N={} M={} K={}",
                dims.n_groups, dims.n_items, dims.n_global
            )));
        }
        let dense = layout.ok_or_else(|| bad("missing layout".into()))?;
        let shard_size = shard_size.ok_or_else(|| bad("missing shard_size".into()))?;
        if shard_size == 0 {
            return Err(bad("shard_size must be positive".into()));
        }
        let n_shards = n_shards.ok_or_else(|| bad("missing n_shards".into()))?;
        if n_shards != dims.n_groups.div_ceil(shard_size) {
            return Err(bad(format!(
                "n_shards {n_shards} inconsistent with N={} at shard_size {shard_size}",
                dims.n_groups
            )));
        }
        if budgets.len() != dims.n_global {
            return Err(bad(format!(
                "manifest has {} budgets but K={}",
                budgets.len(),
                dims.n_global
            )));
        }
        if shards.len() != n_shards {
            return Err(bad(format!("manifest lists {} of {n_shards} shards", shards.len())));
        }
        let mut manifest_hashes = vec![0u64; n_shards];
        let mut seen = vec![false; n_shards];
        for (idx, name, hash) in shards {
            if idx >= n_shards || seen[idx] {
                return Err(bad(format!("shard index {idx} out of range or duplicated")));
            }
            if name != shard_file_name(idx) {
                return Err(bad(format!(
                    "shard {idx} filename {name:?} (want {:?})",
                    shard_file_name(idx)
                )));
            }
            seen[idx] = true;
            manifest_hashes[idx] = hash;
        }
        Ok(Self {
            dir,
            dims,
            dense,
            shard_size,
            budgets,
            locals: LaminarProfile::single(dims.n_items, 1), // replaced in open()
            manifest_hashes,
            views: (0..n_shards).map(|_| OnceLock::new()).collect(),
        })
    }

    /// The store directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Groups per shard file.
    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    /// Number of shard files.
    pub fn n_shards(&self) -> usize {
        self.views.len()
    }

    /// Validate a shard header (however its bytes arrived — mmap or a
    /// staged read) against the manifest's expectations for shard `idx`.
    pub(crate) fn check_shard_header(
        &self,
        hdr: &ShardHeader,
        idx: usize,
        what: &str,
    ) -> Result<()> {
        let err = |m: String| Error::InvalidProblem(format!("{what}: {m}"));
        if hdr.dense != self.dense {
            return Err(err("shard layout disagrees with manifest".into()));
        }
        if hdr.n_items as usize != self.dims.n_items
            || hdr.n_global as usize != self.dims.n_global
        {
            return Err(err(format!(
                "shard shape M={} K={} disagrees with manifest M={} K={}",
                hdr.n_items, hdr.n_global, self.dims.n_items, self.dims.n_global
            )));
        }
        if hdr.rows as usize != self.shard_size {
            return Err(err(format!(
                "shard rows {} disagree with manifest shard_size {}",
                hdr.rows, self.shard_size
            )));
        }
        let want_start = idx * self.shard_size;
        let want_live = (self.dims.n_groups - want_start).min(self.shard_size);
        if hdr.group_start as usize != want_start || hdr.n_groups as usize != want_live {
            return Err(err(format!(
                "shard covers groups [{}, {}) but manifest expects [{}, {})",
                hdr.group_start,
                hdr.group_start + hdr.n_groups,
                want_start,
                want_start + want_live
            )));
        }
        if hdr.payload_hash != self.manifest_hashes[idx] {
            return Err(err(format!(
                "shard payload hash {:016x} disagrees with manifest {:016x}",
                hdr.payload_hash, self.manifest_hashes[idx]
            )));
        }
        Ok(())
    }

    /// Path of shard file `idx`.
    pub(crate) fn shard_path(&self, idx: usize) -> PathBuf {
        self.dir.join(shard_file_name(idx))
    }

    /// Map + header-validate shard `idx`, returning errors instead of
    /// panicking (the `Result`-flavored twin of the hot-path [`view`]).
    ///
    /// [`view`]: MmapProblem::view
    fn try_view(&self, idx: usize) -> Result<&ShardView> {
        if let Some(v) = self.views[idx].get() {
            return Ok(v);
        }
        let v = ShardView::open(&self.dir.join(shard_file_name(idx)), self, idx)?;
        // under a race another worker may have initialized concurrently;
        // both opened the same immutable file, so either value is correct
        Ok(self.views[idx].get_or_init(|| v))
    }

    /// Hot-path shard access for `fill_group` (which cannot return errors).
    /// Panics with a descriptive message on I/O or validation failure;
    /// callers that want a `Result` should [`preload`](MmapProblem::preload)
    /// first.
    fn view(&self, idx: usize) -> &ShardView {
        match self.try_view(idx) {
            Ok(v) => v,
            Err(e) => panic!("shard store read failed mid-solve: {e}"),
        }
    }

    /// Eagerly map and header-validate every shard, surfacing failures as
    /// errors before a solve starts.
    pub fn preload(&self) -> Result<()> {
        for idx in 0..self.n_shards() {
            self.try_view(idx)?;
        }
        Ok(())
    }

    /// Recompute every shard's payload checksum against the manifest.
    /// Reads all data once, sequentially per shard — O(store size) I/O.
    pub fn verify(&self) -> Result<()> {
        for idx in 0..self.n_shards() {
            let v = self.try_view(idx)?;
            let actual = xxh64(&v.map.bytes()[HEADER_LEN..], 0);
            if actual != self.manifest_hashes[idx] {
                return Err(Error::InvalidProblem(format!(
                    "{}: payload checksum mismatch (stored {:016x}, computed {actual:016x})",
                    self.dir.join(shard_file_name(idx)).display(),
                    self.manifest_hashes[idx]
                )));
            }
        }
        Ok(())
    }

    /// Zero-copy view of one group's profits (little-endian hosts).
    #[cfg(target_endian = "little")]
    pub fn group_prices(&self, i: usize) -> &[f32] {
        let (v, row, m) = self.locate(i);
        let off = v.hdr.prices.0 as usize + row * m * 4;
        crate::instance::store::mmap::cast_f32_slice(&v.map.bytes()[off..off + m * 4])
    }

    #[inline]
    fn locate(&self, i: usize) -> (&ShardView, usize, usize) {
        debug_assert!(i < self.dims.n_groups, "group {i} out of range");
        let idx = i / self.shard_size;
        (self.view(idx), i % self.shard_size, self.dims.n_items)
    }
}

impl GroupSource for MmapProblem {
    fn dims(&self) -> Dims {
        self.dims
    }

    fn is_dense(&self) -> bool {
        self.dense
    }

    fn locals(&self) -> &LaminarProfile {
        &self.locals
    }

    fn budgets(&self) -> &[f64] {
        &self.budgets
    }

    fn store_dir(&self) -> Option<std::path::PathBuf> {
        Some(self.dir.clone())
    }

    fn fill_group(&self, i: usize, buf: &mut GroupBuf) {
        let (v, row, m) = self.locate(i);
        let k = self.dims.n_global;
        let bytes = v.map.bytes();
        let p_off = v.hdr.prices.0 as usize + row * m * 4;
        copy_f32_le(&bytes[p_off..p_off + m * 4], &mut buf.profits);
        match &mut buf.costs {
            CostsBuf::Dense(dst) => {
                assert!(self.dense, "dense GroupBuf for a sparse store");
                let w = m * k * 4;
                let off = v.hdr.costs.0 as usize + row * w;
                copy_f32_le(&bytes[off..off + w], dst);
            }
            CostsBuf::Sparse { knap, cost } => {
                assert!(!self.dense, "sparse GroupBuf for a dense store");
                let rows = v.hdr.rows as usize;
                let knap_off = v.hdr.costs.0 as usize + row * m * 4;
                let cost_off = v.hdr.costs.0 as usize + rows * m * 4 + row * m * 4;
                copy_u32_le(&bytes[knap_off..knap_off + m * 4], knap);
                copy_f32_le(&bytes[cost_off..cost_off + m * 4], cost);
            }
        }
    }

    fn preferred_shard_size(&self) -> Option<usize> {
        Some(self.shard_size)
    }

    /// Blocks never cross a shard-file boundary, so every block is one
    /// contiguous region of one mapping.
    fn block_end(&self, start: usize, end: usize) -> usize {
        let boundary = (start / self.shard_size + 1) * self.shard_size;
        end.min(boundary)
    }

    /// Zero-copy block: the on-disk little-endian `f32`/`u32` sections are
    /// reinterpreted in place (the mmap *is* the block). Solver map
    /// workers read straight from the page cache with no per-group copy.
    #[cfg(target_endian = "little")]
    fn fill_block<'a>(
        &'a self,
        start: usize,
        end: usize,
        _buf: &'a mut crate::instance::problem::BlockBuf,
    ) -> crate::instance::problem::GroupBlock<'a> {
        use crate::instance::problem::{BlockCosts, GroupBlock};
        use crate::instance::store::mmap::{cast_f32_slice, cast_u32_slice};
        // real asserts, not debug: a caller ignoring block_end (or the
        // n_groups bound) would otherwise read zero-padded tail rows or
        // run past the prices section into the costs section of the same
        // mapping — in-bounds bytes, silently wrong numbers. Two compares
        // per block, amortized over thousands of groups.
        assert!(
            end <= self.dims.n_groups,
            "block [{start}, {end}) reaches past the {} live groups into shard padding",
            self.dims.n_groups
        );
        let (v, row, m) = self.locate(start);
        let len = end - start;
        assert!(
            row + len <= v.hdr.rows as usize,
            "block [{start}, {end}) crosses a shard-file boundary (see GroupSource::block_end)"
        );
        let k = self.dims.n_global;
        let bytes = v.map.bytes();
        let p_off = v.hdr.prices.0 as usize + row * m * 4;
        let profits = cast_f32_slice(&bytes[p_off..p_off + len * m * 4]);
        let costs = if self.dense {
            let w = m * k * 4;
            let off = v.hdr.costs.0 as usize + row * w;
            BlockCosts::Dense(cast_f32_slice(&bytes[off..off + len * w]))
        } else {
            let rows = v.hdr.rows as usize;
            let knap_off = v.hdr.costs.0 as usize + row * m * 4;
            let cost_off = v.hdr.costs.0 as usize + (rows + row) * m * 4;
            BlockCosts::Sparse {
                knap: cast_u32_slice(&bytes[knap_off..knap_off + len * m * 4]),
                cost: cast_f32_slice(&bytes[cost_off..cost_off + len * m * 4]),
            }
        };
        GroupBlock::new(start, m, k, profits, costs)
    }
}
