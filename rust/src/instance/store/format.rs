//! On-disk shard-file layout (see `docs/shard-format.md` for the spec).
//!
//! Every shard file is self-contained: a fixed 128-byte little-endian
//! header, then 64-byte-aligned `laminar` / `prices` / `costs` sections.
//! Self-containment is deliberate — a distributed map worker holding one
//! shard file can reconstruct its groups without any other file, which is
//! exactly how the paper's mappers stream rows out of a sharded store.
//!
//! All multi-byte values are little-endian. `f32` arrays are stored raw,
//! so on little-endian hosts a memory-mapped section can be reinterpreted
//! in place (the [`super::mmap`] reader's zero-copy path).

use crate::error::{Error, Result};
use crate::instance::laminar::{LaminarProfile, LocalConstraint};
use crate::instance::store::checksum::xxh64;

/// Shard-file magic bytes.
pub const MAGIC: [u8; 8] = *b"BSKPSHRD";
/// Current format version.
pub const VERSION: u32 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 128;
/// Section alignment in bytes (cache-line sized; keeps `f32`/`u32` arrays
/// well over their 4-byte alignment requirement).
pub const SECTION_ALIGN: usize = 64;
/// Header flag bit: dense cost layout (unset ⇒ sparse).
pub const FLAG_DENSE: u32 = 1;
/// Manifest file name inside a store directory.
pub const MANIFEST_NAME: &str = "store.manifest";
/// Manifest format tag (first non-comment line must declare it).
pub const MANIFEST_FORMAT: &str = "bskp-shard-v1";

/// Round `off` up to the next multiple of [`SECTION_ALIGN`].
pub fn align_up(off: usize) -> usize {
    off.div_ceil(SECTION_ALIGN) * SECTION_ALIGN
}

/// Shard-file name for shard index `idx` (zero-padded so lexicographic
/// order equals shard order).
pub fn shard_file_name(idx: usize) -> String {
    format!("shard-{idx:06}.bskp")
}

/// Parsed (or to-be-written) shard-file header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardHeader {
    /// Dense (`true`) or sparse cost layout.
    pub dense: bool,
    /// Global id of the shard's first group.
    pub group_start: u64,
    /// Live groups in the shard (`≤ rows`).
    pub n_groups: u64,
    /// Array row count including the zero-padded tail of the final shard.
    pub rows: u64,
    /// Items per group `M`.
    pub n_items: u32,
    /// Global constraints `K`.
    pub n_global: u32,
    /// Byte range of the laminar section.
    pub laminar: (u64, u64),
    /// Byte range of the prices section.
    pub prices: (u64, u64),
    /// Byte range of the costs section.
    pub costs: (u64, u64),
    /// XXH64 (seed 0) of the payload bytes `[HEADER_LEN, file_len)`.
    pub payload_hash: u64,
}

impl ShardHeader {
    /// Serialize to the fixed 128-byte header block.
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut h = [0u8; HEADER_LEN];
        h[0..8].copy_from_slice(&MAGIC);
        h[8..12].copy_from_slice(&VERSION.to_le_bytes());
        let flags: u32 = if self.dense { FLAG_DENSE } else { 0 };
        h[12..16].copy_from_slice(&flags.to_le_bytes());
        h[16..24].copy_from_slice(&self.group_start.to_le_bytes());
        h[24..32].copy_from_slice(&self.n_groups.to_le_bytes());
        h[32..40].copy_from_slice(&self.rows.to_le_bytes());
        h[40..44].copy_from_slice(&self.n_items.to_le_bytes());
        h[44..48].copy_from_slice(&self.n_global.to_le_bytes());
        h[48..56].copy_from_slice(&self.laminar.0.to_le_bytes());
        h[56..64].copy_from_slice(&self.laminar.1.to_le_bytes());
        h[64..72].copy_from_slice(&self.prices.0.to_le_bytes());
        h[72..80].copy_from_slice(&self.prices.1.to_le_bytes());
        h[80..88].copy_from_slice(&self.costs.0.to_le_bytes());
        h[88..96].copy_from_slice(&self.costs.1.to_le_bytes());
        h[96..104].copy_from_slice(&self.payload_hash.to_le_bytes());
        let header_hash = xxh64(&h[0..104], 0);
        h[104..112].copy_from_slice(&header_hash.to_le_bytes());
        // bytes 112..128 reserved, zero
        h
    }

    /// Parse and validate a header block (magic, version, header checksum,
    /// section ranges within `file_len`).
    pub fn decode(h: &[u8], file_len: u64, what: &str) -> Result<Self> {
        let bad = |m: String| Error::InvalidProblem(format!("{what}: {m}"));
        if h.len() < HEADER_LEN {
            return Err(bad(format!("file too short for header ({} bytes)", h.len())));
        }
        if h[0..8] != MAGIC {
            return Err(bad("bad magic (not a bskp shard file)".into()));
        }
        let u32_at = |o: usize| u32::from_le_bytes(h[o..o + 4].try_into().unwrap());
        let u64_at = |o: usize| u64::from_le_bytes(h[o..o + 8].try_into().unwrap());
        let version = u32_at(8);
        if version != VERSION {
            return Err(bad(format!("unsupported shard format version {version} (want {VERSION})")));
        }
        let stored_header_hash = u64_at(104);
        let actual = xxh64(&h[0..104], 0);
        if stored_header_hash != actual {
            return Err(bad(format!(
                "header checksum mismatch (stored {stored_header_hash:016x}, computed {actual:016x})"
            )));
        }
        let hdr = Self {
            dense: u32_at(12) & FLAG_DENSE != 0,
            group_start: u64_at(16),
            n_groups: u64_at(24),
            rows: u64_at(32),
            n_items: u32_at(40),
            n_global: u32_at(44),
            laminar: (u64_at(48), u64_at(56)),
            prices: (u64_at(64), u64_at(72)),
            costs: (u64_at(80), u64_at(88)),
            payload_hash: u64_at(96),
        };
        if hdr.n_groups > hdr.rows {
            return Err(bad(format!("n_groups {} exceeds rows {}", hdr.n_groups, hdr.rows)));
        }
        for (name, (off, len)) in
            [("laminar", hdr.laminar), ("prices", hdr.prices), ("costs", hdr.costs)]
        {
            let end = off.checked_add(len).ok_or_else(|| bad(format!("{name} range overflows")))?;
            if off < HEADER_LEN as u64 || end > file_len {
                return Err(bad(format!(
                    "{name} section [{off}, {end}) outside file of {file_len} bytes"
                )));
            }
        }
        let m = hdr.n_items as u64;
        if hdr.prices.1 != hdr.rows * m * 4 {
            return Err(bad(format!(
                "prices length {} does not match rows {} × M {}",
                hdr.prices.1, hdr.rows, hdr.n_items
            )));
        }
        let want_costs = if hdr.dense {
            hdr.rows * m * hdr.n_global as u64 * 4
        } else {
            hdr.rows * m * 8 // u32 knap array + f32 cost array
        };
        if hdr.costs.1 != want_costs {
            return Err(bad(format!(
                "costs length {} does not match layout (want {want_costs})",
                hdr.costs.1
            )));
        }
        Ok(hdr)
    }
}

/// Serialize a laminar profile: `u32 count`, then per constraint
/// `u32 cap, u32 len, u16 items[len]`.
pub fn encode_laminar(profile: &LaminarProfile) -> Vec<u8> {
    let cs = profile.constraints();
    let mut out = Vec::with_capacity(4 + cs.iter().map(|c| 8 + 2 * c.items.len()).sum::<usize>());
    out.extend_from_slice(&(cs.len() as u32).to_le_bytes());
    for c in cs {
        out.extend_from_slice(&c.cap.to_le_bytes());
        out.extend_from_slice(&(c.items.len() as u32).to_le_bytes());
        for &j in &c.items {
            out.extend_from_slice(&j.to_le_bytes());
        }
    }
    out
}

/// Inverse of [`encode_laminar`] (revalidates laminarity on the way in).
pub fn decode_laminar(bytes: &[u8], what: &str) -> Result<LaminarProfile> {
    fn truncated(what: &str) -> Error {
        Error::InvalidProblem(format!("{what}: laminar section truncated"))
    }
    fn take_u32(bytes: &[u8], p: &mut usize, what: &str) -> Result<u32> {
        let s = bytes.get(*p..*p + 4).ok_or_else(|| truncated(what))?;
        *p += 4;
        Ok(u32::from_le_bytes(s.try_into().unwrap()))
    }
    let mut p = 0usize;
    let count = take_u32(bytes, &mut p, what)? as usize;
    let mut cs = Vec::with_capacity(count.min(1 << 20));
    for _ in 0..count {
        let cap = take_u32(bytes, &mut p, what)?;
        let len = take_u32(bytes, &mut p, what)? as usize;
        let raw = bytes.get(p..p + len * 2).ok_or_else(|| truncated(what))?;
        p += len * 2;
        let items: Vec<u16> =
            raw.chunks_exact(2).map(|b| u16::from_le_bytes(b.try_into().unwrap())).collect();
        cs.push(LocalConstraint::new(items, cap));
    }
    LaminarProfile::new(cs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> ShardHeader {
        ShardHeader {
            dense: true,
            group_start: 4096,
            n_groups: 100,
            rows: 128,
            n_items: 10,
            n_global: 4,
            laminar: (128, 44),
            prices: (192, 128 * 10 * 4),
            costs: (192 + align_up(128 * 10 * 4) as u64, 128 * 10 * 4 * 4),
            payload_hash: 0xDEAD_BEEF_CAFE_F00D,
        }
    }

    #[test]
    fn header_roundtrip() {
        let h = header();
        let bytes = h.encode();
        let file_len = (h.costs.0 + h.costs.1) as u64;
        let back = ShardHeader::decode(&bytes, file_len, "test").unwrap();
        assert_eq!(h, back);
    }

    #[test]
    fn header_rejects_corruption() {
        let h = header();
        let file_len = h.costs.0 + h.costs.1;
        let mut bytes = h.encode();
        bytes[20] ^= 0xFF; // corrupt group_start → header checksum fails
        assert!(ShardHeader::decode(&bytes, file_len, "test").is_err());

        let mut bytes = h.encode();
        bytes[0] = b'X';
        assert!(ShardHeader::decode(&bytes, file_len, "test").is_err());

        // section past end of file
        assert!(ShardHeader::decode(&h.encode(), file_len - 1, "test").is_err());
    }

    #[test]
    fn laminar_roundtrip() {
        let p = LaminarProfile::scenario_c223(10);
        let enc = encode_laminar(&p);
        let back = decode_laminar(&enc, "test").unwrap();
        assert_eq!(p.constraints(), back.constraints());
        assert!(decode_laminar(&enc[..enc.len() - 1], "test").is_err());
    }

    #[test]
    fn alignment() {
        assert_eq!(align_up(0), 0);
        assert_eq!(align_up(1), 64);
        assert_eq!(align_up(64), 64);
        assert_eq!(align_up(65), 128);
    }
}
