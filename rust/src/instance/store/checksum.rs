//! XXH64 checksum (Collet's xxHash, 64-bit variant).
//!
//! The shard files carry an XXH64 of their payload so a reader can detect
//! truncation or bit rot before solving off a corrupt store. The offline
//! registry has no `xxhash-rust`/`twox-hash`, so this is the reference
//! algorithm transcribed directly (public domain); the test vectors below
//! pin it to the published outputs.

const PRIME64_1: u64 = 0x9E3779B185EBCA87;
const PRIME64_2: u64 = 0xC2B2AE3D27D4EB4F;
const PRIME64_3: u64 = 0x165667B19E3779F9;
const PRIME64_4: u64 = 0x85EBCA77C2B2AE63;
const PRIME64_5: u64 = 0x27D4EB2F165667C5;

#[inline]
fn round(acc: u64, lane: u64) -> u64 {
    acc.wrapping_add(lane.wrapping_mul(PRIME64_2)).rotate_left(31).wrapping_mul(PRIME64_1)
}

#[inline]
fn merge_round(acc: u64, val: u64) -> u64 {
    (acc ^ round(0, val)).wrapping_mul(PRIME64_1).wrapping_add(PRIME64_4)
}

#[inline]
fn read_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

#[inline]
fn read_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes(b[..4].try_into().unwrap())
}

/// One-shot XXH64 of `data` with `seed`.
pub fn xxh64(data: &[u8], seed: u64) -> u64 {
    let len = data.len();
    let mut h: u64;
    let mut p = 0usize;

    if len >= 32 {
        let mut v1 = seed.wrapping_add(PRIME64_1).wrapping_add(PRIME64_2);
        let mut v2 = seed.wrapping_add(PRIME64_2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(PRIME64_1);
        while p + 32 <= len {
            v1 = round(v1, read_u64(&data[p..]));
            v2 = round(v2, read_u64(&data[p + 8..]));
            v3 = round(v3, read_u64(&data[p + 16..]));
            v4 = round(v4, read_u64(&data[p + 24..]));
            p += 32;
        }
        h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = merge_round(h, v1);
        h = merge_round(h, v2);
        h = merge_round(h, v3);
        h = merge_round(h, v4);
    } else {
        h = seed.wrapping_add(PRIME64_5);
    }

    h = h.wrapping_add(len as u64);

    while p + 8 <= len {
        h = (h ^ round(0, read_u64(&data[p..]))).rotate_left(27).wrapping_mul(PRIME64_1).wrapping_add(PRIME64_4);
        p += 8;
    }
    if p + 4 <= len {
        h = (h ^ (read_u32(&data[p..]) as u64).wrapping_mul(PRIME64_1))
            .rotate_left(23)
            .wrapping_mul(PRIME64_2)
            .wrapping_add(PRIME64_3);
        p += 4;
    }
    while p < len {
        h = (h ^ (data[p] as u64).wrapping_mul(PRIME64_5)).rotate_left(11).wrapping_mul(PRIME64_1);
        p += 1;
    }

    h ^= h >> 33;
    h = h.wrapping_mul(PRIME64_2);
    h ^= h >> 29;
    h = h.wrapping_mul(PRIME64_3);
    h ^= h >> 32;
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_vectors() {
        // xxHash's own test vectors (xxhsum / the reference README)
        assert_eq!(xxh64(b"", 0), 0xEF46_DB37_51D8_E999);
        assert_eq!(xxh64(b"abc", 0), 0x44BC_2CF5_AD77_0999);
    }

    #[test]
    fn seed_and_content_sensitivity() {
        let data = b"billion-scale knapsack shard payload";
        assert_ne!(xxh64(data, 0), xxh64(data, 1));
        let mut flipped = data.to_vec();
        flipped[7] ^= 1;
        assert_ne!(xxh64(data, 0), xxh64(&flipped, 0));
    }

    #[test]
    fn covers_every_tail_length() {
        // exercise the 32-byte stripe loop plus all finalization branches
        let data: Vec<u8> = (0..=255u8).collect();
        let mut seen = std::collections::HashSet::new();
        for l in 0..data.len() {
            assert!(seen.insert(xxh64(&data[..l], 42)), "collision at prefix {l}");
        }
    }
}
