//! [`StagedProblem`]: a shard store served through the async I/O
//! subsystem ([`crate::io`]) instead of borrow-only mmap.
//!
//! Wraps an open [`MmapProblem`] (manifest parsing, laminar profile and
//! the `fill_group` sampling path are shared) and reroutes the hot block
//! path: `fill_block` copies group sections out of a whole-shard
//! [`crate::io::IoLease`] obtained from a [`PrefetchingShardReader`], so
//! while the kernels chew shard `k` the backend is already reading
//! shards `k+1`/`k+2`. The bytes and the offset math are exactly the
//! mmap path's (a lease holds the entire shard file, header included, so
//! section offsets are the on-disk header offsets), and each staged
//! shard's header is validated against the manifest on first touch just
//! like a fresh mapping — results are bit-identical to mmap serving by
//! construction.

use crate::cluster::{Clock, SystemClock};
use crate::error::Result;
use crate::instance::laminar::LaminarProfile;
use crate::instance::problem::{BlockBuf, Dims, GroupBlock, GroupBuf, GroupSource};
use crate::instance::store::format::ShardHeader;
use crate::instance::store::mmap::{copy_f32_le, copy_u32_le};
use crate::instance::store::reader::MmapProblem;
use crate::io::{build_backend_clocked, IoBackendKind, IoStats, PrefetchingShardReader};
use std::sync::{Arc, OnceLock};

/// Cap on the number of f32 values a staged block holds (the
/// [`GroupSource::block_end`] default) — staged blocks are owned copies,
/// so they stay cache-resident like every other staging source.
const BLOCK_STAGING_F32: usize = 262_144;

/// A shard store served by prefetch-staged reads. See the module docs.
pub struct StagedProblem {
    inner: MmapProblem,
    reader: PrefetchingShardReader,
    /// Per-shard header decoded from staged bytes, validated on first
    /// touch (same checks a fresh mapping runs).
    headers: Vec<OnceLock<ShardHeader>>,
}

impl StagedProblem {
    /// Open `dir` for prefetch-staged serving through a `kind` backend,
    /// reading `depth` shards ahead while up to `parallel_hint` map
    /// workers consume distinct shards concurrently.
    ///
    /// Returns the source plus any fallback notes (e.g. io_uring
    /// unavailable → thread pool) for the solve planner to surface.
    pub fn open(
        dir: &std::path::Path,
        kind: IoBackendKind,
        depth: usize,
        parallel_hint: usize,
    ) -> Result<(Self, Vec<String>)> {
        Self::open_clocked(dir, kind, depth, parallel_hint, Arc::new(SystemClock))
    }

    /// [`StagedProblem::open`] with io timing routed through an explicit
    /// [`Clock`] — the solve planner passes the session clock here so a
    /// staged solve under the deterministic simulator keeps virtual-time
    /// io accounting.
    pub fn open_clocked(
        dir: &std::path::Path,
        kind: IoBackendKind,
        depth: usize,
        parallel_hint: usize,
        clock: Arc<dyn Clock>,
    ) -> Result<(Self, Vec<String>)> {
        let inner = MmapProblem::open(dir)?;
        Self::from_mmap_clocked(inner, kind, depth, parallel_hint, clock)
    }

    /// [`StagedProblem::open`] over an already-open [`MmapProblem`].
    pub fn from_mmap(
        inner: MmapProblem,
        kind: IoBackendKind,
        depth: usize,
        parallel_hint: usize,
    ) -> Result<(Self, Vec<String>)> {
        Self::from_mmap_clocked(inner, kind, depth, parallel_hint, Arc::new(SystemClock))
    }

    /// [`StagedProblem::from_mmap`] with an explicit [`Clock`].
    pub fn from_mmap_clocked(
        inner: MmapProblem,
        kind: IoBackendKind,
        depth: usize,
        parallel_hint: usize,
        clock: Arc<dyn Clock>,
    ) -> Result<(Self, Vec<String>)> {
        let n_shards = inner.n_shards();
        let file_len = std::fs::metadata(inner.shard_path(0))?.len() as usize;
        let parallel = parallel_hint.max(1);
        // every concurrent consumer can hold one shard resident while
        // `depth` more are in flight; the spare slots keep demand reads
        // from waiting on lookahead
        let resident = parallel + 1;
        let n_slots = (parallel + depth + 2).min(n_shards.max(1) + depth + 1);
        let (backend, fallback) =
            build_backend_clocked(kind, n_slots, file_len, Arc::clone(&clock))?;
        let paths = (0..n_shards).map(|i| inner.shard_path(i)).collect();
        let reader =
            PrefetchingShardReader::with_clock(backend, paths, file_len, depth, resident, clock)?;
        let staged = Self {
            headers: (0..n_shards).map(|_| OnceLock::new()).collect(),
            inner,
            reader,
        };
        Ok((staged, fallback.into_iter().collect()))
    }

    /// Backend name for plans (`"threadpool"` / `"io_uring"`).
    pub fn backend_name(&self) -> &'static str {
        self.reader.backend_name()
    }

    /// Configured lookahead depth.
    pub fn depth(&self) -> usize {
        self.reader.depth()
    }

    /// Cumulative I/O statistics (reader + backend).
    pub fn io_stats(&self) -> IoStats {
        self.reader.stats()
    }

    /// The wrapped mmap source.
    pub fn inner(&self) -> &MmapProblem {
        &self.inner
    }

    /// Staged bytes + validated header of the shard holding group
    /// `start`. Panics on I/O or validation failure, mirroring the mmap
    /// hot path (`fill_block` cannot return errors).
    fn shard_for(&self, start: usize) -> (std::sync::Arc<crate::io::IoLease>, &ShardHeader) {
        let idx = start / self.inner.shard_size();
        let lease = match self.reader.shard(idx) {
            Ok(l) => l,
            Err(e) => panic!("staged shard read failed mid-solve: {e}"),
        };
        let hdr = loop {
            if let Some(h) = self.headers[idx].get() {
                break h;
            }
            let bytes = lease.bytes();
            let what = self.inner.shard_path(idx).display().to_string();
            let decoded = ShardHeader::decode(bytes, bytes.len() as u64, &what)
                .and_then(|h| self.inner.check_shard_header(&h, idx, &what).map(|()| h));
            match decoded {
                Ok(h) => break self.headers[idx].get_or_init(|| h),
                Err(e) => panic!("staged shard read failed mid-solve: {e}"),
            }
        };
        (lease, hdr)
    }
}

impl GroupSource for StagedProblem {
    fn dims(&self) -> Dims {
        self.inner.dims()
    }

    fn is_dense(&self) -> bool {
        self.inner.is_dense()
    }

    fn locals(&self) -> &LaminarProfile {
        self.inner.locals()
    }

    fn budgets(&self) -> &[f64] {
        self.inner.budgets()
    }

    fn store_dir(&self) -> Option<std::path::PathBuf> {
        self.inner.store_dir()
    }

    /// Single-group access (presolve sampling, point queries) stays on
    /// the mmap path — it is random-access, exactly what prefetch cannot
    /// help and the page cache handles well.
    fn fill_group(&self, i: usize, buf: &mut GroupBuf) {
        self.inner.fill_group(i, buf)
    }

    fn preferred_shard_size(&self) -> Option<usize> {
        self.inner.preferred_shard_size()
    }

    /// Staged blocks respect both boundaries: the storage-shard edge (a
    /// block reads from one lease) and the owned-staging cap (copied
    /// blocks stay cache-resident).
    fn block_end(&self, start: usize, end: usize) -> usize {
        let d = self.dims();
        let per_group =
            if self.is_dense() { d.n_items * (d.n_global + 1) } else { 3 * d.n_items };
        let cap = (BLOCK_STAGING_F32 / per_group.max(1)).max(1);
        let boundary = (start / self.inner.shard_size() + 1) * self.inner.shard_size();
        end.min(start + cap).min(boundary)
    }

    /// The mmap path's offset math over staged bytes: same sections, same
    /// little-endian decode, copied into `buf` instead of borrowed — the
    /// resulting `f32`/`u32` values are bit-identical.
    fn fill_block<'a>(&'a self, start: usize, end: usize, buf: &'a mut BlockBuf) -> GroupBlock<'a> {
        let d = self.dims();
        assert!(
            end <= d.n_groups,
            "block [{start}, {end}) reaches past the {} live groups into shard padding",
            d.n_groups
        );
        let (lease, hdr) = self.shard_for(start);
        let row = start % self.inner.shard_size();
        let len = end - start;
        assert!(
            row + len <= hdr.rows as usize,
            "block [{start}, {end}) crosses a shard-file boundary (see GroupSource::block_end)"
        );
        let (m, k) = (d.n_items, d.n_global);
        let dense = self.is_dense();
        let bytes = lease.bytes();
        buf.ensure(len, m, k, dense);
        let p_off = hdr.prices.0 as usize + row * m * 4;
        copy_f32_le(&bytes[p_off..p_off + len * m * 4], &mut buf.profits[..len * m]);
        if dense {
            let w = m * k * 4;
            let off = hdr.costs.0 as usize + row * w;
            copy_f32_le(&bytes[off..off + len * w], &mut buf.dense[..len * m * k]);
        } else {
            let rows = hdr.rows as usize;
            let knap_off = hdr.costs.0 as usize + row * m * 4;
            let cost_off = hdr.costs.0 as usize + (rows + row) * m * 4;
            copy_u32_le(&bytes[knap_off..knap_off + len * m * 4], &mut buf.knap[..len * m]);
            copy_f32_le(&bytes[cost_off..cost_off + len * m * 4], &mut buf.cost[..len * m]);
        }
        buf.block(start, len, m, k, dense)
    }
}

const _ASSERT_SYNC: fn() = || {
    fn is_sync<T: Sync>() {}
    is_sync::<StagedProblem>();
};

impl std::fmt::Debug for StagedProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StagedProblem")
            .field("dir", &self.inner.dir())
            .field("backend", &self.backend_name())
            .field("depth", &self.depth())
            .finish()
    }
}
