//! Minimal read-only memory mapping.
//!
//! The offline registry has no `memmap2`/`libc`, so on 64-bit unix we
//! declare the two libc symbols we need directly (every rust binary on
//! these targets already links libc) and wrap them in an RAII handle. The
//! hand-rolled declaration uses a 64-bit `off_t`, which only matches the
//! C ABI on 64-bit platforms — 32-bit unix (and every non-unix target)
//! falls back to reading the file into an owned buffer: still bounded by
//! one shard at a time, just not zero-copy.
//!
//! Mappings are `MAP_PRIVATE` + `PROT_READ`: the kernel pages data in on
//! demand and evicts it under memory pressure, which is what lets
//! [`super::reader::MmapProblem`] serve instances larger than RAM.

use crate::error::{Error, Result};
use std::fs::File;
use std::path::Path;

/// A read-only byte view of a file: memory-mapped on 64-bit unix, owned
/// on other platforms.
pub struct Mmap {
    #[cfg(all(unix, target_pointer_width = "64"))]
    ptr: *const u8,
    #[cfg(all(unix, target_pointer_width = "64"))]
    len: usize,
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    buf: Vec<u8>,
}

// SAFETY: the mapping is immutable (PROT_READ, MAP_PRIVATE) for its whole
// lifetime, so concurrent reads from any thread are safe.
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Send for Mmap {}
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Sync for Mmap {}

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    // same values on linux and macOS
    pub const MADV_SEQUENTIAL: c_int = 2;
    pub const MADV_WILLNEED: c_int = 3;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }
}

/// `PALLAS_NO_MADVISE` off-switch for the readahead hints, resolved once.
#[cfg(all(unix, target_pointer_width = "64"))]
fn madvise_enabled() -> bool {
    use std::sync::OnceLock;
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        !matches!(
            std::env::var("PALLAS_NO_MADVISE").ok().as_deref(),
            Some(v) if !v.is_empty() && v != "0"
        )
    })
}

impl Mmap {
    /// Map `path` read-only in its entirety.
    pub fn open(path: &Path) -> Result<Self> {
        let file = File::open(path).map_err(|e| {
            Error::Io(std::io::Error::new(e.kind(), format!("{}: {e}", path.display())))
        })?;
        let len = file.metadata()?.len() as usize;
        Self::from_file(&file, len, path)
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn from_file(file: &File, len: usize, path: &Path) -> Result<Self> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return Ok(Self { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0 });
        }
        // SAFETY: fd is valid for the duration of the call; we request a
        // fresh private read-only mapping at a kernel-chosen address.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(Error::Runtime(format!(
                "mmap of {} ({len} bytes) failed: {}",
                path.display(),
                std::io::Error::last_os_error()
            )));
        }
        if madvise_enabled() {
            // best-effort readahead hints: map workers scan a shard's
            // sections front-to-back (SEQUENTIAL) and will touch the whole
            // file soon (WILLNEED). Advice only — ignore failures
            // (PALLAS_NO_MADVISE=1 skips the calls entirely).
            // SAFETY: ptr/len are the mapping established above.
            unsafe {
                sys::madvise(ptr, len, sys::MADV_SEQUENTIAL);
                sys::madvise(ptr, len, sys::MADV_WILLNEED);
            }
        }
        Ok(Self { ptr: ptr as *const u8, len })
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn from_file(file: &File, len: usize, _path: &Path) -> Result<Self> {
        use std::io::Read;
        let mut buf = Vec::with_capacity(len);
        let mut f = file;
        f.read_to_end(&mut buf)?;
        Ok(Self { buf })
    }

    /// The mapped bytes.
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            if self.len == 0 {
                return &[];
            }
            // SAFETY: ptr/len come from a successful mmap that lives as
            // long as `self`; the mapping is never written.
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            &self.buf
        }
    }

    /// Mapped length in bytes.
    pub fn len(&self) -> usize {
        self.bytes().len()
    }

    /// True when the mapping is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Drop for Mmap {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: ptr/len are the exact values returned by mmap.
            unsafe {
                sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
            }
        }
    }
}

/// Reinterpret a little-endian `f32` byte region as `&[f32]` without
/// copying. Panics if `bytes` is misaligned or has a ragged length — both
/// impossible for sections written by [`super::writer::ShardWriter`]
/// (64-byte-aligned offsets, exact lengths), so a panic here indicates a
/// corrupt file that slipped past the checksum.
#[cfg(target_endian = "little")]
#[inline]
pub fn cast_f32_slice(bytes: &[u8]) -> &[f32] {
    assert_eq!(bytes.len() % 4, 0, "f32 section has ragged length");
    assert_eq!(bytes.as_ptr() as usize % std::mem::align_of::<f32>(), 0, "f32 section misaligned");
    // SAFETY: alignment and length are checked above; any u32 bit pattern
    // is a valid f32; the source is immutable for the borrow's lifetime.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, bytes.len() / 4) }
}

/// Reinterpret a little-endian `u32` byte region as `&[u32]` (see
/// [`cast_f32_slice`]).
#[cfg(target_endian = "little")]
#[inline]
pub fn cast_u32_slice(bytes: &[u8]) -> &[u32] {
    assert_eq!(bytes.len() % 4, 0, "u32 section has ragged length");
    assert_eq!(bytes.as_ptr() as usize % std::mem::align_of::<u32>(), 0, "u32 section misaligned");
    // SAFETY: as above.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const u32, bytes.len() / 4) }
}

/// Copy a little-endian `f32` byte region into `out` (endian-safe path;
/// on little-endian hosts this is a plain memcpy via the zero-copy cast).
#[inline]
pub fn copy_f32_le(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), out.len() * 4);
    #[cfg(target_endian = "little")]
    out.copy_from_slice(cast_f32_slice(bytes));
    #[cfg(not(target_endian = "little"))]
    for (o, b) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *o = f32::from_le_bytes(b.try_into().unwrap());
    }
}

/// Copy a little-endian `u32` byte region into `out` (see [`copy_f32_le`]).
#[inline]
pub fn copy_u32_le(bytes: &[u8], out: &mut [u32]) {
    debug_assert_eq!(bytes.len(), out.len() * 4);
    #[cfg(target_endian = "little")]
    out.copy_from_slice(cast_u32_slice(bytes));
    #[cfg(not(target_endian = "little"))]
    for (o, b) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *o = u32::from_le_bytes(b.try_into().unwrap());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("bskp_mmap_{}_{name}", std::process::id()))
    }

    #[test]
    fn maps_file_contents() {
        let path = tmp("basic");
        let data: Vec<u8> = (0..=255u8).cycle().take(8192).collect();
        std::fs::File::create(&path).unwrap().write_all(&data).unwrap();
        let map = Mmap::open(&path).unwrap();
        assert_eq!(map.bytes(), &data[..]);
        assert_eq!(map.len(), 8192);
        drop(map);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_is_empty_view() {
        let path = tmp("empty");
        std::fs::File::create(&path).unwrap();
        let map = Mmap::open(&path).unwrap();
        assert!(map.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors_with_path() {
        let err = Mmap::open(Path::new("/nonexistent/bskp_shard")).unwrap_err();
        assert!(err.to_string().contains("bskp_shard"));
    }

    #[test]
    fn f32_cast_and_copy_roundtrip() {
        let vals: Vec<f32> = (0..64).map(|i| i as f32 * 0.5 - 3.0).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let mut out = vec![0.0f32; vals.len()];
        copy_f32_le(&bytes, &mut out);
        assert_eq!(out, vals);
        let ints: Vec<u32> = (0..64).collect();
        let bytes: Vec<u8> = ints.iter().flat_map(|v| v.to_le_bytes()).collect();
        let mut out = vec![0u32; ints.len()];
        copy_u32_le(&bytes, &mut out);
        assert_eq!(out, ints);
    }
}
