//! Out-of-core instance store: solve instances bigger than RAM.
//!
//! The paper's billion-variable runs never materialize the instance on one
//! node — mappers stream rows out of a sharded distributed store. This
//! module is that store for a single box: a versioned, little-endian,
//! columnar shard-file format ([`format`], spec in `docs/shard-format.md`)
//! written by a streaming [`ShardWriter`] (or the parallel
//! [`write_source`]) and read back by [`MmapProblem`], a memory-mapped
//! [`crate::instance::GroupSource`] the solvers run against directly —
//! `dd`, `scd` and the LP bound all solve straight off disk, with the
//! kernel page cache as the only "RAM copy" of the data.
//!
//! Layout highlights:
//!
//! * one file per shard of `shard_size` groups, plus a text manifest;
//! * each shard is **self-contained** (it carries the laminar profile), so
//!   a distributed worker needs exactly one file to map its shard;
//! * sections are 64-byte aligned raw `f32`/`u32` arrays — on
//!   little-endian hosts the mapped bytes are reinterpreted in place;
//! * XXH64 checksums ([`checksum`]) over every payload, verified on demand;
//! * the final partial shard is zero-padded to full `shard_size` rows so
//!   every file has identical geometry (what the XLA slab batching wants).

pub mod checksum;
pub mod format;
pub mod mmap;
pub mod reader;
pub mod staged;
pub mod writer;

pub use checksum::xxh64;
pub use reader::MmapProblem;
pub use staged::StagedProblem;
pub use writer::{write_source, ShardWriter, StoreMeta, StoreSummary};
