//! Streaming shard-store writer.
//!
//! [`ShardWriter`] accepts groups one at a time (in group-id order) and
//! flushes a shard file every `shard_size` groups, so the producer — the
//! synthetic generator, an ETL job, anything that can emit [`GroupBuf`]s —
//! never holds more than one shard in memory. [`write_source`] is the
//! parallel fast path for [`GroupSource`]s whose groups are independently
//! computable (the synthetic generator): each cluster worker encodes and
//! writes whole shard files on its own.
//!
//! The final partial shard is zero-padded to the full `shard_size` rows so
//! every shard file has an identical layout (fixed slab shapes are what
//! the XLA map phase batches on); the header records the live group count.

use crate::error::{Error, Result};
use crate::instance::problem::{CostsBuf, Dims, GroupBuf, GroupSource};
use crate::instance::store::checksum::xxh64;
use crate::instance::store::format::{
    align_up, encode_laminar, shard_file_name, ShardHeader, HEADER_LEN, MANIFEST_FORMAT,
    MANIFEST_NAME,
};
use crate::instance::laminar::LaminarProfile;
use crate::mapreduce::Cluster;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Instance-level metadata shared by every shard of a store.
#[derive(Debug, Clone)]
pub struct StoreMeta {
    /// Instance dimensions.
    pub dims: Dims,
    /// Dense or sparse cost layout.
    pub dense: bool,
    /// Global budgets `B_k`.
    pub budgets: Vec<f64>,
    /// Hierarchical local constraints (replicated into every shard file so
    /// each shard is self-contained).
    pub locals: LaminarProfile,
    /// Groups per shard file.
    pub shard_size: usize,
}

impl StoreMeta {
    /// Capture the metadata of an existing source.
    pub fn of<S: GroupSource + ?Sized>(source: &S, shard_size: usize) -> Self {
        Self {
            dims: source.dims(),
            dense: source.is_dense(),
            budgets: source.budgets().to_vec(),
            locals: source.locals().clone(),
            shard_size,
        }
    }

    /// Number of shard files for `n_groups` at `shard_size`.
    pub fn n_shards(&self) -> usize {
        self.dims.n_groups.div_ceil(self.shard_size)
    }

    /// Check dimensions, shard size and budget count (shared by
    /// [`ShardWriter::create`] and [`write_source`]).
    pub fn validate(&self) -> Result<()> {
        if self.dims.n_groups == 0 || self.dims.n_items == 0 || self.dims.n_global == 0 {
            return Err(Error::InvalidProblem(format!(
                "store dimensions must be positive, got N={} M={} K={}",
                self.dims.n_groups, self.dims.n_items, self.dims.n_global
            )));
        }
        if self.shard_size == 0 {
            return Err(Error::InvalidProblem("store shard_size must be positive".into()));
        }
        if self.budgets.len() != self.dims.n_global {
            return Err(Error::InvalidProblem(format!(
                "store expects {} budgets, got {}",
                self.dims.n_global,
                self.budgets.len()
            )));
        }
        Ok(())
    }
}

/// Summary returned by a completed write.
#[derive(Debug, Clone)]
pub struct StoreSummary {
    /// Store directory.
    pub dir: PathBuf,
    /// Shard files written.
    pub n_shards: usize,
    /// Total bytes across shard files.
    pub bytes: u64,
}

/// Encode one shard (header + sections) into a single buffer and return
/// it with its payload hash. The staging arrays are `shard_size` rows
/// with `n_live` live ones; the zeroed tail becomes the on-disk padding
/// of the final partial shard.
fn encode_shard(
    meta: &StoreMeta,
    group_start: usize,
    profits: &[f32],
    costs_dense: &[f32],
    costs_knap: &[u32],
    costs_cost: &[f32],
    n_live: usize,
) -> (Vec<u8>, u64) {
    let m = meta.dims.n_items;
    let k = meta.dims.n_global;
    let rows = meta.shard_size;
    let laminar_bytes = encode_laminar(&meta.locals);
    let laminar_off = HEADER_LEN;
    let prices_off = align_up(laminar_off + laminar_bytes.len());
    let prices_len = rows * m * 4;
    let costs_off = align_up(prices_off + prices_len);
    let costs_len = if meta.dense { rows * m * k * 4 } else { rows * m * 8 };
    let file_len = costs_off + costs_len;

    let mut out = vec![0u8; file_len];
    out[laminar_off..laminar_off + laminar_bytes.len()].copy_from_slice(&laminar_bytes);
    {
        let dst = &mut out[prices_off..prices_off + prices_len];
        for (chunk, v) in dst.chunks_exact_mut(4).zip(profits) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
    }
    if meta.dense {
        let dst = &mut out[costs_off..costs_off + costs_len];
        for (chunk, v) in dst.chunks_exact_mut(4).zip(costs_dense) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
    } else {
        let knap_len = rows * m * 4;
        let (knap_dst, cost_dst) = out[costs_off..costs_off + costs_len].split_at_mut(knap_len);
        for (chunk, v) in knap_dst.chunks_exact_mut(4).zip(costs_knap) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        for (chunk, v) in cost_dst.chunks_exact_mut(4).zip(costs_cost) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
    }

    let payload_hash = xxh64(&out[HEADER_LEN..], 0);
    let header = ShardHeader {
        dense: meta.dense,
        group_start: group_start as u64,
        n_groups: n_live as u64,
        rows: rows as u64,
        n_items: m as u32,
        n_global: k as u32,
        laminar: (laminar_off as u64, laminar_bytes.len() as u64),
        prices: (prices_off as u64, prices_len as u64),
        costs: (costs_off as u64, costs_len as u64),
        payload_hash,
    };
    out[..HEADER_LEN].copy_from_slice(&header.encode());
    (out, payload_hash)
}

/// Columnar staging buffers for the shard currently being filled
/// (`shard_size` rows; the only per-shard allocation, reused throughout).
struct ShardStage {
    profits: Vec<f32>,
    costs_dense: Vec<f32>,
    costs_knap: Vec<u32>,
    costs_cost: Vec<f32>,
    n_live: usize,
}

impl ShardStage {
    fn new(meta: &StoreMeta) -> Self {
        let m = meta.dims.n_items;
        let rows = meta.shard_size;
        Self {
            profits: vec![0.0; rows * m],
            costs_dense: if meta.dense { vec![0.0; rows * m * meta.dims.n_global] } else { Vec::new() },
            costs_knap: if meta.dense { Vec::new() } else { vec![0; rows * m] },
            costs_cost: if meta.dense { Vec::new() } else { vec![0.0; rows * m] },
            n_live: 0,
        }
    }

    fn clear(&mut self) {
        self.profits.iter_mut().for_each(|v| *v = 0.0);
        self.costs_dense.iter_mut().for_each(|v| *v = 0.0);
        self.costs_knap.iter_mut().for_each(|v| *v = 0);
        self.costs_cost.iter_mut().for_each(|v| *v = 0.0);
        self.n_live = 0;
    }

    fn push(&mut self, meta: &StoreMeta, buf: &GroupBuf) {
        let m = meta.dims.n_items;
        let k = meta.dims.n_global;
        let row = self.n_live;
        self.profits[row * m..(row + 1) * m].copy_from_slice(&buf.profits);
        match &buf.costs {
            CostsBuf::Dense(b) => {
                assert!(meta.dense, "dense GroupBuf appended to a sparse store");
                self.costs_dense[row * m * k..(row + 1) * m * k].copy_from_slice(b);
            }
            CostsBuf::Sparse { knap, cost } => {
                assert!(!meta.dense, "sparse GroupBuf appended to a dense store");
                self.costs_knap[row * m..(row + 1) * m].copy_from_slice(knap);
                self.costs_cost[row * m..(row + 1) * m].copy_from_slice(cost);
            }
        }
        self.n_live += 1;
    }
}

/// Streaming writer: groups in, shard files + manifest out.
pub struct ShardWriter {
    meta: StoreMeta,
    dir: PathBuf,
    stage: ShardStage,
    next_group: usize,
    shard_hashes: Vec<u64>,
    bytes: u64,
}

impl ShardWriter {
    /// Create the store directory (and parents) and start writing.
    pub fn create<P: AsRef<Path>>(dir: P, meta: StoreMeta) -> Result<Self> {
        meta.validate()?;
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let stage = ShardStage::new(&meta);
        Ok(Self { meta, dir, stage, next_group: 0, shard_hashes: Vec::new(), bytes: 0 })
    }

    /// The store metadata.
    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    /// Append the next group (ids are implicit and sequential). Flushes a
    /// shard file automatically when the stage fills.
    pub fn append_group(&mut self, buf: &GroupBuf) -> Result<()> {
        if self.next_group >= self.meta.dims.n_groups {
            return Err(Error::InvalidProblem(format!(
                "appended more groups than the declared N={}",
                self.meta.dims.n_groups
            )));
        }
        self.stage.push(&self.meta, buf);
        self.next_group += 1;
        if self.stage.n_live == self.meta.shard_size {
            self.flush_stage()?;
        }
        Ok(())
    }

    fn flush_stage(&mut self) -> Result<()> {
        let idx = self.shard_hashes.len();
        let group_start = idx * self.meta.shard_size;
        let (encoded, payload_hash) = encode_shard(
            &self.meta,
            group_start,
            &self.stage.profits,
            &self.stage.costs_dense,
            &self.stage.costs_knap,
            &self.stage.costs_cost,
            self.stage.n_live,
        );
        let path = self.dir.join(shard_file_name(idx));
        std::fs::write(&path, &encoded)?;
        self.bytes += encoded.len() as u64;
        self.shard_hashes.push(payload_hash);
        self.stage.clear();
        Ok(())
    }

    /// Flush the final (padded) partial shard and write the manifest.
    /// Errors if fewer groups than the declared `N` were appended.
    pub fn finish(mut self) -> Result<StoreSummary> {
        if self.next_group != self.meta.dims.n_groups {
            return Err(Error::InvalidProblem(format!(
                "store received {} of {} declared groups",
                self.next_group, self.meta.dims.n_groups
            )));
        }
        if self.stage.n_live > 0 {
            self.flush_stage()?;
        }
        let hashes = std::mem::take(&mut self.shard_hashes);
        write_manifest(&self.dir, &self.meta, &hashes)?;
        Ok(StoreSummary { dir: self.dir, n_shards: hashes.len(), bytes: self.bytes })
    }
}

/// Write `<dir>/store.manifest` (text, tab-separated — same idiom as the
/// runtime's artifact manifest).
fn write_manifest(dir: &Path, meta: &StoreMeta, shard_hashes: &[u64]) -> Result<()> {
    let mut text = String::new();
    text.push_str("# bskp shard store — see docs/shard-format.md\n");
    text.push_str(&format!("format\t{MANIFEST_FORMAT}\n"));
    text.push_str(&format!("layout\t{}\n", if meta.dense { "dense" } else { "sparse" }));
    text.push_str(&format!("n_groups\t{}\n", meta.dims.n_groups));
    text.push_str(&format!("n_items\t{}\n", meta.dims.n_items));
    text.push_str(&format!("n_global\t{}\n", meta.dims.n_global));
    text.push_str(&format!("shard_size\t{}\n", meta.shard_size));
    text.push_str(&format!("n_shards\t{}\n", shard_hashes.len()));
    for b in &meta.budgets {
        // rust float formatting is shortest-roundtrip, so budgets survive
        // the text manifest bit-exactly
        text.push_str(&format!("budget\t{b}\n"));
    }
    for (idx, h) in shard_hashes.iter().enumerate() {
        text.push_str(&format!("shard\t{idx}\t{}\t{h:016x}\n", shard_file_name(idx)));
    }
    let tmp = dir.join(format!("{MANIFEST_NAME}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(text.as_bytes())?;
        f.sync_all()?;
    }
    // atomic publish: readers never observe a half-written manifest
    std::fs::rename(&tmp, dir.join(MANIFEST_NAME))?;
    Ok(())
}

/// Write every shard of `source` into `dir` in parallel: one cluster
/// worker per shard file, each staging only its own shard (bounded memory
/// per worker), then the manifest. This is the `gen --out` fast path.
pub fn write_source<S: GroupSource + ?Sized>(
    source: &S,
    dir: &Path,
    shard_size: usize,
    cluster: &Cluster,
) -> Result<StoreSummary> {
    source.validate()?;
    let meta = StoreMeta::of(source, shard_size);
    meta.validate()?;
    std::fs::create_dir_all(dir)?;
    let n_shards = meta.n_shards();
    let n = meta.dims.n_groups;

    let results: Vec<Result<(u64, u64)>> = cluster.map_shards(n_shards, |idx| {
        let group_start = idx * shard_size;
        let group_end = ((idx + 1) * shard_size).min(n);
        let mut stage = ShardStage::new(&meta);
        let mut buf = GroupBuf::new(meta.dims, meta.dense);
        for i in group_start..group_end {
            source.fill_group(i, &mut buf);
            stage.push(&meta, &buf);
        }
        let (encoded, hash) = encode_shard(
            &meta,
            group_start,
            &stage.profits,
            &stage.costs_dense,
            &stage.costs_knap,
            &stage.costs_cost,
            stage.n_live,
        );
        std::fs::write(dir.join(shard_file_name(idx)), &encoded)?;
        Ok((hash, encoded.len() as u64))
    });

    let mut hashes = Vec::with_capacity(n_shards);
    let mut bytes = 0u64;
    for r in results {
        let (h, b) = r?;
        hashes.push(h);
        bytes += b;
    }
    write_manifest(dir, &meta, &hashes)?;
    Ok(StoreSummary { dir: dir.to_path_buf(), n_shards, bytes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bskp_writer_{}_{name}", std::process::id()))
    }

    #[test]
    fn writer_rejects_wrong_counts() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(5, 3, 3));
        let dir = tmp("counts");
        let meta = StoreMeta::of(&p, 2);
        let mut w = ShardWriter::create(&dir, meta).unwrap();
        let mut buf = GroupBuf::new(p.dims(), false);
        for i in 0..4 {
            p.fill_group(i, &mut buf);
            w.append_group(&buf).unwrap();
        }
        // finishing one group early must fail loudly
        assert!(w.finish().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_and_parallel_paths_write_identical_shards() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(25, 4, 4).with_seed(3));
        let (da, db) = (tmp("stream"), tmp("par"));
        let mut w = ShardWriter::create(&da, StoreMeta::of(&p, 8)).unwrap();
        let mut buf = GroupBuf::new(p.dims(), false);
        for i in 0..25 {
            p.fill_group(i, &mut buf);
            w.append_group(&buf).unwrap();
        }
        let sa = w.finish().unwrap();
        let sb = write_source(&p, &db, 8, &Cluster::new(3)).unwrap();
        assert_eq!(sa.n_shards, 4);
        assert_eq!(sa.n_shards, sb.n_shards);
        assert_eq!(sa.bytes, sb.bytes);
        for idx in 0..4 {
            let a = std::fs::read(da.join(shard_file_name(idx))).unwrap();
            let b = std::fs::read(db.join(shard_file_name(idx))).unwrap();
            assert_eq!(a, b, "shard {idx} differs between streaming and parallel writers");
        }
        assert_eq!(
            std::fs::read(da.join(MANIFEST_NAME)).unwrap(),
            std::fs::read(db.join(MANIFEST_NAME)).unwrap()
        );
        std::fs::remove_dir_all(&da).ok();
        std::fs::remove_dir_all(&db).ok();
    }
}
