//! Synthetic instance generators matching the paper's experiment setup (§6):
//! `p_ij ~ U[0,1]`; cost coefficients either `U[0,1]` or the Fig-1 mixture
//! (`U[0,1]` w.p. ½, `U[0,10]` w.p. ½); *sparse* and *dense* global
//! constraint classes; budgets scaled with `M`, `N` and the local profile so
//! the global constraints bind.
//!
//! Groups are derived deterministically from `(seed, group_id)` via
//! [`crate::rng::mix64`], so instances are never materialized: a
//! 100-million-group problem costs no memory, exactly like the paper's
//! mappers streaming rows out of a distributed store.

use crate::instance::laminar::LaminarProfile;
use crate::instance::problem::{CostsBuf, Dims, GroupBuf, GroupSource};
use crate::instance::store::{write_source, StoreSummary};
use crate::mapreduce::Cluster;
use crate::rng::{mix64, Xoshiro256pp};
use std::path::Path;

/// Global-constraint class (paper §6: "Two classes of global constraints
/// (sparse and dense) are experimented with").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostClass {
    /// Every item consumes from every knapsack: `b_ijk > 0` for all `k`.
    Dense,
    /// Each item consumes from exactly one knapsack (Algorithm 5's
    /// precondition when `M = K` with the identity mapping).
    Sparse,
}

/// Distribution for a coefficient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Dist {
    /// Uniform on `[lo, hi)`.
    Uniform { lo: f64, hi: f64 },
    /// 50/50 mixture of two uniforms (the paper's Fig-1 cost setting).
    MixUniform { lo1: f64, hi1: f64, lo2: f64, hi2: f64 },
}

impl Dist {
    /// Standard `U[0,1)`.
    pub const UNIT: Dist = Dist::Uniform { lo: 0.0, hi: 1.0 };

    /// Sample once.
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        match *self {
            Dist::Uniform { lo, hi } => rng.uniform(lo, hi),
            Dist::MixUniform { lo1, hi1, lo2, hi2 } => {
                if rng.coin(0.5) {
                    rng.uniform(lo1, hi1)
                } else {
                    rng.uniform(lo2, hi2)
                }
            }
        }
    }

    /// Expected value (used for budget scaling).
    pub fn mean(&self) -> f64 {
        match *self {
            Dist::Uniform { lo, hi } => 0.5 * (lo + hi),
            Dist::MixUniform { lo1, hi1, lo2, hi2 } => 0.25 * (lo1 + hi1) + 0.25 * (lo2 + hi2),
        }
    }
}

/// Full generator specification.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// `N`.
    pub n_groups: usize,
    /// `M`.
    pub n_items: usize,
    /// `K`.
    pub n_global: usize,
    /// Sparse vs dense costs.
    pub cost_class: CostClass,
    /// Profit distribution (paper: `U[0,1]`).
    pub profit_dist: Dist,
    /// Cost distribution (paper: `U[0,1]`, or the Fig-1 mixture).
    pub cost_dist: Dist,
    /// Hierarchical local constraints shared by all groups.
    pub locals: LaminarProfile,
    /// Budget as a fraction of the expected *unconstrained* consumption;
    /// < 1 makes the global constraints bind (paper scales budgets "to
    /// ensure tightness").
    pub budget_tightness: f64,
    /// Instance seed.
    pub seed: u64,
}

impl GeneratorConfig {
    /// Paper's sparse class: `U[0,1]` profits/costs, `C=[1]` locals unless
    /// overridden, identity item→knapsack mapping when `m == k`.
    pub fn sparse(n: usize, m: usize, k: usize) -> Self {
        Self {
            n_groups: n,
            n_items: m,
            n_global: k,
            cost_class: CostClass::Sparse,
            profit_dist: Dist::UNIT,
            cost_dist: Dist::UNIT,
            locals: LaminarProfile::single(m, 1),
            budget_tightness: 0.25,
            seed: 0,
        }
    }

    /// Paper's dense class.
    pub fn dense(n: usize, m: usize, k: usize) -> Self {
        Self { cost_class: CostClass::Dense, ..Self::sparse(n, m, k) }
    }

    /// The Fig-1 setting: dense, `M=10`, `b` from the 50/50
    /// `U[0,1]`/`U[0,10]` mixture, local scenario supplied by the caller.
    pub fn fig1(n: usize, k: usize, locals: LaminarProfile) -> Self {
        Self {
            n_items: 10,
            cost_dist: Dist::MixUniform { lo1: 0.0, hi1: 1.0, lo2: 0.0, hi2: 10.0 },
            locals,
            ..Self::dense(n, 10, k)
        }
    }

    /// Override the seed (builder style).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Override local constraints.
    pub fn with_locals(mut self, locals: LaminarProfile) -> Self {
        self.locals = locals;
        self
    }

    /// Override budget tightness.
    pub fn with_tightness(mut self, t: f64) -> Self {
        self.budget_tightness = t;
        self
    }

    /// Budgets scaled with `N`, `M` and the local profile: expected
    /// unconstrained consumption of knapsack `k` times the tightness
    /// factor. Dense items consume from all `K` knapsacks; sparse items
    /// from exactly one (uniformly, or identity when `m == k`).
    pub fn budgets(&self) -> Vec<f64> {
        let sel = self.locals.max_selected(self.n_items) as f64;
        let per_group = match self.cost_class {
            CostClass::Dense => sel * self.cost_dist.mean(),
            CostClass::Sparse => sel * self.cost_dist.mean() / self.n_global as f64,
        };
        let b = (self.budget_tightness * self.n_groups as f64 * per_group).max(f64::MIN_POSITIVE);
        vec![b; self.n_global]
    }
}

/// A [`GroupSource`] that regenerates any group on demand from the seed.
#[derive(Debug, Clone)]
pub struct SyntheticProblem {
    config: GeneratorConfig,
    budgets: Vec<f64>,
}

impl SyntheticProblem {
    /// Build from a config (budgets derived once via
    /// [`GeneratorConfig::budgets`]).
    pub fn new(config: GeneratorConfig) -> Self {
        let budgets = config.budgets();
        Self { config, budgets }
    }

    /// The generating config.
    pub fn config(&self) -> &GeneratorConfig {
        &self.config
    }

    /// Replace budgets (pre-solving rescales them on the sampled
    /// subproblem).
    pub fn with_budgets(mut self, budgets: Vec<f64>) -> Self {
        self.budgets = budgets;
        self
    }

    /// Stream the instance into an on-disk shard store at `dir` (see
    /// [`crate::instance::store`]): cluster workers generate and write
    /// whole shard files in parallel, each holding at most one shard's
    /// buffers in memory, so arbitrarily large instances materialize to
    /// disk in bounded RAM. Solve the result with
    /// [`crate::instance::store::MmapProblem::open`].
    pub fn write_shards<P: AsRef<Path>>(
        &self,
        dir: P,
        shard_size: usize,
        cluster: &Cluster,
    ) -> crate::error::Result<StoreSummary> {
        write_source(self, dir.as_ref(), shard_size, cluster)
    }
}

impl GroupSource for SyntheticProblem {
    fn dims(&self) -> Dims {
        Dims {
            n_groups: self.config.n_groups,
            n_items: self.config.n_items,
            n_global: self.config.n_global,
        }
    }

    fn is_dense(&self) -> bool {
        self.config.cost_class == CostClass::Dense
    }

    fn locals(&self) -> &LaminarProfile {
        &self.config.locals
    }

    fn budgets(&self) -> &[f64] {
        &self.budgets
    }

    fn fill_group(&self, i: usize, buf: &mut GroupBuf) {
        let mut rng = Xoshiro256pp::new(mix64(self.config.seed, i as u64));
        let m = self.config.n_items;
        let k = self.config.n_global;
        for j in 0..m {
            buf.profits[j] = self.config.profit_dist.sample(&mut rng) as f32;
        }
        match &mut buf.costs {
            CostsBuf::Dense(b) => {
                debug_assert_eq!(b.len(), m * k);
                for v in b.iter_mut() {
                    *v = self.config.cost_dist.sample(&mut rng) as f32;
                }
            }
            CostsBuf::Sparse { knap, cost } => {
                for j in 0..m {
                    knap[j] =
                        if m == k { j as u32 } else { rng.below(k as u64) as u32 };
                    cost[j] = self.config.cost_dist.sample(&mut rng) as f32;
                }
            }
        }
    }

    /// Generate the whole block straight into the SoA columns — the same
    /// per-group RNG streams as [`GroupSource::fill_group`] (each group is
    /// seeded independently from `(seed, id)`), minus the per-group
    /// staging copy.
    fn fill_block<'a>(
        &'a self,
        start: usize,
        end: usize,
        buf: &'a mut crate::instance::problem::BlockBuf,
    ) -> crate::instance::problem::GroupBlock<'a> {
        let m = self.config.n_items;
        let k = self.config.n_global;
        let dense = self.is_dense();
        let len = end - start;
        buf.ensure(len, m, k, dense);
        for g in 0..len {
            let mut rng = Xoshiro256pp::new(mix64(self.config.seed, (start + g) as u64));
            for p in &mut buf.profits[g * m..(g + 1) * m] {
                *p = self.config.profit_dist.sample(&mut rng) as f32;
            }
            if dense {
                for v in &mut buf.dense[g * m * k..(g + 1) * m * k] {
                    *v = self.config.cost_dist.sample(&mut rng) as f32;
                }
            } else {
                for j in 0..m {
                    buf.knap[g * m + j] =
                        if m == k { j as u32 } else { rng.below(k as u64) as u32 };
                    buf.cost[g * m + j] = self.config.cost_dist.sample(&mut rng) as f32;
                }
            }
        }
        buf.block(start, len, m, k, dense)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::problem::GroupBuf;

    #[test]
    fn deterministic_per_group() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(100, 10, 10).with_seed(5));
        let mut a = GroupBuf::new(p.dims(), false);
        let mut b = GroupBuf::new(p.dims(), false);
        p.fill_group(42, &mut a);
        p.fill_group(7, &mut b); // interleave another group
        p.fill_group(42, &mut b);
        assert_eq!(a.profits, b.profits);
        assert_eq!(a.costs, b.costs);
    }

    #[test]
    fn sparse_identity_mapping_when_m_equals_k() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(10, 6, 6));
        let mut buf = GroupBuf::new(p.dims(), false);
        p.fill_group(3, &mut buf);
        match &buf.costs {
            CostsBuf::Sparse { knap, .. } => {
                assert_eq!(knap, &(0..6).collect::<Vec<u32>>());
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn sparse_random_mapping_in_range() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(10, 5, 16));
        let mut buf = GroupBuf::new(p.dims(), false);
        for i in 0..10 {
            p.fill_group(i, &mut buf);
            match &buf.costs {
                CostsBuf::Sparse { knap, .. } => assert!(knap.iter().all(|&x| x < 16)),
                _ => panic!("expected sparse"),
            }
        }
    }

    #[test]
    fn values_within_distribution_support() {
        let cfg = GeneratorConfig::fig1(50, 5, LaminarProfile::scenario_c223(10));
        let p = SyntheticProblem::new(cfg);
        assert!(p.is_dense());
        let mut buf = GroupBuf::new(p.dims(), true);
        for i in 0..50 {
            p.fill_group(i, &mut buf);
            assert!(buf.profits.iter().all(|&x| (0.0..1.0).contains(&x)));
            match &buf.costs {
                CostsBuf::Dense(b) => assert!(b.iter().all(|&x| (0.0..10.0).contains(&x))),
                _ => panic!("expected dense"),
            }
        }
    }

    #[test]
    fn budgets_scale_with_n_and_tightness() {
        let c1 = GeneratorConfig::sparse(1000, 10, 10);
        let c2 = GeneratorConfig::sparse(2000, 10, 10);
        assert!((c2.budgets()[0] / c1.budgets()[0] - 2.0).abs() < 1e-9);
        let c3 = GeneratorConfig::sparse(1000, 10, 10).with_tightness(0.5);
        assert!((c3.budgets()[0] / c1.budgets()[0] - 2.0).abs() < 1e-9);
        // dense budgets don't divide by K
        let cd = GeneratorConfig::dense(1000, 10, 10);
        assert!(cd.budgets()[0] > c1.budgets()[0]);
    }

    #[test]
    fn different_seeds_differ() {
        let p1 = SyntheticProblem::new(GeneratorConfig::sparse(10, 4, 4).with_seed(1));
        let p2 = SyntheticProblem::new(GeneratorConfig::sparse(10, 4, 4).with_seed(2));
        let mut a = GroupBuf::new(p1.dims(), false);
        let mut b = GroupBuf::new(p2.dims(), false);
        p1.fill_group(0, &mut a);
        p2.fill_group(0, &mut b);
        assert_ne!(a.profits, b.profits);
    }

    #[test]
    fn validates() {
        let p = SyntheticProblem::new(GeneratorConfig::dense(10, 4, 3));
        p.validate().unwrap();
    }

    #[test]
    fn block_generation_matches_fill_group_bitwise() {
        use crate::instance::problem::{BlockBuf, RowCosts};
        for cfg in [
            GeneratorConfig::sparse(64, 5, 3).with_seed(9),
            GeneratorConfig::dense(64, 4, 6).with_seed(9),
        ] {
            let p = SyntheticProblem::new(cfg);
            let dense = p.is_dense();
            let mut bb = BlockBuf::new();
            let block = p.fill_block(10, 30, &mut bb);
            let mut buf = GroupBuf::new(p.dims(), dense);
            for g in 0..block.len() {
                p.fill_group(10 + g, &mut buf);
                let row = block.row(g);
                assert_eq!(row.profits, &buf.profits[..]);
                match (row.costs, &buf.costs) {
                    (RowCosts::Dense(b), CostsBuf::Dense(want)) => assert_eq!(b, &want[..]),
                    (
                        RowCosts::Sparse { knap, cost },
                        CostsBuf::Sparse { knap: wk, cost: wc },
                    ) => {
                        assert_eq!(knap, &wk[..]);
                        assert_eq!(cost, &wc[..]);
                    }
                    _ => panic!("layout mismatch"),
                }
            }
        }
    }
}
