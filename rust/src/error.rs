//! Library error type.
//!
//! Hand-rolled (the offline registry has no `thiserror` for this toolchain's
//! feature set we need); a small closed enum keeps match sites exhaustive.

use std::fmt;

/// Errors produced by the bskp library.
#[derive(Debug)]
pub enum Error {
    /// Problem data failed validation (dimension mismatch, negative budget,
    /// non-laminar local constraints, ...).
    InvalidProblem(String),
    /// Solver configuration is inconsistent.
    InvalidConfig(String),
    /// The solver exhausted its iteration budget without converging.
    NotConverged { iterations: usize, residual: f64 },
    /// An LP sub-solver failed (unbounded / infeasible master).
    Lp(String),
    /// PJRT runtime failure (artifact missing, compile error, exec error).
    Runtime(String),
    /// CLI usage error.
    Usage(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidProblem(m) => write!(f, "invalid problem: {m}"),
            Error::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            Error::NotConverged { iterations, residual } => {
                write!(f, "not converged after {iterations} iterations (residual {residual:.3e})")
            }
            Error::Lp(m) => write!(f, "lp solver: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Usage(m) => write!(f, "usage: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        let e = Error::InvalidProblem("bad".into());
        assert!(e.to_string().contains("invalid problem"));
        let e = Error::NotConverged { iterations: 3, residual: 0.5 };
        assert!(e.to_string().contains("3 iterations"));
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "x").into();
        assert!(matches!(e, Error::Io(_)));
    }
}
