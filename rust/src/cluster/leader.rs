//! The leader-side remote executor.
//!
//! [`RemoteCluster`] owns one [`WorkerLink`](super::membership::WorkerLink)
//! per configured worker and drives synchronous rounds: the global shard
//! partition is cut into contiguous **chunks** (a fixed function of the
//! round, independent of which worker computes what), and chunks are dealt
//! to live workers from a pending queue by one of two [`ExchangeMode`]s:
//! *waves* — one chunk per live worker per wave, slot order, a full
//! barrier between waves — or the default *overlapped* gather, which
//! deals the whole queue round-robin (slot order again) and keeps a
//! small task pipeline in flight per link, so workers never idle on a
//! wave barrier and the leader's waiting overlaps their compute. Either
//! deal is a pure function of (pending chunks, live set): which worker
//! computes which chunk never depends on thread scheduling, so a
//! simulated run's event trace is replayable from its seed, and a
//! production run's assignment is auditable from its logs. Partials are
//! merged **in chunk order** with compensated sums — the result does not
//! depend on worker count, scheduling, mid-round failures, or the
//! exchange mode. (Versus the earlier work-stealing queue this trades
//! intra-round rebalancing for a deterministic deal; overlap mode
//! recovers the pipelining a work queue would give, without giving up
//! the deterministic assignment.)
//!
//! **Failure handling.** A worker that errors or times out on a chunk is
//! marked dead for the session; its chunk goes back on the queue and a
//! survivor re-executes it in a later wave. Because every task frame
//! carries the round's full broadcast state (λ, active mask, reduce mode),
//! re-dispatch resumes from the λ the round started with — a lost worker
//! costs one chunk of recomputation. Only when *every* worker is gone does
//! the round (and the solve) fail; with checkpointing enabled the λ trail
//! survives for a warm-started retry.
//!
//! All timing goes through the transport's [`Clock`]: wall time on TCP,
//! virtual time under [`super::sim`] — which is how a 10-minute exchange
//! timeout can fire in microseconds of test time.

use crate::cluster::clock::Clock;
use crate::cluster::env_ms;
use crate::cluster::frames::EXT_LEN;
use crate::cluster::membership::{NetCounters, WorkerLink};
use crate::cluster::protocol::{span_ext, Geometry, InstanceFingerprint, Msg};
use crate::cluster::transport::{TcpTransport, Transport};
use crate::error::{Error, Result};
use crate::instance::problem::GroupSource;
use crate::instance::shard::Shards;
use crate::mapreduce::Cluster;
use crate::obs::metrics::{Counter, Histogram};
use crate::obs::{names, Track};
use crate::solver::config::ReduceMode;
use crate::solver::rounds::RoundAgg;
use crate::solver::scd::{ScdAcc, ScdRoundSpec, ThresholdAcc};
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Default per-exchange timeout. This is the *only* detector for a worker
/// that is silently partitioned (process death shows up immediately as
/// RST/EOF), so it must comfortably exceed the slowest honest chunk: at
/// N = 1e9 a chunk is ~N/64 groups, minutes of folding on a loaded box.
/// 10 minutes trades partition-detection latency for never killing a
/// healthy-but-slow fleet; tighten via `PALLAS_CLUSTER_TIMEOUT_MS` when
/// chunks are known to be fast.
const DEFAULT_TIMEOUT_MS: u64 = 600_000;

/// Default connect/handshake timeout (seconds, not minutes: planning must
/// reach its in-process fallback promptly when a fleet is blackholed).
const DEFAULT_CONNECT_TIMEOUT_MS: u64 = 5_000;

/// Chunks per round: a pure function of the shard count — deliberately
/// **independent of worker count and liveness**, so the chunk partition
/// (and with it the merge order of every compensated sum) is identical
/// for any fleet size and any mid-round failure pattern. 64 chunks give
/// fine-grained dealing and re-dispatch for any realistic fleet while
/// keeping per-round frame counts and per-chunk accumulators bounded.
const CHUNKS_PER_ROUND: usize = 64;

fn chunk_count(n_shards: usize) -> usize {
    n_shards.min(CHUNKS_PER_ROUND)
}

/// How the leader waits on its per-round exchange.
///
/// Both modes use the identical chunk partition and merge partials in
/// chunk order, so the solve result is bit-identical either way; they
/// differ only in when the leader is *waiting*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Strict wave barriers: one chunk per live worker per wave, the
    /// next wave starts only after every exchange of the current one
    /// returned. The whole fleet idles on each wave's straggler, but
    /// leader and worker never have more than one frame outstanding per
    /// link — the most conservative flow control, and the mode whose
    /// per-link traces are totally ordered (the chaos suite pins it for
    /// its exact replay assertions).
    Wave,
    /// Overlapped gather: the round's whole chunk queue is dealt up
    /// front (round-robin over live workers, slot order) and each link
    /// keeps a small pipeline of tasks in flight, so a worker starts
    /// its next chunk the moment it finishes one instead of idling on
    /// the slowest peer. Stragglers only delay their own queue. This is
    /// the default; `PALLAS_EXCHANGE=wave` restores wave barriers (e.g.
    /// when frames are so large that pipelined task + partial bytes
    /// could both sit in kernel socket buffers at once).
    Overlap,
}

impl ExchangeMode {
    /// The environment-configured mode: `PALLAS_EXCHANGE=wave` or
    /// `overlap` (the default, also used for unset/unknown values).
    pub fn from_env() -> Self {
        match std::env::var("PALLAS_EXCHANGE").ok().as_deref() {
            Some("wave") => ExchangeMode::Wave,
            _ => ExchangeMode::Overlap,
        }
    }
}

/// Session timeout policy, resolved once at connect time. [`Default`]
/// reads the `PALLAS_CLUSTER_TIMEOUT_MS` / `PALLAS_CLUSTER_CONNECT_TIMEOUT_MS`
/// / `PALLAS_EXCHANGE` knobs; tests inject explicit values instead of
/// mutating the process environment.
#[derive(Debug, Clone, Copy)]
pub struct ConnectOptions {
    /// Bound on dial + handshake per worker.
    pub connect_timeout: Duration,
    /// Bound on each task/partial exchange for the rest of the session.
    pub exchange_timeout: Duration,
    /// Wave-barrier or overlapped gather (see [`ExchangeMode`]).
    pub exchange: ExchangeMode,
}

impl ConnectOptions {
    /// The environment-configured policy (documented defaults when the
    /// knobs are unset).
    pub fn from_env() -> Self {
        Self {
            connect_timeout: env_ms(
                "PALLAS_CLUSTER_CONNECT_TIMEOUT_MS",
                DEFAULT_CONNECT_TIMEOUT_MS,
            ),
            exchange_timeout: env_ms("PALLAS_CLUSTER_TIMEOUT_MS", DEFAULT_TIMEOUT_MS),
            exchange: ExchangeMode::from_env(),
        }
    }
}

impl Default for ConnectOptions {
    fn default() -> Self {
        Self::from_env()
    }
}

/// Point-in-time wire statistics of a [`RemoteCluster`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetSnapshot {
    /// Task bytes written to workers (frames included).
    pub bytes_sent: u64,
    /// Partial bytes read from workers (frames included).
    pub bytes_received: u64,
    /// Gather rounds completed.
    pub rounds: u64,
    /// Total time inside gathers, milliseconds (virtual under the
    /// simulator).
    pub round_ms: f64,
    /// Chunks re-dispatched after a worker loss.
    pub redispatches: u64,
    /// Workers lost during the session.
    pub workers_lost: u64,
    /// Workers still live.
    pub workers_live: usize,
    /// Workers the session started with.
    pub workers_total: usize,
    /// Advertised map-thread capacity across all started workers.
    pub capacity: usize,
}

/// What one wave exchange produced (processed in deal order, so queue
/// re-adds and counters are deterministic).
enum WaveOutcome {
    /// The chunk's partial arrived.
    Done(usize, Msg),
    /// The worker died on this chunk; re-queue it for a survivor.
    Lost(usize, String),
    /// A protocol-level abort: the round (and solve) must fail.
    Fatal(String),
}

/// Tasks in flight per link in overlapped gather (sent, reply not yet
/// read). Two is enough to hide the leader's reply-drain time behind the
/// worker's compute — the worker picks up task k+1 from its receive
/// buffer the instant it finishes k — while keeping at most one task
/// frame queued in kernel buffers per link.
const PIPELINE_DEPTH: usize = 2;

/// What one link's overlapped run of its dealt queue produced (processed
/// in slot order, so queue re-adds and counters are deterministic).
struct SlotRun {
    /// Partials that arrived, in task order.
    done: Vec<(usize, Msg)>,
    /// Chunks the dead link never answered (the failing chunk, then the
    /// rest of its pipeline, then its unsent queue — a deterministic
    /// order for re-dispatch).
    lost: Vec<usize>,
    /// Why the link died, when it did.
    loss: Option<String>,
    /// A protocol-level abort: the round (and solve) must fail.
    fatal: Option<String>,
}

impl SlotRun {
    fn new() -> Self {
        Self { done: Vec::new(), lost: Vec::new(), loss: None, fatal: None }
    }
}

/// Leader-side registry handles, resolved once per session so the hot
/// exchange paths bump atomics and never look a metric up by name
/// ([`crate::obs::metrics`]). Per-link breakdowns live in the span trace
/// (one `link/<slot>` track each); the registry carries the fleet-wide
/// aggregates a scrape wants.
struct LeaderObs {
    exchanges: Arc<Counter>,
    exchange_latency_ns: Arc<Histogram>,
    exchange_bytes: Arc<Histogram>,
    redeals: Arc<Counter>,
    workers_lost: Arc<Counter>,
    gather_rounds: Arc<Counter>,
    gather_latency_ns: Arc<Histogram>,
}

impl LeaderObs {
    fn new() -> Self {
        let r = crate::obs::metrics::global();
        Self {
            exchanges: r.counter("bskp_cluster_exchanges_total"),
            exchange_latency_ns: r.histogram("bskp_cluster_exchange_latency_ns"),
            exchange_bytes: r.histogram("bskp_cluster_exchange_bytes"),
            redeals: r.counter("bskp_cluster_redeals_total"),
            workers_lost: r.counter("bskp_cluster_workers_lost_total"),
            gather_rounds: r.counter("bskp_cluster_gather_rounds_total"),
            gather_latency_ns: r.histogram("bskp_cluster_gather_latency_ns"),
        }
    }
}

/// A fleet of `pallas worker` processes, driven over a [`Transport`] with
/// the same map→combine→reduce contract as the in-process
/// [`Cluster`] (see [`super::Exec`]).
pub struct RemoteCluster {
    slots: Vec<Mutex<WorkerLink>>,
    leader_pool: Cluster,
    capacity: usize,
    counters: NetCounters,
    clock: Arc<dyn Clock>,
    exchange: ExchangeMode,
    obs: LeaderObs,
}

impl RemoteCluster {
    /// Connect over TCP to `addrs` and handshake each against `source`'s
    /// fingerprint, with environment-configured timeouts. Unreachable or
    /// mismatched workers are skipped with a human-readable note;
    /// connecting to **zero** workers is the only hard error (callers
    /// fall back to the in-process pool on it).
    pub fn connect<S: GroupSource + ?Sized>(
        addrs: &[String],
        source: &S,
    ) -> Result<(Self, Vec<String>)> {
        Self::connect_with(&TcpTransport, addrs, source, ConnectOptions::from_env())
    }

    /// [`RemoteCluster::connect`] over an explicit [`Transport`] and
    /// timeout policy — the entry point the deterministic simulator (and
    /// any future transport) uses; TCP behavior is unchanged.
    pub fn connect_with<S: GroupSource + ?Sized>(
        transport: &dyn Transport,
        addrs: &[String],
        source: &S,
        opts: ConnectOptions,
    ) -> Result<(Self, Vec<String>)> {
        let fingerprint = InstanceFingerprint::of(source);
        // dial concurrently: N blackholed hosts must cost one connect
        // timeout, not N, before planning can fall back in-process
        let dials: Vec<Result<WorkerLink>> = std::thread::scope(|s| {
            let handles: Vec<_> = addrs
                .iter()
                .map(|addr| {
                    let fingerprint = &fingerprint;
                    s.spawn(move || WorkerLink::connect(transport, addr, fingerprint, opts))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(Error::Runtime("worker dial thread panicked".into()))
                    })
                })
                .collect()
        });
        let mut slots = Vec::new();
        let mut skipped = Vec::new();
        for (addr, dial) in addrs.iter().zip(dials) {
            match dial {
                Ok(link) => slots.push(Mutex::new(link)),
                Err(e) => skipped.push(format!("worker {addr} skipped: {e}")),
            }
        }
        if slots.is_empty() {
            return Err(Error::Runtime(format!(
                "no cluster workers reachable at [{}]{}",
                addrs.join(", "),
                skipped
                    .iter()
                    .map(|s| format!("; {s}"))
                    .collect::<String>(),
            )));
        }
        let capacity = slots.iter().map(|s| s.lock().unwrap().threads).sum();
        let fleet = Self {
            slots,
            leader_pool: Cluster::configured(),
            capacity,
            counters: NetCounters::default(),
            clock: transport.clock(),
            exchange: opts.exchange,
            obs: LeaderObs::new(),
        };
        Ok((fleet, skipped))
    }

    /// Replace the pool used for leader-local phases (§5.3 pre-solve
    /// sampling, §5.4's sequential walk). The session planner threads the
    /// session's own `--workers` pool through here so distributed solves
    /// honor it; the default is [`Cluster::configured`].
    pub fn with_leader_pool(mut self, pool: Cluster) -> Self {
        self.leader_pool = pool;
        self
    }

    /// Workers the session started with.
    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Workers still live.
    pub fn workers_live(&self) -> usize {
        self.slots.iter().filter(|s| s.lock().unwrap().is_live()).count()
    }

    /// Total advertised map-thread capacity (drives shard planning).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The configured worker addresses.
    pub fn addrs(&self) -> Vec<String> {
        self.slots.iter().map(|s| s.lock().unwrap().addr.clone()).collect()
    }

    /// The leader-local pool used for the phases that stay on the leader
    /// (§5.3 pre-solve sampling, the sequential part of §5.4).
    pub(crate) fn leader_pool(&self) -> &Cluster {
        &self.leader_pool
    }

    /// Wire statistics so far.
    pub fn stats(&self) -> NetSnapshot {
        let c = &self.counters;
        NetSnapshot {
            bytes_sent: c.bytes_sent.load(Ordering::Relaxed),
            bytes_received: c.bytes_received.load(Ordering::Relaxed),
            rounds: c.rounds.load(Ordering::Relaxed),
            round_ms: c.round_us.load(Ordering::Relaxed) as f64 / 1e3,
            redispatches: c.redispatches.load(Ordering::Relaxed),
            workers_lost: c.workers_lost.load(Ordering::Relaxed),
            workers_live: self.workers_live(),
            workers_total: self.slots.len(),
            capacity: self.capacity,
        }
    }

    /// Dispatch one round: cut `[0, n_shards)` into chunks, deal them to
    /// live workers, gather the partials **indexed by chunk** — wave by
    /// wave or overlapped, per the session's [`ExchangeMode`] (the
    /// partition, the merge order and therefore the result are identical
    /// either way). Lost workers re-queue their chunks; the round only
    /// fails when no live worker remains (or a worker reports a
    /// protocol-level abort).
    fn gather<F>(&self, n_shards: usize, task: F) -> Result<Vec<Msg>>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        if n_shards == 0 {
            return Ok(Vec::new());
        }
        let t0 = self.clock.now_ns();
        // the gather ordinal doubles as the round index in span-context
        // frame extensions and EXCHANGE span arguments
        let round = self.counters.rounds.load(Ordering::Relaxed);
        let n_chunks = chunk_count(n_shards);
        let per = n_shards.div_ceil(n_chunks);
        let n_chunks = n_shards.div_ceil(per);
        let mut pending: VecDeque<usize> = (0..n_chunks).collect();
        let mut results: Vec<Option<Msg>> = (0..n_chunks).map(|_| None).collect();
        let mut last_loss = String::new();

        while !pending.is_empty() {
            let live: Vec<usize> = (0..self.slots.len())
                .filter(|&i| self.slots[i].lock().unwrap().is_live())
                .collect();
            if live.is_empty() {
                return Err(Error::Runtime(format!(
                    "all cluster workers lost mid-round ({} of {} chunks done){}",
                    results.iter().filter(|r| r.is_some()).count(),
                    n_chunks,
                    if last_loss.is_empty() {
                        String::new()
                    } else {
                        format!("; last failure: {last_loss}")
                    },
                )));
            }
            match self.exchange {
                ExchangeMode::Wave => self.wave_step(
                    round,
                    per,
                    n_shards,
                    &live,
                    &mut pending,
                    &mut results,
                    &mut last_loss,
                    &task,
                )?,
                ExchangeMode::Overlap => self.overlap_step(
                    round,
                    per,
                    n_shards,
                    &live,
                    &mut pending,
                    &mut results,
                    &mut last_loss,
                    &task,
                )?,
            }
        }

        self.counters.count(&self.counters.rounds, 1);
        let dur_ns = self.clock.now_ns().saturating_sub(t0);
        self.counters.count(&self.counters.round_us, dur_ns / 1_000);
        if crate::obs::metrics_enabled() {
            self.obs.gather_rounds.inc();
            self.obs.gather_latency_ns.observe(dur_ns);
        }
        Ok(results.into_iter().map(|r| r.expect("all chunks gathered")).collect())
    }

    /// One wave: one pending chunk per live worker, a barrier, then the
    /// outcomes in deal order.
    #[allow(clippy::too_many_arguments)]
    fn wave_step<F>(
        &self,
        round: u64,
        per: usize,
        n_shards: usize,
        live: &[usize],
        pending: &mut VecDeque<usize>,
        results: &mut [Option<Msg>],
        last_loss: &mut String,
        task: &F,
    ) -> Result<()>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        // the wave deal: one pending chunk per live worker, slot
        // order — a pure function of (pending, live)
        let deals: Vec<(usize, usize)> = live
            .iter()
            .map_while(|&slot| pending.pop_front().map(|chunk| (slot, chunk)))
            .collect();
        let trace_on = crate::obs::trace_enabled();
        let want_obs = trace_on || crate::obs::metrics_enabled();
        let ext = span_ext::encode_task(round, trace_on);
        let outcomes: Vec<WaveOutcome> = std::thread::scope(|s| {
            let handles: Vec<_> = deals
                .iter()
                .map(|&(slot, chunk)| {
                    let ext = &ext;
                    s.spawn(move || {
                        let lo = chunk * per;
                        let hi = (lo + per).min(n_shards);
                        let mut link = self.slots[slot].lock().unwrap();
                        let t0 = if want_obs { self.clock.now_ns() } else { 0 };
                        let result = link
                            .send_task(&task(lo, hi), ext, &self.counters)
                            .and_then(|()| link.recv_partial(&self.counters));
                        match result {
                            Ok((Msg::Abort { message }, _, _)) => WaveOutcome::Fatal(format!(
                                "worker {} aborted the round: {message}",
                                link.addr
                            )),
                            Ok((reply, reply_ext, received)) => {
                                if want_obs {
                                    self.observe_exchange(
                                        slot,
                                        round,
                                        lo as u64,
                                        t0,
                                        received,
                                        reply_ext.as_ref(),
                                    );
                                }
                                WaveOutcome::Done(chunk, reply)
                            }
                            Err(e) => {
                                // dead worker: back on the queue for
                                // a survivor in the next wave
                                link.kill();
                                WaveOutcome::Lost(chunk, format!("worker {}: {e}", link.addr))
                            }
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        WaveOutcome::Fatal("worker exchange thread panicked".into())
                    })
                })
                .collect()
        });
        for outcome in outcomes {
            match outcome {
                WaveOutcome::Done(chunk, reply) => results[chunk] = Some(reply),
                WaveOutcome::Lost(chunk, loss) => {
                    *last_loss = loss;
                    self.note_loss(round, per, std::slice::from_ref(&chunk));
                    pending.push_back(chunk);
                    self.counters.count(&self.counters.workers_lost, 1);
                    self.counters.count(&self.counters.redispatches, 1);
                }
                WaveOutcome::Fatal(message) => return Err(Error::Runtime(message)),
            }
        }
        Ok(())
    }

    /// Record one finished exchange: fleet-wide registry metrics plus —
    /// when tracing — the per-link `EXCHANGE` span and the worker's
    /// shipped task span, re-based onto the leader clock so it ends at
    /// receipt (the wire carries only the code and duration; round and
    /// chunk come from the in-flight task it matches).
    fn observe_exchange(
        &self,
        slot: usize,
        round: u64,
        lo: u64,
        t0_ns: u64,
        bytes: usize,
        reply_ext: Option<&[u8; EXT_LEN]>,
    ) {
        let now = self.clock.now_ns();
        let dur_ns = now.saturating_sub(t0_ns);
        if crate::obs::metrics_enabled() {
            self.obs.exchanges.inc();
            self.obs.exchange_latency_ns.observe(dur_ns);
            self.obs.exchange_bytes.observe(bytes as u64);
        }
        if crate::obs::trace_enabled() {
            let track = Track::Link(slot as u16);
            crate::obs::complete(track, names::EXCHANGE, t0_ns, dur_ns, round, lo);
            if let Some(ext) = reply_ext {
                let (code, w_dur) = span_ext::decode_span(ext);
                crate::obs::complete(track, code, now.saturating_sub(w_dur), w_dur, round, lo);
            }
        }
    }

    /// Record chunks going back on the deal queue after a worker loss:
    /// a `REDEAL` instant per chunk plus the fleet-wide counters.
    fn note_loss(&self, round: u64, per: usize, chunks: &[usize]) {
        if crate::obs::metrics_enabled() {
            self.obs.workers_lost.inc();
            self.obs.redeals.add(chunks.len() as u64);
        }
        for &chunk in chunks {
            crate::obs::instant(
                self.clock.as_ref(),
                Track::Leader,
                names::REDEAL,
                round,
                (chunk * per) as u64,
            );
        }
    }

    /// One overlapped pass: deal the *whole* pending queue round-robin
    /// over the live workers (slot order — a pure function of
    /// `(pending, live)`, like the wave deal), then run every link's
    /// queue concurrently with a [`PIPELINE_DEPTH`]-deep task pipeline
    /// per link. Outcomes are processed in slot order, so counter
    /// updates and the re-queue order of lost chunks are deterministic;
    /// partials land indexed by chunk, so the merge (and the solve
    /// result) is bit-identical to wave mode.
    #[allow(clippy::too_many_arguments)]
    fn overlap_step<F>(
        &self,
        round: u64,
        per: usize,
        n_shards: usize,
        live: &[usize],
        pending: &mut VecDeque<usize>,
        results: &mut [Option<Msg>],
        last_loss: &mut String,
        task: &F,
    ) -> Result<()>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        let mut queues: Vec<Vec<usize>> = vec![Vec::new(); live.len()];
        for (i, chunk) in pending.drain(..).enumerate() {
            queues[i % live.len()].push(chunk);
        }
        let runs: Vec<SlotRun> = std::thread::scope(|s| {
            let handles: Vec<_> = live
                .iter()
                .zip(&queues)
                .map(|(&slot, queue)| {
                    s.spawn(move || self.run_slot(slot, round, queue, per, n_shards, task))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        let mut run = SlotRun::new();
                        run.fatal = Some("worker exchange thread panicked".into());
                        run
                    })
                })
                .collect()
        });
        for run in runs {
            if let Some(message) = run.fatal {
                return Err(Error::Runtime(message));
            }
            for (chunk, reply) in run.done {
                results[chunk] = Some(reply);
            }
            if let Some(loss) = run.loss {
                *last_loss = loss;
                self.counters.count(&self.counters.workers_lost, 1);
                self.counters.count(&self.counters.redispatches, run.lost.len() as u64);
                self.note_loss(round, per, &run.lost);
                for chunk in run.lost {
                    pending.push_back(chunk);
                }
            }
        }
        Ok(())
    }

    /// Drive one link through its dealt queue with up to
    /// [`PIPELINE_DEPTH`] tasks in flight: fill the pipeline, read the
    /// oldest partial, refill. The wire stays strict request/response
    /// (every send is balanced by one receive, replies arrive in task
    /// order); only the leader's waiting overlaps with the worker's
    /// compute. Any wire error kills the link and reports every
    /// unanswered chunk as lost, in a deterministic order.
    fn run_slot<F>(
        &self,
        slot: usize,
        round: u64,
        queue: &[usize],
        per: usize,
        n_shards: usize,
        task: &F,
    ) -> SlotRun
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        let trace_on = crate::obs::trace_enabled();
        let want_obs = trace_on || crate::obs::metrics_enabled();
        let ext = span_ext::encode_task(round, trace_on);
        let mut run = SlotRun::new();
        let mut link = self.slots[slot].lock().unwrap();
        // in-flight chunks with their send instants: a pipelined chunk's
        // exchange latency is its full turnaround, send to reply
        let mut inflight: VecDeque<(usize, u64)> = VecDeque::new();
        let mut next = 0usize;
        loop {
            while inflight.len() < PIPELINE_DEPTH && next < queue.len() {
                let chunk = queue[next];
                let lo = chunk * per;
                let hi = (lo + per).min(n_shards);
                let t_sent = if want_obs { self.clock.now_ns() } else { 0 };
                match link.send_task(&task(lo, hi), &ext, &self.counters) {
                    Ok(()) => {
                        inflight.push_back((chunk, t_sent));
                        next += 1;
                    }
                    Err(e) => {
                        link.kill();
                        run.loss = Some(format!("worker {}: {e}", link.addr));
                        run.lost.push(chunk);
                        run.lost.extend(inflight.drain(..).map(|(c, _)| c));
                        run.lost.extend(queue[next + 1..].iter().copied());
                        return run;
                    }
                }
            }
            let Some((chunk, t_sent)) = inflight.pop_front() else { return run };
            match link.recv_partial(&self.counters) {
                Ok((Msg::Abort { message }, _, _)) => {
                    run.fatal =
                        Some(format!("worker {} aborted the round: {message}", link.addr));
                    return run;
                }
                Ok((reply, reply_ext, received)) => {
                    if want_obs {
                        let lo = (chunk * per) as u64;
                        self.observe_exchange(
                            slot,
                            round,
                            lo,
                            t_sent,
                            received,
                            reply_ext.as_ref(),
                        );
                    }
                    run.done.push((chunk, reply));
                }
                Err(e) => {
                    link.kill();
                    run.loss = Some(format!("worker {}: {e}", link.addr));
                    run.lost.push(chunk);
                    run.lost.extend(inflight.drain(..).map(|(c, _)| c));
                    run.lost.extend(queue[next..].iter().copied());
                    return run;
                }
            }
        }
    }

    /// Distributed evaluation round (DD rounds, final evaluations).
    pub(crate) fn eval_round(
        &self,
        shards: Shards,
        kk: usize,
        lambda: &[f64],
    ) -> Result<RoundAgg> {
        let geo = Geometry::of(shards);
        let parts = self.gather(shards.count(), |lo, hi| Msg::EvalTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: lambda.to_vec(),
        })?;
        let mut agg = RoundAgg::new(kk);
        for part in parts {
            match part {
                Msg::EvalPartial(a) if a.consumption.len() == kk => agg = agg.merge(a),
                other => return Err(unexpected("eval-partial", &other)),
            }
        }
        Ok(agg)
    }

    /// Distributed SCD round.
    pub(crate) fn scd_round(&self, shards: Shards, spec: &ScdRoundSpec<'_>) -> Result<ScdAcc> {
        let geo = Geometry::of(shards);
        let kk = spec.lambda.len();
        let parts = self.gather(shards.count(), |lo, hi| Msg::ScdTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: spec.lambda.to_vec(),
            active: spec.active_mask.to_vec(),
            sparse_q: spec.sparse_q,
            reduce: spec.reduce,
        })?;
        let mut acc = ScdAcc::new(spec.reduce, spec.lambda);
        for part in parts {
            match part {
                Msg::ScdPartial(a)
                    if a.round.consumption.len() == kk
                        && thresholds_fit(&a.thresholds, spec.reduce, kk) =>
                {
                    acc = acc.merge(a)
                }
                other => return Err(unexpected("scd-partial", &other)),
            }
        }
        Ok(acc)
    }

    /// Distributed §5.4 ranking round.
    pub(crate) fn rank_round(&self, shards: Shards, lambda: &[f64]) -> Result<Vec<(f32, u32)>> {
        let geo = Geometry::of(shards);
        let parts = self.gather(shards.count(), |lo, hi| Msg::RankTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: lambda.to_vec(),
        })?;
        let n_groups = shards.n_total() as u32;
        let mut ranked = Vec::new();
        for part in parts {
            match part {
                Msg::RankPartial(r) if r.iter().all(|&(_, i)| i < n_groups) => ranked.extend(r),
                other => return Err(unexpected("rank-partial", &other)),
            }
        }
        Ok(ranked)
    }
}

/// Does a shipped threshold accumulator have the variant and width the
/// round expects? (A fingerprint-verified worker always satisfies this;
/// the check turns a hypothetical protocol bug into a clean error instead
/// of a mis-merge.)
fn thresholds_fit(t: &ThresholdAcc, reduce: ReduceMode, kk: usize) -> bool {
    match (t, reduce) {
        (ThresholdAcc::Exact(v), ReduceMode::Exact) => v.len() == kk,
        (ThresholdAcc::Bucketed(h), ReduceMode::Bucketed { .. }) => h.len() == kk,
        _ => false,
    }
}

fn unexpected(want: &str, got: &Msg) -> Error {
    Error::Runtime(format!(
        "cluster protocol violation: expected a well-formed {want}, got {} \
         (mismatched binaries?)",
        got.name()
    ))
}

impl Drop for RemoteCluster {
    fn drop(&mut self) {
        for slot in &self.slots {
            if let Ok(mut link) = slot.lock() {
                link.shutdown();
            }
        }
    }
}
