//! The leader-side remote executor.
//!
//! [`RemoteCluster`] owns one [`WorkerLink`](super::membership::WorkerLink)
//! per configured worker and drives synchronous rounds: the global shard
//! partition is cut into contiguous **chunks** (a fixed function of the
//! round, independent of which worker computes what), and chunks are dealt
//! to live workers from a pending queue by one of two [`ExchangeMode`]s:
//! *waves* — one chunk per live worker per wave, slot order, a full
//! barrier between waves — or the default *overlapped* gather, which
//! deals the whole queue round-robin (slot order again) and keeps a
//! small task pipeline in flight per link, so workers never idle on a
//! wave barrier and the leader's waiting overlaps their compute. Either
//! deal is a pure function of (pending chunks, live set): which worker
//! computes which chunk never depends on thread scheduling, so a
//! simulated run's event trace is replayable from its seed, and a
//! production run's assignment is auditable from its logs. Partials are
//! merged **in chunk order** with compensated sums — the result does not
//! depend on worker count, scheduling, mid-round failures, or the
//! exchange mode. (Versus the earlier work-stealing queue this trades
//! intra-round rebalancing for a deterministic deal; overlap mode
//! recovers the pipelining a work queue would give, without giving up
//! the deterministic assignment.)
//!
//! **Failure handling.** A worker that errors or times out on a chunk is
//! marked dead; its chunk goes back on the queue and a survivor
//! re-executes it in a later wave. Because every task frame carries the
//! round's full broadcast state (λ, active mask, reduce mode),
//! re-dispatch resumes from the λ the round started with — a lost worker
//! costs one chunk of recomputation. Only when *every* worker is gone does
//! the round (and the solve) fail; with checkpointing enabled the λ trail
//! survives for a warm-started retry.
//!
//! **Elastic membership.** All membership work happens at the deal
//! boundary (the top of each gather pass), so the deal stays a pure
//! function of `(pending, live)` and simulated traces stay replayable.
//! With a redial budget (`PALLAS_CLUSTER_REDIALS` /
//! [`ConnectOptions::redial_budget`]) the leader re-dials
//! transiently-dead links on an exponential-backoff schedule with
//! deterministic jitter ([`Backoff`]), re-handshaking the instance
//! fingerprint; a peer that answers and *refuses* is retired permanently.
//! A session constructed with a join listener
//! ([`RemoteCluster::connect_elastic`]) admits fresh `bskp worker --join`
//! processes mid-solve over the `Join`/`Admit` frames; admitted workers
//! receive chunks from the next deal on. A quorum floor
//! (`PALLAS_MIN_WORKERS` / [`ConnectOptions::min_workers`]) turns a
//! too-degraded fleet into a typed fail-fast error instead of a grind;
//! above the floor but below full strength the solve continues degraded,
//! with a `Degraded` note per strength transition. Every membership
//! change lands in the [`MembershipEvent`] log (surfaced through
//! `SolveReport::membership`), the metrics registry and the flight
//! recorder.
//!
//! **The relay tier.** With `PALLAS_RELAY_FANOUT` ([`RelayFanout`]) the
//! leader promotes some workers to *relays* at the deal boundary: each
//! relay is dealt a subtree of leaf workers (`RelayAssign`), the leader
//! hands the leaves' connections off to it, and from then on exchanges
//! contiguous *runs* of chunks with the relays instead of single chunks
//! with every worker — the per-round receive count drops from O(workers)
//! to O(relays). A relay splits its run on the identical global chunk
//! grid ([`crate::cluster::chunk_plan`]), fans sub-chunks over its
//! subtree and merges the partials in ascending chunk order before
//! replying with one `RelayPartial`, so the leader's final merge sees the
//! same operands in the same order as a flat gather: **flat and two-level
//! topologies are bit-identical** for any relay count. Leaf failures are
//! absorbed relay-side (local recompute; the loss is reported in the
//! envelope); a relay failure re-queues its runs, invalidates the cached
//! topology and the next boundary re-parents the orphaned subtree onto
//! survivors — or back to direct exchanges when no relay remains. The
//! tier requires a retained transport (the [`RemoteCluster::connect_with`]
//! path stays structurally flat).
//!
//! All timing goes through the transport's [`Clock`]: wall time on TCP,
//! virtual time under [`super::sim`] — which is how a 10-minute exchange
//! timeout can fire in microseconds of test time.

use crate::cluster::clock::{Backoff, Clock};
use crate::cluster::frames::EXT_LEN;
use crate::cluster::membership::{NetCounters, WorkerLink};
use crate::cluster::protocol::{
    recv_msg, send_msg, span_ext, Geometry, InstanceFingerprint, Msg,
};
use crate::cluster::transport::{NetListener, NetStream, TcpTransport, Transport};
use crate::cluster::{env_count, env_ms};
use crate::error::{Error, Result};
use crate::instance::problem::GroupSource;
use crate::instance::shard::Shards;
use crate::mapreduce::Cluster;
use crate::obs::metrics::{Counter, Gauge, Histogram};
use crate::obs::{names, Track};
use crate::solver::config::ReduceMode;
use crate::solver::rounds::RoundAgg;
use crate::solver::scd::{ScdAcc, ScdRoundSpec, ThresholdAcc};
use crate::solver::stats::{MembershipChange, MembershipEvent};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Default per-exchange timeout. This is the *only* detector for a worker
/// that is silently partitioned (process death shows up immediately as
/// RST/EOF), so it must comfortably exceed the slowest honest chunk: at
/// N = 1e9 a chunk is ~N/64 groups, minutes of folding on a loaded box.
/// 10 minutes trades partition-detection latency for never killing a
/// healthy-but-slow fleet; tighten via `PALLAS_CLUSTER_TIMEOUT_MS` when
/// chunks are known to be fast.
const DEFAULT_TIMEOUT_MS: u64 = 600_000;

/// Default connect/handshake timeout (seconds, not minutes: planning must
/// reach its in-process fallback promptly when a fleet is blackholed).
const DEFAULT_CONNECT_TIMEOUT_MS: u64 = 5_000;

/// Default redial budget: 0 — self-healing is opt-in
/// (`PALLAS_CLUSTER_REDIALS`), so by default a failed worker stays failed
/// for the session and existing failure semantics (and chaos-replay
/// baselines) are byte-identical.
const DEFAULT_REDIALS: u64 = 0;

/// Default base redial backoff; doubles per failed attempt with
/// deterministic jitter, capped at [`REDIAL_BACKOFF_CAP_MS`].
const DEFAULT_REDIAL_BACKOFF_MS: u64 = 100;

/// Redial backoff cap: a flapping worker is probed at least this often.
const REDIAL_BACKOFF_CAP_MS: u64 = 30_000;

/// Default quorum floor: one live worker keeps the solve going (the
/// pre-elastic behavior).
const DEFAULT_MIN_WORKERS: u64 = 1;

/// Minimum live fleet before [`RelayFanout::Auto`] engages the two-level
/// tier: below this, a relay layer only adds a hop without shrinking the
/// leader's fan-in meaningfully.
const AUTO_RELAY_MIN_WORKERS: usize = 6;

/// The two-level reduce topology policy (`PALLAS_RELAY_FANOUT`). The
/// chunk partition and merge order are identical in every mode, so the
/// solve result is bit-identical flat or two-level — the policy only
/// moves where partials are combined.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RelayFanout {
    /// Single-level gather: the leader exchanges with every worker
    /// directly (`PALLAS_RELAY_FANOUT=flat|off|0`).
    Flat,
    /// Derive the fanout as ⌈√W⌉ leaves per relay from the live worker
    /// count, engaging only once the fleet reaches
    /// [`AUTO_RELAY_MIN_WORKERS`]. The default.
    Auto,
    /// Exactly this many leaves per relay; engages from 2 live workers.
    Leaves(usize),
}

impl RelayFanout {
    /// The environment-configured policy: unset/`auto` → [`Auto`],
    /// `flat`/`off`/`0` → [`Flat`], an integer n ≥ 1 → [`Leaves`]`(n)`
    /// (unparsable values fall back to [`Auto`]).
    ///
    /// [`Auto`]: RelayFanout::Auto
    /// [`Flat`]: RelayFanout::Flat
    /// [`Leaves`]: RelayFanout::Leaves
    pub fn from_env() -> Self {
        match std::env::var("PALLAS_RELAY_FANOUT").ok().as_deref() {
            Some("flat") | Some("off") | Some("0") => RelayFanout::Flat,
            Some("auto") | Some("") | None => RelayFanout::Auto,
            Some(v) => v
                .parse::<usize>()
                .ok()
                .filter(|&n| n >= 1)
                .map(RelayFanout::Leaves)
                .unwrap_or(RelayFanout::Auto),
        }
    }
}

/// How the leader waits on its per-round exchange.
///
/// Both modes use the identical chunk partition and merge partials in
/// chunk order, so the solve result is bit-identical either way; they
/// differ only in when the leader is *waiting*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Strict wave barriers: one chunk per live worker per wave, the
    /// next wave starts only after every exchange of the current one
    /// returned. The whole fleet idles on each wave's straggler, but
    /// leader and worker never have more than one frame outstanding per
    /// link — the most conservative flow control, and the mode whose
    /// per-link traces are totally ordered (the chaos suite pins it for
    /// its exact replay assertions).
    Wave,
    /// Overlapped gather: the round's whole chunk queue is dealt up
    /// front (round-robin over live workers, slot order) and each link
    /// keeps a small pipeline of tasks in flight, so a worker starts
    /// its next chunk the moment it finishes one instead of idling on
    /// the slowest peer. Stragglers only delay their own queue. This is
    /// the default; `PALLAS_EXCHANGE=wave` restores wave barriers (e.g.
    /// when frames are so large that pipelined task + partial bytes
    /// could both sit in kernel socket buffers at once).
    Overlap,
}

impl ExchangeMode {
    /// The environment-configured mode: `PALLAS_EXCHANGE=wave` or
    /// `overlap` (the default, also used for unset/unknown values).
    pub fn from_env() -> Self {
        match std::env::var("PALLAS_EXCHANGE").ok().as_deref() {
            Some("wave") => ExchangeMode::Wave,
            _ => ExchangeMode::Overlap,
        }
    }
}

/// Session timeout policy, resolved once at connect time. [`Default`]
/// reads the `PALLAS_CLUSTER_TIMEOUT_MS` / `PALLAS_CLUSTER_CONNECT_TIMEOUT_MS`
/// / `PALLAS_EXCHANGE` knobs; tests inject explicit values instead of
/// mutating the process environment.
#[derive(Debug, Clone, Copy)]
pub struct ConnectOptions {
    /// Bound on dial + handshake per worker.
    pub connect_timeout: Duration,
    /// Bound on each task/partial exchange for the rest of the session.
    pub exchange_timeout: Duration,
    /// Wave-barrier or overlapped gather (see [`ExchangeMode`]).
    pub exchange: ExchangeMode,
    /// Redial attempts allowed per link for the whole session
    /// (`PALLAS_CLUSTER_REDIALS`; 0 — the default — disables healing).
    /// The budget is *total*, not per outage, so a flapping worker
    /// cannot crash-redial-crash forever.
    pub redial_budget: u32,
    /// Base redial backoff (`PALLAS_CLUSTER_REDIAL_BACKOFF_MS`): the
    /// n-th consecutive failed redial of an outage waits
    /// `base · 2ⁿ` plus deterministic jitter, capped at 30 s.
    pub redial_backoff: Duration,
    /// Quorum floor (`PALLAS_MIN_WORKERS`): with fewer live workers the
    /// gather fails fast (typed error) instead of grinding on degraded;
    /// at or above it but below full strength the solve continues with a
    /// `Degraded` membership note.
    pub min_workers: usize,
    /// Two-level reduce policy (`PALLAS_RELAY_FANOUT`, see
    /// [`RelayFanout`]). Only effective on sessions with a retained
    /// transport ([`RemoteCluster::connect_elastic`]); the
    /// borrowed-transport path stays flat.
    pub relay_fanout: RelayFanout,
}

impl ConnectOptions {
    /// The environment-configured policy (documented defaults when the
    /// knobs are unset).
    pub fn from_env() -> Self {
        Self {
            connect_timeout: env_ms(
                "PALLAS_CLUSTER_CONNECT_TIMEOUT_MS",
                DEFAULT_CONNECT_TIMEOUT_MS,
            ),
            exchange_timeout: env_ms("PALLAS_CLUSTER_TIMEOUT_MS", DEFAULT_TIMEOUT_MS),
            exchange: ExchangeMode::from_env(),
            redial_budget: env_count("PALLAS_CLUSTER_REDIALS", DEFAULT_REDIALS).min(u32::MAX as u64)
                as u32,
            redial_backoff: env_ms(
                "PALLAS_CLUSTER_REDIAL_BACKOFF_MS",
                DEFAULT_REDIAL_BACKOFF_MS,
            ),
            min_workers: env_count("PALLAS_MIN_WORKERS", DEFAULT_MIN_WORKERS).max(1) as usize,
            relay_fanout: RelayFanout::from_env(),
        }
    }
}

impl Default for ConnectOptions {
    fn default() -> Self {
        Self::from_env()
    }
}

/// Point-in-time wire statistics of a [`RemoteCluster`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetSnapshot {
    /// Task bytes written to workers (frames included).
    pub bytes_sent: u64,
    /// Partial bytes read from workers (frames included).
    pub bytes_received: u64,
    /// Gather rounds completed.
    pub rounds: u64,
    /// Total time inside gathers, milliseconds (virtual under the
    /// simulator).
    pub round_ms: f64,
    /// Chunks re-dispatched after a worker loss.
    pub redispatches: u64,
    /// Workers lost during the session.
    pub workers_lost: u64,
    /// Successful redials of transiently-dead links.
    pub redials: u64,
    /// Workers admitted mid-solve through the join listener.
    pub joins: u64,
    /// Workers still live.
    pub workers_live: usize,
    /// Workers in the session: dial-time plus admitted.
    pub workers_total: usize,
    /// Advertised map-thread capacity across all session workers.
    pub capacity: usize,
    /// Protocol frames written by the leader (tasks, control).
    pub frames_sent: u64,
    /// Protocol frames read by the leader (partials, control replies).
    /// Under a relay topology this grows O(relays) per round instead of
    /// O(workers) — the observable the relay tier exists to shrink.
    pub frames_received: u64,
    /// Relays active in the current topology (0 when flat).
    pub relays: usize,
}

/// What one wave exchange produced (processed in deal order, so queue
/// re-adds and counters are deterministic).
enum WaveOutcome {
    /// The chunk's partial arrived.
    Done(usize, Msg),
    /// The worker in this slot died on this chunk; re-queue it for a
    /// survivor (and log the loss against the slot).
    Lost { slot: usize, chunk: usize, loss: String },
    /// A protocol-level abort: the round (and solve) must fail.
    Fatal(String),
}

/// Tasks in flight per link in overlapped gather (sent, reply not yet
/// read). Two is enough to hide the leader's reply-drain time behind the
/// worker's compute — the worker picks up task k+1 from its receive
/// buffer the instant it finishes k — while keeping at most one task
/// frame queued in kernel buffers per link.
const PIPELINE_DEPTH: usize = 2;

/// What one link's overlapped run of its dealt queue produced (processed
/// in slot order, so queue re-adds and counters are deterministic).
struct SlotRun {
    /// Partials that arrived, in task order.
    done: Vec<(usize, Msg)>,
    /// Chunks the dead link never answered (the failing chunk, then the
    /// rest of its pipeline, then its unsent queue — a deterministic
    /// order for re-dispatch).
    lost: Vec<usize>,
    /// Why the link died, when it did.
    loss: Option<String>,
    /// A protocol-level abort: the round (and solve) must fail.
    fatal: Option<String>,
}

impl SlotRun {
    fn new() -> Self {
        Self { done: Vec::new(), lost: Vec::new(), loss: None, fatal: None }
    }
}

/// The installed two-level topology: which slots are relays, which leaf
/// slots each relay was dealt (in `RelayAssign` order — `RelayPartial`
/// loss reports index this list, so dead or unreached leaves keep their
/// position), and which slots still exchange directly with the leader.
#[derive(Clone)]
struct Topology {
    /// `(relay slot, leaf slots in assignment order)` per subtree.
    subtrees: Vec<(usize, Vec<usize>)>,
    /// Slots the leader exchanges with directly (demoted relays whose
    /// whole subtree was unreachable land here too).
    direct: Vec<usize>,
    /// `(alive slots, fanout)` the topology was built for — any
    /// membership or policy change misses this stamp and forces a
    /// rebuild at the next deal boundary.
    stamp: (Vec<usize>, usize),
}

/// One relay candidate for [`plan_topology`]: everything the placement
/// needs to know about an alive slot, decoupled from the link structs so
/// the planner is a pure, unit-testable function.
struct TopoSlot {
    slot: usize,
    /// The leader currently holds this slot's stream (promoting it to
    /// relay costs nothing; a delegated slot would need a reattach dial).
    live_stream: bool,
    /// Shard-index span `[lo, hi)` the worker's store replica advertises.
    span: (u64, u64),
    /// Already serving as a relay — preferred, to keep placements sticky
    /// across rebuilds that don't change the fleet shape.
    is_relay_now: bool,
}

/// What [`plan_topology`] decided (slot indices only — the leader turns
/// it into an installed [`Topology`] by reattaching, detaching and
/// dealing `RelayAssign`s).
struct TopologyPlan {
    subtrees: Vec<(usize, Vec<usize>)>,
    direct: Vec<usize>,
}

/// What one relay exchange pass produced (processed in deal order).
struct RelayRun {
    /// Aggregate partials that arrived: `(first chunk, chunk span,
    /// subtree-merged partial)`.
    done: Vec<(usize, usize, Msg)>,
    /// Chunks the dead relay never answered, for re-dispatch.
    lost_chunks: Vec<usize>,
    /// Leaf *slots* the relay reported lost while recovering (their work
    /// was recomputed relay-side — membership bookkeeping only, no
    /// re-dispatch).
    leaf_losses: Vec<usize>,
    /// Why the relay died, when it did.
    loss: Option<String>,
    /// A protocol-level abort: the round (and solve) must fail.
    fatal: Option<String>,
}

impl RelayRun {
    fn new() -> Self {
        Self {
            done: Vec::new(),
            lost_chunks: Vec::new(),
            leaf_losses: Vec::new(),
            loss: None,
            fatal: None,
        }
    }
}

/// What one hierarchical uplink produced (a relay's aggregate run or a
/// direct slot's per-chunk run).
enum HierRun {
    Relay(usize, RelayRun),
    Direct(usize, SlotRun),
}

/// Pure relay placement over the alive slots. Subtree `i` of `r` is
/// nominally responsible for shards `[i·S/r, (i+1)·S/r)`; its relay is
/// the unused streamed candidate preferring (1) a replica span covering
/// that range — the relay can recompute any leaf loss from local data —
/// then (2) an incumbent relay, then (3) the lowest slot, so the plan is
/// deterministic. Remaining candidates become leaves, round-robin in
/// slot order (hot-joins land in the emptiest subtree); with no relays
/// everyone exchanges directly.
fn plan_topology(cands: &[TopoSlot], fanout: usize, n_shards: usize) -> TopologyPlan {
    let w = cands.len();
    let streamed = cands.iter().filter(|c| c.live_stream).count();
    let want_r = w.div_ceil(fanout.max(1) + 1);
    let r = if w < 2 { 0 } else { want_r.min(streamed) };
    if r == 0 {
        return TopologyPlan {
            subtrees: Vec::new(),
            direct: cands.iter().map(|c| c.slot).collect(),
        };
    }
    let mut used = vec![false; w];
    let mut subtrees: Vec<(usize, Vec<usize>)> = Vec::with_capacity(r);
    for i in 0..r {
        let (range_lo, range_hi) = (
            (i * n_shards / r) as u64,
            ((i + 1) * n_shards / r) as u64,
        );
        let pick = cands
            .iter()
            .enumerate()
            .filter(|(ci, c)| !used[*ci] && c.live_stream)
            .min_by_key(|(_, c)| {
                let covers = c.span.0 <= range_lo && c.span.1 >= range_hi;
                (!covers as u8, !c.is_relay_now as u8, c.slot)
            });
        match pick {
            Some((ci, c)) => {
                used[ci] = true;
                subtrees.push((c.slot, Vec::new()));
            }
            None => break,
        }
    }
    if subtrees.is_empty() {
        return TopologyPlan {
            subtrees: Vec::new(),
            direct: cands.iter().map(|c| c.slot).collect(),
        };
    }
    let n_sub = subtrees.len();
    for (i, leaf) in cands
        .iter()
        .enumerate()
        .filter(|(ci, _)| !used[*ci])
        .map(|(_, c)| c.slot)
        .enumerate()
    {
        subtrees[i % n_sub].1.push(leaf);
    }
    TopologyPlan { subtrees, direct: Vec::new() }
}

/// Leader-side registry handles, resolved once per session so the hot
/// exchange paths bump atomics and never look a metric up by name
/// ([`crate::obs::metrics`]). Per-link breakdowns live in the span trace
/// (one `link/<slot>` track each); the registry carries the fleet-wide
/// aggregates a scrape wants.
struct LeaderObs {
    exchanges: Arc<Counter>,
    exchange_latency_ns: Arc<Histogram>,
    exchange_bytes: Arc<Histogram>,
    redeals: Arc<Counter>,
    workers_lost: Arc<Counter>,
    gather_rounds: Arc<Counter>,
    gather_latency_ns: Arc<Histogram>,
    redials: Arc<Counter>,
    joins: Arc<Counter>,
    degraded: Arc<Counter>,
    relays_active: Arc<Gauge>,
    relay_assigns: Arc<Counter>,
    relay_partials: Arc<Counter>,
    relay_leaf_losses: Arc<Counter>,
}

impl LeaderObs {
    fn new() -> Self {
        let r = crate::obs::metrics::global();
        Self {
            exchanges: r.counter("bskp_cluster_exchanges_total"),
            exchange_latency_ns: r.histogram("bskp_cluster_exchange_latency_ns"),
            exchange_bytes: r.histogram("bskp_cluster_exchange_bytes"),
            redeals: r.counter("bskp_cluster_redeals_total"),
            workers_lost: r.counter("bskp_cluster_workers_lost_total"),
            gather_rounds: r.counter("bskp_cluster_gather_rounds_total"),
            gather_latency_ns: r.histogram("bskp_cluster_gather_latency_ns"),
            redials: r.counter("bskp_cluster_redials_total"),
            joins: r.counter("bskp_cluster_joins_total"),
            degraded: r.counter("bskp_cluster_degraded_total"),
            relays_active: r.gauge("bskp_cluster_relays_active"),
            relay_assigns: r.counter("bskp_cluster_relay_assigns_total"),
            relay_partials: r.counter("bskp_cluster_relay_partials_total"),
            relay_leaf_losses: r.counter("bskp_cluster_relay_leaf_losses_total"),
        }
    }
}

/// A fleet of `pallas worker` processes, driven over a [`Transport`] with
/// the same map→combine→reduce contract as the in-process
/// [`Cluster`] (see [`super::Exec`]).
pub struct RemoteCluster {
    /// Worker links: dial-time slots first, mid-solve admissions
    /// appended. Only [`RemoteCluster::admit_joiners`] ever grows the
    /// vector, and only at a deal boundary.
    slots: RwLock<Vec<Arc<Mutex<WorkerLink>>>>,
    leader_pool: Cluster,
    counters: NetCounters,
    clock: Arc<dyn Clock>,
    opts: ConnectOptions,
    fingerprint: InstanceFingerprint,
    /// Retained dialer for round-boundary redials; `None` on the
    /// borrowed-transport [`RemoteCluster::connect_with`] path, where
    /// healing is structurally off.
    transport: Option<Arc<dyn Transport>>,
    /// Mid-solve join listener, when the session runs one.
    join: Option<Mutex<Box<dyn NetListener>>>,
    /// Membership changes in occurrence order (drained into
    /// `SolveReport::membership`).
    events: Mutex<Vec<MembershipEvent>>,
    /// Live count at the last `Degraded` note (`usize::MAX` at full
    /// strength) — dedupes the note to strength *transitions*, not
    /// rounds.
    degraded_live: AtomicUsize,
    /// The current two-level topology, rebuilt lazily at deal boundaries
    /// when membership or policy changes (`None` — flat — until the relay
    /// tier first engages).
    topology: Mutex<Option<Topology>>,
    /// Subtree count of the current topology (mirrors the
    /// `bskp_cluster_relays_active` gauge for [`RemoteCluster::stats`]).
    relays_active: AtomicUsize,
    obs: LeaderObs,
}

impl RemoteCluster {
    /// Connect over TCP to `addrs` and handshake each against `source`'s
    /// fingerprint, with environment-configured timeouts. Unreachable or
    /// mismatched workers are skipped with a human-readable note;
    /// connecting to **zero** workers is the only hard error (callers
    /// fall back to the in-process pool on it).
    pub fn connect<S: GroupSource + ?Sized>(
        addrs: &[String],
        source: &S,
    ) -> Result<(Self, Vec<String>)> {
        Self::connect_elastic(
            Arc::new(TcpTransport),
            addrs,
            source,
            ConnectOptions::from_env(),
            None,
        )
    }

    /// [`RemoteCluster::connect`] over a borrowed [`Transport`] and an
    /// explicit timeout policy. The transport cannot be retained past the
    /// call, so this session never redials and never admits joiners —
    /// the pre-elastic contract, which parts of the chaos suite pin.
    /// Elastic sessions use [`RemoteCluster::connect_elastic`].
    pub fn connect_with<S: GroupSource + ?Sized>(
        transport: &dyn Transport,
        addrs: &[String],
        source: &S,
        opts: ConnectOptions,
    ) -> Result<(Self, Vec<String>)> {
        Self::connect_inner(transport, None, addrs, source, opts, None)
    }

    /// [`RemoteCluster::connect`] with the full elastic feature set: the
    /// transport is retained for round-boundary redials
    /// (`opts.redial_budget`), and `join`, when given, is polled at every
    /// deal boundary for mid-solve worker admissions.
    pub fn connect_elastic<S: GroupSource + ?Sized>(
        transport: Arc<dyn Transport>,
        addrs: &[String],
        source: &S,
        opts: ConnectOptions,
        join: Option<Box<dyn NetListener>>,
    ) -> Result<(Self, Vec<String>)> {
        Self::connect_inner(transport.as_ref(), Some(Arc::clone(&transport)), addrs, source, opts, join)
    }

    fn connect_inner<S: GroupSource + ?Sized>(
        transport: &dyn Transport,
        retained: Option<Arc<dyn Transport>>,
        addrs: &[String],
        source: &S,
        opts: ConnectOptions,
        join: Option<Box<dyn NetListener>>,
    ) -> Result<(Self, Vec<String>)> {
        let fingerprint = InstanceFingerprint::of(source);
        // dial concurrently: N blackholed hosts must cost one connect
        // timeout, not N, before planning can fall back in-process
        let dials: Vec<Result<WorkerLink>> = std::thread::scope(|s| {
            let handles: Vec<_> = addrs
                .iter()
                .map(|addr| {
                    let fingerprint = &fingerprint;
                    s.spawn(move || WorkerLink::connect(transport, addr, fingerprint, opts))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(Error::Runtime("worker dial thread panicked".into()))
                    })
                })
                .collect()
        });
        let mut slots = Vec::new();
        let mut skipped = Vec::new();
        for (addr, dial) in addrs.iter().zip(dials) {
            match dial {
                Ok(link) => slots.push(Arc::new(Mutex::new(link))),
                Err(e) => skipped.push(format!("worker {addr} skipped: {e}")),
            }
        }
        if slots.is_empty() {
            return Err(Error::Runtime(format!(
                "no cluster workers reachable at [{}]{}",
                addrs.join(", "),
                skipped
                    .iter()
                    .map(|s| format!("; {s}"))
                    .collect::<String>(),
            )));
        }
        let fleet = Self {
            slots: RwLock::new(slots),
            leader_pool: Cluster::configured(),
            counters: NetCounters::default(),
            clock: transport.clock(),
            opts,
            fingerprint,
            transport: retained,
            join: join.map(Mutex::new),
            events: Mutex::new(Vec::new()),
            degraded_live: AtomicUsize::new(usize::MAX),
            topology: Mutex::new(None),
            relays_active: AtomicUsize::new(0),
            obs: LeaderObs::new(),
        };
        Ok((fleet, skipped))
    }

    /// Replace the pool used for leader-local phases (§5.3 pre-solve
    /// sampling, §5.4's sequential walk). The session planner threads the
    /// session's own `--workers` pool through here so distributed solves
    /// honor it; the default is [`Cluster::configured`].
    pub fn with_leader_pool(mut self, pool: Cluster) -> Self {
        self.leader_pool = pool;
        self
    }

    /// Workers in the session: dial-time plus admitted joiners.
    pub fn workers(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    /// Workers still live (directly linked or delegated to a relay).
    pub fn workers_live(&self) -> usize {
        self.slots.read().unwrap().iter().filter(|s| s.lock().unwrap().is_alive()).count()
    }

    /// Total advertised map-thread capacity (drives shard planning).
    pub fn capacity(&self) -> usize {
        self.slots.read().unwrap().iter().map(|s| s.lock().unwrap().threads).sum()
    }

    /// The session's worker addresses (dial-time plus admitted).
    pub fn addrs(&self) -> Vec<String> {
        self.slots.read().unwrap().iter().map(|s| s.lock().unwrap().addr.clone()).collect()
    }

    /// Membership changes so far (losses, redials, admissions,
    /// degradations), in occurrence order — the session planner attaches
    /// them to `SolveReport::membership`.
    pub fn membership_events(&self) -> Vec<MembershipEvent> {
        self.events.lock().unwrap().clone()
    }

    fn push_event(&self, event: MembershipEvent) {
        self.events.lock().unwrap().push(event);
    }

    /// The leader-local pool used for the phases that stay on the leader
    /// (§5.3 pre-solve sampling, the sequential part of §5.4).
    pub(crate) fn leader_pool(&self) -> &Cluster {
        &self.leader_pool
    }

    /// Wire statistics so far.
    pub fn stats(&self) -> NetSnapshot {
        let c = &self.counters;
        let slots = self.slots.read().unwrap();
        let (mut workers_live, mut capacity) = (0, 0);
        for slot in slots.iter() {
            let link = slot.lock().unwrap();
            workers_live += link.is_alive() as usize;
            capacity += link.threads;
        }
        NetSnapshot {
            bytes_sent: c.bytes_sent.load(Ordering::Relaxed),
            bytes_received: c.bytes_received.load(Ordering::Relaxed),
            rounds: c.rounds.load(Ordering::Relaxed),
            round_ms: c.round_us.load(Ordering::Relaxed) as f64 / 1e3,
            redispatches: c.redispatches.load(Ordering::Relaxed),
            workers_lost: c.workers_lost.load(Ordering::Relaxed),
            redials: c.redials.load(Ordering::Relaxed),
            joins: c.joins.load(Ordering::Relaxed),
            workers_live,
            workers_total: slots.len(),
            capacity,
            frames_sent: c.frames_sent.load(Ordering::Relaxed),
            frames_received: c.frames_received.load(Ordering::Relaxed),
            relays: self.relays_active.load(Ordering::Relaxed),
        }
    }

    /// Round-boundary healing: redial every transiently-dead link whose
    /// backoff deadline has passed, while its session budget lasts. A
    /// successful redial re-enters the deal from this round on; a dial
    /// failure schedules the next probe on the exponential-backoff curve
    /// (deterministic jitter, seeded by the slot); a handshake refusal
    /// retires the link for good. No-op without a budget or without a
    /// retained transport (the [`RemoteCluster::connect_with`] path).
    fn heal(&self, round: u64) {
        if self.opts.redial_budget == 0 {
            return;
        }
        let Some(transport) = self.transport.as_ref() else { return };
        let slots = self.slots.read().unwrap().clone();
        for (slot, link) in slots.iter().enumerate() {
            let mut link = link.lock().unwrap();
            // is_alive, not is_live: a delegated leaf's stream was handed
            // to its relay on purpose — redialing it would steal it back
            if link.is_alive()
                || link.permanent
                || link.redials_spent >= self.opts.redial_budget
                || self.clock.now_ns() < link.next_redial_at_ns
            {
                continue;
            }
            link.redials_spent += 1;
            match link.redial(transport.as_ref(), &self.fingerprint, self.opts) {
                Ok(()) => {
                    self.counters.count(&self.counters.redials, 1);
                    if crate::obs::metrics_enabled() {
                        self.obs.redials.inc();
                    }
                    crate::obs::instant(
                        self.clock.as_ref(),
                        Track::Leader,
                        names::REDIAL,
                        round,
                        slot as u64,
                    );
                    self.push_event(MembershipEvent {
                        round,
                        worker: Some(slot),
                        change: MembershipChange::Redialed,
                        detail: format!(
                            "worker {} redialed ({} of {} redials spent)",
                            link.addr, link.redials_spent, self.opts.redial_budget
                        ),
                    });
                }
                Err(e) => {
                    let delay = Backoff::delay(
                        self.opts.redial_backoff,
                        Duration::from_millis(REDIAL_BACKOFF_CAP_MS),
                        slot as u64,
                        link.attempts,
                    );
                    link.attempts = link.attempts.saturating_add(1);
                    link.next_redial_at_ns =
                        self.clock.now_ns().saturating_add(delay.as_nanos() as u64);
                    if link.permanent {
                        self.push_event(MembershipEvent {
                            round,
                            worker: Some(slot),
                            change: MembershipChange::Lost,
                            detail: format!("worker {} retired: {e}", link.addr),
                        });
                    }
                }
            }
        }
    }

    /// The earliest future redial deadline among still-healable links —
    /// what the quorum wait sleeps to (virtual time under the simulator).
    /// `None` when no dead link can come back: healing off, transport not
    /// retained, or every dead link permanent / out of budget.
    fn earliest_redial(&self, slots: &[Arc<Mutex<WorkerLink>>]) -> Option<u64> {
        if self.opts.redial_budget == 0 || self.transport.is_none() {
            return None;
        }
        slots
            .iter()
            .filter_map(|slot| {
                let link = slot.lock().unwrap();
                (!link.is_alive()
                    && !link.permanent
                    && link.redials_spent < self.opts.redial_budget)
                    .then_some(link.next_redial_at_ns)
            })
            .min()
    }

    /// Emit a `Degraded` membership note when the live count *transitions*
    /// while below full strength (the `degraded_live` latch dedupes the
    /// note to transitions, not rounds), clearing the latch once the fleet
    /// is whole again.
    fn note_degraded(&self, round: u64, live: usize, total: usize) {
        if live >= total {
            self.degraded_live.store(usize::MAX, Ordering::Relaxed);
            return;
        }
        if self.degraded_live.swap(live, Ordering::Relaxed) != live {
            if crate::obs::metrics_enabled() {
                self.obs.degraded.inc();
            }
            crate::obs::instant(
                self.clock.as_ref(),
                Track::Leader,
                names::DEGRADED,
                round,
                live as u64,
            );
            self.push_event(MembershipEvent {
                round,
                worker: None,
                change: MembershipChange::Degraded,
                detail: format!("continuing degraded: {live} of {total} workers live"),
            });
        }
    }

    /// Drain the mid-solve join listener: every queued `bskp worker
    /// --join` dial-in that passes the version (frame layer) and
    /// fingerprint checks becomes a fresh slot and receives chunks from
    /// this deal on. Non-blocking — an idle listener costs one poll per
    /// deal boundary.
    fn admit_joiners(&self, round: u64) {
        let Some(join) = self.join.as_ref() else { return };
        loop {
            let polled = join.lock().unwrap().poll_accept();
            match polled {
                Ok(Some(stream)) => self.admit_one(round, stream),
                // transient accept failures retry at the next boundary
                Ok(None) | Err(_) => return,
            }
        }
    }

    fn admit_one(&self, round: u64, stream: Box<dyn NetStream>) {
        match self.join_handshake(stream) {
            Ok((threads, span, stream)) => {
                let addr = stream.peer();
                let slot = {
                    let mut slots = self.slots.write().unwrap();
                    slots.push(Arc::new(Mutex::new(WorkerLink::admitted(
                        addr.clone(),
                        threads as usize,
                        span,
                        stream,
                    ))));
                    slots.len() - 1
                };
                self.counters.count(&self.counters.joins, 1);
                if crate::obs::metrics_enabled() {
                    self.obs.joins.inc();
                }
                crate::obs::instant(
                    self.clock.as_ref(),
                    Track::Leader,
                    names::JOIN,
                    round,
                    slot as u64,
                );
                self.push_event(MembershipEvent {
                    round,
                    worker: Some(slot),
                    change: MembershipChange::Admitted,
                    detail: format!("worker {addr} joined mid-solve ({threads} threads)"),
                });
            }
            Err(e) => {
                // a refused joiner never becomes a slot; note it for the
                // membership log so operators see the refusal
                self.push_event(MembershipEvent {
                    round,
                    worker: None,
                    change: MembershipChange::Lost,
                    detail: format!("join refused: {e}"),
                });
            }
        }
    }

    /// The leader half of the mid-solve admission handshake: expect
    /// `Join` (capacity + fingerprint), verify the fingerprint, reply
    /// `Admit`, and install the session's exchange timeouts. Version skew
    /// is caught by the frame layer before the message even decodes.
    fn join_handshake(
        &self,
        mut stream: Box<dyn NetStream>,
    ) -> Result<(u32, (u64, u64), Box<dyn NetStream>)> {
        stream.set_read_timeout(Some(self.opts.connect_timeout))?;
        stream.set_write_timeout(Some(self.opts.connect_timeout))?;
        let (msg, _) = recv_msg(&mut stream)?;
        let (threads, theirs, span) = match msg {
            Msg::Join { threads, fingerprint, shard_lo, shard_hi } => {
                (threads, fingerprint, (shard_lo, shard_hi))
            }
            other => {
                let _ = send_msg(
                    &mut stream,
                    &Msg::Abort { message: format!("expected join, got {}", other.name()) },
                );
                return Err(Error::Runtime(format!(
                    "joiner opened with {} instead of join",
                    other.name()
                )));
            }
        };
        if theirs != self.fingerprint {
            let message = format!(
                "joiner serves a different instance: leader has [{}], joiner has [{theirs}]",
                self.fingerprint
            );
            let _ = send_msg(&mut stream, &Msg::Abort { message: message.clone() });
            return Err(Error::Runtime(message));
        }
        send_msg(&mut stream, &Msg::Admit)?;
        stream.set_read_timeout(Some(self.opts.exchange_timeout))?;
        stream.set_write_timeout(Some(self.opts.exchange_timeout))?;
        Ok((threads, span, stream))
    }

    /// Dispatch one round: cut `[0, n_shards)` into chunks, deal them to
    /// live workers, gather the partials **indexed by chunk** — wave by
    /// wave or overlapped, per the session's [`ExchangeMode`] (the
    /// partition, the merge order and therefore the result are identical
    /// either way). Lost workers re-queue their chunks; the round only
    /// fails when no live worker remains (or a worker reports a
    /// protocol-level abort).
    fn gather<F>(&self, n_shards: usize, task: F) -> Result<Vec<Msg>>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        if n_shards == 0 {
            return Ok(Vec::new());
        }
        let t0 = self.clock.now_ns();
        // the gather ordinal doubles as the round index in span-context
        // frame extensions and EXCHANGE span arguments
        let round = self.counters.rounds.load(Ordering::Relaxed);
        let (per, n_chunks) =
            crate::cluster::chunk_plan(n_shards, crate::cluster::CHUNKS_PER_ROUND);
        let mut pending: VecDeque<usize> = (0..n_chunks).collect();
        let mut results: Vec<Option<Msg>> = (0..n_chunks).map(|_| None).collect();
        // subtree aggregates from relay exchanges: (first chunk, chunk
        // span, merged partial) — kept apart from `results` because one
        // entry covers a contiguous run of chunks
        let mut hier_done: Vec<(usize, usize, Msg)> = Vec::new();
        let mut last_loss = String::new();

        while !pending.is_empty() {
            // every membership change happens here, at the deal boundary:
            // drain the join listener, redial transiently-dead links whose
            // backoff elapsed, then revalidate the relay topology — so the
            // deal below stays a pure function of (pending, topology) and
            // sim traces stay replayable
            self.admit_joiners(round);
            self.heal(round);
            let topology = self.ensure_topology(round, n_shards);
            let slots: Vec<Arc<Mutex<WorkerLink>>> = self.slots.read().unwrap().clone();
            let live: Vec<usize> =
                (0..slots.len()).filter(|&i| slots[i].lock().unwrap().is_alive()).collect();
            if live.is_empty() || live.len() < self.opts.min_workers {
                // healing may still restore quorum: wait out the earliest
                // redial deadline (a virtual sleep under sim) and retry
                if let Some(at_ns) = self.earliest_redial(&slots) {
                    let now = self.clock.now_ns();
                    self.clock
                        .sleep(Duration::from_nanos(at_ns.saturating_sub(now).max(1)));
                    continue;
                }
                let done = results.iter().filter(|r| r.is_some()).count()
                    + hier_done.iter().map(|&(_, span, _)| span).sum::<usize>();
                let failure = if last_loss.is_empty() {
                    String::new()
                } else {
                    format!("; last failure: {last_loss}")
                };
                if live.is_empty() {
                    return Err(Error::Runtime(format!(
                        "all cluster workers lost mid-round ({done} of {n_chunks} chunks \
                         done){failure}",
                    )));
                }
                return Err(Error::Runtime(format!(
                    "cluster quorum lost: {} of {} workers live, below the \
                     PALLAS_MIN_WORKERS floor of {} ({done} of {n_chunks} chunks \
                     done){failure}",
                    live.len(),
                    slots.len(),
                    self.opts.min_workers,
                )));
            }
            self.note_degraded(round, live.len(), slots.len());
            if let Some(topo) = topology {
                self.hier_step(
                    round,
                    per,
                    n_shards,
                    &slots,
                    &topo,
                    &mut pending,
                    &mut results,
                    &mut hier_done,
                    &mut last_loss,
                    &task,
                )?;
                continue;
            }
            // flat: ensure_topology flattened any prior relay tier, so no
            // alive slot is delegated here and is_alive == is_live
            match self.opts.exchange {
                ExchangeMode::Wave => self.wave_step(
                    round,
                    per,
                    n_shards,
                    &slots,
                    &live,
                    &mut pending,
                    &mut results,
                    &mut last_loss,
                    &task,
                )?,
                ExchangeMode::Overlap => self.overlap_step(
                    round,
                    per,
                    n_shards,
                    &slots,
                    &live,
                    &mut pending,
                    &mut results,
                    &mut last_loss,
                    &task,
                )?,
            }
        }

        self.counters.count(&self.counters.rounds, 1);
        let dur_ns = self.clock.now_ns().saturating_sub(t0);
        self.counters.count(&self.counters.round_us, dur_ns / 1_000);
        if crate::obs::metrics_enabled() {
            self.obs.gather_rounds.inc();
            self.obs.gather_latency_ns.observe(dur_ns);
        }
        // assemble in ascending chunk order: per-chunk partials and
        // subtree aggregates interleave on the same global chunk grid, so
        // the caller's in-order merge folds the identical operand
        // sequence a flat gather would have produced
        let mut assembled: Vec<(usize, Msg)> = results
            .into_iter()
            .enumerate()
            .filter_map(|(chunk, r)| r.map(|msg| (chunk, msg)))
            .collect();
        assembled.extend(hier_done.into_iter().map(|(chunk, _, msg)| (chunk, msg)));
        assembled.sort_by_key(|&(chunk, _)| chunk);
        Ok(assembled.into_iter().map(|(_, msg)| msg).collect())
    }

    /// One wave: one pending chunk per live worker, a barrier, then the
    /// outcomes in deal order.
    #[allow(clippy::too_many_arguments)]
    fn wave_step<F>(
        &self,
        round: u64,
        per: usize,
        n_shards: usize,
        slots: &[Arc<Mutex<WorkerLink>>],
        live: &[usize],
        pending: &mut VecDeque<usize>,
        results: &mut [Option<Msg>],
        last_loss: &mut String,
        task: &F,
    ) -> Result<()>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        // the wave deal: one pending chunk per live worker, slot
        // order — a pure function of (pending, live)
        let deals: Vec<(usize, usize)> = live
            .iter()
            .map_while(|&slot| pending.pop_front().map(|chunk| (slot, chunk)))
            .collect();
        let trace_on = crate::obs::trace_enabled();
        let want_obs = trace_on || crate::obs::metrics_enabled();
        let ext = span_ext::encode_task(round, trace_on);
        let outcomes: Vec<WaveOutcome> = std::thread::scope(|s| {
            let handles: Vec<_> = deals
                .iter()
                .map(|&(slot, chunk)| {
                    let ext = &ext;
                    s.spawn(move || {
                        let lo = chunk * per;
                        let hi = (lo + per).min(n_shards);
                        let mut link = slots[slot].lock().unwrap();
                        let t0 = if want_obs { self.clock.now_ns() } else { 0 };
                        let result = link
                            .send_task(&task(lo, hi), ext, &self.counters)
                            .and_then(|()| link.recv_partial(&self.counters));
                        match result {
                            Ok((Msg::Abort { message }, _, _)) => WaveOutcome::Fatal(format!(
                                "worker {} aborted the round: {message}",
                                link.addr
                            )),
                            Ok((reply, reply_ext, received)) => {
                                if want_obs {
                                    self.observe_exchange(
                                        slot,
                                        round,
                                        lo as u64,
                                        t0,
                                        received,
                                        reply_ext.as_ref(),
                                    );
                                }
                                WaveOutcome::Done(chunk, reply)
                            }
                            Err(e) => {
                                // dead worker: back on the queue for
                                // a survivor in the next wave
                                link.kill();
                                WaveOutcome::Lost {
                                    slot,
                                    chunk,
                                    loss: format!("worker {}: {e}", link.addr),
                                }
                            }
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        WaveOutcome::Fatal("worker exchange thread panicked".into())
                    })
                })
                .collect()
        });
        for outcome in outcomes {
            match outcome {
                WaveOutcome::Done(chunk, reply) => results[chunk] = Some(reply),
                WaveOutcome::Lost { slot, chunk, loss } => {
                    self.push_event(MembershipEvent {
                        round,
                        worker: Some(slot),
                        change: MembershipChange::Lost,
                        detail: loss.clone(),
                    });
                    *last_loss = loss;
                    self.note_loss(round, per, std::slice::from_ref(&chunk));
                    pending.push_back(chunk);
                    self.counters.count(&self.counters.workers_lost, 1);
                    self.counters.count(&self.counters.redispatches, 1);
                }
                WaveOutcome::Fatal(message) => return Err(Error::Runtime(message)),
            }
        }
        Ok(())
    }

    /// Record one finished exchange: fleet-wide registry metrics plus —
    /// when tracing — the per-link `EXCHANGE` span and the worker's
    /// shipped task span, re-based onto the leader clock so it ends at
    /// receipt (the wire carries only the code and duration; round and
    /// chunk come from the in-flight task it matches).
    fn observe_exchange(
        &self,
        slot: usize,
        round: u64,
        lo: u64,
        t0_ns: u64,
        bytes: usize,
        reply_ext: Option<&[u8; EXT_LEN]>,
    ) {
        let now = self.clock.now_ns();
        let dur_ns = now.saturating_sub(t0_ns);
        if crate::obs::metrics_enabled() {
            self.obs.exchanges.inc();
            self.obs.exchange_latency_ns.observe(dur_ns);
            self.obs.exchange_bytes.observe(bytes as u64);
        }
        if crate::obs::trace_enabled() {
            let track = Track::Link(slot as u16);
            crate::obs::complete(track, names::EXCHANGE, t0_ns, dur_ns, round, lo);
            if let Some(ext) = reply_ext {
                let (code, w_dur) = span_ext::decode_span(ext);
                crate::obs::complete(track, code, now.saturating_sub(w_dur), w_dur, round, lo);
            }
        }
    }

    /// Record chunks going back on the deal queue after a worker loss:
    /// a `REDEAL` instant per chunk plus the fleet-wide counters.
    fn note_loss(&self, round: u64, per: usize, chunks: &[usize]) {
        if crate::obs::metrics_enabled() {
            self.obs.workers_lost.inc();
            self.obs.redeals.add(chunks.len() as u64);
        }
        for &chunk in chunks {
            crate::obs::instant(
                self.clock.as_ref(),
                Track::Leader,
                names::REDEAL,
                round,
                (chunk * per) as u64,
            );
        }
    }

    /// One overlapped pass: deal the *whole* pending queue round-robin
    /// over the live workers (slot order — a pure function of
    /// `(pending, live)`, like the wave deal), then run every link's
    /// queue concurrently with a [`PIPELINE_DEPTH`]-deep task pipeline
    /// per link. Outcomes are processed in slot order, so counter
    /// updates and the re-queue order of lost chunks are deterministic;
    /// partials land indexed by chunk, so the merge (and the solve
    /// result) is bit-identical to wave mode.
    #[allow(clippy::too_many_arguments)]
    fn overlap_step<F>(
        &self,
        round: u64,
        per: usize,
        n_shards: usize,
        slots: &[Arc<Mutex<WorkerLink>>],
        live: &[usize],
        pending: &mut VecDeque<usize>,
        results: &mut [Option<Msg>],
        last_loss: &mut String,
        task: &F,
    ) -> Result<()>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        let mut queues: Vec<Vec<usize>> = vec![Vec::new(); live.len()];
        for (i, chunk) in pending.drain(..).enumerate() {
            queues[i % live.len()].push(chunk);
        }
        let runs: Vec<SlotRun> = std::thread::scope(|s| {
            let handles: Vec<_> = live
                .iter()
                .zip(&queues)
                .map(|(&slot, queue)| {
                    s.spawn(move || self.run_slot(slots, slot, round, queue, per, n_shards, task))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        let mut run = SlotRun::new();
                        run.fatal = Some("worker exchange thread panicked".into());
                        run
                    })
                })
                .collect()
        });
        for (run, &slot) in runs.into_iter().zip(live) {
            if let Some(message) = run.fatal {
                return Err(Error::Runtime(message));
            }
            for (chunk, reply) in run.done {
                results[chunk] = Some(reply);
            }
            if let Some(loss) = run.loss {
                self.push_event(MembershipEvent {
                    round,
                    worker: Some(slot),
                    change: MembershipChange::Lost,
                    detail: loss.clone(),
                });
                *last_loss = loss;
                self.counters.count(&self.counters.workers_lost, 1);
                self.counters.count(&self.counters.redispatches, run.lost.len() as u64);
                self.note_loss(round, per, &run.lost);
                for chunk in run.lost {
                    pending.push_back(chunk);
                }
            }
        }
        Ok(())
    }

    /// Drive one link through its dealt queue with up to
    /// [`PIPELINE_DEPTH`] tasks in flight: fill the pipeline, read the
    /// oldest partial, refill. The wire stays strict request/response
    /// (every send is balanced by one receive, replies arrive in task
    /// order); only the leader's waiting overlaps with the worker's
    /// compute. Any wire error kills the link and reports every
    /// unanswered chunk as lost, in a deterministic order.
    #[allow(clippy::too_many_arguments)]
    fn run_slot<F>(
        &self,
        slots: &[Arc<Mutex<WorkerLink>>],
        slot: usize,
        round: u64,
        queue: &[usize],
        per: usize,
        n_shards: usize,
        task: &F,
    ) -> SlotRun
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        let trace_on = crate::obs::trace_enabled();
        let want_obs = trace_on || crate::obs::metrics_enabled();
        let ext = span_ext::encode_task(round, trace_on);
        let mut run = SlotRun::new();
        let mut link = slots[slot].lock().unwrap();
        // in-flight chunks with their send instants: a pipelined chunk's
        // exchange latency is its full turnaround, send to reply
        let mut inflight: VecDeque<(usize, u64)> = VecDeque::new();
        let mut next = 0usize;
        loop {
            while inflight.len() < PIPELINE_DEPTH && next < queue.len() {
                let chunk = queue[next];
                let lo = chunk * per;
                let hi = (lo + per).min(n_shards);
                let t_sent = if want_obs { self.clock.now_ns() } else { 0 };
                match link.send_task(&task(lo, hi), &ext, &self.counters) {
                    Ok(()) => {
                        inflight.push_back((chunk, t_sent));
                        next += 1;
                    }
                    Err(e) => {
                        link.kill();
                        run.loss = Some(format!("worker {}: {e}", link.addr));
                        run.lost.push(chunk);
                        run.lost.extend(inflight.drain(..).map(|(c, _)| c));
                        run.lost.extend(queue[next + 1..].iter().copied());
                        return run;
                    }
                }
            }
            let Some((chunk, t_sent)) = inflight.pop_front() else { return run };
            match link.recv_partial(&self.counters) {
                Ok((Msg::Abort { message }, _, _)) => {
                    run.fatal =
                        Some(format!("worker {} aborted the round: {message}", link.addr));
                    return run;
                }
                Ok((reply, reply_ext, received)) => {
                    if want_obs {
                        let lo = (chunk * per) as u64;
                        self.observe_exchange(
                            slot,
                            round,
                            lo,
                            t_sent,
                            received,
                            reply_ext.as_ref(),
                        );
                    }
                    run.done.push((chunk, reply));
                }
                Err(e) => {
                    link.kill();
                    run.loss = Some(format!("worker {}: {e}", link.addr));
                    run.lost.push(chunk);
                    run.lost.extend(inflight.drain(..).map(|(c, _)| c));
                    run.lost.extend(queue[next..].iter().copied());
                    return run;
                }
            }
        }
    }

    /// Resolve the relay policy against the current fleet and return the
    /// topology to gather through this pass (`None` — flat). A cached
    /// topology is reused while its stamp (alive slots + fanout) holds
    /// and every participant is still in the state the build left it in;
    /// anything else rebuilds at this deal boundary. When the policy
    /// resolves to flat, any leftover tier is dismantled first so the
    /// flat deal sees directly-linked workers only.
    fn ensure_topology(&self, round: u64, n_shards: usize) -> Option<Topology> {
        let slots: Vec<Arc<Mutex<WorkerLink>>> = self.slots.read().unwrap().clone();
        let alive: Vec<usize> =
            (0..slots.len()).filter(|&i| slots[i].lock().unwrap().is_alive()).collect();
        let fanout = if self.transport.is_none() {
            // borrowed transport: the leader cannot hand a leaf's session
            // to a relay it could never redial — structurally flat
            None
        } else {
            match self.opts.relay_fanout {
                RelayFanout::Flat => None,
                RelayFanout::Auto if alive.len() >= AUTO_RELAY_MIN_WORKERS => {
                    Some((alive.len() as f64).sqrt().ceil() as usize)
                }
                RelayFanout::Auto => None,
                RelayFanout::Leaves(n) if alive.len() >= 2 => Some(n.max(1)),
                RelayFanout::Leaves(_) => None,
            }
        };
        let Some(fanout) = fanout else {
            self.flatten(round, &slots);
            return None;
        };
        {
            let cached = self.topology.lock().unwrap();
            if let Some(topo) = cached.as_ref() {
                if topo.stamp.1 == fanout
                    && topo.stamp.0 == alive
                    && topology_healthy(topo, &slots)
                {
                    return (!topo.subtrees.is_empty()).then(|| topo.clone());
                }
            }
        }
        let topo = self.rebuild_topology(round, n_shards, &slots, fanout);
        let out = (!topo.subtrees.is_empty()).then(|| topo.clone());
        *self.topology.lock().unwrap() = Some(topo);
        out
    }

    /// Dismantle any relay tier: demote live relays (an empty
    /// `RelayAssign` makes the relay drop its leaf links), then bring
    /// every delegated leaf back onto a direct leader stream. A no-op on
    /// sessions that never built a tier.
    fn flatten(&self, round: u64, slots: &[Arc<Mutex<WorkerLink>>]) {
        let prior = self.topology.lock().unwrap().take();
        if let Some(prior) = &prior {
            self.relays_active.store(0, Ordering::Relaxed);
            if crate::obs::metrics_enabled() {
                self.obs.relays_active.set(0);
            }
            self.demote_relays(prior, slots);
        }
        for (slot, cell) in slots.iter().enumerate() {
            if cell.lock().unwrap().delegated {
                self.reattach(round, slots, slot);
            }
        }
    }

    /// Force a rebuild at the next deal boundary *without* forgetting the
    /// installed structure: the stamp is poisoned (no alive list ever
    /// matches an empty one, no fanout is 0) so the cache check misses,
    /// while the subtree list survives for [`RemoteCluster::demote_relays`]
    /// — surviving relays must release their leaves before any
    /// re-parenting dial, or that dial could park behind the stale hold.
    fn invalidate_topology(&self) {
        if let Some(t) = self.topology.lock().unwrap().as_mut() {
            t.stamp = (Vec::new(), 0);
        }
    }

    /// Tell each live relay to release its subtree (empty `RelayAssign`),
    /// restoring the plain per-task deadline on success and killing the
    /// link on any control-plane failure. After this every former leaf's
    /// worker session is back in (or heading to) its accept loop, so no
    /// re-parenting dial can park behind a stale hold — the ordering that
    /// keeps rebuilds deadlock-free on any transport.
    fn demote_relays(&self, prior: &Topology, slots: &[Arc<Mutex<WorkerLink>>]) {
        let demote = Msg::RelayAssign {
            leaves: Vec::new(),
            connect_timeout_ms: self.opts.connect_timeout.as_millis().max(1) as u64,
            exchange_timeout_ms: self.opts.exchange_timeout.as_millis().max(1) as u64,
        };
        for &(relay, _) in &prior.subtrees {
            let Some(cell) = slots.get(relay) else { continue };
            let mut link = cell.lock().unwrap();
            if !link.is_live() {
                continue;
            }
            let reply = link
                .send_control(&demote, &self.counters)
                .and_then(|()| link.recv_control(&self.counters));
            match reply {
                Ok(Msg::RelayReady { .. }) => {
                    link.set_exchange_deadline(self.opts.exchange_timeout)
                }
                _ => link.kill(),
            }
        }
    }

    /// Bring one slot back onto a direct leader stream. Budget-free: a
    /// delegated leaf's stream was handed off deliberately, so this dial
    /// is topology bookkeeping, not failure healing. Returns whether the
    /// slot is live afterwards; an unreachable worker is retired with a
    /// `Lost` note (and stays healable under the session budget).
    fn reattach(&self, round: u64, slots: &[Arc<Mutex<WorkerLink>>], slot: usize) -> bool {
        let Some(transport) = self.transport.as_ref() else { return false };
        let mut link = slots[slot].lock().unwrap();
        if link.is_live() {
            return true;
        }
        link.delegated = false;
        match link.redial(transport.as_ref(), &self.fingerprint, self.opts) {
            Ok(()) => true,
            Err(e) => {
                let detail = format!("worker {} lost during re-parenting: {e}", link.addr);
                link.kill();
                drop(link);
                self.counters.count(&self.counters.workers_lost, 1);
                if crate::obs::metrics_enabled() {
                    self.obs.workers_lost.inc();
                }
                self.push_event(MembershipEvent {
                    round,
                    worker: Some(slot),
                    change: MembershipChange::Lost,
                    detail,
                });
                false
            }
        }
    }

    /// Tear the old relay tier down and build one for the current fleet:
    /// demote, plan, reattach planned relays and direct slots, hand each
    /// subtree's leaf sessions to its relay (`RelayAssign`/`RelayReady`),
    /// and stamp the result with the fleet it was built for.
    fn rebuild_topology(
        &self,
        round: u64,
        n_shards: usize,
        slots: &[Arc<Mutex<WorkerLink>>],
        fanout: usize,
    ) -> Topology {
        // (0) teardown first, remembering incumbents for stickiness
        let prior = self.topology.lock().unwrap().take();
        let mut incumbents: Vec<usize> = Vec::new();
        if let Some(prior) = &prior {
            incumbents.extend(prior.subtrees.iter().map(|&(r, _)| r));
            self.demote_relays(prior, slots);
        }
        // (1) plan over the now-flat alive fleet
        let cands: Vec<TopoSlot> = slots
            .iter()
            .enumerate()
            .filter_map(|(i, cell)| {
                let link = cell.lock().unwrap();
                link.is_alive().then(|| TopoSlot {
                    slot: i,
                    live_stream: link.is_live(),
                    span: link.span,
                    is_relay_now: incumbents.contains(&i),
                })
            })
            .collect();
        let plan = plan_topology(&cands, fanout, n_shards);
        // (2) every planned relay and direct slot needs a live leader
        // stream again (leaves hand theirs off)
        let mut subtrees: Vec<(usize, Vec<usize>)> = Vec::new();
        let mut direct: Vec<usize> = Vec::new();
        for &slot in &plan.direct {
            if self.reattach(round, slots, slot) {
                direct.push(slot);
            }
        }
        for (relay, leaves) in plan.subtrees {
            if !self.reattach(round, slots, relay) {
                // the planned relay is gone: its leaves fall back to
                // direct exchanges in this topology
                for &leaf in &leaves {
                    if self.reattach(round, slots, leaf) {
                        direct.push(leaf);
                    }
                }
                continue;
            }
            // (3) hand each leaf's session to the relay: close our stream
            // so the worker returns to accept, then deal the subtree
            // (addresses resolved before taking the relay's lock — one
            // link lock at a time, always)
            let mut addrs: Vec<String> = Vec::with_capacity(leaves.len());
            for &leaf in &leaves {
                let mut link = slots[leaf].lock().unwrap();
                if link.is_live() {
                    link.shutdown();
                }
                link.delegated = true;
                addrs.push(link.addr.clone());
            }
            let n_leaves = leaves.len();
            let assign = Msg::RelayAssign {
                leaves: addrs,
                connect_timeout_ms: self.opts.connect_timeout.as_millis().max(1) as u64,
                exchange_timeout_ms: self.opts.exchange_timeout.as_millis().max(1) as u64,
            };
            let reply = {
                let mut link = slots[relay].lock().unwrap();
                link.send_control(&assign, &self.counters)
                    .and_then(|()| link.recv_control(&self.counters))
            };
            match reply {
                Ok(Msg::RelayReady { reached, .. }) => {
                    let mut reached_any = false;
                    for (i, &leaf) in leaves.iter().enumerate() {
                        if reached.get(i).copied().unwrap_or(false) {
                            reached_any = true;
                            continue;
                        }
                        // the relay could not dial it: the worker is gone
                        // (still healable later under the session budget)
                        let mut link = slots[leaf].lock().unwrap();
                        let detail =
                            format!("worker {} unreachable from its relay", link.addr);
                        link.kill();
                        drop(link);
                        self.counters.count(&self.counters.workers_lost, 1);
                        if crate::obs::metrics_enabled() {
                            self.obs.workers_lost.inc();
                        }
                        self.push_event(MembershipEvent {
                            round,
                            worker: Some(leaf),
                            change: MembershipChange::Lost,
                            detail,
                        });
                    }
                    if reached_any {
                        // a relay exchange covers leaf recovery and local
                        // recompute in the worst case: double its deadline
                        slots[relay]
                            .lock()
                            .unwrap()
                            .set_exchange_deadline(self.opts.exchange_timeout * 2);
                        if crate::obs::metrics_enabled() {
                            self.obs.relay_assigns.inc();
                        }
                        crate::obs::instant(
                            self.clock.as_ref(),
                            Track::Leader,
                            names::RELAY_ASSIGN,
                            round,
                            n_leaves as u64,
                        );
                        subtrees.push((relay, leaves));
                    } else {
                        // a subtree with no reachable leaf is just a
                        // direct worker
                        direct.push(relay);
                    }
                }
                Ok(Msg::Abort { message }) => self.relay_setup_loss(
                    round,
                    slots,
                    relay,
                    format!("relay refused its subtree: {message}"),
                    &leaves,
                    &mut direct,
                ),
                Ok(other) => self.relay_setup_loss(
                    round,
                    slots,
                    relay,
                    format!("relay answered assignment with {}", other.name()),
                    &leaves,
                    &mut direct,
                ),
                Err(e) => self.relay_setup_loss(
                    round,
                    slots,
                    relay,
                    format!("relay lost during assignment: {e}"),
                    &leaves,
                    &mut direct,
                ),
            }
        }
        let n_relays = subtrees.len();
        self.relays_active.store(n_relays, Ordering::Relaxed);
        if crate::obs::metrics_enabled() {
            self.obs.relays_active.set(n_relays as i64);
        }
        let alive_now: Vec<usize> =
            (0..slots.len()).filter(|&i| slots[i].lock().unwrap().is_alive()).collect();
        Topology { subtrees, direct, stamp: (alive_now, fanout) }
    }

    /// A relay died (or misbehaved) during assignment: retire its link
    /// and fall its planned leaves back to direct exchanges. (Killing the
    /// relay's stream makes its worker process drop the leaf links it may
    /// already hold, so the leaves' reattach dials cannot park forever.)
    fn relay_setup_loss(
        &self,
        round: u64,
        slots: &[Arc<Mutex<WorkerLink>>],
        relay: usize,
        detail: String,
        leaves: &[usize],
        direct: &mut Vec<usize>,
    ) {
        slots[relay].lock().unwrap().kill();
        self.counters.count(&self.counters.workers_lost, 1);
        if crate::obs::metrics_enabled() {
            self.obs.workers_lost.inc();
        }
        self.push_event(MembershipEvent {
            round,
            worker: Some(relay),
            change: MembershipChange::Lost,
            detail,
        });
        for &leaf in leaves {
            if self.reattach(round, slots, leaf) {
                direct.push(leaf);
            }
        }
    }

    /// One hierarchical pass: deal the pending queue as contiguous runs
    /// over the uplinks — each subtree weighted by its size, each direct
    /// slot weight one — then exchange concurrently: relays answer whole
    /// runs with subtree aggregates, direct slots run their chunks
    /// through the same pipelined exchange as a flat overlap pass.
    /// Outcomes are processed in deal order, so counters and re-queues
    /// stay deterministic.
    #[allow(clippy::too_many_arguments)]
    fn hier_step<F>(
        &self,
        round: u64,
        per: usize,
        n_shards: usize,
        slots: &[Arc<Mutex<WorkerLink>>],
        topo: &Topology,
        pending: &mut VecDeque<usize>,
        results: &mut [Option<Msg>],
        hier_done: &mut Vec<(usize, usize, Msg)>,
        last_loss: &mut String,
        task: &F,
    ) -> Result<()>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        // runs are contiguous in chunk space so a relay's aggregate
        // covers one dense range
        let mut chunks: Vec<usize> = pending.drain(..).collect();
        chunks.sort_unstable();
        #[derive(Clone, Copy)]
        enum Uplink<'a> {
            Relay(usize, &'a [usize]),
            Direct(usize),
        }
        let mut uplinks: Vec<(Uplink, usize)> = Vec::new();
        for (relay, leaves) in &topo.subtrees {
            let alive =
                leaves.iter().filter(|&&l| slots[l].lock().unwrap().is_alive()).count();
            uplinks.push((Uplink::Relay(*relay, leaves), 1 + alive));
        }
        for &d in &topo.direct {
            if slots[d].lock().unwrap().is_live() {
                uplinks.push((Uplink::Direct(d), 1));
            }
        }
        if uplinks.is_empty() {
            // every uplink died since the topology was installed: force a
            // rebuild and let the quorum logic decide what remains
            self.invalidate_topology();
            pending.extend(chunks);
            return Ok(());
        }
        // contiguous weighted deal: uplink u takes the next
        // ⌈rem · wᵤ / rem_w⌉ chunks — every uplink gets work proportional
        // to its subtree, and a relay's range stays dense
        let mut deals: Vec<(Uplink, &[usize])> = Vec::new();
        let mut rem = chunks.len();
        let mut rem_w: usize = uplinks.iter().map(|&(_, w)| w).sum();
        let mut cursor = 0usize;
        for &(uplink, w) in &uplinks {
            if rem == 0 {
                break;
            }
            let take = ((rem * w).div_ceil(rem_w)).min(rem);
            if take > 0 {
                deals.push((uplink, &chunks[cursor..cursor + take]));
            }
            cursor += take;
            rem -= take;
            rem_w -= w;
        }
        let runs: Vec<HierRun> = std::thread::scope(|s| {
            let handles: Vec<_> = deals
                .iter()
                .map(|&(uplink, run)| {
                    s.spawn(move || match uplink {
                        Uplink::Relay(relay, leaves) => HierRun::Relay(
                            relay,
                            self.run_relay(
                                slots, relay, leaves, round, run, per, n_shards, task,
                            ),
                        ),
                        Uplink::Direct(slot) => HierRun::Direct(
                            slot,
                            self.run_slot(slots, slot, round, run, per, n_shards, task),
                        ),
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        let mut run = RelayRun::new();
                        run.fatal = Some("relay exchange thread panicked".into());
                        HierRun::Relay(usize::MAX, run)
                    })
                })
                .collect()
        });
        let mut leaf_dead = false;
        for outcome in runs {
            match outcome {
                HierRun::Relay(relay, run) => {
                    if let Some(message) = run.fatal {
                        return Err(Error::Runtime(message));
                    }
                    if crate::obs::metrics_enabled() {
                        self.obs.relay_partials.add(run.done.len() as u64);
                    }
                    hier_done.extend(run.done);
                    for leaf in run.leaf_losses {
                        let mut link = slots[leaf].lock().unwrap();
                        if !link.is_alive() {
                            continue; // already retired this pass
                        }
                        let detail = format!(
                            "worker {} lost from its relay subtree (work recomputed \
                             relay-side)",
                            link.addr
                        );
                        link.kill();
                        drop(link);
                        leaf_dead = true;
                        self.counters.count(&self.counters.workers_lost, 1);
                        if crate::obs::metrics_enabled() {
                            self.obs.workers_lost.inc();
                            self.obs.relay_leaf_losses.inc();
                        }
                        self.push_event(MembershipEvent {
                            round,
                            worker: Some(leaf),
                            change: MembershipChange::Lost,
                            detail,
                        });
                    }
                    if let Some(loss) = run.loss {
                        self.push_event(MembershipEvent {
                            round,
                            worker: Some(relay),
                            change: MembershipChange::Lost,
                            detail: loss.clone(),
                        });
                        *last_loss = loss;
                        self.counters.count(&self.counters.workers_lost, 1);
                        self.counters
                            .count(&self.counters.redispatches, run.lost_chunks.len() as u64);
                        self.note_loss(round, per, &run.lost_chunks);
                        pending.extend(run.lost_chunks);
                        // the subtree is orphaned: rebuild at the next
                        // boundary (its leaves re-parent or go direct)
                        self.invalidate_topology();
                    }
                }
                HierRun::Direct(slot, run) => {
                    if let Some(message) = run.fatal {
                        return Err(Error::Runtime(message));
                    }
                    for (chunk, reply) in run.done {
                        results[chunk] = Some(reply);
                    }
                    if let Some(loss) = run.loss {
                        self.push_event(MembershipEvent {
                            round,
                            worker: Some(slot),
                            change: MembershipChange::Lost,
                            detail: loss.clone(),
                        });
                        *last_loss = loss;
                        self.counters.count(&self.counters.workers_lost, 1);
                        self.counters
                            .count(&self.counters.redispatches, run.lost.len() as u64);
                        self.note_loss(round, per, &run.lost);
                        pending.extend(run.lost);
                        self.invalidate_topology();
                    }
                }
            }
        }
        if leaf_dead {
            // leaf deaths are absorbed by their relay, so the topology
            // stays valid — refresh its stamp to the shrunken fleet
            // instead of forcing a full teardown and rebuild
            let alive_now: Vec<usize> =
                (0..slots.len()).filter(|&i| slots[i].lock().unwrap().is_alive()).collect();
            if let Some(t) = self.topology.lock().unwrap().as_mut() {
                t.stamp.0 = alive_now;
            }
        }
        Ok(())
    }

    /// Drive one relay through its dealt run of chunks: contiguous
    /// stretches go out as single ranged tasks, each answered by a
    /// subtree aggregate (`RelayPartial`). A relay that lost every leaf
    /// mid-pass answers with a *plain* partial — it computed the range
    /// itself — which is accepted unchanged, since a one-operand merge
    /// is the operand. Any wire error kills the relay and reports every
    /// unanswered chunk for re-dispatch.
    #[allow(clippy::too_many_arguments)]
    fn run_relay<F>(
        &self,
        slots: &[Arc<Mutex<WorkerLink>>],
        relay: usize,
        leaves: &[usize],
        round: u64,
        run_chunks: &[usize],
        per: usize,
        n_shards: usize,
        task: &F,
    ) -> RelayRun
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        let trace_on = crate::obs::trace_enabled();
        let want_obs = trace_on || crate::obs::metrics_enabled();
        let ext = span_ext::encode_task(round, trace_on);
        let mut run = RelayRun::new();
        let mut link = slots[relay].lock().unwrap();
        let mut i = 0usize;
        while i < run_chunks.len() {
            let mut j = i + 1;
            while j < run_chunks.len() && run_chunks[j] == run_chunks[j - 1] + 1 {
                j += 1;
            }
            let (first, last) = (run_chunks[i], run_chunks[j - 1]);
            let lo = first * per;
            let hi = ((last + 1) * per).min(n_shards);
            let t0 = if want_obs { self.clock.now_ns() } else { 0 };
            let reply = link
                .send_task(&task(lo, hi), &ext, &self.counters)
                .and_then(|()| link.recv_partial(&self.counters));
            match reply {
                Ok((Msg::Abort { message }, _, _)) => {
                    run.fatal =
                        Some(format!("relay {} aborted the round: {message}", link.addr));
                    return run;
                }
                Ok((Msg::RelayPartial { lost, inner }, reply_ext, received)) => {
                    if want_obs {
                        self.observe_exchange(
                            relay,
                            round,
                            lo as u64,
                            t0,
                            received,
                            reply_ext.as_ref(),
                        );
                    }
                    // loss indices address the assignment-order leaf list
                    for li in lost {
                        if let Some(&leaf) = leaves.get(li as usize) {
                            run.leaf_losses.push(leaf);
                        }
                    }
                    run.done.push((first, last - first + 1, *inner));
                }
                Ok((
                    reply @ (Msg::EvalPartial(_) | Msg::ScdPartial(_) | Msg::RankPartial(_)),
                    reply_ext,
                    received,
                )) => {
                    // demoted-relay window: its last leaf died earlier in
                    // this pass, so it folded the range locally
                    if want_obs {
                        self.observe_exchange(
                            relay,
                            round,
                            lo as u64,
                            t0,
                            received,
                            reply_ext.as_ref(),
                        );
                    }
                    run.done.push((first, last - first + 1, reply));
                }
                Ok((other, _, _)) => {
                    run.fatal = Some(format!(
                        "relay {} answered a ranged task with {}",
                        link.addr,
                        other.name()
                    ));
                    return run;
                }
                Err(e) => {
                    link.kill();
                    run.loss = Some(format!("relay {}: {e}", link.addr));
                    run.lost_chunks.extend(run_chunks[i..].iter().copied());
                    return run;
                }
            }
            i = j;
        }
        run
    }

    /// Distributed evaluation round (DD rounds, final evaluations).
    pub(crate) fn eval_round(
        &self,
        shards: Shards,
        kk: usize,
        lambda: &[f64],
    ) -> Result<RoundAgg> {
        let geo = Geometry::of(shards);
        let parts = self.gather(shards.count(), |lo, hi| Msg::EvalTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: lambda.to_vec(),
        })?;
        let mut agg = RoundAgg::new(kk);
        for part in parts {
            match part {
                Msg::EvalPartial(a) if a.consumption.len() == kk => agg = agg.merge(a),
                other => return Err(unexpected("eval-partial", &other)),
            }
        }
        Ok(agg)
    }

    /// Distributed SCD round.
    pub(crate) fn scd_round(&self, shards: Shards, spec: &ScdRoundSpec<'_>) -> Result<ScdAcc> {
        let geo = Geometry::of(shards);
        let kk = spec.lambda.len();
        let parts = self.gather(shards.count(), |lo, hi| Msg::ScdTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: spec.lambda.to_vec(),
            active: spec.active_mask.to_vec(),
            sparse_q: spec.sparse_q,
            reduce: spec.reduce,
        })?;
        let mut acc = ScdAcc::new(spec.reduce, spec.lambda);
        for part in parts {
            match part {
                Msg::ScdPartial(a)
                    if a.round.consumption.len() == kk
                        && thresholds_fit(&a.thresholds, spec.reduce, kk) =>
                {
                    acc = acc.merge(a)
                }
                other => return Err(unexpected("scd-partial", &other)),
            }
        }
        Ok(acc)
    }

    /// Distributed §5.4 ranking round.
    pub(crate) fn rank_round(&self, shards: Shards, lambda: &[f64]) -> Result<Vec<(f32, u32)>> {
        let geo = Geometry::of(shards);
        let parts = self.gather(shards.count(), |lo, hi| Msg::RankTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: lambda.to_vec(),
        })?;
        let n_groups = shards.n_total() as u32;
        let mut ranked = Vec::new();
        for part in parts {
            match part {
                Msg::RankPartial(r) if r.iter().all(|&(_, i)| i < n_groups) => ranked.extend(r),
                other => return Err(unexpected("rank-partial", &other)),
            }
        }
        Ok(ranked)
    }
}

/// Does a shipped threshold accumulator have the variant and width the
/// round expects? (A fingerprint-verified worker always satisfies this;
/// the check turns a hypothetical protocol bug into a clean error instead
/// of a mis-merge.)
fn thresholds_fit(t: &ThresholdAcc, reduce: ReduceMode, kk: usize) -> bool {
    match (t, reduce) {
        (ThresholdAcc::Exact(v), ReduceMode::Exact) => v.len() == kk,
        (ThresholdAcc::Bucketed(h), ReduceMode::Bucketed { .. }) => h.len() == kk,
        _ => false,
    }
}

/// Is every participant of the installed topology still in the state the
/// build left it in? Relays and direct slots must hold live leader
/// streams; alive leaves must still be delegated (a healed leaf that
/// reacquired a direct stream invalidates the tier, since it would be
/// dealt twice).
fn topology_healthy(topo: &Topology, slots: &[Arc<Mutex<WorkerLink>>]) -> bool {
    let leaf_ok = |leaf: usize| {
        let link = slots[leaf].lock().unwrap();
        !link.is_alive() || link.delegated
    };
    topo.subtrees.iter().all(|(relay, leaves)| {
        slots[*relay].lock().unwrap().is_live() && leaves.iter().all(|&l| leaf_ok(l))
    }) && topo.direct.iter().all(|&d| slots[d].lock().unwrap().is_live())
}

fn unexpected(want: &str, got: &Msg) -> Error {
    Error::Runtime(format!(
        "cluster protocol violation: expected a well-formed {want}, got {} \
         (mismatched binaries?)",
        got.name()
    ))
}

impl Drop for RemoteCluster {
    fn drop(&mut self) {
        for slot in self.slots.read().unwrap().iter() {
            if let Ok(mut link) = slot.lock() {
                link.shutdown();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(slot: usize, live_stream: bool, is_relay_now: bool) -> TopoSlot {
        TopoSlot { slot, live_stream, span: (0, u64::MAX), is_relay_now }
    }

    #[test]
    fn plan_small_fleets_stay_flat() {
        let plan = plan_topology(&[cand(0, true, false)], 2, 64);
        assert!(plan.subtrees.is_empty());
        assert_eq!(plan.direct, vec![0]);
        assert!(plan_topology(&[], 2, 64).subtrees.is_empty());
    }

    #[test]
    fn plan_deals_leaves_round_robin() {
        let cands: Vec<TopoSlot> = (0..6).map(|i| cand(i, true, false)).collect();
        let plan = plan_topology(&cands, 2, 64);
        // 6 workers at fanout 2 → ⌈6/3⌉ = 2 relays (slots 0 and 1),
        // leaves alternate over the subtrees in slot order
        assert_eq!(plan.subtrees.len(), 2);
        assert_eq!(plan.subtrees[0], (0, vec![2, 4]));
        assert_eq!(plan.subtrees[1], (1, vec![3, 5]));
        assert!(plan.direct.is_empty());
    }

    #[test]
    fn plan_relay_count_capped_by_streamed_slots() {
        // only slot 2 still holds a leader stream, so it is the only
        // possible relay even though the fanout asks for two
        let cands = vec![
            cand(0, false, false),
            cand(1, false, false),
            cand(2, true, false),
            cand(3, false, false),
        ];
        let plan = plan_topology(&cands, 1, 64);
        assert_eq!(plan.subtrees.len(), 1);
        assert_eq!(plan.subtrees[0], (2, vec![0, 1, 3]));
        assert!(plan.direct.is_empty());
    }

    #[test]
    fn plan_prefers_incumbent_relays() {
        let cands = vec![
            cand(0, true, false),
            cand(1, true, false),
            cand(2, true, false),
            cand(3, true, true),
        ];
        let plan = plan_topology(&cands, 3, 64);
        // one relay wanted; the incumbent wins over lower slot numbers
        assert_eq!(plan.subtrees.len(), 1);
        assert_eq!(plan.subtrees[0], (3, vec![0, 1, 2]));
    }

    #[test]
    fn plan_prefers_covering_replica_spans() {
        // subtree 0 is nominally [0, 32), subtree 1 [32, 64): the two
        // slots whose replicas cover those ranges are picked as relays
        // (they can recompute any leaf loss from local shards), ahead of
        // lower-numbered slots that cover nothing
        let cands = vec![
            TopoSlot { slot: 0, live_stream: true, span: (32, 64), is_relay_now: false },
            TopoSlot { slot: 1, live_stream: true, span: (0, 32), is_relay_now: false },
            TopoSlot { slot: 2, live_stream: true, span: (64, 64), is_relay_now: false },
            TopoSlot { slot: 3, live_stream: true, span: (64, 64), is_relay_now: false },
            TopoSlot { slot: 4, live_stream: true, span: (64, 64), is_relay_now: false },
            TopoSlot { slot: 5, live_stream: true, span: (64, 64), is_relay_now: false },
        ];
        let plan = plan_topology(&cands, 2, 64);
        assert_eq!(plan.subtrees.len(), 2);
        assert_eq!(plan.subtrees[0].0, 1); // covers [0, 32)
        assert_eq!(plan.subtrees[1].0, 0); // covers [32, 64)
        assert_eq!(plan.subtrees[0].1, vec![2, 4]);
        assert_eq!(plan.subtrees[1].1, vec![3, 5]);
    }

    #[test]
    fn plan_with_no_streamed_slot_goes_direct() {
        let cands = vec![cand(0, false, false), cand(1, false, false)];
        let plan = plan_topology(&cands, 2, 64);
        assert!(plan.subtrees.is_empty());
        assert_eq!(plan.direct, vec![0, 1]);
    }
}
