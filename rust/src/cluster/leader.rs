//! The leader-side remote executor.
//!
//! [`RemoteCluster`] owns one [`WorkerLink`](super::membership::WorkerLink)
//! per configured worker and drives synchronous rounds: the global shard
//! partition is cut into contiguous **chunks** (a fixed function of the
//! round, independent of which worker computes what), and chunks are dealt
//! to live workers from a pending queue by one of two [`ExchangeMode`]s:
//! *waves* — one chunk per live worker per wave, slot order, a full
//! barrier between waves — or the default *overlapped* gather, which
//! deals the whole queue round-robin (slot order again) and keeps a
//! small task pipeline in flight per link, so workers never idle on a
//! wave barrier and the leader's waiting overlaps their compute. Either
//! deal is a pure function of (pending chunks, live set): which worker
//! computes which chunk never depends on thread scheduling, so a
//! simulated run's event trace is replayable from its seed, and a
//! production run's assignment is auditable from its logs. Partials are
//! merged **in chunk order** with compensated sums — the result does not
//! depend on worker count, scheduling, mid-round failures, or the
//! exchange mode. (Versus the earlier work-stealing queue this trades
//! intra-round rebalancing for a deterministic deal; overlap mode
//! recovers the pipelining a work queue would give, without giving up
//! the deterministic assignment.)
//!
//! **Failure handling.** A worker that errors or times out on a chunk is
//! marked dead; its chunk goes back on the queue and a survivor
//! re-executes it in a later wave. Because every task frame carries the
//! round's full broadcast state (λ, active mask, reduce mode),
//! re-dispatch resumes from the λ the round started with — a lost worker
//! costs one chunk of recomputation. Only when *every* worker is gone does
//! the round (and the solve) fail; with checkpointing enabled the λ trail
//! survives for a warm-started retry.
//!
//! **Elastic membership.** All membership work happens at the deal
//! boundary (the top of each gather pass), so the deal stays a pure
//! function of `(pending, live)` and simulated traces stay replayable.
//! With a redial budget (`PALLAS_CLUSTER_REDIALS` /
//! [`ConnectOptions::redial_budget`]) the leader re-dials
//! transiently-dead links on an exponential-backoff schedule with
//! deterministic jitter ([`Backoff`]), re-handshaking the instance
//! fingerprint; a peer that answers and *refuses* is retired permanently.
//! A session constructed with a join listener
//! ([`RemoteCluster::connect_elastic`]) admits fresh `bskp worker --join`
//! processes mid-solve over the `Join`/`Admit` frames; admitted workers
//! receive chunks from the next deal on. A quorum floor
//! (`PALLAS_MIN_WORKERS` / [`ConnectOptions::min_workers`]) turns a
//! too-degraded fleet into a typed fail-fast error instead of a grind;
//! above the floor but below full strength the solve continues degraded,
//! with a `Degraded` note per strength transition. Every membership
//! change lands in the [`MembershipEvent`] log (surfaced through
//! `SolveReport::membership`), the metrics registry and the flight
//! recorder.
//!
//! All timing goes through the transport's [`Clock`]: wall time on TCP,
//! virtual time under [`super::sim`] — which is how a 10-minute exchange
//! timeout can fire in microseconds of test time.

use crate::cluster::clock::{Backoff, Clock};
use crate::cluster::frames::EXT_LEN;
use crate::cluster::membership::{NetCounters, WorkerLink};
use crate::cluster::protocol::{
    recv_msg, send_msg, span_ext, Geometry, InstanceFingerprint, Msg,
};
use crate::cluster::transport::{NetListener, NetStream, TcpTransport, Transport};
use crate::cluster::{env_count, env_ms};
use crate::error::{Error, Result};
use crate::instance::problem::GroupSource;
use crate::instance::shard::Shards;
use crate::mapreduce::Cluster;
use crate::obs::metrics::{Counter, Histogram};
use crate::obs::{names, Track};
use crate::solver::config::ReduceMode;
use crate::solver::rounds::RoundAgg;
use crate::solver::scd::{ScdAcc, ScdRoundSpec, ThresholdAcc};
use crate::solver::stats::{MembershipChange, MembershipEvent};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Default per-exchange timeout. This is the *only* detector for a worker
/// that is silently partitioned (process death shows up immediately as
/// RST/EOF), so it must comfortably exceed the slowest honest chunk: at
/// N = 1e9 a chunk is ~N/64 groups, minutes of folding on a loaded box.
/// 10 minutes trades partition-detection latency for never killing a
/// healthy-but-slow fleet; tighten via `PALLAS_CLUSTER_TIMEOUT_MS` when
/// chunks are known to be fast.
const DEFAULT_TIMEOUT_MS: u64 = 600_000;

/// Default connect/handshake timeout (seconds, not minutes: planning must
/// reach its in-process fallback promptly when a fleet is blackholed).
const DEFAULT_CONNECT_TIMEOUT_MS: u64 = 5_000;

/// Default redial budget: 0 — self-healing is opt-in
/// (`PALLAS_CLUSTER_REDIALS`), so by default a failed worker stays failed
/// for the session and existing failure semantics (and chaos-replay
/// baselines) are byte-identical.
const DEFAULT_REDIALS: u64 = 0;

/// Default base redial backoff; doubles per failed attempt with
/// deterministic jitter, capped at [`REDIAL_BACKOFF_CAP_MS`].
const DEFAULT_REDIAL_BACKOFF_MS: u64 = 100;

/// Redial backoff cap: a flapping worker is probed at least this often.
const REDIAL_BACKOFF_CAP_MS: u64 = 30_000;

/// Default quorum floor: one live worker keeps the solve going (the
/// pre-elastic behavior).
const DEFAULT_MIN_WORKERS: u64 = 1;

/// Chunks per round: a pure function of the shard count — deliberately
/// **independent of worker count and liveness**, so the chunk partition
/// (and with it the merge order of every compensated sum) is identical
/// for any fleet size and any mid-round failure pattern. 64 chunks give
/// fine-grained dealing and re-dispatch for any realistic fleet while
/// keeping per-round frame counts and per-chunk accumulators bounded.
const CHUNKS_PER_ROUND: usize = 64;

fn chunk_count(n_shards: usize) -> usize {
    n_shards.min(CHUNKS_PER_ROUND)
}

/// How the leader waits on its per-round exchange.
///
/// Both modes use the identical chunk partition and merge partials in
/// chunk order, so the solve result is bit-identical either way; they
/// differ only in when the leader is *waiting*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Strict wave barriers: one chunk per live worker per wave, the
    /// next wave starts only after every exchange of the current one
    /// returned. The whole fleet idles on each wave's straggler, but
    /// leader and worker never have more than one frame outstanding per
    /// link — the most conservative flow control, and the mode whose
    /// per-link traces are totally ordered (the chaos suite pins it for
    /// its exact replay assertions).
    Wave,
    /// Overlapped gather: the round's whole chunk queue is dealt up
    /// front (round-robin over live workers, slot order) and each link
    /// keeps a small pipeline of tasks in flight, so a worker starts
    /// its next chunk the moment it finishes one instead of idling on
    /// the slowest peer. Stragglers only delay their own queue. This is
    /// the default; `PALLAS_EXCHANGE=wave` restores wave barriers (e.g.
    /// when frames are so large that pipelined task + partial bytes
    /// could both sit in kernel socket buffers at once).
    Overlap,
}

impl ExchangeMode {
    /// The environment-configured mode: `PALLAS_EXCHANGE=wave` or
    /// `overlap` (the default, also used for unset/unknown values).
    pub fn from_env() -> Self {
        match std::env::var("PALLAS_EXCHANGE").ok().as_deref() {
            Some("wave") => ExchangeMode::Wave,
            _ => ExchangeMode::Overlap,
        }
    }
}

/// Session timeout policy, resolved once at connect time. [`Default`]
/// reads the `PALLAS_CLUSTER_TIMEOUT_MS` / `PALLAS_CLUSTER_CONNECT_TIMEOUT_MS`
/// / `PALLAS_EXCHANGE` knobs; tests inject explicit values instead of
/// mutating the process environment.
#[derive(Debug, Clone, Copy)]
pub struct ConnectOptions {
    /// Bound on dial + handshake per worker.
    pub connect_timeout: Duration,
    /// Bound on each task/partial exchange for the rest of the session.
    pub exchange_timeout: Duration,
    /// Wave-barrier or overlapped gather (see [`ExchangeMode`]).
    pub exchange: ExchangeMode,
    /// Redial attempts allowed per link for the whole session
    /// (`PALLAS_CLUSTER_REDIALS`; 0 — the default — disables healing).
    /// The budget is *total*, not per outage, so a flapping worker
    /// cannot crash-redial-crash forever.
    pub redial_budget: u32,
    /// Base redial backoff (`PALLAS_CLUSTER_REDIAL_BACKOFF_MS`): the
    /// n-th consecutive failed redial of an outage waits
    /// `base · 2ⁿ` plus deterministic jitter, capped at 30 s.
    pub redial_backoff: Duration,
    /// Quorum floor (`PALLAS_MIN_WORKERS`): with fewer live workers the
    /// gather fails fast (typed error) instead of grinding on degraded;
    /// at or above it but below full strength the solve continues with a
    /// `Degraded` membership note.
    pub min_workers: usize,
}

impl ConnectOptions {
    /// The environment-configured policy (documented defaults when the
    /// knobs are unset).
    pub fn from_env() -> Self {
        Self {
            connect_timeout: env_ms(
                "PALLAS_CLUSTER_CONNECT_TIMEOUT_MS",
                DEFAULT_CONNECT_TIMEOUT_MS,
            ),
            exchange_timeout: env_ms("PALLAS_CLUSTER_TIMEOUT_MS", DEFAULT_TIMEOUT_MS),
            exchange: ExchangeMode::from_env(),
            redial_budget: env_count("PALLAS_CLUSTER_REDIALS", DEFAULT_REDIALS).min(u32::MAX as u64)
                as u32,
            redial_backoff: env_ms(
                "PALLAS_CLUSTER_REDIAL_BACKOFF_MS",
                DEFAULT_REDIAL_BACKOFF_MS,
            ),
            min_workers: env_count("PALLAS_MIN_WORKERS", DEFAULT_MIN_WORKERS).max(1) as usize,
        }
    }
}

impl Default for ConnectOptions {
    fn default() -> Self {
        Self::from_env()
    }
}

/// Point-in-time wire statistics of a [`RemoteCluster`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetSnapshot {
    /// Task bytes written to workers (frames included).
    pub bytes_sent: u64,
    /// Partial bytes read from workers (frames included).
    pub bytes_received: u64,
    /// Gather rounds completed.
    pub rounds: u64,
    /// Total time inside gathers, milliseconds (virtual under the
    /// simulator).
    pub round_ms: f64,
    /// Chunks re-dispatched after a worker loss.
    pub redispatches: u64,
    /// Workers lost during the session.
    pub workers_lost: u64,
    /// Successful redials of transiently-dead links.
    pub redials: u64,
    /// Workers admitted mid-solve through the join listener.
    pub joins: u64,
    /// Workers still live.
    pub workers_live: usize,
    /// Workers in the session: dial-time plus admitted.
    pub workers_total: usize,
    /// Advertised map-thread capacity across all session workers.
    pub capacity: usize,
}

/// What one wave exchange produced (processed in deal order, so queue
/// re-adds and counters are deterministic).
enum WaveOutcome {
    /// The chunk's partial arrived.
    Done(usize, Msg),
    /// The worker in this slot died on this chunk; re-queue it for a
    /// survivor (and log the loss against the slot).
    Lost { slot: usize, chunk: usize, loss: String },
    /// A protocol-level abort: the round (and solve) must fail.
    Fatal(String),
}

/// Tasks in flight per link in overlapped gather (sent, reply not yet
/// read). Two is enough to hide the leader's reply-drain time behind the
/// worker's compute — the worker picks up task k+1 from its receive
/// buffer the instant it finishes k — while keeping at most one task
/// frame queued in kernel buffers per link.
const PIPELINE_DEPTH: usize = 2;

/// What one link's overlapped run of its dealt queue produced (processed
/// in slot order, so queue re-adds and counters are deterministic).
struct SlotRun {
    /// Partials that arrived, in task order.
    done: Vec<(usize, Msg)>,
    /// Chunks the dead link never answered (the failing chunk, then the
    /// rest of its pipeline, then its unsent queue — a deterministic
    /// order for re-dispatch).
    lost: Vec<usize>,
    /// Why the link died, when it did.
    loss: Option<String>,
    /// A protocol-level abort: the round (and solve) must fail.
    fatal: Option<String>,
}

impl SlotRun {
    fn new() -> Self {
        Self { done: Vec::new(), lost: Vec::new(), loss: None, fatal: None }
    }
}

/// Leader-side registry handles, resolved once per session so the hot
/// exchange paths bump atomics and never look a metric up by name
/// ([`crate::obs::metrics`]). Per-link breakdowns live in the span trace
/// (one `link/<slot>` track each); the registry carries the fleet-wide
/// aggregates a scrape wants.
struct LeaderObs {
    exchanges: Arc<Counter>,
    exchange_latency_ns: Arc<Histogram>,
    exchange_bytes: Arc<Histogram>,
    redeals: Arc<Counter>,
    workers_lost: Arc<Counter>,
    gather_rounds: Arc<Counter>,
    gather_latency_ns: Arc<Histogram>,
    redials: Arc<Counter>,
    joins: Arc<Counter>,
    degraded: Arc<Counter>,
}

impl LeaderObs {
    fn new() -> Self {
        let r = crate::obs::metrics::global();
        Self {
            exchanges: r.counter("bskp_cluster_exchanges_total"),
            exchange_latency_ns: r.histogram("bskp_cluster_exchange_latency_ns"),
            exchange_bytes: r.histogram("bskp_cluster_exchange_bytes"),
            redeals: r.counter("bskp_cluster_redeals_total"),
            workers_lost: r.counter("bskp_cluster_workers_lost_total"),
            gather_rounds: r.counter("bskp_cluster_gather_rounds_total"),
            gather_latency_ns: r.histogram("bskp_cluster_gather_latency_ns"),
            redials: r.counter("bskp_cluster_redials_total"),
            joins: r.counter("bskp_cluster_joins_total"),
            degraded: r.counter("bskp_cluster_degraded_total"),
        }
    }
}

/// A fleet of `pallas worker` processes, driven over a [`Transport`] with
/// the same map→combine→reduce contract as the in-process
/// [`Cluster`] (see [`super::Exec`]).
pub struct RemoteCluster {
    /// Worker links: dial-time slots first, mid-solve admissions
    /// appended. Only [`RemoteCluster::admit_joiners`] ever grows the
    /// vector, and only at a deal boundary.
    slots: RwLock<Vec<Arc<Mutex<WorkerLink>>>>,
    leader_pool: Cluster,
    counters: NetCounters,
    clock: Arc<dyn Clock>,
    opts: ConnectOptions,
    fingerprint: InstanceFingerprint,
    /// Retained dialer for round-boundary redials; `None` on the
    /// borrowed-transport [`RemoteCluster::connect_with`] path, where
    /// healing is structurally off.
    transport: Option<Arc<dyn Transport>>,
    /// Mid-solve join listener, when the session runs one.
    join: Option<Mutex<Box<dyn NetListener>>>,
    /// Membership changes in occurrence order (drained into
    /// `SolveReport::membership`).
    events: Mutex<Vec<MembershipEvent>>,
    /// Live count at the last `Degraded` note (`usize::MAX` at full
    /// strength) — dedupes the note to strength *transitions*, not
    /// rounds.
    degraded_live: AtomicUsize,
    obs: LeaderObs,
}

impl RemoteCluster {
    /// Connect over TCP to `addrs` and handshake each against `source`'s
    /// fingerprint, with environment-configured timeouts. Unreachable or
    /// mismatched workers are skipped with a human-readable note;
    /// connecting to **zero** workers is the only hard error (callers
    /// fall back to the in-process pool on it).
    pub fn connect<S: GroupSource + ?Sized>(
        addrs: &[String],
        source: &S,
    ) -> Result<(Self, Vec<String>)> {
        Self::connect_elastic(
            Arc::new(TcpTransport),
            addrs,
            source,
            ConnectOptions::from_env(),
            None,
        )
    }

    /// [`RemoteCluster::connect`] over a borrowed [`Transport`] and an
    /// explicit timeout policy. The transport cannot be retained past the
    /// call, so this session never redials and never admits joiners —
    /// the pre-elastic contract, which parts of the chaos suite pin.
    /// Elastic sessions use [`RemoteCluster::connect_elastic`].
    pub fn connect_with<S: GroupSource + ?Sized>(
        transport: &dyn Transport,
        addrs: &[String],
        source: &S,
        opts: ConnectOptions,
    ) -> Result<(Self, Vec<String>)> {
        Self::connect_inner(transport, None, addrs, source, opts, None)
    }

    /// [`RemoteCluster::connect`] with the full elastic feature set: the
    /// transport is retained for round-boundary redials
    /// (`opts.redial_budget`), and `join`, when given, is polled at every
    /// deal boundary for mid-solve worker admissions.
    pub fn connect_elastic<S: GroupSource + ?Sized>(
        transport: Arc<dyn Transport>,
        addrs: &[String],
        source: &S,
        opts: ConnectOptions,
        join: Option<Box<dyn NetListener>>,
    ) -> Result<(Self, Vec<String>)> {
        Self::connect_inner(transport.as_ref(), Some(Arc::clone(&transport)), addrs, source, opts, join)
    }

    fn connect_inner<S: GroupSource + ?Sized>(
        transport: &dyn Transport,
        retained: Option<Arc<dyn Transport>>,
        addrs: &[String],
        source: &S,
        opts: ConnectOptions,
        join: Option<Box<dyn NetListener>>,
    ) -> Result<(Self, Vec<String>)> {
        let fingerprint = InstanceFingerprint::of(source);
        // dial concurrently: N blackholed hosts must cost one connect
        // timeout, not N, before planning can fall back in-process
        let dials: Vec<Result<WorkerLink>> = std::thread::scope(|s| {
            let handles: Vec<_> = addrs
                .iter()
                .map(|addr| {
                    let fingerprint = &fingerprint;
                    s.spawn(move || WorkerLink::connect(transport, addr, fingerprint, opts))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(Error::Runtime("worker dial thread panicked".into()))
                    })
                })
                .collect()
        });
        let mut slots = Vec::new();
        let mut skipped = Vec::new();
        for (addr, dial) in addrs.iter().zip(dials) {
            match dial {
                Ok(link) => slots.push(Arc::new(Mutex::new(link))),
                Err(e) => skipped.push(format!("worker {addr} skipped: {e}")),
            }
        }
        if slots.is_empty() {
            return Err(Error::Runtime(format!(
                "no cluster workers reachable at [{}]{}",
                addrs.join(", "),
                skipped
                    .iter()
                    .map(|s| format!("; {s}"))
                    .collect::<String>(),
            )));
        }
        let fleet = Self {
            slots: RwLock::new(slots),
            leader_pool: Cluster::configured(),
            counters: NetCounters::default(),
            clock: transport.clock(),
            opts,
            fingerprint,
            transport: retained,
            join: join.map(Mutex::new),
            events: Mutex::new(Vec::new()),
            degraded_live: AtomicUsize::new(usize::MAX),
            obs: LeaderObs::new(),
        };
        Ok((fleet, skipped))
    }

    /// Replace the pool used for leader-local phases (§5.3 pre-solve
    /// sampling, §5.4's sequential walk). The session planner threads the
    /// session's own `--workers` pool through here so distributed solves
    /// honor it; the default is [`Cluster::configured`].
    pub fn with_leader_pool(mut self, pool: Cluster) -> Self {
        self.leader_pool = pool;
        self
    }

    /// Workers in the session: dial-time plus admitted joiners.
    pub fn workers(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    /// Workers still live.
    pub fn workers_live(&self) -> usize {
        self.slots.read().unwrap().iter().filter(|s| s.lock().unwrap().is_live()).count()
    }

    /// Total advertised map-thread capacity (drives shard planning).
    pub fn capacity(&self) -> usize {
        self.slots.read().unwrap().iter().map(|s| s.lock().unwrap().threads).sum()
    }

    /// The session's worker addresses (dial-time plus admitted).
    pub fn addrs(&self) -> Vec<String> {
        self.slots.read().unwrap().iter().map(|s| s.lock().unwrap().addr.clone()).collect()
    }

    /// Membership changes so far (losses, redials, admissions,
    /// degradations), in occurrence order — the session planner attaches
    /// them to `SolveReport::membership`.
    pub fn membership_events(&self) -> Vec<MembershipEvent> {
        self.events.lock().unwrap().clone()
    }

    fn push_event(&self, event: MembershipEvent) {
        self.events.lock().unwrap().push(event);
    }

    /// The leader-local pool used for the phases that stay on the leader
    /// (§5.3 pre-solve sampling, the sequential part of §5.4).
    pub(crate) fn leader_pool(&self) -> &Cluster {
        &self.leader_pool
    }

    /// Wire statistics so far.
    pub fn stats(&self) -> NetSnapshot {
        let c = &self.counters;
        let slots = self.slots.read().unwrap();
        let (mut workers_live, mut capacity) = (0, 0);
        for slot in slots.iter() {
            let link = slot.lock().unwrap();
            workers_live += link.is_live() as usize;
            capacity += link.threads;
        }
        NetSnapshot {
            bytes_sent: c.bytes_sent.load(Ordering::Relaxed),
            bytes_received: c.bytes_received.load(Ordering::Relaxed),
            rounds: c.rounds.load(Ordering::Relaxed),
            round_ms: c.round_us.load(Ordering::Relaxed) as f64 / 1e3,
            redispatches: c.redispatches.load(Ordering::Relaxed),
            workers_lost: c.workers_lost.load(Ordering::Relaxed),
            redials: c.redials.load(Ordering::Relaxed),
            joins: c.joins.load(Ordering::Relaxed),
            workers_live,
            workers_total: slots.len(),
            capacity,
        }
    }

    /// Round-boundary healing: redial every transiently-dead link whose
    /// backoff deadline has passed, while its session budget lasts. A
    /// successful redial re-enters the deal from this round on; a dial
    /// failure schedules the next probe on the exponential-backoff curve
    /// (deterministic jitter, seeded by the slot); a handshake refusal
    /// retires the link for good. No-op without a budget or without a
    /// retained transport (the [`RemoteCluster::connect_with`] path).
    fn heal(&self, round: u64) {
        if self.opts.redial_budget == 0 {
            return;
        }
        let Some(transport) = self.transport.as_ref() else { return };
        let slots = self.slots.read().unwrap().clone();
        for (slot, link) in slots.iter().enumerate() {
            let mut link = link.lock().unwrap();
            if link.is_live()
                || link.permanent
                || link.redials_spent >= self.opts.redial_budget
                || self.clock.now_ns() < link.next_redial_at_ns
            {
                continue;
            }
            link.redials_spent += 1;
            match link.redial(transport.as_ref(), &self.fingerprint, self.opts) {
                Ok(()) => {
                    self.counters.count(&self.counters.redials, 1);
                    if crate::obs::metrics_enabled() {
                        self.obs.redials.inc();
                    }
                    crate::obs::instant(
                        self.clock.as_ref(),
                        Track::Leader,
                        names::REDIAL,
                        round,
                        slot as u64,
                    );
                    self.push_event(MembershipEvent {
                        round,
                        worker: Some(slot),
                        change: MembershipChange::Redialed,
                        detail: format!(
                            "worker {} redialed ({} of {} redials spent)",
                            link.addr, link.redials_spent, self.opts.redial_budget
                        ),
                    });
                }
                Err(e) => {
                    let delay = Backoff::delay(
                        self.opts.redial_backoff,
                        Duration::from_millis(REDIAL_BACKOFF_CAP_MS),
                        slot as u64,
                        link.attempts,
                    );
                    link.attempts = link.attempts.saturating_add(1);
                    link.next_redial_at_ns =
                        self.clock.now_ns().saturating_add(delay.as_nanos() as u64);
                    if link.permanent {
                        self.push_event(MembershipEvent {
                            round,
                            worker: Some(slot),
                            change: MembershipChange::Lost,
                            detail: format!("worker {} retired: {e}", link.addr),
                        });
                    }
                }
            }
        }
    }

    /// The earliest future redial deadline among still-healable links —
    /// what the quorum wait sleeps to (virtual time under the simulator).
    /// `None` when no dead link can come back: healing off, transport not
    /// retained, or every dead link permanent / out of budget.
    fn earliest_redial(&self, slots: &[Arc<Mutex<WorkerLink>>]) -> Option<u64> {
        if self.opts.redial_budget == 0 || self.transport.is_none() {
            return None;
        }
        slots
            .iter()
            .filter_map(|slot| {
                let link = slot.lock().unwrap();
                (!link.is_live()
                    && !link.permanent
                    && link.redials_spent < self.opts.redial_budget)
                    .then_some(link.next_redial_at_ns)
            })
            .min()
    }

    /// Emit a `Degraded` membership note when the live count *transitions*
    /// while below full strength (the `degraded_live` latch dedupes the
    /// note to transitions, not rounds), clearing the latch once the fleet
    /// is whole again.
    fn note_degraded(&self, round: u64, live: usize, total: usize) {
        if live >= total {
            self.degraded_live.store(usize::MAX, Ordering::Relaxed);
            return;
        }
        if self.degraded_live.swap(live, Ordering::Relaxed) != live {
            if crate::obs::metrics_enabled() {
                self.obs.degraded.inc();
            }
            crate::obs::instant(
                self.clock.as_ref(),
                Track::Leader,
                names::DEGRADED,
                round,
                live as u64,
            );
            self.push_event(MembershipEvent {
                round,
                worker: None,
                change: MembershipChange::Degraded,
                detail: format!("continuing degraded: {live} of {total} workers live"),
            });
        }
    }

    /// Drain the mid-solve join listener: every queued `bskp worker
    /// --join` dial-in that passes the version (frame layer) and
    /// fingerprint checks becomes a fresh slot and receives chunks from
    /// this deal on. Non-blocking — an idle listener costs one poll per
    /// deal boundary.
    fn admit_joiners(&self, round: u64) {
        let Some(join) = self.join.as_ref() else { return };
        loop {
            let polled = join.lock().unwrap().poll_accept();
            match polled {
                Ok(Some(stream)) => self.admit_one(round, stream),
                // transient accept failures retry at the next boundary
                Ok(None) | Err(_) => return,
            }
        }
    }

    fn admit_one(&self, round: u64, stream: Box<dyn NetStream>) {
        match self.join_handshake(stream) {
            Ok((threads, stream)) => {
                let addr = stream.peer();
                let slot = {
                    let mut slots = self.slots.write().unwrap();
                    slots.push(Arc::new(Mutex::new(WorkerLink::admitted(
                        addr.clone(),
                        threads as usize,
                        stream,
                    ))));
                    slots.len() - 1
                };
                self.counters.count(&self.counters.joins, 1);
                if crate::obs::metrics_enabled() {
                    self.obs.joins.inc();
                }
                crate::obs::instant(
                    self.clock.as_ref(),
                    Track::Leader,
                    names::JOIN,
                    round,
                    slot as u64,
                );
                self.push_event(MembershipEvent {
                    round,
                    worker: Some(slot),
                    change: MembershipChange::Admitted,
                    detail: format!("worker {addr} joined mid-solve ({threads} threads)"),
                });
            }
            Err(e) => {
                // a refused joiner never becomes a slot; note it for the
                // membership log so operators see the refusal
                self.push_event(MembershipEvent {
                    round,
                    worker: None,
                    change: MembershipChange::Lost,
                    detail: format!("join refused: {e}"),
                });
            }
        }
    }

    /// The leader half of the mid-solve admission handshake: expect
    /// `Join` (capacity + fingerprint), verify the fingerprint, reply
    /// `Admit`, and install the session's exchange timeouts. Version skew
    /// is caught by the frame layer before the message even decodes.
    fn join_handshake(
        &self,
        mut stream: Box<dyn NetStream>,
    ) -> Result<(u32, Box<dyn NetStream>)> {
        stream.set_read_timeout(Some(self.opts.connect_timeout))?;
        stream.set_write_timeout(Some(self.opts.connect_timeout))?;
        let (msg, _) = recv_msg(&mut stream)?;
        let (threads, theirs) = match msg {
            Msg::Join { threads, fingerprint } => (threads, fingerprint),
            other => {
                let _ = send_msg(
                    &mut stream,
                    &Msg::Abort { message: format!("expected join, got {}", other.name()) },
                );
                return Err(Error::Runtime(format!(
                    "joiner opened with {} instead of join",
                    other.name()
                )));
            }
        };
        if theirs != self.fingerprint {
            let message = format!(
                "joiner serves a different instance: leader has [{}], joiner has [{theirs}]",
                self.fingerprint
            );
            let _ = send_msg(&mut stream, &Msg::Abort { message: message.clone() });
            return Err(Error::Runtime(message));
        }
        send_msg(&mut stream, &Msg::Admit)?;
        stream.set_read_timeout(Some(self.opts.exchange_timeout))?;
        stream.set_write_timeout(Some(self.opts.exchange_timeout))?;
        Ok((threads, stream))
    }

    /// Dispatch one round: cut `[0, n_shards)` into chunks, deal them to
    /// live workers, gather the partials **indexed by chunk** — wave by
    /// wave or overlapped, per the session's [`ExchangeMode`] (the
    /// partition, the merge order and therefore the result are identical
    /// either way). Lost workers re-queue their chunks; the round only
    /// fails when no live worker remains (or a worker reports a
    /// protocol-level abort).
    fn gather<F>(&self, n_shards: usize, task: F) -> Result<Vec<Msg>>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        if n_shards == 0 {
            return Ok(Vec::new());
        }
        let t0 = self.clock.now_ns();
        // the gather ordinal doubles as the round index in span-context
        // frame extensions and EXCHANGE span arguments
        let round = self.counters.rounds.load(Ordering::Relaxed);
        let n_chunks = chunk_count(n_shards);
        let per = n_shards.div_ceil(n_chunks);
        let n_chunks = n_shards.div_ceil(per);
        let mut pending: VecDeque<usize> = (0..n_chunks).collect();
        let mut results: Vec<Option<Msg>> = (0..n_chunks).map(|_| None).collect();
        let mut last_loss = String::new();

        while !pending.is_empty() {
            // every membership change happens here, at the deal boundary:
            // drain the join listener, then redial transiently-dead links
            // whose backoff elapsed — so the deal below stays a pure
            // function of (pending, live) and sim traces stay replayable
            self.admit_joiners(round);
            self.heal(round);
            let slots: Vec<Arc<Mutex<WorkerLink>>> = self.slots.read().unwrap().clone();
            let live: Vec<usize> =
                (0..slots.len()).filter(|&i| slots[i].lock().unwrap().is_live()).collect();
            if live.is_empty() || live.len() < self.opts.min_workers {
                // healing may still restore quorum: wait out the earliest
                // redial deadline (a virtual sleep under sim) and retry
                if let Some(at_ns) = self.earliest_redial(&slots) {
                    let now = self.clock.now_ns();
                    self.clock
                        .sleep(Duration::from_nanos(at_ns.saturating_sub(now).max(1)));
                    continue;
                }
                let done = results.iter().filter(|r| r.is_some()).count();
                let failure = if last_loss.is_empty() {
                    String::new()
                } else {
                    format!("; last failure: {last_loss}")
                };
                if live.is_empty() {
                    return Err(Error::Runtime(format!(
                        "all cluster workers lost mid-round ({done} of {n_chunks} chunks \
                         done){failure}",
                    )));
                }
                return Err(Error::Runtime(format!(
                    "cluster quorum lost: {} of {} workers live, below the \
                     PALLAS_MIN_WORKERS floor of {} ({done} of {n_chunks} chunks \
                     done){failure}",
                    live.len(),
                    slots.len(),
                    self.opts.min_workers,
                )));
            }
            self.note_degraded(round, live.len(), slots.len());
            match self.opts.exchange {
                ExchangeMode::Wave => self.wave_step(
                    round,
                    per,
                    n_shards,
                    &slots,
                    &live,
                    &mut pending,
                    &mut results,
                    &mut last_loss,
                    &task,
                )?,
                ExchangeMode::Overlap => self.overlap_step(
                    round,
                    per,
                    n_shards,
                    &slots,
                    &live,
                    &mut pending,
                    &mut results,
                    &mut last_loss,
                    &task,
                )?,
            }
        }

        self.counters.count(&self.counters.rounds, 1);
        let dur_ns = self.clock.now_ns().saturating_sub(t0);
        self.counters.count(&self.counters.round_us, dur_ns / 1_000);
        if crate::obs::metrics_enabled() {
            self.obs.gather_rounds.inc();
            self.obs.gather_latency_ns.observe(dur_ns);
        }
        Ok(results.into_iter().map(|r| r.expect("all chunks gathered")).collect())
    }

    /// One wave: one pending chunk per live worker, a barrier, then the
    /// outcomes in deal order.
    #[allow(clippy::too_many_arguments)]
    fn wave_step<F>(
        &self,
        round: u64,
        per: usize,
        n_shards: usize,
        slots: &[Arc<Mutex<WorkerLink>>],
        live: &[usize],
        pending: &mut VecDeque<usize>,
        results: &mut [Option<Msg>],
        last_loss: &mut String,
        task: &F,
    ) -> Result<()>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        // the wave deal: one pending chunk per live worker, slot
        // order — a pure function of (pending, live)
        let deals: Vec<(usize, usize)> = live
            .iter()
            .map_while(|&slot| pending.pop_front().map(|chunk| (slot, chunk)))
            .collect();
        let trace_on = crate::obs::trace_enabled();
        let want_obs = trace_on || crate::obs::metrics_enabled();
        let ext = span_ext::encode_task(round, trace_on);
        let outcomes: Vec<WaveOutcome> = std::thread::scope(|s| {
            let handles: Vec<_> = deals
                .iter()
                .map(|&(slot, chunk)| {
                    let ext = &ext;
                    s.spawn(move || {
                        let lo = chunk * per;
                        let hi = (lo + per).min(n_shards);
                        let mut link = slots[slot].lock().unwrap();
                        let t0 = if want_obs { self.clock.now_ns() } else { 0 };
                        let result = link
                            .send_task(&task(lo, hi), ext, &self.counters)
                            .and_then(|()| link.recv_partial(&self.counters));
                        match result {
                            Ok((Msg::Abort { message }, _, _)) => WaveOutcome::Fatal(format!(
                                "worker {} aborted the round: {message}",
                                link.addr
                            )),
                            Ok((reply, reply_ext, received)) => {
                                if want_obs {
                                    self.observe_exchange(
                                        slot,
                                        round,
                                        lo as u64,
                                        t0,
                                        received,
                                        reply_ext.as_ref(),
                                    );
                                }
                                WaveOutcome::Done(chunk, reply)
                            }
                            Err(e) => {
                                // dead worker: back on the queue for
                                // a survivor in the next wave
                                link.kill();
                                WaveOutcome::Lost {
                                    slot,
                                    chunk,
                                    loss: format!("worker {}: {e}", link.addr),
                                }
                            }
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        WaveOutcome::Fatal("worker exchange thread panicked".into())
                    })
                })
                .collect()
        });
        for outcome in outcomes {
            match outcome {
                WaveOutcome::Done(chunk, reply) => results[chunk] = Some(reply),
                WaveOutcome::Lost { slot, chunk, loss } => {
                    self.push_event(MembershipEvent {
                        round,
                        worker: Some(slot),
                        change: MembershipChange::Lost,
                        detail: loss.clone(),
                    });
                    *last_loss = loss;
                    self.note_loss(round, per, std::slice::from_ref(&chunk));
                    pending.push_back(chunk);
                    self.counters.count(&self.counters.workers_lost, 1);
                    self.counters.count(&self.counters.redispatches, 1);
                }
                WaveOutcome::Fatal(message) => return Err(Error::Runtime(message)),
            }
        }
        Ok(())
    }

    /// Record one finished exchange: fleet-wide registry metrics plus —
    /// when tracing — the per-link `EXCHANGE` span and the worker's
    /// shipped task span, re-based onto the leader clock so it ends at
    /// receipt (the wire carries only the code and duration; round and
    /// chunk come from the in-flight task it matches).
    fn observe_exchange(
        &self,
        slot: usize,
        round: u64,
        lo: u64,
        t0_ns: u64,
        bytes: usize,
        reply_ext: Option<&[u8; EXT_LEN]>,
    ) {
        let now = self.clock.now_ns();
        let dur_ns = now.saturating_sub(t0_ns);
        if crate::obs::metrics_enabled() {
            self.obs.exchanges.inc();
            self.obs.exchange_latency_ns.observe(dur_ns);
            self.obs.exchange_bytes.observe(bytes as u64);
        }
        if crate::obs::trace_enabled() {
            let track = Track::Link(slot as u16);
            crate::obs::complete(track, names::EXCHANGE, t0_ns, dur_ns, round, lo);
            if let Some(ext) = reply_ext {
                let (code, w_dur) = span_ext::decode_span(ext);
                crate::obs::complete(track, code, now.saturating_sub(w_dur), w_dur, round, lo);
            }
        }
    }

    /// Record chunks going back on the deal queue after a worker loss:
    /// a `REDEAL` instant per chunk plus the fleet-wide counters.
    fn note_loss(&self, round: u64, per: usize, chunks: &[usize]) {
        if crate::obs::metrics_enabled() {
            self.obs.workers_lost.inc();
            self.obs.redeals.add(chunks.len() as u64);
        }
        for &chunk in chunks {
            crate::obs::instant(
                self.clock.as_ref(),
                Track::Leader,
                names::REDEAL,
                round,
                (chunk * per) as u64,
            );
        }
    }

    /// One overlapped pass: deal the *whole* pending queue round-robin
    /// over the live workers (slot order — a pure function of
    /// `(pending, live)`, like the wave deal), then run every link's
    /// queue concurrently with a [`PIPELINE_DEPTH`]-deep task pipeline
    /// per link. Outcomes are processed in slot order, so counter
    /// updates and the re-queue order of lost chunks are deterministic;
    /// partials land indexed by chunk, so the merge (and the solve
    /// result) is bit-identical to wave mode.
    #[allow(clippy::too_many_arguments)]
    fn overlap_step<F>(
        &self,
        round: u64,
        per: usize,
        n_shards: usize,
        slots: &[Arc<Mutex<WorkerLink>>],
        live: &[usize],
        pending: &mut VecDeque<usize>,
        results: &mut [Option<Msg>],
        last_loss: &mut String,
        task: &F,
    ) -> Result<()>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        let mut queues: Vec<Vec<usize>> = vec![Vec::new(); live.len()];
        for (i, chunk) in pending.drain(..).enumerate() {
            queues[i % live.len()].push(chunk);
        }
        let runs: Vec<SlotRun> = std::thread::scope(|s| {
            let handles: Vec<_> = live
                .iter()
                .zip(&queues)
                .map(|(&slot, queue)| {
                    s.spawn(move || self.run_slot(slots, slot, round, queue, per, n_shards, task))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        let mut run = SlotRun::new();
                        run.fatal = Some("worker exchange thread panicked".into());
                        run
                    })
                })
                .collect()
        });
        for (run, &slot) in runs.into_iter().zip(live) {
            if let Some(message) = run.fatal {
                return Err(Error::Runtime(message));
            }
            for (chunk, reply) in run.done {
                results[chunk] = Some(reply);
            }
            if let Some(loss) = run.loss {
                self.push_event(MembershipEvent {
                    round,
                    worker: Some(slot),
                    change: MembershipChange::Lost,
                    detail: loss.clone(),
                });
                *last_loss = loss;
                self.counters.count(&self.counters.workers_lost, 1);
                self.counters.count(&self.counters.redispatches, run.lost.len() as u64);
                self.note_loss(round, per, &run.lost);
                for chunk in run.lost {
                    pending.push_back(chunk);
                }
            }
        }
        Ok(())
    }

    /// Drive one link through its dealt queue with up to
    /// [`PIPELINE_DEPTH`] tasks in flight: fill the pipeline, read the
    /// oldest partial, refill. The wire stays strict request/response
    /// (every send is balanced by one receive, replies arrive in task
    /// order); only the leader's waiting overlaps with the worker's
    /// compute. Any wire error kills the link and reports every
    /// unanswered chunk as lost, in a deterministic order.
    #[allow(clippy::too_many_arguments)]
    fn run_slot<F>(
        &self,
        slots: &[Arc<Mutex<WorkerLink>>],
        slot: usize,
        round: u64,
        queue: &[usize],
        per: usize,
        n_shards: usize,
        task: &F,
    ) -> SlotRun
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        let trace_on = crate::obs::trace_enabled();
        let want_obs = trace_on || crate::obs::metrics_enabled();
        let ext = span_ext::encode_task(round, trace_on);
        let mut run = SlotRun::new();
        let mut link = slots[slot].lock().unwrap();
        // in-flight chunks with their send instants: a pipelined chunk's
        // exchange latency is its full turnaround, send to reply
        let mut inflight: VecDeque<(usize, u64)> = VecDeque::new();
        let mut next = 0usize;
        loop {
            while inflight.len() < PIPELINE_DEPTH && next < queue.len() {
                let chunk = queue[next];
                let lo = chunk * per;
                let hi = (lo + per).min(n_shards);
                let t_sent = if want_obs { self.clock.now_ns() } else { 0 };
                match link.send_task(&task(lo, hi), &ext, &self.counters) {
                    Ok(()) => {
                        inflight.push_back((chunk, t_sent));
                        next += 1;
                    }
                    Err(e) => {
                        link.kill();
                        run.loss = Some(format!("worker {}: {e}", link.addr));
                        run.lost.push(chunk);
                        run.lost.extend(inflight.drain(..).map(|(c, _)| c));
                        run.lost.extend(queue[next + 1..].iter().copied());
                        return run;
                    }
                }
            }
            let Some((chunk, t_sent)) = inflight.pop_front() else { return run };
            match link.recv_partial(&self.counters) {
                Ok((Msg::Abort { message }, _, _)) => {
                    run.fatal =
                        Some(format!("worker {} aborted the round: {message}", link.addr));
                    return run;
                }
                Ok((reply, reply_ext, received)) => {
                    if want_obs {
                        let lo = (chunk * per) as u64;
                        self.observe_exchange(
                            slot,
                            round,
                            lo,
                            t_sent,
                            received,
                            reply_ext.as_ref(),
                        );
                    }
                    run.done.push((chunk, reply));
                }
                Err(e) => {
                    link.kill();
                    run.loss = Some(format!("worker {}: {e}", link.addr));
                    run.lost.push(chunk);
                    run.lost.extend(inflight.drain(..).map(|(c, _)| c));
                    run.lost.extend(queue[next..].iter().copied());
                    return run;
                }
            }
        }
    }

    /// Distributed evaluation round (DD rounds, final evaluations).
    pub(crate) fn eval_round(
        &self,
        shards: Shards,
        kk: usize,
        lambda: &[f64],
    ) -> Result<RoundAgg> {
        let geo = Geometry::of(shards);
        let parts = self.gather(shards.count(), |lo, hi| Msg::EvalTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: lambda.to_vec(),
        })?;
        let mut agg = RoundAgg::new(kk);
        for part in parts {
            match part {
                Msg::EvalPartial(a) if a.consumption.len() == kk => agg = agg.merge(a),
                other => return Err(unexpected("eval-partial", &other)),
            }
        }
        Ok(agg)
    }

    /// Distributed SCD round.
    pub(crate) fn scd_round(&self, shards: Shards, spec: &ScdRoundSpec<'_>) -> Result<ScdAcc> {
        let geo = Geometry::of(shards);
        let kk = spec.lambda.len();
        let parts = self.gather(shards.count(), |lo, hi| Msg::ScdTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: spec.lambda.to_vec(),
            active: spec.active_mask.to_vec(),
            sparse_q: spec.sparse_q,
            reduce: spec.reduce,
        })?;
        let mut acc = ScdAcc::new(spec.reduce, spec.lambda);
        for part in parts {
            match part {
                Msg::ScdPartial(a)
                    if a.round.consumption.len() == kk
                        && thresholds_fit(&a.thresholds, spec.reduce, kk) =>
                {
                    acc = acc.merge(a)
                }
                other => return Err(unexpected("scd-partial", &other)),
            }
        }
        Ok(acc)
    }

    /// Distributed §5.4 ranking round.
    pub(crate) fn rank_round(&self, shards: Shards, lambda: &[f64]) -> Result<Vec<(f32, u32)>> {
        let geo = Geometry::of(shards);
        let parts = self.gather(shards.count(), |lo, hi| Msg::RankTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: lambda.to_vec(),
        })?;
        let n_groups = shards.n_total() as u32;
        let mut ranked = Vec::new();
        for part in parts {
            match part {
                Msg::RankPartial(r) if r.iter().all(|&(_, i)| i < n_groups) => ranked.extend(r),
                other => return Err(unexpected("rank-partial", &other)),
            }
        }
        Ok(ranked)
    }
}

/// Does a shipped threshold accumulator have the variant and width the
/// round expects? (A fingerprint-verified worker always satisfies this;
/// the check turns a hypothetical protocol bug into a clean error instead
/// of a mis-merge.)
fn thresholds_fit(t: &ThresholdAcc, reduce: ReduceMode, kk: usize) -> bool {
    match (t, reduce) {
        (ThresholdAcc::Exact(v), ReduceMode::Exact) => v.len() == kk,
        (ThresholdAcc::Bucketed(h), ReduceMode::Bucketed { .. }) => h.len() == kk,
        _ => false,
    }
}

fn unexpected(want: &str, got: &Msg) -> Error {
    Error::Runtime(format!(
        "cluster protocol violation: expected a well-formed {want}, got {} \
         (mismatched binaries?)",
        got.name()
    ))
}

impl Drop for RemoteCluster {
    fn drop(&mut self) {
        for slot in self.slots.read().unwrap().iter() {
            if let Ok(mut link) = slot.lock() {
                link.shutdown();
            }
        }
    }
}
