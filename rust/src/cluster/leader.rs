//! The leader-side remote executor.
//!
//! [`RemoteCluster`] owns one [`WorkerLink`](super::membership::WorkerLink)
//! per configured worker and drives synchronous rounds: the global shard
//! partition is cut into contiguous **chunks** (a fixed function of the
//! round, independent of which worker computes what), chunks are dealt to
//! workers from a shared queue (work stealing across machines, like the
//! thread pool's stealing across cores), and the partials are merged **in
//! chunk order** with compensated sums — so the result does not depend on
//! worker count, scheduling, or mid-round failures.
//!
//! **Failure handling.** A worker that errors or times out on a chunk is
//! marked dead for the session; its chunk goes back on the queue and a
//! survivor re-executes it. Because every task frame carries the round's
//! full broadcast state (λ, active mask, reduce mode), re-dispatch resumes
//! from the λ the round started with — a lost worker costs one chunk of
//! recomputation. Only when *every* worker is gone does the round (and the
//! solve) fail; with checkpointing enabled the λ trail survives for a
//! warm-started retry.

use crate::cluster::env_ms;
use crate::cluster::membership::{NetCounters, WorkerLink};
use crate::cluster::protocol::{Geometry, InstanceFingerprint, Msg};
use crate::error::{Error, Result};
use crate::instance::problem::GroupSource;
use crate::instance::shard::Shards;
use crate::mapreduce::Cluster;
use crate::solver::config::ReduceMode;
use crate::solver::rounds::RoundAgg;
use crate::solver::scd::{ScdAcc, ScdRoundSpec, ThresholdAcc};
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::Mutex;

/// Default per-exchange timeout. This is the *only* detector for a worker
/// that is silently partitioned (process death shows up immediately as
/// RST/EOF), so it must comfortably exceed the slowest honest chunk: at
/// N = 1e9 a chunk is ~N/64 groups, minutes of folding on a loaded box.
/// 10 minutes trades partition-detection latency for never killing a
/// healthy-but-slow fleet; tighten via `PALLAS_CLUSTER_TIMEOUT_MS` when
/// chunks are known to be fast.
const DEFAULT_TIMEOUT_MS: u64 = 600_000;

/// Default connect/handshake timeout (seconds, not minutes: planning must
/// reach its in-process fallback promptly when a fleet is blackholed).
const DEFAULT_CONNECT_TIMEOUT_MS: u64 = 5_000;

/// Chunks per round: a pure function of the shard count — deliberately
/// **independent of worker count and liveness**, so the chunk partition
/// (and with it the merge order of every compensated sum) is identical
/// for any fleet size and any mid-round failure pattern. 64 chunks give
/// fine-grained stealing and re-dispatch for any realistic fleet while
/// keeping per-round frame counts and per-chunk accumulators bounded.
const CHUNKS_PER_ROUND: usize = 64;

fn chunk_count(n_shards: usize) -> usize {
    n_shards.min(CHUNKS_PER_ROUND)
}

/// Point-in-time wire statistics of a [`RemoteCluster`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetSnapshot {
    /// Task bytes written to workers (frames included).
    pub bytes_sent: u64,
    /// Partial bytes read from workers (frames included).
    pub bytes_received: u64,
    /// Gather rounds completed.
    pub rounds: u64,
    /// Total wall time inside gathers, milliseconds.
    pub round_ms: f64,
    /// Chunks re-dispatched after a worker loss.
    pub redispatches: u64,
    /// Workers lost during the session.
    pub workers_lost: u64,
    /// Workers still live.
    pub workers_live: usize,
    /// Workers the session started with.
    pub workers_total: usize,
    /// Advertised map-thread capacity across all started workers.
    pub capacity: usize,
}

/// A fleet of `pallas worker` processes, driven over TCP with the same
/// map→combine→reduce contract as the in-process
/// [`Cluster`] (see [`super::Exec`]).
pub struct RemoteCluster {
    slots: Vec<Mutex<WorkerLink>>,
    leader_pool: Cluster,
    capacity: usize,
    counters: NetCounters,
}

impl RemoteCluster {
    /// Connect to `addrs` and handshake each against `source`'s
    /// fingerprint. Unreachable or mismatched workers are skipped with a
    /// human-readable note; connecting to **zero** workers is the only
    /// hard error (callers fall back to the in-process pool on it).
    pub fn connect<S: GroupSource + ?Sized>(
        addrs: &[String],
        source: &S,
    ) -> Result<(Self, Vec<String>)> {
        let fingerprint = InstanceFingerprint::of(source);
        let exchange_timeout = env_ms("PALLAS_CLUSTER_TIMEOUT_MS", DEFAULT_TIMEOUT_MS);
        let connect_timeout =
            env_ms("PALLAS_CLUSTER_CONNECT_TIMEOUT_MS", DEFAULT_CONNECT_TIMEOUT_MS);
        // dial concurrently: N blackholed hosts must cost one connect
        // timeout, not N, before planning can fall back in-process
        let dials: Vec<Result<WorkerLink>> = std::thread::scope(|s| {
            let handles: Vec<_> = addrs
                .iter()
                .map(|addr| {
                    let fingerprint = &fingerprint;
                    s.spawn(move || {
                        WorkerLink::connect(addr, fingerprint, connect_timeout, exchange_timeout)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(Error::Runtime("worker dial thread panicked".into()))
                    })
                })
                .collect()
        });
        let mut slots = Vec::new();
        let mut skipped = Vec::new();
        for (addr, dial) in addrs.iter().zip(dials) {
            match dial {
                Ok(link) => slots.push(Mutex::new(link)),
                Err(e) => skipped.push(format!("worker {addr} skipped: {e}")),
            }
        }
        if slots.is_empty() {
            return Err(Error::Runtime(format!(
                "no cluster workers reachable at [{}]{}",
                addrs.join(", "),
                skipped
                    .iter()
                    .map(|s| format!("; {s}"))
                    .collect::<String>(),
            )));
        }
        let capacity = slots.iter().map(|s| s.lock().unwrap().threads).sum();
        let leader_pool = Cluster::configured();
        Ok((Self { slots, leader_pool, capacity, counters: NetCounters::default() }, skipped))
    }

    /// Replace the pool used for leader-local phases (§5.3 pre-solve
    /// sampling, §5.4's sequential walk). The session planner threads the
    /// session's own `--workers` pool through here so distributed solves
    /// honor it; the default is [`Cluster::configured`].
    pub fn with_leader_pool(mut self, pool: Cluster) -> Self {
        self.leader_pool = pool;
        self
    }

    /// Workers the session started with.
    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Workers still live.
    pub fn workers_live(&self) -> usize {
        self.slots.iter().filter(|s| s.lock().unwrap().is_live()).count()
    }

    /// Total advertised map-thread capacity (drives shard planning).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The configured worker addresses.
    pub fn addrs(&self) -> Vec<String> {
        self.slots.iter().map(|s| s.lock().unwrap().addr.clone()).collect()
    }

    /// The leader-local pool used for the phases that stay on the leader
    /// (§5.3 pre-solve sampling, the sequential part of §5.4).
    pub(crate) fn leader_pool(&self) -> &Cluster {
        &self.leader_pool
    }

    /// Wire statistics so far.
    pub fn stats(&self) -> NetSnapshot {
        let c = &self.counters;
        NetSnapshot {
            bytes_sent: c.bytes_sent.load(Ordering::Relaxed),
            bytes_received: c.bytes_received.load(Ordering::Relaxed),
            rounds: c.rounds.load(Ordering::Relaxed),
            round_ms: c.round_us.load(Ordering::Relaxed) as f64 / 1e3,
            redispatches: c.redispatches.load(Ordering::Relaxed),
            workers_lost: c.workers_lost.load(Ordering::Relaxed),
            workers_live: self.workers_live(),
            workers_total: self.slots.len(),
            capacity: self.capacity,
        }
    }

    /// Dispatch one round: cut `[0, n_shards)` into chunks, deal them to
    /// live workers, gather the partials **indexed by chunk**. Lost
    /// workers re-queue their chunk; the round only fails when no live
    /// worker remains (or a worker reports a protocol-level abort).
    fn gather<F>(&self, n_shards: usize, task: F) -> Result<Vec<Msg>>
    where
        F: Fn(usize, usize) -> Msg + Sync,
    {
        if n_shards == 0 {
            return Ok(Vec::new());
        }
        let t0 = std::time::Instant::now();
        let n_chunks = chunk_count(n_shards);
        let per = n_shards.div_ceil(n_chunks);
        let n_chunks = n_shards.div_ceil(per);
        let queue: Mutex<VecDeque<usize>> = Mutex::new((0..n_chunks).collect());
        let results: Mutex<Vec<Option<Msg>>> =
            Mutex::new((0..n_chunks).map(|_| None).collect());
        let fatal: Mutex<Option<Error>> = Mutex::new(None);
        let mut last_loss = String::new();

        loop {
            let live: Vec<usize> = (0..self.slots.len())
                .filter(|&i| self.slots[i].lock().unwrap().is_live())
                .collect();
            if live.is_empty() {
                return Err(Error::Runtime(format!(
                    "all cluster workers lost mid-round ({} of {} chunks done){}",
                    results.lock().unwrap().iter().filter(|r| r.is_some()).count(),
                    n_chunks,
                    if last_loss.is_empty() {
                        String::new()
                    } else {
                        format!("; last failure: {last_loss}")
                    },
                )));
            }
            let losses: Mutex<Vec<String>> = Mutex::new(Vec::new());
            std::thread::scope(|s| {
                for &slot in &live {
                    let (queue, results, fatal, losses) = (&queue, &results, &fatal, &losses);
                    let task = &task;
                    s.spawn(move || {
                        let mut link = self.slots[slot].lock().unwrap();
                        loop {
                            if fatal.lock().unwrap().is_some() {
                                break;
                            }
                            let Some(chunk) = queue.lock().unwrap().pop_front() else {
                                break;
                            };
                            let lo = chunk * per;
                            let hi = (lo + per).min(n_shards);
                            match link.exchange(&task(lo, hi), &self.counters) {
                                Ok(Msg::Abort { message }) => {
                                    *fatal.lock().unwrap() = Some(Error::Runtime(format!(
                                        "worker {} aborted the round: {message}",
                                        link.addr
                                    )));
                                    break;
                                }
                                Ok(reply) => {
                                    results.lock().unwrap()[chunk] = Some(reply);
                                }
                                Err(e) => {
                                    // dead worker: back on the queue for a
                                    // survivor (possibly one still looping
                                    // in this very scope)
                                    losses
                                        .lock()
                                        .unwrap()
                                        .push(format!("worker {}: {e}", link.addr));
                                    link.kill();
                                    queue.lock().unwrap().push_back(chunk);
                                    self.counters
                                        .count(&self.counters.workers_lost, 1);
                                    self.counters
                                        .count(&self.counters.redispatches, 1);
                                    break;
                                }
                            }
                        }
                    });
                }
            });
            if let Some(e) = fatal.lock().unwrap().take() {
                return Err(e);
            }
            if let Some(loss) = losses.lock().unwrap().last() {
                last_loss = loss.clone();
            }
            let done = queue.lock().unwrap().is_empty()
                && results.lock().unwrap().iter().all(|r| r.is_some());
            if done {
                break;
            }
        }

        self.counters.count(&self.counters.rounds, 1);
        self.counters
            .count(&self.counters.round_us, t0.elapsed().as_micros() as u64);
        let out = results.into_inner().unwrap();
        Ok(out.into_iter().map(|r| r.expect("all chunks gathered")).collect())
    }

    /// Distributed evaluation round (DD rounds, final evaluations).
    pub(crate) fn eval_round(
        &self,
        shards: Shards,
        kk: usize,
        lambda: &[f64],
    ) -> Result<RoundAgg> {
        let geo = Geometry::of(shards);
        let parts = self.gather(shards.count(), |lo, hi| Msg::EvalTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: lambda.to_vec(),
        })?;
        let mut agg = RoundAgg::new(kk);
        for part in parts {
            match part {
                Msg::EvalPartial(a) if a.consumption.len() == kk => agg = agg.merge(a),
                other => return Err(unexpected("eval-partial", &other)),
            }
        }
        Ok(agg)
    }

    /// Distributed SCD round.
    pub(crate) fn scd_round(&self, shards: Shards, spec: &ScdRoundSpec<'_>) -> Result<ScdAcc> {
        let geo = Geometry::of(shards);
        let kk = spec.lambda.len();
        let parts = self.gather(shards.count(), |lo, hi| Msg::ScdTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: spec.lambda.to_vec(),
            active: spec.active_mask.to_vec(),
            sparse_q: spec.sparse_q,
            reduce: spec.reduce,
        })?;
        let mut acc = ScdAcc::new(spec.reduce, spec.lambda);
        for part in parts {
            match part {
                Msg::ScdPartial(a)
                    if a.round.consumption.len() == kk
                        && thresholds_fit(&a.thresholds, spec.reduce, kk) =>
                {
                    acc = acc.merge(a)
                }
                other => return Err(unexpected("scd-partial", &other)),
            }
        }
        Ok(acc)
    }

    /// Distributed §5.4 ranking round.
    pub(crate) fn rank_round(&self, shards: Shards, lambda: &[f64]) -> Result<Vec<(f32, u32)>> {
        let geo = Geometry::of(shards);
        let parts = self.gather(shards.count(), |lo, hi| Msg::RankTask {
            geo,
            lo: lo as u64,
            hi: hi as u64,
            lambda: lambda.to_vec(),
        })?;
        let n_groups = shards.n_total() as u32;
        let mut ranked = Vec::new();
        for part in parts {
            match part {
                Msg::RankPartial(r) if r.iter().all(|&(_, i)| i < n_groups) => ranked.extend(r),
                other => return Err(unexpected("rank-partial", &other)),
            }
        }
        Ok(ranked)
    }
}

/// Does a shipped threshold accumulator have the variant and width the
/// round expects? (A fingerprint-verified worker always satisfies this;
/// the check turns a hypothetical protocol bug into a clean error instead
/// of a mis-merge.)
fn thresholds_fit(t: &ThresholdAcc, reduce: ReduceMode, kk: usize) -> bool {
    match (t, reduce) {
        (ThresholdAcc::Exact(v), ReduceMode::Exact) => v.len() == kk,
        (ThresholdAcc::Bucketed(h), ReduceMode::Bucketed { .. }) => h.len() == kk,
        _ => false,
    }
}

fn unexpected(want: &str, got: &Msg) -> Error {
    Error::Runtime(format!(
        "cluster protocol violation: expected a well-formed {want}, got {} \
         (mismatched binaries?)",
        got.name()
    ))
}

impl Drop for RemoteCluster {
    fn drop(&mut self) {
        for slot in &self.slots {
            if let Ok(mut link) = slot.lock() {
                link.shutdown();
            }
        }
    }
}
