//! Time as a capability.
//!
//! Every cluster-layer timeout, retry pause and duration metric goes
//! through [`Clock`] instead of bare `Instant::now()` / `thread::sleep`,
//! so the deterministic simulator ([`super::sim`]) can substitute a
//! [`VirtualClock`] and no test ever sleeps wall-clock time. Stream-level
//! read/write deadlines stay expressed as `Duration`s on
//! [`super::transport::NetStream`]; what changes per transport is how
//! those durations elapse — against the OS clock on TCP, against virtual
//! nanoseconds under the simulator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Monotonic time source for the cluster layer.
pub trait Clock: Send + Sync {
    /// Nanoseconds since an arbitrary (per-process) epoch. Monotone
    /// non-decreasing.
    fn now_ns(&self) -> u64;

    /// Pause the caller for `d` — wall-clock on the system clock, a pure
    /// virtual-time advance on the simulator's.
    fn sleep(&self, d: Duration);
}

/// The production clock: `Instant` against a process-wide epoch, real
/// `thread::sleep`.
#[derive(Debug, Clone, Copy, Default)]
pub struct SystemClock;

static EPOCH: OnceLock<Instant> = OnceLock::new();

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// A virtual clock: time is a counter, advanced only by simulation events
/// (frame deliveries, fired timeouts, explicit sleeps). Two runs that
/// process the same event sequence read the same timestamps, and a
/// 10-minute timeout "elapses" in microseconds of wall time.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_ns: AtomicU64,
}

impl VirtualClock {
    /// A clock starting at virtual zero.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Move the clock forward to `t` (no-op when it is already past —
    /// `fetch_max`, so concurrent advances commute and the final reading
    /// is order-independent).
    pub fn advance_to(&self, t_ns: u64) {
        self.now_ns.fetch_max(t_ns, Ordering::Relaxed);
    }
}

impl Clock for VirtualClock {
    fn now_ns(&self) -> u64 {
        self.now_ns.load(Ordering::Relaxed)
    }

    fn sleep(&self, d: Duration) {
        self.now_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Exponential backoff with deterministic jitter, elapsing through the
/// [`Clock`] seam — real sleeps in production, pure virtual-time advances
/// under the simulator, so no test ever sleeps wall-clock time.
///
/// The schedule is a pure function of `(base, cap, seed, attempt)`:
/// `base · 2^attempt` plus up to 25 % jitter drawn from
/// [`mix64(seed, attempt)`](crate::rng::mix64), capped at `cap`. Sharing
/// one helper keeps every retry loop (worker/serve accept loops, leader
/// redials, join dials) on the same replayable curve.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    seed: u64,
    attempt: u32,
}

impl Backoff {
    /// A fresh schedule starting at `base`, never exceeding `cap`.
    /// `seed` decorrelates the jitter of independent retry loops.
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Self {
        Self { base, cap, seed, attempt: 0 }
    }

    /// The delay `attempt` consecutive failures in — a pure function, so
    /// callers that keep their own attempt counters (the leader's
    /// per-link redial schedule) share the exact curve of the stateful
    /// helper.
    pub fn delay(base: Duration, cap: Duration, seed: u64, attempt: u32) -> Duration {
        let base_ns = (base.as_nanos() as u64).max(1);
        let raw = base_ns.saturating_mul(1u64 << attempt.min(20));
        let jitter = crate::rng::mix64(seed, attempt as u64) % (raw / 4).max(1);
        Duration::from_nanos(raw.saturating_add(jitter).min(cap.as_nanos() as u64))
    }

    /// Sleep the next delay on `clock` and advance the schedule.
    pub fn wait(&mut self, clock: &dyn Clock) {
        let d = Self::delay(self.base, self.cap, self.seed, self.attempt);
        self.attempt = self.attempt.saturating_add(1);
        clock.sleep(d);
    }

    /// A success resets the schedule to the base delay.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotone() {
        let c = SystemClock;
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_never_really_sleeps() {
        let c = VirtualClock::new();
        let wall = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(wall.elapsed() < Duration::from_secs(1), "virtual sleep must not block");
        assert_eq!(c.now_ns(), 3600 * 1_000_000_000);
        c.advance_to(10); // already past: no-op
        assert_eq!(c.now_ns(), 3600 * 1_000_000_000);
        c.advance_to(u64::MAX - 1);
        assert_eq!(c.now_ns(), u64::MAX - 1);
    }

    #[test]
    fn backoff_is_deterministic_and_grows_exponentially() {
        let base = Duration::from_millis(100);
        let cap = Duration::from_secs(60);
        for attempt in 0..8 {
            let a = Backoff::delay(base, cap, 7, attempt);
            let b = Backoff::delay(base, cap, 7, attempt);
            assert_eq!(a, b, "same (seed, attempt) must give the same delay");
            let raw = 100u64 << attempt;
            assert!(a >= Duration::from_millis(raw), "attempt {attempt}: {a:?} < base·2^n");
            assert!(a < Duration::from_millis(raw + raw / 4 + 1), "attempt {attempt}: {a:?}");
        }
        // doubling beats max jitter: the schedule is strictly monotone
        for attempt in 0..7 {
            assert!(
                Backoff::delay(base, cap, 7, attempt + 1) > Backoff::delay(base, cap, 7, attempt)
            );
        }
        // the cap bounds arbitrarily late attempts
        assert_eq!(Backoff::delay(base, cap, 7, 63), cap);
    }

    #[test]
    fn backoff_waits_in_virtual_time_and_resets() {
        let c = VirtualClock::new();
        let mut b = Backoff::new(Duration::from_millis(100), Duration::from_secs(5), 3);
        let wall = Instant::now();
        b.wait(c.as_ref());
        let first = c.now_ns();
        assert!(first >= 100_000_000, "first wait must be at least the base delay");
        b.wait(c.as_ref());
        assert!(c.now_ns() - first > first, "second wait must back off further");
        b.reset();
        let at = c.now_ns();
        b.wait(c.as_ref());
        assert_eq!(c.now_ns() - at, first, "reset must restart the schedule");
        assert!(wall.elapsed() < Duration::from_secs(1), "backoff must not sleep for real");
    }
}
