//! Time as a capability.
//!
//! Every cluster-layer timeout, retry pause and duration metric goes
//! through [`Clock`] instead of bare `Instant::now()` / `thread::sleep`,
//! so the deterministic simulator ([`super::sim`]) can substitute a
//! [`VirtualClock`] and no test ever sleeps wall-clock time. Stream-level
//! read/write deadlines stay expressed as `Duration`s on
//! [`super::transport::NetStream`]; what changes per transport is how
//! those durations elapse — against the OS clock on TCP, against virtual
//! nanoseconds under the simulator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Monotonic time source for the cluster layer.
pub trait Clock: Send + Sync {
    /// Nanoseconds since an arbitrary (per-process) epoch. Monotone
    /// non-decreasing.
    fn now_ns(&self) -> u64;

    /// Pause the caller for `d` — wall-clock on the system clock, a pure
    /// virtual-time advance on the simulator's.
    fn sleep(&self, d: Duration);
}

/// The production clock: `Instant` against a process-wide epoch, real
/// `thread::sleep`.
#[derive(Debug, Clone, Copy, Default)]
pub struct SystemClock;

static EPOCH: OnceLock<Instant> = OnceLock::new();

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// A virtual clock: time is a counter, advanced only by simulation events
/// (frame deliveries, fired timeouts, explicit sleeps). Two runs that
/// process the same event sequence read the same timestamps, and a
/// 10-minute timeout "elapses" in microseconds of wall time.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_ns: AtomicU64,
}

impl VirtualClock {
    /// A clock starting at virtual zero.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Move the clock forward to `t` (no-op when it is already past —
    /// `fetch_max`, so concurrent advances commute and the final reading
    /// is order-independent).
    pub fn advance_to(&self, t_ns: u64) {
        self.now_ns.fetch_max(t_ns, Ordering::Relaxed);
    }
}

impl Clock for VirtualClock {
    fn now_ns(&self) -> u64 {
        self.now_ns.load(Ordering::Relaxed)
    }

    fn sleep(&self, d: Duration) {
        self.now_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotone() {
        let c = SystemClock;
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_never_really_sleeps() {
        let c = VirtualClock::new();
        let wall = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(wall.elapsed() < Duration::from_secs(1), "virtual sleep must not block");
        assert_eq!(c.now_ns(), 3600 * 1_000_000_000);
        c.advance_to(10); // already past: no-op
        assert_eq!(c.now_ns(), 3600 * 1_000_000_000);
        c.advance_to(u64::MAX - 1);
        assert_eq!(c.now_ns(), u64::MAX - 1);
    }
}
