//! The transport seam: byte streams the cluster runtime is generic over.
//!
//! Framing (`frames`), the handshake, leader dispatch and worker
//! sessions are all written against these three traits instead of concrete
//! `TcpStream` / `TcpListener`:
//!
//! * [`NetStream`] — a reliable, ordered, bidirectional byte stream with
//!   read/write deadlines (exactly `TcpStream`'s contract);
//! * [`NetListener`] — an accept loop producing such streams;
//! * [`Transport`] — the leader-side dialer, plus the [`Clock`] that
//!   timeouts and duration metrics elapse against.
//!
//! [`TcpTransport`] is the production implementation — byte-for-byte the
//! wire behavior the runtime always had (the traits add no framing, no
//! headers, nothing). [`super::sim`] provides the second implementation:
//! an in-memory network with a virtual clock and seeded fault injection,
//! which is what makes cluster failures reproducible from a seed.

use crate::cluster::clock::{Clock, SystemClock};
use crate::error::{Error, Result};
use std::io;
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

/// A reliable ordered byte stream between a leader and a worker.
///
/// Deadlines are `Duration`s, as on `TcpStream`: a blocked read/write
/// fails with `ErrorKind::TimedOut`/`WouldBlock` once the duration has
/// elapsed — wall-clock on TCP, virtual time on the simulator.
pub trait NetStream: io::Read + io::Write + Send {
    /// Bound every subsequent read. `None` removes the bound.
    fn set_read_timeout(&mut self, t: Option<Duration>) -> io::Result<()>;

    /// Bound every subsequent write. `None` removes the bound.
    fn set_write_timeout(&mut self, t: Option<Duration>) -> io::Result<()>;

    /// Peer address, for diagnostics only.
    fn peer(&self) -> String;
}

impl NetStream for TcpStream {
    fn set_read_timeout(&mut self, t: Option<Duration>) -> io::Result<()> {
        TcpStream::set_read_timeout(self, t)
    }

    fn set_write_timeout(&mut self, t: Option<Duration>) -> io::Result<()> {
        TcpStream::set_write_timeout(self, t)
    }

    fn peer(&self) -> String {
        self.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into())
    }
}

/// Accept side of a transport (what `pallas worker` serves on).
pub trait NetListener: Send + Sync {
    /// Block for the next inbound stream. `Ok(None)` means the listener
    /// is permanently retired (simulator shutdown) and the serve loop
    /// should return; `Err` is a transient accept failure the caller may
    /// retry after a breath.
    fn accept_stream(&self) -> io::Result<Option<Box<dyn NetStream>>>;

    /// Non-blocking accept: a queued inbound stream if one is already
    /// waiting, `Ok(None)` otherwise — never blocks. This is how the
    /// leader drains its mid-solve join listener at round boundaries
    /// without stalling the gather. The default suits listeners that
    /// cannot poll: nothing is ever pending.
    fn poll_accept(&self) -> io::Result<Option<Box<dyn NetStream>>> {
        Ok(None)
    }

    /// Bound address, for announcements.
    fn local_addr(&self) -> String;

    /// The clock this listener's timeouts elapse against.
    fn clock(&self) -> Arc<dyn Clock>;

    /// A dialer on the same network this listener accepts from, if the
    /// transport supports worker-originated dials. A worker serving on
    /// this listener uses it to reach *other workers* when the leader
    /// promotes it to a relay (`RelayAssign`); `None` (the default) means
    /// the worker cannot dial and refuses relay assignments.
    fn dialer(&self) -> Option<Arc<dyn Transport>> {
        None
    }
}

/// Leader-side dialer + the clock its session runs on.
pub trait Transport: Send + Sync {
    /// Open a stream to `addr`, bounding the dial by `connect_timeout`.
    fn dial(&self, addr: &str, connect_timeout: Duration) -> Result<Box<dyn NetStream>>;

    /// The clock cluster timeouts and duration metrics elapse against.
    fn clock(&self) -> Arc<dyn Clock>;
}

/// The production transport: plain `TcpStream` dialing, `SystemClock`
/// time. Wire bytes are identical to the pre-seam runtime.
#[derive(Debug, Clone, Copy, Default)]
pub struct TcpTransport;

impl Transport for TcpTransport {
    fn dial(&self, addr: &str, connect_timeout: Duration) -> Result<Box<dyn NetStream>> {
        // try every resolved address (dual-stack hosts often resolve ::1
        // first while the worker bound IPv4), keeping the last error
        let socks: Vec<_> = addr
            .to_socket_addrs()
            .map_err(|e| Error::Runtime(format!("cannot resolve {addr}: {e}")))?
            .collect();
        if socks.is_empty() {
            return Err(Error::Runtime(format!("{addr} resolves to no address")));
        }
        let mut stream = None;
        let mut last_err = String::new();
        for sock in &socks {
            match TcpStream::connect_timeout(sock, connect_timeout) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = e.to_string(),
            }
        }
        let stream =
            stream.ok_or_else(|| Error::Runtime(format!("connect {addr}: {last_err}")))?;
        stream.set_nodelay(true).ok();
        Ok(Box::new(stream))
    }

    fn clock(&self) -> Arc<dyn Clock> {
        Arc::new(SystemClock)
    }
}

/// [`NetListener`] over a bound `TcpListener` (what [`TcpTransport`]
/// peers accept on).
pub struct TcpNetListener {
    inner: TcpListener,
}

impl TcpNetListener {
    /// Wrap a bound listener.
    pub fn new(inner: TcpListener) -> Self {
        Self { inner }
    }
}

impl NetListener for TcpNetListener {
    fn accept_stream(&self) -> io::Result<Option<Box<dyn NetStream>>> {
        let (stream, _) = self.inner.accept()?;
        stream.set_nodelay(true).ok();
        Ok(Some(Box::new(stream)))
    }

    fn poll_accept(&self) -> io::Result<Option<Box<dyn NetStream>>> {
        self.inner.set_nonblocking(true)?;
        let accepted = self.inner.accept();
        // restore blocking before surfacing any result so a later
        // accept_stream is unaffected even when the poll errors
        self.inner.set_nonblocking(false)?;
        match accepted {
            Ok((stream, _)) => {
                // the accepted socket's non-blocking flag is platform-
                // dependent; force the blocking contract NetStream expects
                stream.set_nonblocking(false)?;
                stream.set_nodelay(true).ok();
                Ok(Some(Box::new(stream)))
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e),
        }
    }

    fn local_addr(&self) -> String {
        self.inner.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    fn clock(&self) -> Arc<dyn Clock> {
        Arc::new(SystemClock)
    }

    fn dialer(&self) -> Option<Arc<dyn Transport>> {
        Some(Arc::new(TcpTransport))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    #[test]
    fn tcp_roundtrip_through_the_seam() {
        // the traits must add nothing: bytes written through a boxed
        // NetStream arrive verbatim on the accepted boxed NetStream
        let listener = TcpNetListener::new(TcpListener::bind("127.0.0.1:0").unwrap());
        let addr = listener.local_addr();
        let server = std::thread::spawn(move || {
            let mut s = listener.accept_stream().unwrap().expect("tcp accept");
            let mut buf = [0u8; 5];
            s.read_exact(&mut buf).unwrap();
            s.write_all(&buf).unwrap();
            buf
        });
        let mut c = TcpTransport.dial(&addr, Duration::from_secs(5)).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        c.write_all(b"hello").unwrap();
        c.flush().unwrap();
        let mut back = [0u8; 5];
        c.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"hello");
        assert_eq!(server.join().unwrap(), *b"hello");
        assert!(!c.peer().is_empty());
    }

    #[test]
    fn tcp_poll_accept_never_blocks() {
        let listener = TcpNetListener::new(TcpListener::bind("127.0.0.1:0").unwrap());
        assert!(listener.poll_accept().unwrap().is_none(), "idle listener polls empty");
        let addr = listener.local_addr();
        let mut c = TcpTransport.dial(&addr, Duration::from_secs(5)).unwrap();
        let mut polled = None;
        for _ in 0..100 {
            if let Some(s) = listener.poll_accept().unwrap() {
                polled = Some(s);
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        let mut s = polled.expect("dialed stream surfaces through poll_accept");
        c.write_all(b"hi").unwrap();
        c.flush().unwrap();
        let mut buf = [0u8; 2];
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"hi");
        assert!(listener.poll_accept().unwrap().is_none(), "queue drained");
    }

    #[test]
    fn tcp_dial_refused_is_a_clean_error() {
        // port 9 (discard) is almost surely closed on loopback
        let err = TcpTransport.dial("127.0.0.1:9", Duration::from_millis(200)).unwrap_err();
        assert!(err.to_string().contains("127.0.0.1:9"), "{err}");
    }
}
