//! Deterministic cluster simulation: an in-memory [`Transport`] with a
//! virtual clock and seeded fault injection.
//!
//! [`SimNet`] hosts N in-process workers (each a real
//! [`worker::serve_net`](super::worker::serve_net) loop on its own thread,
//! memory-mapping its shard-store replica) and hands the leader a
//! [`SimTransport`] whose streams carry the *unchanged* frame bytes of the
//! TCP protocol. A whole `solve_scd_exec` / `solve_dd_exec` — handshake,
//! rounds, failures, re-dispatch — runs without a socket, and every
//! failure is replayable from `(seed, FaultPlan)` alone.
//!
//! ## Fault model
//!
//! The production transport is TCP: a *reliable, ordered* stream. The
//! simulator therefore injects faults the way they reach a TCP
//! application, not the way they happen on the wire:
//!
//! * **drop** — a lost segment is retransmitted: the frame arrives late
//!   (one RTO per loss). More than [`MAX_RETRANSMITS`] consecutive losses
//!   breaks the connection (both ends see EOF), like a TCP give-up.
//! * **delay / jitter** — added one-way latency, per frame.
//! * **duplicate / reorder** — the reliable layer suppresses duplicates
//!   and resequences out-of-order segments; both surface purely as extra
//!   head-of-line latency (and as flags in the trace).
//! * **corrupt** — a flipped byte that *escaped* TCP's weak 16-bit
//!   checksum (or a bad NIC / middlebox). It is delivered, and the frame
//!   layer's XXH64 **must** reject it — that is the check the chaos suite
//!   exercises.
//! * **crash / stall** — a worker dies when a chosen frame sequence
//!   number is hit (or on demand via [`SimNet::crash_worker`], e.g. from a
//!   `SolveObserver` at a chosen round); a stalled worker's replies are
//!   delayed past the leader's exchange timeout, which then fires in
//!   **virtual** time — no test ever sleeps wall-clock time. A crashed
//!   worker can [`SimNet::rejoin_worker`] and accept new sessions;
//!   without a redial budget the leader never resurrects a link *within*
//!   a session (itself under test), while [`LinkFaults::redial_after`]
//!   plans a deterministic restart for a leader that heals.
//! * **elasticity** — [`LinkFaults::redial_after`] restarts a crashed
//!   worker after N bounced re-dials (exercising the leader's backoff
//!   redial loop), and [`FaultPlan::join_at_round`] admits fresh workers
//!   mid-solve through the leader's join listener
//!   ([`SimNet::join_worker`] / [`SimNet::elastic_observer`]).
//!
//! Every per-frame decision is a pure function of
//! `(seed, worker, connection, direction, frame seq)` — independent of
//! thread interleaving — and chunk dealing on the leader is a pure
//! function of round state, so two runs with the same `(seed, plan)`
//! produce identical per-link event traces ([`SimNet::trace`]) and
//! bit-identical `SolveReport`s. Delivery times anchor on the *sender's*
//! stream-local virtual clock, so this holds under the overlapped
//! exchange too — with one caveat: overlap flushes a link's two
//! directions concurrently, so the recorded order of causally unrelated
//! events from opposite directions within one link can vary between
//! replays. Wave mode ([`super::ExchangeMode::Wave`]) keeps each link's
//! trace totally ordered; overlap replays compare equal after sorting
//! events by `(worker, conn, dir, seq)`.
//!
//! ## Virtual time
//!
//! Each link carries its own virtual clock, advanced by deliveries and
//! fired timeouts; the global [`VirtualClock`] is the running maximum.
//! A blocking read decides *virtually* whether its deadline fires: it
//! waits (wall-clock) only while the peer is genuinely computing, and
//! resolves instantly once the peer is blocked too or the next arrival
//! is known — a 10-minute exchange timeout costs microseconds of test
//! time. A real-time guard (`PALLAS_SIM_HANG_SECS`, default 30 s)
//! panics with the full trace if the simulation ever truly wedges, so a
//! protocol deadlock fails loudly instead of hanging CI.
//!
//! `docs/simulation.md` is the user guide; `rust/tests/
//! proptest_cluster_sim.rs` is the chaos suite built on this module.

use crate::cluster::clock::{Clock, VirtualClock};
use crate::cluster::transport::{NetListener, NetStream, Transport};
use crate::cluster::worker;
use crate::error::{Error, Result};
use crate::instance::store::MmapProblem;
use crate::mapreduce::Cluster;
use crate::rng::{mix64, Xoshiro256pp};
use std::collections::VecDeque;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Retransmission timeout: the virtual latency a dropped segment costs.
pub const RETRANSMIT_NS: u64 = 200_000_000;

/// Consecutive losses of one frame before the connection is declared
/// broken (TCP give-up).
pub const MAX_RETRANSMITS: u32 = 5;

/// Frame direction on a link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// Leader → worker (hello, tasks, shutdown).
    ToWorker = 0,
    /// Worker → leader (welcome, partials, aborts).
    ToLeader = 1,
}

/// Which end of a link a stream is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Side {
    Leader,
    Worker,
}

impl Side {
    fn inbound(self) -> Dir {
        match self {
            Side::Leader => Dir::ToLeader,
            Side::Worker => Dir::ToWorker,
        }
    }

    fn outbound(self) -> Dir {
        match self {
            Side::Leader => Dir::ToWorker,
            Side::Worker => Dir::ToLeader,
        }
    }
}

/// Per-worker-link fault schedule. Frame sequence numbers count flushed
/// frames per direction per connection, starting at 0 — so seq 0 is the
/// handshake frame (`Hello` / `Welcome`) and tasks/partials start at 1.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LinkFaults {
    /// Base one-way latency, virtual nanoseconds.
    pub delay_ns: u64,
    /// Seeded uniform extra latency in `[0, jitter_ns]`.
    pub jitter_ns: u64,
    /// Per-transmission segment-loss probability (recovered by
    /// retransmission: +[`RETRANSMIT_NS`] each; > [`MAX_RETRANSMITS`]
    /// consecutive losses breaks the link).
    pub drop_prob: f64,
    /// Probability a frame is duplicated in flight (suppressed by the
    /// reliable layer; traced, costs a little extra latency).
    pub dup_prob: f64,
    /// Probability a frame's segments arrive out of order (resequenced;
    /// traced, costs head-of-line latency).
    pub reorder_prob: f64,
    /// Random per-frame corruption probability (payload byte flip that
    /// escaped the transport checksum; the frame layer's XXH64 must
    /// reject it).
    pub corrupt_prob: f64,
    /// Corrupt exactly these `(direction, frame seq)` frames.
    pub corrupt_frames: Vec<(Dir, u64)>,
    /// Crash the worker when the leader flushes task-direction frame
    /// `seq` (the frame vanishes; the worker is dead from then on).
    pub crash_on_task: Option<u64>,
    /// Crash the worker when it flushes reply-direction frame `seq`
    /// (received the task, died before answering — the mid-round case).
    pub crash_on_reply: Option<u64>,
    /// From reply frame `.0` on, add `.1` virtual ns to every reply — a
    /// stalled worker; set `.1` beyond the exchange timeout to make the
    /// leader's detector fire.
    pub stall_after: Option<(u64, u64)>,
    /// Refuse new connections (dial fails; the planner should skip this
    /// worker with a note).
    pub refuse_dials: bool,
    /// After a crash, the worker "restarts": the first N re-dials still
    /// fail (the process is coming back up), then the endpoint accepts
    /// again. `Some(0)` restarts instantly. `None` (the default) keeps a
    /// crashed worker down for good — the pre-elastic behavior. Pair
    /// with a leader-side redial budget (`PALLAS_CLUSTER_REDIALS` /
    /// `ConnectOptions::redial_budget`) to exercise self-healing.
    pub redial_after: Option<u32>,
}

/// A fault-free link.
pub const NO_FAULTS: LinkFaults = LinkFaults {
    delay_ns: 0,
    jitter_ns: 0,
    drop_prob: 0.0,
    dup_prob: 0.0,
    reorder_prob: 0.0,
    corrupt_prob: 0.0,
    corrupt_frames: Vec::new(),
    crash_on_task: None,
    crash_on_reply: None,
    stall_after: None,
    refuse_dials: false,
    redial_after: None,
};

/// The fault plan DSL: one [`LinkFaults`] per worker (by the order
/// workers were added); missing entries are fault-free.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Per-worker fault schedules.
    pub links: Vec<LinkFaults>,
    /// Mid-solve admissions: `(round, threads)` pairs. At the start of
    /// solve round `round` (0-based, as a [`SolveObserver`] counts
    /// them) a fresh worker with a `threads`-wide pool dials the
    /// leader's join listener — the sim analogue of launching
    /// `bskp worker --join` mid-solve. Executed by the observer from
    /// [`SimNet::elastic_observer`]; ignored without one.
    pub join_at_round: Vec<(u64, usize)>,
}

impl FaultPlan {
    /// No faults anywhere: the simulator as a plain loopback transport.
    pub fn healthy() -> Self {
        Self::default()
    }

    fn faults_for(&self, worker: usize) -> &LinkFaults {
        self.links.get(worker).unwrap_or(&NO_FAULTS)
    }
}

/// One simulation event, attributed to `(worker, conn, dir, seq)` and
/// stamped with link-local virtual time. Event order within a link is the
/// link's own causal order; [`SimNet::trace`] returns links in canonical
/// `(worker, conn)` order, so two equal traces mean two identical runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Worker endpoint index (order of [`SimNet::add_worker`] calls).
    pub worker: usize,
    /// Connection ordinal on that worker (0 = first dial).
    pub conn: u64,
    /// Frame direction, when the event concerns a frame.
    pub dir: Option<Dir>,
    /// Frame sequence number in that direction (0 when not a frame).
    pub seq: u64,
    /// Link-local virtual time of the event, nanoseconds.
    pub at_ns: u64,
    /// What happened.
    pub kind: TraceKind,
}

/// Event kinds in a simulation trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceKind {
    /// Leader dialed this worker. (Acceptance is not a separate event:
    /// its real-time order against the leader's first flush is arbitrary,
    /// and traces must not record scheduling accidents.)
    Dialed,
    /// A frame was (eventually) delivered, with its injected faults.
    Delivered {
        /// Total one-way latency, nanoseconds.
        delay_ns: u64,
        /// Segments lost and retransmitted.
        retransmits: u32,
        /// A duplicate was suppressed by the reliable layer.
        duplicated: bool,
        /// Segments were resequenced.
        reordered: bool,
        /// A payload byte flip escaped the transport checksum (the frame
        /// layer's XXH64 must reject the frame).
        corrupted: bool,
    },
    /// Too many consecutive losses: the connection broke.
    LinkBroken {
        /// Retransmits attempted before giving up.
        retransmits: u32,
    },
    /// A blocked read's virtual deadline fired before the next arrival.
    TimedOut {
        /// The virtual deadline that fired.
        deadline_ns: u64,
    },
    /// The worker crashed (fault-plan trigger or [`SimNet::crash_worker`]).
    Crashed,
    /// The worker came back and accepts again ([`SimNet::rejoin_worker`]).
    Rejoined,
}

/// What a blocking receive resolved to.
enum RecvOutcome {
    /// A frame arrived at `at_ns`.
    Frame { bytes: Vec<u8>, at_ns: u64 },
    /// No more frames will ever arrive (peer closed / crashed / broken).
    Eof,
    /// The reader's virtual deadline fired first.
    TimedOut,
}

struct PipeState {
    /// Delivered frames: `(virtual arrival, bytes)`, arrival-ordered.
    buf: VecDeque<(u64, Vec<u8>)>,
    /// Frames flushed into this pipe (the per-direction seq counter).
    sent: u64,
    /// Frames popped by the reader.
    received: u64,
    /// In-order delivery floor.
    last_arrival: u64,
    /// No further frames will be delivered.
    closed: bool,
    /// A reader is blocked on this pipe…
    reader_waiting: bool,
    /// …with this virtual deadline (`u64::MAX` = none).
    reader_deadline: u64,
}

impl PipeState {
    fn new() -> Self {
        Self {
            buf: VecDeque::new(),
            sent: 0,
            received: 0,
            last_arrival: 0,
            closed: false,
            reader_waiting: false,
            reader_deadline: u64::MAX,
        }
    }
}

struct LinkState {
    ep: usize,
    ordinal: u64,
    /// Link-local virtual clock (advanced by deliveries and timeouts).
    vnow_ns: u64,
    broken: bool,
    /// `pipes[Dir as usize]`.
    pipes: [PipeState; 2],
    events: Vec<TraceEvent>,
}

impl LinkState {
    fn push_event(&mut self, dir: Option<Dir>, seq: u64, at_ns: u64, kind: TraceKind) {
        self.events.push(TraceEvent { worker: self.ep, conn: self.ordinal, dir, seq, at_ns, kind });
    }

    fn close_pipes(&mut self) {
        self.pipes[0].closed = true;
        self.pipes[1].closed = true;
    }
}

struct EpState {
    addr: String,
    alive: bool,
    /// Dialed, not yet accepted link ids.
    pending: VecDeque<usize>,
    /// Connection ordinal counter.
    conns: u64,
    /// Dials refused since the last crash (drives
    /// [`LinkFaults::redial_after`]; resets when the worker restarts).
    failed_dials: u32,
}

struct SimState {
    closed: bool,
    eps: Vec<EpState>,
    links: Vec<LinkState>,
    /// Events not tied to one connection ([`SimNet::crash_worker`] /
    /// [`SimNet::rejoin_worker`] calls, which happen on the driving
    /// thread at deterministic points).
    admin: Vec<TraceEvent>,
}

struct Hub {
    seed: u64,
    plan: FaultPlan,
    clock: Arc<VirtualClock>,
    state: Mutex<SimState>,
    cv: Condvar,
    hang_guard: Duration,
}

fn hang_guard_from_env() -> Duration {
    let secs = std::env::var("PALLAS_SIM_HANG_SECS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&s| s > 0)
        .unwrap_or(30);
    Duration::from_secs(secs)
}

fn broken_pipe(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::BrokenPipe, format!("sim: {what}"))
}

impl Hub {
    /// Seeded per-frame fault RNG: a pure function of the frame identity,
    /// immune to thread interleaving.
    fn frame_rng(&self, ep: usize, ordinal: u64, dir: Dir, seq: u64) -> Xoshiro256pp {
        let link_seed = mix64(self.seed, ((ep as u64) << 32) ^ ordinal);
        Xoshiro256pp::new(mix64(link_seed, ((dir as u64) << 48) ^ seq))
    }

    fn crash_ep(st: &mut SimState, ep: usize) {
        st.eps[ep].alive = false;
        st.eps[ep].pending.clear();
        for link in st.links.iter_mut().filter(|l| l.ep == ep) {
            link.close_pipes();
        }
    }

    /// Open a connection to the endpoint serving `addr`. (Associated fn:
    /// the stream it builds must hold the hub's `Arc`.)
    fn dial(hub: &Arc<Hub>, addr: &str) -> Result<Box<dyn NetStream>> {
        let mut st = hub.state.lock().unwrap();
        if st.closed {
            return Err(Error::Runtime("sim: network is shut down".into()));
        }
        let ep = st
            .eps
            .iter()
            .position(|e| e.addr == addr)
            .ok_or_else(|| Error::Runtime(format!("sim: no worker endpoint at {addr}")))?;
        if hub.plan.faults_for(ep).refuse_dials {
            return Err(Error::Runtime(format!("sim: {addr} refused the connection")));
        }
        if !st.eps[ep].alive {
            // the redial_after verb: the crashed worker "restarts" once
            // enough re-dials have bounced off it, then accepts again
            let revive = match hub.plan.faults_for(ep).redial_after {
                Some(after) => st.eps[ep].failed_dials >= after,
                None => false,
            };
            if !revive {
                st.eps[ep].failed_dials = st.eps[ep].failed_dials.saturating_add(1);
                return Err(Error::Runtime(format!("sim: {addr} is down (crashed worker)")));
            }
            st.eps[ep].alive = true;
            st.eps[ep].failed_dials = 0;
            let at = hub.clock.now_ns();
            let conn = st.eps[ep].conns;
            st.admin.push(TraceEvent {
                worker: ep,
                conn,
                dir: None,
                seq: 0,
                at_ns: at,
                kind: TraceKind::Rejoined,
            });
        }
        let ordinal = st.eps[ep].conns;
        st.eps[ep].conns += 1;
        let mut link = LinkState {
            ep,
            ordinal,
            vnow_ns: 0,
            broken: false,
            pipes: [PipeState::new(), PipeState::new()],
            events: Vec::new(),
        };
        link.push_event(None, 0, 0, TraceKind::Dialed);
        st.links.push(link);
        let id = st.links.len() - 1;
        st.eps[ep].pending.push_back(id);
        hub.cv.notify_all();
        Ok(Box::new(SimStream {
            hub: Arc::clone(hub),
            link: id,
            ep,
            ordinal,
            side: Side::Leader,
            last_vnow: 0,
            read_buf: Vec::new(),
            read_pos: 0,
            write_buf: Vec::new(),
            read_timeout: None,
        }))
    }

    /// Block for the next inbound connection on `ep` (worker accept).
    fn accept(hub: &Arc<Hub>, ep: usize) -> Option<Box<dyn NetStream>> {
        let mut st = hub.state.lock().unwrap();
        loop {
            if st.closed {
                return None;
            }
            if st.eps[ep].alive {
                if let Some(id) = st.eps[ep].pending.pop_front() {
                    let ordinal = st.links[id].ordinal;
                    return Some(Box::new(SimStream {
                        hub: Arc::clone(hub),
                        link: id,
                        ep,
                        ordinal,
                        side: Side::Worker,
                        last_vnow: 0,
                        read_buf: Vec::new(),
                        read_pos: 0,
                        write_buf: Vec::new(),
                        read_timeout: None,
                    }));
                }
            }
            // idle accept loops are legitimate (a worker may sit unused
            // for the whole test), so no hang panic here
            let (guard, _) = hub.cv.wait_timeout(st, hub.hang_guard).unwrap();
            st = guard;
        }
    }

    /// Flush one complete frame onto a link; returns the virtual send
    /// time. Applies the fault plan: a pure function of the frame
    /// identity. `sender_vnow` is the *sending stream's* own virtual
    /// time ([`SimStream::last_vnow`]) — arrivals anchor on it rather
    /// than on the shared link clock, so that when the leader pipelines
    /// (overlapped gather: a task flush can race the peer's deliveries
    /// on the same link) the delivery schedule stays a pure function of
    /// each side's own causal history, not of thread interleaving.
    fn send_frame(
        &self,
        link: usize,
        side: Side,
        sender_vnow: u64,
        frame: Vec<u8>,
    ) -> io::Result<u64> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(broken_pipe("network is shut down"));
        }
        let (ep, ordinal) = {
            let l = &st.links[link];
            if l.broken {
                return Err(broken_pipe("link is broken"));
            }
            (l.ep, l.ordinal)
        };
        if !st.eps[ep].alive {
            return Err(broken_pipe("worker is down"));
        }
        let dir = side.outbound();
        if st.links[link].pipes[dir as usize].closed {
            return Err(broken_pipe("peer closed the stream"));
        }
        let seq = st.links[link].pipes[dir as usize].sent;
        st.links[link].pipes[dir as usize].sent += 1;
        let faults = self.plan.faults_for(ep);
        let send_vnow = sender_vnow;

        // crash triggers: the worker process dies on this very frame
        if side == Side::Leader && faults.crash_on_task == Some(seq) {
            st.links[link].push_event(Some(dir), seq, send_vnow, TraceKind::Crashed);
            Self::crash_ep(&mut st, ep);
            self.cv.notify_all();
            // TCP accepts the bytes into its buffer; the sender learns on
            // its next read
            return Ok(send_vnow);
        }
        if side == Side::Worker && faults.crash_on_reply == Some(seq) {
            st.links[link].push_event(Some(dir), seq, send_vnow, TraceKind::Crashed);
            Self::crash_ep(&mut st, ep);
            self.cv.notify_all();
            return Err(broken_pipe("worker crashed mid-reply"));
        }

        let mut rng = self.frame_rng(ep, ordinal, dir, seq);
        let mut retransmits = 0u32;
        while faults.drop_prob > 0.0 && rng.coin(faults.drop_prob) {
            retransmits += 1;
            if retransmits > MAX_RETRANSMITS {
                let l = &mut st.links[link];
                l.broken = true;
                l.close_pipes();
                l.push_event(Some(dir), seq, send_vnow, TraceKind::LinkBroken { retransmits });
                self.cv.notify_all();
                // the write itself "succeeded" into the local buffer; the
                // failure surfaces on the next read, as on real TCP
                return Ok(send_vnow);
            }
        }
        let mut delay = faults.delay_ns.saturating_add(retransmits as u64 * RETRANSMIT_NS);
        if faults.jitter_ns > 0 {
            delay = delay.saturating_add(rng.below(faults.jitter_ns + 1));
        }
        let duplicated = faults.dup_prob > 0.0 && rng.coin(faults.dup_prob);
        if duplicated {
            delay = delay.saturating_add(RETRANSMIT_NS / 4);
        }
        let reordered = faults.reorder_prob > 0.0 && rng.coin(faults.reorder_prob);
        if reordered {
            delay = delay.saturating_add(RETRANSMIT_NS / 2);
        }
        if side == Side::Worker {
            if let Some((from_seq, extra_ns)) = faults.stall_after {
                if seq >= from_seq {
                    delay = delay.saturating_add(extra_ns);
                }
            }
        }
        let corrupted = faults.corrupt_frames.iter().any(|&(d, s)| d == dir && s == seq)
            || (faults.corrupt_prob > 0.0 && rng.coin(faults.corrupt_prob));
        let mut bytes = frame;
        if corrupted && bytes.len() >= 24 {
            // flip inside the payload (or, for empty payloads, inside the
            // trailing checksum) so the XXH64 verification must trip —
            // never inside the header, whose violations have their own
            // error paths
            let payload_len = bytes.len() - 24;
            let idx = if payload_len > 0 {
                16 + rng.below(payload_len as u64) as usize
            } else {
                16 + rng.below(8) as usize
            };
            bytes[idx] ^= 0xA5;
        }
        let l = &mut st.links[link];
        let arrival = (send_vnow.saturating_add(delay)).max(l.pipes[dir as usize].last_arrival);
        l.pipes[dir as usize].last_arrival = arrival;
        l.pipes[dir as usize].buf.push_back((arrival, bytes));
        l.push_event(
            Some(dir),
            seq,
            arrival,
            TraceKind::Delivered { delay_ns: delay, retransmits, duplicated, reordered, corrupted },
        );
        self.cv.notify_all();
        Ok(send_vnow)
    }

    /// Block until a frame arrives, the pipe is finished, or the virtual
    /// `deadline` fires. The wall-clock wait only lasts while the peer is
    /// genuinely running; once the peer is blocked too (or the next
    /// arrival is already known) the outcome is decided instantly in
    /// virtual time. Panics (with the trace) if nothing happens for
    /// `hang_guard` of real time — the "never hang" backstop.
    fn recv_frame(&self, link: usize, side: Side, deadline: u64) -> RecvOutcome {
        let mut st = self.state.lock().unwrap();
        let dir = side.inbound();
        loop {
            let front_arrival = st.links[link].pipes[dir as usize].buf.front().map(|(a, _)| *a);
            if let Some(arrival) = front_arrival {
                if arrival <= deadline {
                    let l = &mut st.links[link];
                    let (at, bytes) = l.pipes[dir as usize].buf.pop_front().unwrap();
                    l.pipes[dir as usize].received += 1;
                    l.vnow_ns = l.vnow_ns.max(at);
                    self.clock.advance_to(l.vnow_ns);
                    return RecvOutcome::Frame { bytes, at_ns: at };
                }
                // the next arrival is already past the deadline: the
                // timeout fires first, in virtual time
                self.fire_timeout(&mut st, link, dir, deadline);
                return RecvOutcome::TimedOut;
            }
            {
                let l = &st.links[link];
                if l.pipes[dir as usize].closed || l.broken || st.closed || !st.eps[l.ep].alive {
                    return RecvOutcome::Eof;
                }
            }
            // mutual block: both ends waiting, nothing in flight — the
            // earlier virtual deadline fires (leader on ties, so the
            // outcome never depends on which thread checks first)
            let peer_dir = side.outbound();
            let (peer_waiting, peer_deadline) = {
                let p = &st.links[link].pipes[peer_dir as usize];
                (p.reader_waiting, p.reader_deadline)
            };
            if peer_waiting
                && (deadline < peer_deadline
                    || (deadline == peer_deadline && side == Side::Leader))
            {
                if deadline == u64::MAX {
                    panic!(
                        "sim deadlock: both link ends blocked with no timeout\n{}",
                        Self::dump(&st)
                    );
                }
                self.fire_timeout(&mut st, link, dir, deadline);
                return RecvOutcome::TimedOut;
            }
            {
                let p = &mut st.links[link].pipes[dir as usize];
                p.reader_waiting = true;
                p.reader_deadline = deadline;
            }
            if peer_waiting {
                // registering may hand the peer the earlier-deadline role;
                // wake it to re-check. No livelock: of two blocked ends
                // exactly one satisfies the fire predicate, so each
                // notify either ends in a delivery or in that end firing.
                self.cv.notify_all();
            }
            let (guard, wait) = self.cv.wait_timeout(st, self.hang_guard).unwrap();
            st = guard;
            st.links[link].pipes[dir as usize].reader_waiting = false;
            if wait.timed_out() {
                panic!(
                    "sim hang: no event for {:?} of real time (is a worker thread dead?)\n{}",
                    self.hang_guard,
                    Self::dump(&st)
                );
            }
        }
    }

    fn fire_timeout(&self, st: &mut SimState, link: usize, dir: Dir, deadline: u64) {
        let l = &mut st.links[link];
        l.vnow_ns = l.vnow_ns.max(deadline);
        let seq = l.pipes[dir as usize].received;
        l.push_event(Some(dir), seq, deadline, TraceKind::TimedOut { deadline_ns: deadline });
        self.clock.advance_to(l.vnow_ns);
        self.cv.notify_all();
    }

    /// One side hung up: no more frames in either direction.
    fn close_stream(&self, link: usize) {
        let Ok(mut st) = self.state.lock() else { return };
        st.links[link].close_pipes();
        self.cv.notify_all();
    }

    fn dump(st: &SimState) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (i, e) in st.eps.iter().enumerate() {
            let _ = writeln!(
                out,
                "worker {i} ({}): alive={} pending={} conns={}",
                e.addr,
                e.alive,
                e.pending.len(),
                e.conns
            );
        }
        for l in &st.links {
            let _ = writeln!(
                out,
                "link w{}#{}: vnow={}ns broken={} to_worker(sent={} recv={} buf={} closed={} \
                 waiting={}) to_leader(sent={} recv={} buf={} closed={} waiting={})",
                l.ep,
                l.ordinal,
                l.vnow_ns,
                l.broken,
                l.pipes[0].sent,
                l.pipes[0].received,
                l.pipes[0].buf.len(),
                l.pipes[0].closed,
                l.pipes[0].reader_waiting,
                l.pipes[1].sent,
                l.pipes[1].received,
                l.pipes[1].buf.len(),
                l.pipes[1].closed,
                l.pipes[1].reader_waiting,
            );
        }
        if crate::obs::trace_enabled() {
            let _ = writeln!(out, "--- flight recorder (most recent spans) ---");
            out.push_str(&crate::obs::recorder::dump_text(64));
        }
        out
    }
}

/// One end of a simulated connection. Reads serve frame bytes byte-wise
/// (the frame layer does its usual `read_exact` dance); writes buffer
/// until `flush`, which is exactly one frame in the cluster protocol.
struct SimStream {
    hub: Arc<Hub>,
    link: usize,
    ep: usize,
    ordinal: u64,
    side: Side,
    /// Virtual time of this side's last own action on the link (send,
    /// delivery, fired timeout). Read deadlines anchor here, which makes
    /// them independent of thread interleaving.
    last_vnow: u64,
    read_buf: Vec<u8>,
    read_pos: usize,
    write_buf: Vec<u8>,
    read_timeout: Option<Duration>,
}

impl io::Read for SimStream {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        if self.read_pos >= self.read_buf.len() {
            let deadline = match self.read_timeout {
                Some(t) => self.last_vnow.saturating_add(t.as_nanos() as u64),
                None => u64::MAX,
            };
            match self.hub.recv_frame(self.link, self.side, deadline) {
                RecvOutcome::Frame { bytes, at_ns } => {
                    self.last_vnow = at_ns;
                    self.read_buf = bytes;
                    self.read_pos = 0;
                }
                RecvOutcome::Eof => return Ok(0),
                RecvOutcome::TimedOut => {
                    self.last_vnow = deadline;
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "sim: virtual read deadline fired",
                    ));
                }
            }
        }
        let n = out.len().min(self.read_buf.len() - self.read_pos);
        out[..n].copy_from_slice(&self.read_buf[self.read_pos..self.read_pos + n]);
        self.read_pos += n;
        Ok(n)
    }
}

impl io::Write for SimStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.write_buf.extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        if self.write_buf.is_empty() {
            return Ok(());
        }
        let frame = std::mem::take(&mut self.write_buf);
        let sent_at = self.hub.send_frame(self.link, self.side, self.last_vnow, frame)?;
        self.last_vnow = self.last_vnow.max(sent_at);
        Ok(())
    }
}

impl NetStream for SimStream {
    fn set_read_timeout(&mut self, t: Option<Duration>) -> io::Result<()> {
        self.read_timeout = t;
        Ok(())
    }

    fn set_write_timeout(&mut self, _t: Option<Duration>) -> io::Result<()> {
        // sim writes complete instantly (the latency is modeled on
        // delivery), so a write deadline can never fire
        Ok(())
    }

    fn peer(&self) -> String {
        match self.side {
            Side::Leader => format!("sim://{}#{}", self.ep, self.ordinal),
            Side::Worker => format!("sim-leader://{}#{}", self.ep, self.ordinal),
        }
    }
}

impl Drop for SimStream {
    fn drop(&mut self) {
        self.hub.close_stream(self.link);
    }
}

/// The leader-side dialer into a [`SimNet`].
#[derive(Clone)]
pub struct SimTransport {
    hub: Arc<Hub>,
}

impl Transport for SimTransport {
    fn dial(&self, addr: &str, _connect_timeout: Duration) -> Result<Box<dyn NetStream>> {
        Hub::dial(&self.hub, addr)
    }

    fn clock(&self) -> Arc<dyn Clock> {
        self.hub.clock.clone()
    }
}

/// The accept side of one simulated worker endpoint.
struct SimListener {
    hub: Arc<Hub>,
    ep: usize,
}

impl NetListener for SimListener {
    fn accept_stream(&self) -> io::Result<Option<Box<dyn NetStream>>> {
        Ok(Hub::accept(&self.hub, self.ep))
    }

    fn poll_accept(&self) -> io::Result<Option<Box<dyn NetStream>>> {
        let mut st = self.hub.state.lock().unwrap();
        if st.closed || !st.eps[self.ep].alive {
            return Ok(None);
        }
        let Some(id) = st.eps[self.ep].pending.pop_front() else {
            return Ok(None);
        };
        let ordinal = st.links[id].ordinal;
        Ok(Some(Box::new(SimStream {
            hub: Arc::clone(&self.hub),
            link: id,
            ep: self.ep,
            ordinal,
            side: Side::Worker,
            last_vnow: 0,
            read_buf: Vec::new(),
            read_pos: 0,
            write_buf: Vec::new(),
            read_timeout: None,
        })))
    }

    fn local_addr(&self) -> String {
        self.hub.state.lock().unwrap().eps[self.ep].addr.clone()
    }

    fn clock(&self) -> Arc<dyn Clock> {
        self.hub.clock.clone()
    }

    fn dialer(&self) -> Option<Arc<dyn Transport>> {
        // sim workers can dial their siblings through the shared hub,
        // which is what lets the relay tier run under the simulator
        Some(Arc::new(SimTransport { hub: Arc::clone(&self.hub) }))
    }
}

/// A deterministic in-memory cluster: N in-process workers, a leader-side
/// [`SimTransport`], a shared [`VirtualClock`], a [`FaultPlan`], and the
/// resulting event trace. See the [module docs](self).
pub struct SimNet {
    hub: Arc<Hub>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl SimNet {
    /// A network with the given fault RNG seed and plan.
    pub fn new(seed: u64, plan: FaultPlan) -> Self {
        Self {
            hub: Arc::new(Hub {
                seed,
                plan,
                clock: VirtualClock::new(),
                state: Mutex::new(SimState {
                    closed: false,
                    eps: Vec::new(),
                    links: Vec::new(),
                    admin: Vec::new(),
                }),
                cv: Condvar::new(),
                hang_guard: hang_guard_from_env(),
            }),
            threads: Mutex::new(Vec::new()),
        }
    }

    /// Add one worker serving the shard store under `store`, with a
    /// `threads`-wide map pool, and return its dialable address
    /// (`sim://<index>`). The worker runs the real
    /// [`worker::serve_net`] loop on its own thread.
    ///
    /// Panics if the store does not open — a worker silently serving
    /// nothing would otherwise surface as an opaque "sim hang" panic a
    /// hang-guard later, not as the store problem it is.
    pub fn add_worker(&self, store: &Path, threads: usize) -> String {
        // validate eagerly on the caller (the thread re-opens; mmaps are
        // not moved across threads so non-unix fallbacks keep working)
        if let Err(e) = MmapProblem::open(store) {
            panic!("sim worker cannot open the store {}: {e}", store.display());
        }
        let (ep, addr) = {
            let mut st = self.hub.state.lock().unwrap();
            let ep = st.eps.len();
            let addr = format!("sim://{ep}");
            st.eps.push(EpState {
                addr: addr.clone(),
                alive: true,
                pending: VecDeque::new(),
                conns: 0,
                failed_dials: 0,
            });
            (ep, addr)
        };
        let hub = Arc::clone(&self.hub);
        let dir: PathBuf = store.to_path_buf();
        let handle = std::thread::spawn(move || {
            let problem = MmapProblem::open(&dir)
                .unwrap_or_else(|e| panic!("sim worker {ep}: store vanished: {e}"));
            let pool = Cluster::new(threads);
            let listener = SimListener { hub, ep };
            let _ = worker::serve_net(&listener, &problem, &pool);
        });
        self.threads.lock().unwrap().push(handle);
        addr
    }

    /// Register a bare endpoint — a dialable `sim://<index>` address plus
    /// its accept side — without spawning anything on it. This is how a
    /// non-worker server (the `bskp serve` daemon) is hosted on the
    /// simulated network: the caller runs its own accept loop against the
    /// returned [`NetListener`] on a thread it owns (and joins after
    /// [`SimNet::shutdown`], which makes `accept_stream` return
    /// `Ok(None)`), while clients dial the address through
    /// [`SimNet::transport`]. The endpoint participates in the
    /// [`FaultPlan`] by its index, exactly like a worker added with
    /// [`SimNet::add_worker`].
    pub fn add_endpoint(&self) -> (String, Box<dyn NetListener>) {
        let ep = {
            let mut st = self.hub.state.lock().unwrap();
            let ep = st.eps.len();
            st.eps.push(EpState {
                addr: format!("sim://{ep}"),
                alive: true,
                pending: VecDeque::new(),
                conns: 0,
                failed_dials: 0,
            });
            ep
        };
        (format!("sim://{ep}"), Box::new(SimListener { hub: Arc::clone(&self.hub), ep }))
    }

    /// The dialer to hand to
    /// [`RemoteCluster::connect_with`](super::RemoteCluster::connect_with)
    /// (or [`crate::solve::Solve::transport`]).
    pub fn transport(&self) -> SimTransport {
        SimTransport { hub: Arc::clone(&self.hub) }
    }

    /// The shared virtual clock.
    pub fn clock(&self) -> Arc<VirtualClock> {
        Arc::clone(&self.hub.clock)
    }

    /// Kill worker `index` now: pending and future frames vanish, its
    /// links EOF, dials are refused until [`SimNet::rejoin_worker`].
    /// Deterministic when called from a deterministic point — e.g. a
    /// `SolveObserver` at a chosen round, the sim analogue of SIGKILL in
    /// the TCP integration test.
    pub fn crash_worker(&self, index: usize) {
        let mut st = self.hub.state.lock().unwrap();
        if !st.eps[index].alive {
            return;
        }
        let at = self.hub.clock.now_ns();
        let conn = st.eps[index].conns;
        Hub::crash_ep(&mut st, index);
        st.admin.push(TraceEvent {
            worker: index,
            conn,
            dir: None,
            seq: 0,
            at_ns: at,
            kind: TraceKind::Crashed,
        });
        self.hub.cv.notify_all();
    }

    /// Revive a crashed worker: it accepts new connections again. A
    /// leader session in flight will *not* redial it unless it runs with
    /// a redial budget (`PALLAS_CLUSTER_REDIALS` /
    /// `ConnectOptions::redial_budget`) — without one, links never
    /// resurrect within a session, and only a new connect sees the
    /// revived worker. (Planned, deterministic restarts go through
    /// [`LinkFaults::redial_after`] instead.)
    pub fn rejoin_worker(&self, index: usize) {
        let mut st = self.hub.state.lock().unwrap();
        if st.eps[index].alive {
            return;
        }
        st.eps[index].alive = true;
        let at = self.hub.clock.now_ns();
        let conn = st.eps[index].conns;
        st.admin.push(TraceEvent {
            worker: index,
            conn,
            dir: None,
            seq: 0,
            at_ns: at,
            kind: TraceKind::Rejoined,
        });
        self.hub.cv.notify_all();
    }

    /// Is worker `index` currently accepting?
    pub fn worker_alive(&self, index: usize) -> bool {
        self.hub.state.lock().unwrap().eps[index].alive
    }

    /// Launch a fresh worker that joins a running leader mid-solve: dial
    /// `leader` (the join listener's address from
    /// [`SimNet::add_endpoint`]), put the `Join` frame on the wire
    /// **synchronously** — so when the caller is a round-boundary hook the
    /// admission lands at a deterministic deal — then serve the admitted
    /// session on a new thread, exactly as `bskp worker --join` would.
    ///
    /// Panics if the store does not open, like [`SimNet::add_worker`].
    pub fn join_worker(&self, store: &Path, threads: usize, leader: &str) -> Result<()> {
        if let Err(e) = MmapProblem::open(store) {
            panic!("sim joiner cannot open the store {}: {e}", store.display());
        }
        let transport = self.transport();
        let opts = crate::cluster::leader::ConnectOptions::from_env();
        let mut stream = transport.dial(leader, opts.connect_timeout)?;
        // fingerprint from a caller-side open, dropped before the thread
        // spawns: the Join frame must go out synchronously, but mmaps are
        // not moved across threads (add_worker's rule), so the session
        // thread re-opens its own copy
        let fingerprint = {
            let probe = MmapProblem::open(store)
                .map_err(|e| Error::Runtime(format!("sim joiner: store vanished: {e}")))?;
            crate::cluster::protocol::InstanceFingerprint::of(&probe)
        };
        crate::cluster::protocol::send_msg(
            &mut stream,
            &crate::cluster::protocol::Msg::Join {
                threads: threads.max(1) as u32,
                fingerprint: fingerprint.clone(),
                shard_lo: 0,
                shard_hi: u64::MAX,
            },
        )?;
        let dialer: Arc<dyn Transport> = Arc::new(self.transport());
        let clock = self.hub.clock.clone();
        let dir: PathBuf = store.to_path_buf();
        let handle = std::thread::spawn(move || {
            let problem = MmapProblem::open(&dir)
                .unwrap_or_else(|e| panic!("sim joiner: store vanished: {e}"));
            let pool = Cluster::new(threads);
            let _ = worker::serve_admitted(
                stream,
                &problem,
                &fingerprint,
                &pool,
                clock.as_ref(),
                opts,
                Some(dialer),
            );
        });
        self.threads.lock().unwrap().push(handle);
        Ok(())
    }

    /// A [`SolveObserver`](crate::solver::stats::SolveObserver) that
    /// executes the plan's [`FaultPlan::join_at_round`] verbs: at the
    /// start of each listed solve round it calls [`SimNet::join_worker`]
    /// with `store` and the planned thread count against `leader`. Hooks
    /// run on the leader's solve thread at round boundaries, so planned
    /// admissions are deterministic.
    pub fn elastic_observer(&self, store: &Path, leader: &str) -> ElasticObserver<'_> {
        let mut pending = self.hub.plan.join_at_round.clone();
        pending.sort_unstable();
        ElasticObserver {
            net: self,
            store: store.to_path_buf(),
            leader: leader.to_string(),
            pending,
        }
    }

    /// Retire the network: all blocked operations resolve, worker threads
    /// exit and are joined. Idempotent; also runs on drop. Call it before
    /// [`SimNet::trace`] when comparing full runs, so late worker-side
    /// events are flushed.
    pub fn shutdown(&self) {
        {
            let mut st = self.hub.state.lock().unwrap();
            st.closed = true;
            for link in st.links.iter_mut() {
                link.close_pipes();
            }
            self.hub.cv.notify_all();
        }
        let handles: Vec<_> = self.threads.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    /// The run's event trace in canonical order: links sorted by
    /// `(worker, conn)`, each link's events in causal order, admin events
    /// (crash/rejoin calls) appended. Two runs with the same
    /// `(seed, plan)` and the same driving program produce equal traces.
    pub fn trace(&self) -> Vec<TraceEvent> {
        let st = self.hub.state.lock().unwrap();
        let mut order: Vec<usize> = (0..st.links.len()).collect();
        order.sort_by_key(|&i| (st.links[i].ep, st.links[i].ordinal));
        let mut out = Vec::new();
        for i in order {
            out.extend(st.links[i].events.iter().cloned());
        }
        out.extend(st.admin.iter().cloned());
        out
    }

    /// [`SimNet::trace`] rendered one event per line (for failure
    /// messages and replay diffs).
    pub fn trace_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for e in self.trace() {
            let _ = writeln!(
                out,
                "w{}#{} {:>9}ns {:?} seq={} {:?}",
                e.worker, e.conn, e.at_ns, e.dir, e.seq, e.kind
            );
        }
        out
    }
}

impl Drop for SimNet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The observer behind [`SimNet::elastic_observer`]: fires the plan's
/// [`FaultPlan::join_at_round`] admissions at their solve rounds.
pub struct ElasticObserver<'a> {
    net: &'a SimNet,
    store: PathBuf,
    leader: String,
    /// Remaining `(round, threads)` verbs, sorted by round.
    pending: Vec<(u64, usize)>,
}

impl crate::solver::stats::SolveObserver for ElasticObserver<'_> {
    fn on_round(
        &mut self,
        event: &crate::solver::stats::RoundEvent<'_>,
    ) -> crate::solver::stats::ObserverControl {
        // on_round(iter) runs at the boundary *after* round `iter`, so a
        // verb for round r fires once iter + 1 reaches it — admitted
        // workers receive chunks from round r on
        while self.pending.first().is_some_and(|&(r, _)| r <= event.iter as u64 + 1) {
            let (_, threads) = self.pending.remove(0);
            let _ = self.net.join_worker(&self.store, threads, &self.leader);
        }
        crate::solver::stats::ObserverControl::Continue
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::frames;
    use std::io::Write as _;

    /// A hub with one endpoint and no worker thread — unit tests drive
    /// both ends by hand.
    fn bare_hub(seed: u64, plan: FaultPlan) -> (Arc<Hub>, String) {
        let net = SimNet::new(seed, plan);
        let hub = Arc::clone(&net.hub);
        {
            let mut st = hub.state.lock().unwrap();
            st.eps.push(EpState {
                addr: "sim://0".into(),
                alive: true,
                pending: VecDeque::new(),
                conns: 0,
                failed_dials: 0,
            });
        }
        std::mem::forget(net); // keep the hub open: these tests own both ends
        (hub, "sim://0".into())
    }

    #[test]
    fn frames_cross_the_sim_verbatim() {
        let (hub, addr) = bare_hub(1, FaultPlan::healthy());
        let mut leader = Hub::dial(&hub, &addr).unwrap();
        let mut worker = Hub::accept(&hub, 0).expect("pending conn");
        frames::write_frame(&mut leader, 4, b"task payload").unwrap();
        let (kind, payload, _) = frames::read_frame(&mut worker).unwrap();
        assert_eq!(kind, 4);
        assert_eq!(payload, b"task payload");
        // and the reply direction
        frames::write_frame(&mut worker, 7, b"partial").unwrap();
        let (kind, payload, _) = frames::read_frame(&mut leader).unwrap();
        assert_eq!(kind, 7);
        assert_eq!(payload, b"partial");
    }

    #[test]
    fn corruption_trips_the_checksum() {
        let plan = FaultPlan {
            links: vec![LinkFaults {
                corrupt_frames: vec![(Dir::ToWorker, 0)],
                ..NO_FAULTS
            }],
            ..Default::default()
        };
        let (hub, addr) = bare_hub(2, plan);
        let mut leader = Hub::dial(&hub, &addr).unwrap();
        let mut worker = Hub::accept(&hub, 0).expect("pending conn");
        frames::write_frame(&mut leader, 3, b"sensitive numbers").unwrap();
        let err = frames::read_frame(&mut worker).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn delay_past_deadline_fires_virtually_not_really() {
        let plan = FaultPlan {
            links: vec![LinkFaults { delay_ns: 2_000_000_000, ..NO_FAULTS }],
            ..Default::default()
        };
        let (hub, addr) = bare_hub(3, plan);
        let mut leader = Hub::dial(&hub, &addr).unwrap();
        let mut worker = Hub::accept(&hub, 0).expect("pending conn");
        worker.set_read_timeout(Some(Duration::from_secs(1))).unwrap();
        let wall = std::time::Instant::now();
        frames::write_frame(&mut leader, 3, b"late").unwrap();
        let err = frames::read_frame(&mut worker).unwrap_err();
        let err = match err {
            crate::error::Error::Io(e) => e,
            other => panic!("expected io error, got {other}"),
        };
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
        assert!(wall.elapsed() < Duration::from_secs(5), "timeout must not sleep for real");
        assert_eq!(hub.clock.now_ns(), 1_000_000_000, "clock advanced to the fired deadline");
    }

    #[test]
    fn drop_storms_break_the_link_and_readers_see_eof() {
        let plan = FaultPlan {
            links: vec![LinkFaults { drop_prob: 1.0, ..NO_FAULTS }],
            ..Default::default()
        };
        let (hub, addr) = bare_hub(4, plan);
        let mut leader = Hub::dial(&hub, &addr).unwrap();
        let mut worker = Hub::accept(&hub, 0).expect("pending conn");
        // the write "succeeds" (TCP buffers locally)…
        frames::write_frame(&mut leader, 3, b"doomed").unwrap();
        // …the peer sees EOF…
        let err = frames::read_frame(&mut worker).unwrap_err();
        assert!(matches!(err, crate::error::Error::Io(_)), "{err}");
        // …and the next write fails
        let e = leader.write_all(b"x").and_then(|_| leader.flush()).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn same_seed_same_faults_different_seed_differs() {
        let plan = FaultPlan {
            links: vec![LinkFaults { jitter_ns: 1_000_000, drop_prob: 0.4, ..NO_FAULTS }],
            ..Default::default()
        };
        let run = |seed: u64| -> Vec<TraceEvent> {
            let (hub, addr) = bare_hub(seed, plan.clone());
            let mut leader = Hub::dial(&hub, &addr).unwrap();
            let mut worker = Hub::accept(&hub, 0).expect("pending conn");
            for i in 0..8u8 {
                frames::write_frame(&mut leader, 3, &[i; 9]).unwrap();
                if frames::read_frame(&mut worker).is_err() {
                    break;
                }
            }
            let st = hub.state.lock().unwrap();
            st.links[0].events.clone()
        };
        assert_eq!(run(7), run(7), "same seed must replay the same trace");
        assert_ne!(run(7), run(8), "jittered delays must depend on the seed");
    }
}
