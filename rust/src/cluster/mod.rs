//! L4 — the distributed cluster runtime.
//!
//! The paper runs its solvers on "off-the-shelf distributed computing
//! frameworks (e.g. MPI, Hadoop, Spark)" (§4, footnote 2). This module is
//! that layer for real machines: a zero-dependency MPI-style runtime that
//! executes the same *map → combine → reduce* contract as the in-process
//! [`crate::mapreduce::Cluster`], so `solve_scd` / `solve_dd` run
//! unchanged on either executor (see [`Exec`]).
//!
//! * **Workers** (`pallas worker --listen <addr> --store <dir>`) memory-map
//!   their copy of the PR-1 shard store and wait for task frames; each task
//!   names a contiguous chunk of the global shard partition, and the worker
//!   folds it with its own thread pool ([`worker`]).
//! * **The leader** ([`RemoteCluster`]) broadcasts the per-round state
//!   (λ, active coordinates, reduce mode) inside each task, deals chunks
//!   to workers deterministically, and merges the gathered partials **in
//!   chunk order** with compensated sums — the same deterministic merge
//!   discipline as the thread pool, so results are reproducible across
//!   worker counts and across executors.
//! * **The wire** (`frames`, `protocol`) is length-prefixed binary
//!   frames, each payload protected by the store's XXH64
//!   ([`crate::instance::store::xxh64`]); a version + instance fingerprint
//!   handshake ([`InstanceFingerprint`]) refuses mismatched binaries or
//!   mismatched stores before any work is dispatched.
//!   `docs/cluster-protocol.md` is the normative spec.
//! * **The transport seam** ([`transport`], [`clock`]): framing, the
//!   handshake, dispatch and failure detection are written against
//!   [`Transport`]/[`NetListener`]/[`NetStream`] and a [`Clock`] — TCP
//!   ([`TcpTransport`]) in production, and a deterministic in-memory
//!   simulator ([`sim`]) in tests, where any drop/delay/corruption/crash
//!   schedule is replayable from a seed (`docs/simulation.md`).
//! * **Failure handling & elasticity** (`membership`, `leader`): a worker
//!   that times out or drops its connection is marked dead, its in-flight
//!   chunk goes back on the round's queue, and survivors re-execute it —
//!   the round resumes from the λ it was dispatched with, so a lost worker
//!   costs one chunk of recomputation, not the solve. When a redial budget
//!   is configured (`PALLAS_CLUSTER_REDIALS`), transiently-dead links are
//!   redialed with exponential backoff at round boundaries; a leader
//!   started with a join listener admits fresh `bskp worker --join`
//!   processes mid-solve (`Join`/`Admit` frames); and a quorum policy
//!   (`PALLAS_MIN_WORKERS`) fails fast when the live fleet shrinks below
//!   strength instead of grinding on degraded.
//! * **The relay tier** (`PALLAS_RELAY_FANOUT`, [`RelayFanout`]): on
//!   large fleets the leader promotes some workers to *relays*, each
//!   fanning tasks over a subtree of leaf workers and map-side-combining
//!   their partials into one aggregate frame — the gather's per-round
//!   receive count drops from O(workers) to O(relays) while the merge
//!   stays chunk-order canonical, so flat and two-level topologies are
//!   bit-identical (`docs/cluster-protocol.md` §relay tier).

pub mod clock;
pub(crate) mod exec;
pub(crate) mod frames;
pub(crate) mod leader;
pub(crate) mod membership;
pub(crate) mod protocol;
pub mod sim;
pub mod transport;
pub(crate) mod wire;
pub mod worker;

pub use clock::{Backoff, Clock, SystemClock, VirtualClock};
pub use exec::Exec;
pub use leader::{ConnectOptions, ExchangeMode, NetSnapshot, RelayFanout, RemoteCluster};
pub use protocol::InstanceFingerprint;
pub use sim::{Dir, ElasticObserver, FaultPlan, LinkFaults, SimNet, SimTransport, TraceEvent, TraceKind};
pub use transport::{NetListener, NetStream, TcpNetListener, TcpTransport, Transport};

/// Read a `PALLAS_*` millisecond knob, ignoring unparsable or zero
/// values. Shared by the leader's exchange/connect timeouts and the
/// worker's session idle bound so the knobs can never drift in parsing.
pub(crate) fn env_ms(var: &str, default_ms: u64) -> std::time::Duration {
    std::time::Duration::from_millis(
        std::env::var(var)
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&ms| ms > 0)
            .unwrap_or(default_ms),
    )
}

/// Read a `PALLAS_*` count knob (budgets, quorums), ignoring unparsable
/// values. Unlike [`env_ms`], zero is a meaningful setting — it is how
/// `PALLAS_CLUSTER_REDIALS=0` switches redialing off.
pub(crate) fn env_count(var: &str, default: u64) -> u64 {
    std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Upper bound on chunks dealt per round — enough granularity for
/// re-dispatch after a failure without drowning the wire in tiny tasks.
/// Shared by the leader's deal and the relay's sub-deal (both sides must
/// agree on the chunk grid for the merge to be topology-independent).
pub(crate) const CHUNKS_PER_ROUND: usize = 64;

/// The global chunk partition of a round: `(per, n_chunks)` — chunk `c`
/// covers shards `[c * per, ((c + 1) * per).min(n_shards))`. One pure
/// function shared by the leader's gather and the relay's sub-deal, so a
/// relay splits its task range on exactly the chunk boundaries the
/// leader's flat deal would have used — the precondition for the
/// chunk-order-canonical merge being topology-independent.
pub(crate) fn chunk_plan(n_shards: usize, chunks_per_round: usize) -> (usize, usize) {
    if n_shards == 0 {
        return (1, 0); // an empty round deals no chunks
    }
    let n_chunks = n_shards.min(chunks_per_round).max(1);
    let per = n_shards.div_ceil(n_chunks);
    (per, n_shards.div_ceil(per))
}
