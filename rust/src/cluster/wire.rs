//! Little-endian payload encoding.
//!
//! The offline registry has no serde; messages are packed by hand with
//! these two helpers. Floats travel as raw IEEE-754 bits, so partial
//! accumulators (Kahan sums, bucket histograms) survive the trip
//! bit-for-bit — a prerequisite for the determinism contract (and for
//! the simulator's replay guarantee: the same payload bytes cross TCP
//! and the in-memory transport alike).

use crate::error::{Error, Result};

pub(crate) fn corrupt(what: &str) -> Error {
    Error::Runtime(format!("cluster wire: malformed frame payload ({what})"))
}

/// Append-only payload builder.
#[derive(Default)]
pub(crate) struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    pub(crate) fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub(crate) fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub(crate) fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub(crate) fn f32(&mut self, v: f32) -> &mut Self {
        self.u32(v.to_bits())
    }

    pub(crate) fn f64(&mut self, v: f64) -> &mut Self {
        self.u64(v.to_bits())
    }

    pub(crate) fn f64s(&mut self, vs: &[f64]) -> &mut Self {
        self.u64(vs.len() as u64);
        for &v in vs {
            self.f64(v);
        }
        self
    }

    pub(crate) fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
        self
    }

    /// Append raw bytes with no length prefix — for envelope messages
    /// whose tail is an opaque inner payload (the frame length bounds it).
    pub(crate) fn bytes(&mut self, b: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(b);
        self
    }

    pub(crate) fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor over a received payload; every read is bounds-checked so a
/// truncated or hostile frame surfaces as a clean error, never a panic.
pub(crate) struct Dec<'a> {
    b: &'a [u8],
}

impl<'a> Dec<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Self {
        Self { b }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() < n {
            return Err(corrupt("truncated"));
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A `u64` that will be used as an element count: capped so a corrupt
    /// length prefix cannot trigger a huge allocation before the data runs
    /// out anyway.
    pub(crate) fn len(&mut self) -> Result<usize> {
        self.len_of(1)
    }

    /// An element count for elements of `elem_bytes` wire bytes each —
    /// rejects any count the remaining payload cannot possibly hold.
    pub(crate) fn len_of(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        if n.saturating_mul(elem_bytes.max(1)) > self.b.len() {
            return Err(corrupt("length prefix exceeds payload"));
        }
        Ok(n)
    }

    pub(crate) fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.len_of(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    pub(crate) fn str(&mut self) -> Result<String> {
        let n = self.len()?;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| corrupt("non-utf8 string"))
    }

    /// Take every remaining byte — for envelope messages whose tail is an
    /// opaque inner payload (`RelayPartial`).
    pub(crate) fn rest(&mut self) -> &'a [u8] {
        let all = self.b;
        self.b = &[];
        all
    }

    pub(crate) fn finish(self) -> Result<()> {
        if self.b.is_empty() {
            Ok(())
        } else {
            Err(corrupt("trailing bytes"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut e = Enc::new();
        e.u8(7).u32(70_000).u64(1 << 40).f32(1.5).f64(-0.1).f64s(&[1.0, 2.0]).str("héllo");
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 70_000);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.f32().unwrap(), 1.5);
        assert_eq!(d.f64().unwrap(), -0.1);
        assert_eq!(d.f64s().unwrap(), vec![1.0, 2.0]);
        assert_eq!(d.str().unwrap(), "héllo");
        d.finish().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut e = Enc::new();
        e.u64(5);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes[..4]);
        assert!(d.u64().is_err());
        // absurd length prefix: rejected before allocation
        let mut e = Enc::new();
        e.u64(u64::MAX);
        let bytes = e.into_bytes();
        assert!(Dec::new(&bytes).f64s().is_err());
        assert!(Dec::new(&bytes).str().is_err());
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut e = Enc::new();
        e.u8(1).u8(2);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        d.u8().unwrap();
        assert!(d.finish().is_err());
    }

    #[test]
    fn float_bits_are_preserved() {
        // NaN payloads and signed zero must survive (Kahan compensation
        // terms can be -0.0; bucket bounds start at ±inf)
        for v in [f64::NAN, -0.0, f64::INFINITY, f64::NEG_INFINITY, 1e-308] {
            let mut e = Enc::new();
            e.f64(v);
            let bytes = e.into_bytes();
            let got = Dec::new(&bytes).f64().unwrap();
            assert_eq!(got.to_bits(), v.to_bits());
        }
    }
}
