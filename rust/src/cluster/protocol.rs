//! Message vocabulary of the cluster wire protocol.
//!
//! Fifteen message kinds ride the [`super::frames`] layer: a two-message
//! handshake (`Hello`/`Welcome`) that pins the protocol version and the
//! instance fingerprint, three task kinds (one per map-round flavor:
//! evaluation, SCD threshold emission, §5.4 ranking), their three partial
//! kinds, `Abort` and `Shutdown`, the elastic-membership handshake
//! (`Join`/`Admit`): a fresh worker dials the *leader's* join listener
//! mid-solve, offers its capacity and fingerprint, and — once admitted —
//! serves the same stateless task loop as a dial-time worker; plus the
//! relay tier (`RelayAssign`/`RelayReady`/`RelayPartial`): the leader
//! promotes a worker to fan a task out over a subtree of leaf workers
//! and merge their partials map-side before one aggregate frame comes
//! back upstream (`docs/cluster-protocol.md` §relay tier). Tasks are
//! *self-contained*: shard
//! geometry, chunk bounds and the full per-round broadcast state (λ,
//! active mask, reduce mode) travel in every task, so a worker is
//! stateless between frames and any task can be re-dispatched to any
//! surviving worker after a failure.
//!
//! `docs/cluster-protocol.md` is the normative byte-level spec. The
//! protocol is transport-agnostic (see [`super::transport`]): the same
//! message bytes flow over production TCP and over the deterministic
//! simulator, which is how the chaos suite replays handshake refusals,
//! corrupt frames and mid-round crashes from a seed.

use crate::cluster::wire::{corrupt, Dec, Enc};
use crate::error::Result;
use crate::instance::problem::{CostsBuf, GroupBuf, GroupSource};
use crate::instance::shard::Shards;
use crate::instance::store::xxh64;
use crate::solver::bucketing::BucketHist;
use crate::solver::config::ReduceMode;
use crate::solver::rounds::RoundAgg;
use crate::solver::scd::{ScdAcc, ThresholdAcc};
use crate::util::KahanSum;
use std::io::{Read, Write};

/// Seed for the local-constraint hash.
const LOCALS_SEED: u64 = 0x1A;
/// Seed for the sampled-group data hash.
const SAMPLE_SEED: u64 = 0xDA;

/// Compact identity of an instance: dimensions, cost class, and hashes of
/// the laminar local-constraint profile and three sampled groups' raw
/// coefficients (first, middle, last). Exchanged in the handshake so a
/// leader never dispatches work to a worker that mmap'd a different store
/// — same-shape lookalikes included, since the sampled-data hash reads the
/// actual coefficients.
///
/// Budgets are deliberately **not** part of the identity: the map phase
/// never reads them (they enter only the leader-side reduce), and the
/// production changed-budget re-solve (`resolve --budget-scale`) solves a
/// budget-perturbed *view* of the same store — workers serving the
/// unscaled replica are exactly right for it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InstanceFingerprint {
    pub(crate) n_groups: u64,
    pub(crate) n_items: u32,
    pub(crate) n_global: u32,
    pub(crate) dense: bool,
    pub(crate) locals_hash: u64,
    pub(crate) sample_hash: u64,
}

impl InstanceFingerprint {
    /// Fingerprint of any [`GroupSource`].
    pub fn of<S: GroupSource + ?Sized>(source: &S) -> Self {
        let dims = source.dims();
        let mut locals_bytes = Vec::new();
        for c in source.locals().constraints() {
            locals_bytes.extend_from_slice(&(c.items.len() as u32).to_le_bytes());
            for &j in &c.items {
                locals_bytes.extend_from_slice(&j.to_le_bytes());
            }
            locals_bytes.extend_from_slice(&c.cap.to_le_bytes());
        }
        let mut sample_bytes = Vec::new();
        if dims.n_groups > 0 {
            let mut buf = GroupBuf::new(dims, source.is_dense());
            for i in [0, dims.n_groups / 2, dims.n_groups - 1] {
                source.fill_group(i, &mut buf);
                for p in &buf.profits {
                    sample_bytes.extend_from_slice(&p.to_le_bytes());
                }
                match &buf.costs {
                    CostsBuf::Dense(b) => {
                        for v in b {
                            sample_bytes.extend_from_slice(&v.to_le_bytes());
                        }
                    }
                    CostsBuf::Sparse { knap, cost } => {
                        for k in knap {
                            sample_bytes.extend_from_slice(&k.to_le_bytes());
                        }
                        for v in cost {
                            sample_bytes.extend_from_slice(&v.to_le_bytes());
                        }
                    }
                }
            }
        }
        Self {
            n_groups: dims.n_groups as u64,
            n_items: dims.n_items as u32,
            n_global: dims.n_global as u32,
            dense: source.is_dense(),
            locals_hash: xxh64(&locals_bytes, LOCALS_SEED),
            sample_hash: xxh64(&sample_bytes, SAMPLE_SEED),
        }
    }

    pub(crate) fn encode(&self, e: &mut Enc) {
        e.u64(self.n_groups)
            .u32(self.n_items)
            .u32(self.n_global)
            .u8(self.dense as u8)
            .u64(self.locals_hash)
            .u64(self.sample_hash);
    }

    pub(crate) fn decode(d: &mut Dec<'_>) -> Result<Self> {
        Ok(Self {
            n_groups: d.u64()?,
            n_items: d.u32()?,
            n_global: d.u32()?,
            dense: d.u8()? != 0,
            locals_hash: d.u64()?,
            sample_hash: d.u64()?,
        })
    }
}

impl std::fmt::Display for InstanceFingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "N={} M={} K={} {} locals#{:016x} data#{:016x}",
            self.n_groups,
            self.n_items,
            self.n_global,
            if self.dense { "dense" } else { "sparse" },
            self.locals_hash,
            self.sample_hash,
        )
    }
}

/// The global map-shard partition a task chunk refers to. Fixed by the
/// leader's plan; workers rebuild the identical [`Shards`] from it so a
/// chunk means the same group ranges on every machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Geometry {
    pub(crate) n_total: u64,
    pub(crate) shard_size: u64,
}

impl Geometry {
    pub(crate) fn of(shards: Shards) -> Self {
        Self { n_total: shards.n_total() as u64, shard_size: shards.shard_size() as u64 }
    }

    pub(crate) fn shards(&self) -> Result<Shards> {
        if self.shard_size == 0 {
            return Err(corrupt("zero shard size in task geometry"));
        }
        Ok(Shards::new(self.n_total as usize, self.shard_size as usize))
    }

    fn encode(&self, e: &mut Enc) {
        e.u64(self.n_total).u64(self.shard_size);
    }

    fn decode(d: &mut Dec<'_>) -> Result<Self> {
        Ok(Self { n_total: d.u64()?, shard_size: d.u64()? })
    }
}

/// One protocol message. Kinds 1–2 handshake, 3–5 tasks (leader→worker),
/// 6–8 partials (worker→leader), 9 abort, 10 shutdown, 11–12 the
/// mid-solve join handshake (worker-dialed), 13–15 the two-level relay
/// tier (`docs/cluster-protocol.md` §relay tier).
pub(crate) enum Msg {
    /// Leader → worker: open the session. The worker refuses a fingerprint
    /// that does not match its own store.
    Hello { fingerprint: InstanceFingerprint },
    /// Worker → leader: session accepted; advertises map-thread capacity
    /// and the shard-index span `[shard_lo, shard_hi)` its store replica
    /// covers (today every worker serves the whole store and advertises
    /// `(0, u64::MAX)`; partial replicas are the forward hook the
    /// shard-replica-aware relay placement keys on).
    Welcome { threads: u32, fingerprint: InstanceFingerprint, shard_lo: u64, shard_hi: u64 },
    /// Evaluate shard chunk `[lo, hi)` at fixed λ (DD round / final eval).
    EvalTask { geo: Geometry, lo: u64, hi: u64, lambda: Vec<f64> },
    /// One SCD round over shard chunk `[lo, hi)`.
    ScdTask {
        geo: Geometry,
        lo: u64,
        hi: u64,
        lambda: Vec<f64>,
        active: Vec<bool>,
        sparse_q: Option<u32>,
        reduce: ReduceMode,
    },
    /// §5.4 ranking over shard chunk `[lo, hi)`.
    RankTask { geo: Geometry, lo: u64, hi: u64, lambda: Vec<f64> },
    /// Reply to `EvalTask`.
    EvalPartial(RoundAgg),
    /// Reply to `ScdTask`.
    ScdPartial(ScdAcc),
    /// Reply to `RankTask`: `(p̃_i, group id)` pairs.
    RankPartial(Vec<(f32, u32)>),
    /// Either side: unrecoverable session error (mismatched store, invalid
    /// task). The connection closes after this frame.
    Abort { message: String },
    /// Leader → worker: end the session; the worker returns to accepting.
    Shutdown,
    /// Worker → leader, on a worker-dialed stream to the leader's join
    /// listener: ask to join the running solve, advertising map-thread
    /// capacity, the store fingerprint and the replica's shard span (the
    /// same fields `Welcome` carries, byte for byte). The frame layer has
    /// already pinned the protocol version; the leader checks the
    /// fingerprint and answers `Admit` (or `Abort` on a mismatch).
    Join { threads: u32, fingerprint: InstanceFingerprint, shard_lo: u64, shard_hi: u64 },
    /// Leader → worker: join accepted — from the next round boundary on,
    /// the stream carries the same task/partial traffic as a dial-time
    /// session.
    Admit,
    /// Leader → worker: promote this worker to a *relay* over the given
    /// leaf worker addresses (or update the subtree — the assignment is
    /// idempotent and replaceable; an empty leaf list demotes back to a
    /// plain worker). The timeouts are the leader's connect/exchange
    /// policy, forwarded so relay→leaf links inherit it.
    RelayAssign { leaves: Vec<String>, connect_timeout_ms: u64, exchange_timeout_ms: u64 },
    /// Worker → leader: the relay assignment was applied. `reached[i]`
    /// says whether leaf `i` of the assignment handshook; `threads` is the
    /// subtree's total advertised map capacity (informational — the
    /// leader's per-slot capacity accounting already counts the leaves).
    RelayReady { threads: u32, reached: Vec<bool> },
    /// Relay → leader: one subtree aggregate — the map-side-combined
    /// partial covering the relay's whole task range, wrapped around the
    /// ordinary partial message it would have sent as a plain worker.
    /// `lost` lists assignment-order leaf indices that died during this
    /// exchange (their sub-chunks were recomputed by the relay, so the
    /// aggregate is complete regardless).
    RelayPartial { lost: Vec<u32>, inner: Box<Msg> },
}

impl Msg {
    pub(crate) fn kind(&self) -> u16 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Welcome { .. } => 2,
            Msg::EvalTask { .. } => 3,
            Msg::ScdTask { .. } => 4,
            Msg::RankTask { .. } => 5,
            Msg::EvalPartial(_) => 6,
            Msg::ScdPartial(_) => 7,
            Msg::RankPartial(_) => 8,
            Msg::Abort { .. } => 9,
            Msg::Shutdown => 10,
            Msg::Join { .. } => 11,
            Msg::Admit => 12,
            Msg::RelayAssign { .. } => 13,
            Msg::RelayReady { .. } => 14,
            Msg::RelayPartial { .. } => 15,
        }
    }

    pub(crate) fn name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Welcome { .. } => "welcome",
            Msg::EvalTask { .. } => "eval-task",
            Msg::ScdTask { .. } => "scd-task",
            Msg::RankTask { .. } => "rank-task",
            Msg::EvalPartial(_) => "eval-partial",
            Msg::ScdPartial(_) => "scd-partial",
            Msg::RankPartial(_) => "rank-partial",
            Msg::Abort { .. } => "abort",
            Msg::Shutdown => "shutdown",
            Msg::Join { .. } => "join",
            Msg::Admit => "admit",
            Msg::RelayAssign { .. } => "relay-assign",
            Msg::RelayReady { .. } => "relay-ready",
            Msg::RelayPartial { .. } => "relay-partial",
        }
    }

    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            Msg::Hello { fingerprint } => fingerprint.encode(&mut e),
            Msg::Welcome { threads, fingerprint, shard_lo, shard_hi }
            | Msg::Join { threads, fingerprint, shard_lo, shard_hi } => {
                e.u32(*threads);
                fingerprint.encode(&mut e);
                e.u64(*shard_lo).u64(*shard_hi);
            }
            Msg::EvalTask { geo, lo, hi, lambda } | Msg::RankTask { geo, lo, hi, lambda } => {
                geo.encode(&mut e);
                e.u64(*lo).u64(*hi).f64s(lambda);
            }
            Msg::ScdTask { geo, lo, hi, lambda, active, sparse_q, reduce } => {
                geo.encode(&mut e);
                e.u64(*lo).u64(*hi).f64s(lambda);
                e.u64(active.len() as u64);
                for &a in active {
                    e.u8(a as u8);
                }
                match sparse_q {
                    Some(q) => e.u8(1).u32(*q),
                    None => e.u8(0),
                };
                match reduce {
                    ReduceMode::Exact => e.u8(0),
                    ReduceMode::Bucketed { delta } => e.u8(1).f64(*delta),
                };
            }
            Msg::EvalPartial(agg) => encode_agg(&mut e, agg),
            Msg::ScdPartial(acc) => {
                encode_agg(&mut e, &acc.round);
                encode_thresholds(&mut e, &acc.thresholds);
            }
            Msg::RankPartial(ranked) => {
                e.u64(ranked.len() as u64);
                for &(v, i) in ranked {
                    e.f32(v).u32(i);
                }
            }
            Msg::Abort { message } => {
                e.str(message);
            }
            Msg::Shutdown => {}
            Msg::Admit => {}
            Msg::RelayAssign { leaves, connect_timeout_ms, exchange_timeout_ms } => {
                e.u64(leaves.len() as u64);
                for leaf in leaves {
                    e.str(leaf);
                }
                e.u64(*connect_timeout_ms).u64(*exchange_timeout_ms);
            }
            Msg::RelayReady { threads, reached } => {
                e.u32(*threads);
                e.u64(reached.len() as u64);
                for &r in reached {
                    e.u8(r as u8);
                }
            }
            Msg::RelayPartial { lost, inner } => {
                e.u64(lost.len() as u64);
                for &i in lost {
                    e.u32(i);
                }
                e.u32(inner.kind() as u32);
                let body = inner.encode();
                e.bytes(&body);
            }
        }
        e.into_bytes()
    }

    pub(crate) fn decode(kind: u16, payload: &[u8]) -> Result<Msg> {
        let mut d = Dec::new(payload);
        let msg = match kind {
            1 => Msg::Hello { fingerprint: InstanceFingerprint::decode(&mut d)? },
            2 => Msg::Welcome {
                threads: d.u32()?,
                fingerprint: InstanceFingerprint::decode(&mut d)?,
                shard_lo: d.u64()?,
                shard_hi: d.u64()?,
            },
            3 | 5 => {
                let geo = Geometry::decode(&mut d)?;
                let (lo, hi) = (d.u64()?, d.u64()?);
                let lambda = d.f64s()?;
                if kind == 3 {
                    Msg::EvalTask { geo, lo, hi, lambda }
                } else {
                    Msg::RankTask { geo, lo, hi, lambda }
                }
            }
            4 => {
                let geo = Geometry::decode(&mut d)?;
                let (lo, hi) = (d.u64()?, d.u64()?);
                let lambda = d.f64s()?;
                let n_active = d.len()?;
                let mut active = Vec::with_capacity(n_active);
                for _ in 0..n_active {
                    active.push(d.u8()? != 0);
                }
                let sparse_q = if d.u8()? != 0 { Some(d.u32()?) } else { None };
                let reduce = match d.u8()? {
                    0 => ReduceMode::Exact,
                    1 => {
                        let delta = d.f64()?;
                        if !(delta > 0.0) {
                            return Err(corrupt("non-positive bucketing delta"));
                        }
                        ReduceMode::Bucketed { delta }
                    }
                    _ => return Err(corrupt("unknown reduce mode")),
                };
                Msg::ScdTask { geo, lo, hi, lambda, active, sparse_q, reduce }
            }
            6 => Msg::EvalPartial(decode_agg(&mut d)?),
            7 => {
                let round = decode_agg(&mut d)?;
                let thresholds = decode_thresholds(&mut d)?;
                Msg::ScdPartial(ScdAcc { round, thresholds })
            }
            8 => {
                let n = d.len_of(8)?;
                let mut ranked = Vec::with_capacity(n);
                for _ in 0..n {
                    let v = d.f32()?;
                    let i = d.u32()?;
                    ranked.push((v, i));
                }
                Msg::RankPartial(ranked)
            }
            9 => Msg::Abort { message: d.str()? },
            10 => Msg::Shutdown,
            11 => Msg::Join {
                threads: d.u32()?,
                fingerprint: InstanceFingerprint::decode(&mut d)?,
                shard_lo: d.u64()?,
                shard_hi: d.u64()?,
            },
            12 => Msg::Admit,
            13 => {
                let n = d.len()?;
                let mut leaves = Vec::with_capacity(n);
                for _ in 0..n {
                    leaves.push(d.str()?);
                }
                Msg::RelayAssign {
                    leaves,
                    connect_timeout_ms: d.u64()?,
                    exchange_timeout_ms: d.u64()?,
                }
            }
            14 => {
                let threads = d.u32()?;
                let n = d.len()?;
                let mut reached = Vec::with_capacity(n);
                for _ in 0..n {
                    reached.push(d.u8()? != 0);
                }
                Msg::RelayReady { threads, reached }
            }
            15 => {
                let n = d.len_of(4)?;
                let mut lost = Vec::with_capacity(n);
                for _ in 0..n {
                    lost.push(d.u32()?);
                }
                let inner_kind = d.u32()? as u16;
                // only the three partial kinds may travel inside the
                // envelope — anything else (nested envelopes included)
                // is a malformed frame
                if !(6..=8).contains(&inner_kind) {
                    return Err(corrupt(&format!(
                        "relay-partial envelope around non-partial kind {inner_kind}"
                    )));
                }
                let inner = Msg::decode(inner_kind, d.rest())?;
                Msg::RelayPartial { lost, inner: Box::new(inner) }
            }
            other => return Err(corrupt(&format!("unknown message kind {other}"))),
        };
        d.finish()?;
        Ok(msg)
    }
}

fn encode_kahan(e: &mut Enc, k: &KahanSum) {
    let (sum, comp) = k.parts();
    e.f64(sum).f64(comp);
}

fn decode_kahan(d: &mut Dec<'_>) -> Result<KahanSum> {
    Ok(KahanSum::from_parts(d.f64()?, d.f64()?))
}

fn encode_agg(e: &mut Enc, agg: &RoundAgg) {
    e.u64(agg.consumption.len() as u64);
    for k in &agg.consumption {
        encode_kahan(e, k);
    }
    encode_kahan(e, &agg.primal);
    encode_kahan(e, &agg.dual_inner);
    e.u64(agg.n_selected);
}

fn decode_agg(d: &mut Dec<'_>) -> Result<RoundAgg> {
    let k = d.len_of(16)?;
    let mut agg = RoundAgg::new(0);
    agg.consumption = (0..k).map(|_| decode_kahan(d)).collect::<Result<_>>()?;
    agg.primal = decode_kahan(d)?;
    agg.dual_inner = decode_kahan(d)?;
    agg.n_selected = d.u64()?;
    Ok(agg)
}

fn encode_thresholds(e: &mut Enc, t: &ThresholdAcc) {
    match t {
        ThresholdAcc::Exact(per_k) => {
            e.u8(0).u64(per_k.len() as u64);
            for pairs in per_k {
                e.u64(pairs.len() as u64);
                for &(v1, v2) in pairs {
                    e.f64(v1).f64(v2);
                }
            }
        }
        ThresholdAcc::Bucketed(hists) => {
            e.u8(1).u64(hists.len() as u64);
            let mut words = Vec::with_capacity(BucketHist::wire_len());
            for h in hists {
                words.clear();
                h.to_wire(&mut words);
                for &w in &words {
                    e.f64(w);
                }
            }
        }
    }
}

fn decode_thresholds(d: &mut Dec<'_>) -> Result<ThresholdAcc> {
    match d.u8()? {
        0 => {
            let k = d.len_of(8)?;
            let mut per_k = Vec::with_capacity(k);
            for _ in 0..k {
                // the count prefix is checked against the remaining payload
                // (so a corrupt prefix cannot force a huge allocation);
                // every pair read below is bounds-checked besides
                let n = d.len_of(16)?;
                let mut pairs = Vec::with_capacity(n);
                for _ in 0..n {
                    let v1 = d.f64()?;
                    let v2 = d.f64()?;
                    pairs.push((v1, v2));
                }
                per_k.push(pairs);
            }
            Ok(ThresholdAcc::Exact(per_k))
        }
        1 => {
            let k = d.len_of(BucketHist::wire_len() * 8)?;
            let mut hists = Vec::with_capacity(k);
            let mut words = vec![0.0f64; BucketHist::wire_len()];
            for _ in 0..k {
                for w in words.iter_mut() {
                    *w = d.f64()?;
                }
                hists.push(
                    BucketHist::from_wire(&words)
                        .ok_or_else(|| corrupt("invalid bucket histogram"))?,
                );
            }
            Ok(ThresholdAcc::Bucketed(hists))
        }
        _ => Err(corrupt("unknown threshold accumulator tag")),
    }
}

/// Send one message; returns bytes written.
pub(crate) fn send_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<usize> {
    let payload = msg.encode();
    super::frames::write_frame(w, msg.kind(), &payload)
}

/// Receive one message; returns it with the bytes read.
pub(crate) fn recv_msg<R: Read>(r: &mut R) -> Result<(Msg, usize)> {
    let (kind, payload, n) = super::frames::read_frame(r)?;
    Ok((Msg::decode(kind, &payload)?, n))
}

/// Send one message under a 16-byte span-context frame extension
/// ([`span_ext`]); returns bytes written.
pub(crate) fn send_msg_ext<W: Write>(
    w: &mut W,
    msg: &Msg,
    ext: &[u8; super::frames::EXT_LEN],
) -> Result<usize> {
    let payload = msg.encode();
    super::frames::write_frame_ext(w, msg.kind(), ext, &payload)
}

/// Receive one message that may carry a span-context extension; returns
/// the message, the extension if present, and the bytes read.
pub(crate) fn recv_msg_ext<R: Read>(
    r: &mut R,
) -> Result<(Msg, Option<[u8; super::frames::EXT_LEN]>, usize)> {
    let (kind, ext, payload, n) = super::frames::read_frame_ext(r)?;
    Ok((Msg::decode(kind, &payload)?, ext, n))
}

/// Layout of the 16-byte span-context frame extension (observability;
/// `docs/cluster-protocol.md` §extensions).
///
/// Task direction (leader → worker): `[0..8)` round index, `[8..16)`
/// flags (bit 0: the leader is tracing and wants the worker's task span
/// shipped back on the reply).
///
/// Reply direction (worker → leader): the worker-side task span —
/// `[0..2)` span code, `[2..8)` reserved zero, `[8..16)` duration in
/// worker-clock nanoseconds. The leader re-bases it onto its own clock
/// and fills the argument words from the in-flight task it matches, so
/// the wire carries only what the leader cannot know.
pub(crate) mod span_ext {
    use crate::cluster::frames::EXT_LEN;

    /// Encode the leader→worker task extension.
    pub(crate) fn encode_task(round: u64, trace: bool) -> [u8; EXT_LEN] {
        let mut ext = [0u8; EXT_LEN];
        ext[0..8].copy_from_slice(&round.to_le_bytes());
        ext[8..16].copy_from_slice(&(trace as u64).to_le_bytes());
        ext
    }

    /// Decode a task extension to `(round, trace_wanted)`.
    pub(crate) fn decode_task(ext: &[u8; EXT_LEN]) -> (u64, bool) {
        let round = u64::from_le_bytes(ext[0..8].try_into().unwrap());
        let flags = u64::from_le_bytes(ext[8..16].try_into().unwrap());
        (round, flags & 1 != 0)
    }

    /// Encode the worker→leader reply extension (one shipped task span).
    pub(crate) fn encode_span(code: u16, dur_ns: u64) -> [u8; EXT_LEN] {
        let mut ext = [0u8; EXT_LEN];
        ext[0..2].copy_from_slice(&code.to_le_bytes());
        ext[8..16].copy_from_slice(&dur_ns.to_le_bytes());
        ext
    }

    /// Decode a reply extension to `(code, dur_ns)`.
    pub(crate) fn decode_span(ext: &[u8; EXT_LEN]) -> (u16, u64) {
        let code = u16::from_le_bytes(ext[0..2].try_into().unwrap());
        let dur_ns = u64::from_le_bytes(ext[8..16].try_into().unwrap());
        (code, dur_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};
    use crate::util::KahanSum;

    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        send_msg(&mut buf, msg).unwrap();
        let (back, n) = recv_msg(&mut buf.as_slice()).unwrap();
        assert_eq!(n, buf.len());
        back
    }

    #[test]
    fn fingerprint_distinguishes_lookalike_instances() {
        let a = SyntheticProblem::new(GeneratorConfig::sparse(500, 6, 6).with_seed(1));
        let b = SyntheticProblem::new(GeneratorConfig::sparse(500, 6, 6).with_seed(2));
        let (fa, fb) = (InstanceFingerprint::of(&a), InstanceFingerprint::of(&b));
        // same dims, class and locals: only the sampled-data hash differs
        assert_ne!(fa, fb);
        assert_ne!(fa.sample_hash, fb.sample_hash);
        assert_eq!(fa.locals_hash, fb.locals_hash);

        // a budget-perturbed view of the same instance keeps its identity —
        // that is what lets `resolve --budget-scale` run distributed
        // against workers serving the unscaled store
        let scaled = crate::solve::ScaledBudgets::uniform(&a, 1.05).unwrap();
        assert_eq!(InstanceFingerprint::of(&scaled), fa);
        assert_eq!(fa, InstanceFingerprint::of(&a));
    }

    #[test]
    fn task_messages_roundtrip() {
        let geo = Geometry { n_total: 10_000, shard_size: 256 };
        let msg = Msg::ScdTask {
            geo,
            lo: 3,
            hi: 9,
            lambda: vec![0.5, 0.0, 2.25],
            active: vec![true, false, true],
            sparse_q: Some(7),
            reduce: ReduceMode::Bucketed { delta: 1e-6 },
        };
        match roundtrip(&msg) {
            Msg::ScdTask { geo: g, lo, hi, lambda, active, sparse_q, reduce } => {
                assert_eq!(g, geo);
                assert_eq!((lo, hi), (3, 9));
                assert_eq!(lambda, vec![0.5, 0.0, 2.25]);
                assert_eq!(active, vec![true, false, true]);
                assert_eq!(sparse_q, Some(7));
                assert_eq!(reduce, ReduceMode::Bucketed { delta: 1e-6 });
            }
            other => panic!("wrong kind back: {}", other.name()),
        }
    }

    #[test]
    fn partials_roundtrip_bit_exact() {
        let mut agg = RoundAgg::new(2);
        agg.consumption[0].add(1e16);
        agg.consumption[0].add(1.0); // non-zero compensation term
        agg.consumption[1].add(-3.5);
        agg.primal.add(42.0);
        agg.dual_inner.add(41.5);
        agg.n_selected = 17;
        let back = match roundtrip(&Msg::EvalPartial(agg.clone())) {
            Msg::EvalPartial(a) => a,
            other => panic!("wrong kind back: {}", other.name()),
        };
        let bits = |k: &KahanSum| {
            let (s, c) = k.parts();
            (s.to_bits(), c.to_bits())
        };
        assert_eq!(bits(&back.primal), bits(&agg.primal));
        assert_eq!(bits(&back.dual_inner), bits(&agg.dual_inner));
        for (x, y) in back.consumption.iter().zip(&agg.consumption) {
            assert_eq!(bits(x), bits(y));
        }
        assert_eq!(back.n_selected, 17);

        let mut thresholds = ThresholdAcc::new(ReduceMode::Exact, &[1.0, 1.0]);
        match &mut thresholds {
            ThresholdAcc::Exact(v) => {
                v[0].push((2.5, 0.75));
                v[1].push((0.125, 3.0));
            }
            _ => unreachable!(),
        }
        let acc = ScdAcc { round: agg, thresholds };
        match roundtrip(&Msg::ScdPartial(acc)) {
            Msg::ScdPartial(back) => match back.thresholds {
                ThresholdAcc::Exact(v) => {
                    assert_eq!(v[0], vec![(2.5, 0.75)]);
                    assert_eq!(v[1], vec![(0.125, 3.0)]);
                }
                _ => panic!("wrong threshold variant"),
            },
            other => panic!("wrong kind back: {}", other.name()),
        }
    }

    #[test]
    fn span_ext_rides_task_and_partial_frames() {
        // task with a span-context extension
        let geo = Geometry { n_total: 100, shard_size: 10 };
        let task = Msg::EvalTask { geo, lo: 0, hi: 5, lambda: vec![1.0] };
        let mut buf = Vec::new();
        send_msg_ext(&mut buf, &task, &span_ext::encode_task(12, true)).unwrap();
        let (msg, ext, n) = recv_msg_ext(&mut buf.as_slice()).unwrap();
        assert_eq!(n, buf.len());
        assert!(matches!(msg, Msg::EvalTask { .. }));
        let (round, trace) = span_ext::decode_task(&ext.expect("ext present"));
        assert_eq!(round, 12);
        assert!(trace);

        // reply carrying a worker task span
        let reply = Msg::EvalPartial(RoundAgg::new(1));
        let mut buf = Vec::new();
        send_msg_ext(&mut buf, &reply, &span_ext::encode_span(9, 1_234_567)).unwrap();
        let (msg, ext, _) = recv_msg_ext(&mut buf.as_slice()).unwrap();
        assert!(matches!(msg, Msg::EvalPartial(_)));
        let (code, dur) = span_ext::decode_span(&ext.expect("ext present"));
        assert_eq!((code, dur), (9, 1_234_567));

        // plain frames still read as no-extension through the ext path
        let mut buf = Vec::new();
        send_msg(&mut buf, &Msg::Shutdown).unwrap();
        let (msg, ext, _) = recv_msg_ext(&mut buf.as_slice()).unwrap();
        assert!(matches!(msg, Msg::Shutdown));
        assert!(ext.is_none());
    }

    #[test]
    fn handshake_and_control_roundtrip() {
        let p = SyntheticProblem::new(GeneratorConfig::dense(50, 4, 3).with_seed(9));
        let fp = InstanceFingerprint::of(&p);
        let welcome =
            Msg::Welcome { threads: 8, fingerprint: fp.clone(), shard_lo: 0, shard_hi: u64::MAX };
        match roundtrip(&welcome) {
            Msg::Welcome { threads, fingerprint, shard_lo, shard_hi } => {
                assert_eq!(threads, 8);
                assert_eq!(fingerprint, fp);
                assert_eq!((shard_lo, shard_hi), (0, u64::MAX));
            }
            other => panic!("wrong kind back: {}", other.name()),
        }
        assert!(matches!(roundtrip(&Msg::Shutdown), Msg::Shutdown));
        match roundtrip(&Msg::Abort { message: "nope".into() }) {
            Msg::Abort { message } => assert_eq!(message, "nope"),
            other => panic!("wrong kind back: {}", other.name()),
        }
    }

    #[test]
    fn join_handshake_roundtrips() {
        let p = SyntheticProblem::new(GeneratorConfig::dense(50, 4, 3).with_seed(9));
        let fp = InstanceFingerprint::of(&p);
        let join =
            Msg::Join { threads: 4, fingerprint: fp.clone(), shard_lo: 3, shard_hi: 900 };
        match roundtrip(&join) {
            Msg::Join { threads, fingerprint, shard_lo, shard_hi } => {
                assert_eq!(threads, 4);
                assert_eq!(fingerprint, fp);
                assert_eq!((shard_lo, shard_hi), (3, 900));
            }
            other => panic!("wrong kind back: {}", other.name()),
        }
        assert!(matches!(roundtrip(&Msg::Admit), Msg::Admit));
        // Join carries exactly what Welcome does, so the payloads match
        // byte for byte — only the kind differs (spec'd in
        // docs/cluster-protocol.md)
        let join =
            Msg::Join { threads: 4, fingerprint: fp.clone(), shard_lo: 0, shard_hi: u64::MAX };
        let welcome =
            Msg::Welcome { threads: 4, fingerprint: fp, shard_lo: 0, shard_hi: u64::MAX };
        assert_eq!(join.encode(), welcome.encode());
        assert_eq!((join.kind(), welcome.kind()), (11, 2));
    }

    #[test]
    fn relay_messages_roundtrip() {
        let assign = Msg::RelayAssign {
            leaves: vec!["sim://3".into(), "10.0.0.7:4710".into()],
            connect_timeout_ms: 5_000,
            exchange_timeout_ms: 600_000,
        };
        match roundtrip(&assign) {
            Msg::RelayAssign { leaves, connect_timeout_ms, exchange_timeout_ms } => {
                assert_eq!(leaves, vec!["sim://3".to_string(), "10.0.0.7:4710".to_string()]);
                assert_eq!(connect_timeout_ms, 5_000);
                assert_eq!(exchange_timeout_ms, 600_000);
            }
            other => panic!("wrong kind back: {}", other.name()),
        }
        // an empty assignment (demotion) roundtrips too
        let demote =
            Msg::RelayAssign { leaves: vec![], connect_timeout_ms: 1, exchange_timeout_ms: 2 };
        assert!(matches!(roundtrip(&demote), Msg::RelayAssign { leaves, .. } if leaves.is_empty()));

        match roundtrip(&Msg::RelayReady { threads: 6, reached: vec![true, false, true] }) {
            Msg::RelayReady { threads, reached } => {
                assert_eq!(threads, 6);
                assert_eq!(reached, vec![true, false, true]);
            }
            other => panic!("wrong kind back: {}", other.name()),
        }
    }

    #[test]
    fn relay_partial_envelope_is_bit_exact_and_rejects_non_partials() {
        let mut agg = RoundAgg::new(2);
        agg.consumption[0].add(1e16);
        agg.consumption[0].add(1.0); // non-zero compensation term
        agg.consumption[1].add(-3.5);
        agg.primal.add(42.0);
        agg.n_selected = 5;
        let env = Msg::RelayPartial {
            lost: vec![1, 3],
            inner: Box::new(Msg::EvalPartial(agg.clone())),
        };
        match roundtrip(&env) {
            Msg::RelayPartial { lost, inner } => {
                assert_eq!(lost, vec![1, 3]);
                match *inner {
                    Msg::EvalPartial(back) => {
                        let bits = |k: &KahanSum| {
                            let (s, c) = k.parts();
                            (s.to_bits(), c.to_bits())
                        };
                        for (x, y) in back.consumption.iter().zip(&agg.consumption) {
                            assert_eq!(bits(x), bits(y));
                        }
                        assert_eq!(bits(&back.primal), bits(&agg.primal));
                        assert_eq!(back.n_selected, 5);
                    }
                    other => panic!("wrong inner kind back: {}", other.name()),
                }
            }
            other => panic!("wrong kind back: {}", other.name()),
        }

        // the envelope must refuse non-partial inner kinds — a nested
        // envelope or a smuggled control frame is a malformed payload
        let bad = Msg::RelayPartial {
            lost: vec![],
            inner: Box::new(Msg::Abort { message: "no".into() }),
        };
        let payload = bad.encode();
        assert!(Msg::decode(15, &payload).is_err());
    }

    #[test]
    fn fingerprint_display_carries_full_hash_width() {
        // two stores that differ only in the high 32 bits of their hashes
        // must be refused (inequality) *and* be tellable apart in the
        // error message — the display used to truncate to 32 bits, so the
        // refusal text showed two identical fingerprints
        let a = InstanceFingerprint {
            n_groups: 100,
            n_items: 4,
            n_global: 3,
            dense: false,
            locals_hash: 0x1111_2222_3333_4444,
            sample_hash: 0x5555_6666_7777_8888,
        };
        let b = InstanceFingerprint {
            locals_hash: 0xFFFF_0000_3333_4444, // same low 32 bits
            sample_hash: 0xAAAA_BBBB_7777_8888, // same low 32 bits
            ..a.clone()
        };
        assert_ne!(a, b, "high-bit-only differences must still refuse the handshake");
        assert_ne!(a.to_string(), b.to_string(), "display must distinguish them: {a}");
        assert!(a.to_string().contains("locals#1111222233334444"), "{a}");
        assert!(a.to_string().contains("data#5555666677778888"), "{a}");
    }
}
