//! The worker process loop (`pallas worker --listen <addr> --store <dir>`).
//!
//! A worker memory-maps its replica of the shard store once, then serves
//! leader sessions one at a time: handshake (protocol version at the frame
//! layer, instance fingerprint here), then a stream of task frames, each
//! naming a chunk of the global shard partition plus the round's full
//! broadcast state. Workers are **stateless between frames** — that is
//! what makes leader-side re-dispatch after a failure safe — and survive
//! leader disconnects by returning to `accept`.
//!
//! The loop is generic over the [`NetListener`] seam: production workers
//! accept real TCP connections ([`serve`]/[`serve_source`]); the
//! deterministic simulator runs the *same* session code over in-memory
//! streams ([`serve_net`]), which is how chaos tests exercise this file
//! without sockets or wall-clock timeouts.

use crate::cluster::clock::{Backoff, Clock};
use crate::cluster::frames;
use crate::cluster::leader::ConnectOptions;
use crate::cluster::protocol::{
    recv_msg, recv_msg_ext, send_msg, span_ext, InstanceFingerprint, Msg,
};
use crate::cluster::transport::{NetListener, NetStream, TcpNetListener, Transport};
use crate::obs::{names, Track};
use crate::error::{Error, Result};
use crate::instance::problem::GroupSource;
use crate::instance::store::MmapProblem;
use crate::mapreduce::Cluster;
use crate::solver::postprocess::rank_chunk;
use crate::solver::rounds::{evaluation_chunk, RustEvaluator};
use crate::solver::scd::{scd_round_chunk, ScdRoundCtx, ScdRoundSpec};
use std::net::TcpListener;
use std::path::Path;

/// Open the store under `dir` and serve leader sessions on `listener`
/// forever (returns only if the listener itself fails, or on a store-open
/// error). `pool` is the worker's map thread pool; its size is what the
/// handshake advertises as capacity.
pub fn serve(listener: TcpListener, dir: &Path, pool: &Cluster) -> Result<()> {
    let problem = MmapProblem::open(dir)?;
    serve_source(listener, &problem, pool)
}

/// [`serve`] over an already-open source — what tests use to run loopback
/// workers in-thread against a store they just wrote.
pub fn serve_source<S: GroupSource + ?Sized>(
    listener: TcpListener,
    source: &S,
    pool: &Cluster,
) -> Result<()> {
    serve_net(&TcpNetListener::new(listener), source, pool)
}

/// The transport-generic accept loop: serve leader sessions until the
/// listener is retired (`accept_stream() == Ok(None)`, which TCP never
/// reports but the simulator does on shutdown).
pub fn serve_net<S: GroupSource + ?Sized>(
    listener: &dyn NetListener,
    source: &S,
    pool: &Cluster,
) -> Result<()> {
    source.validate()?;
    let fingerprint = InstanceFingerprint::of(source);
    let clock = listener.clock();
    // persistent accept failures (fd exhaustion, ...) must not become a
    // 100%-CPU spin; back off exponentially, reset on the next success
    let mut backoff =
        Backoff::new(std::time::Duration::from_millis(100), std::time::Duration::from_secs(5), 0);
    loop {
        match listener.accept_stream() {
            // a failed session (leader vanished, corrupt frame) ends the
            // connection, never the worker
            Ok(Some(stream)) => {
                backoff.reset();
                let _ = session(stream, source, &fingerprint, pool, clock.as_ref(), false);
            }
            Ok(None) => return Ok(()),
            Err(_) => backoff.wait(clock.as_ref()),
        }
    }
}

/// Dial a running leader's join listener and serve its session
/// (`bskp worker --join <addr>`): send `Join` with our capacity and
/// fingerprint, wait for `Admit`, then run the regular task loop with the
/// handshake already complete. Dial failures retry up to `dial_attempts`
/// times on the shared backoff helper — the leader may still be binding
/// its listener when the worker starts.
pub fn join_net<S: GroupSource + ?Sized>(
    transport: &dyn Transport,
    leader: &str,
    source: &S,
    pool: &Cluster,
    dial_attempts: u32,
) -> Result<()> {
    source.validate()?;
    let fingerprint = InstanceFingerprint::of(source);
    let clock = transport.clock();
    let opts = ConnectOptions::from_env();
    let mut backoff = Backoff::new(
        std::time::Duration::from_millis(100),
        std::time::Duration::from_secs(5),
        0,
    );
    let mut last = String::new();
    for attempt in 0..dial_attempts.max(1) {
        if attempt > 0 {
            backoff.wait(clock.as_ref());
        }
        let mut stream = match transport.dial(leader, opts.connect_timeout) {
            Ok(s) => s,
            Err(e) => {
                last = e.to_string();
                continue;
            }
        };
        stream.set_write_timeout(Some(opts.connect_timeout))?;
        send_msg(
            &mut stream,
            &Msg::Join { threads: pool.workers() as u32, fingerprint: fingerprint.clone() },
        )?;
        return serve_admitted(stream, source, &fingerprint, pool, clock.as_ref(), opts);
    }
    Err(Error::Runtime(format!("cannot join leader at {leader}: {last}")))
}

/// The worker half of an admission whose `Join` frame is already on the
/// wire: wait for `Admit` (or a typed refusal), then serve the session
/// with the handshake done. Split from [`join_net`] so the simulator can
/// dial and send `Join` synchronously at a planned round boundary and
/// run only this half on the joiner's thread.
pub(crate) fn serve_admitted<S: GroupSource + ?Sized>(
    mut stream: Box<dyn NetStream>,
    source: &S,
    fingerprint: &InstanceFingerprint,
    pool: &Cluster,
    clock: &dyn Clock,
    opts: ConnectOptions,
) -> Result<()> {
    stream.set_read_timeout(Some(opts.connect_timeout))?;
    let (reply, _) = recv_msg(&mut stream)?;
    match reply {
        Msg::Admit => {}
        Msg::Abort { message } => {
            return Err(Error::Runtime(format!("leader refused the join: {message}")))
        }
        other => {
            return Err(Error::Runtime(format!(
                "leader answered join with {}",
                other.name()
            )))
        }
    }
    // the session installs its own idle read timeout; writes go unbounded
    // like an accepted session's
    stream.set_write_timeout(None)?;
    session(stream, source, fingerprint, pool, clock, true)
}

/// Idle bound on one leader session: a leader that vanished without
/// FIN/RST (host power loss, network partition) must not wedge the
/// worker's single accept loop forever. Within a live solve the leader
/// sends the next task as soon as a reply lands, so real gaps are round-
/// scale, far below this. Override with `PALLAS_WORKER_IDLE_TIMEOUT_MS`.
const DEFAULT_IDLE_TIMEOUT_MS: u64 = 600_000;

/// One leader session: loop over frames until shutdown, error, or idle
/// timeout (after which the worker returns to `accept`). Tasks are only
/// served after a successful `Hello` handshake — the fingerprint check
/// happens *before any work*, as the protocol spec requires. Sessions
/// reached through the `Join`/`Admit` admission start with `greeted`
/// already true (that handshake verified the fingerprint).
fn session<S: GroupSource + ?Sized>(
    mut stream: Box<dyn NetStream>,
    source: &S,
    fingerprint: &InstanceFingerprint,
    pool: &Cluster,
    clock: &dyn Clock,
    greeted: bool,
) -> Result<()> {
    let idle = crate::cluster::env_ms("PALLAS_WORKER_IDLE_TIMEOUT_MS", DEFAULT_IDLE_TIMEOUT_MS);
    stream.set_read_timeout(Some(idle))?;
    let obs = crate::obs::metrics::global();
    let (tasks_total, task_ns) =
        (obs.counter("bskp_worker_tasks_total"), obs.histogram("bskp_worker_task_ns"));
    let mut greeted = greeted;
    loop {
        let (msg, ext, _) = recv_msg_ext(&mut stream)?;
        // span-context frame extension: the round index this task belongs
        // to, and whether the leader wants our task span shipped back
        let (round, ship_span) = ext.as_ref().map(span_ext::decode_task).unwrap_or((0, false));
        if !greeted && !matches!(msg, Msg::Hello { .. } | Msg::Shutdown) {
            let abort = Msg::Abort {
                message: format!("{} frame before the hello handshake", msg.name()),
            };
            send_msg(&mut stream, &abort)?;
            return Ok(());
        }
        let is_task =
            matches!(msg, Msg::EvalTask { .. } | Msg::ScdTask { .. } | Msg::RankTask { .. });
        let task_lo = match &msg {
            Msg::EvalTask { lo, .. } | Msg::ScdTask { lo, .. } | Msg::RankTask { lo, .. } => *lo,
            _ => 0,
        };
        let time_task = is_task
            && (ship_span || crate::obs::trace_enabled() || crate::obs::metrics_enabled());
        let t0 = if time_task { clock.now_ns() } else { 0 };
        let reply = match msg {
            Msg::Hello { fingerprint: leaders } => {
                if &leaders != fingerprint {
                    let abort = Msg::Abort {
                        message: format!(
                            "instance fingerprint mismatch: leader has [{leaders}], this \
                             worker's store has [{fingerprint}]"
                        ),
                    };
                    send_msg(&mut stream, &abort)?;
                    return Ok(());
                }
                greeted = true;
                Msg::Welcome { threads: pool.workers() as u32, fingerprint: fingerprint.clone() }
            }
            Msg::EvalTask { geo, lo, hi, lambda } => {
                match check_task(source, geo, lo, hi, &lambda) {
                    Err(e) => abort(e),
                    Ok((shards, lo, hi)) => {
                        let kk = source.dims().n_global;
                        Msg::EvalPartial(evaluation_chunk(
                            &RustEvaluator::new(source),
                            shards,
                            lo,
                            hi,
                            kk,
                            &lambda,
                            pool,
                        ))
                    }
                }
            }
            Msg::ScdTask { geo, lo, hi, lambda, active, sparse_q, reduce } => {
                match check_task(source, geo, lo, hi, &lambda) {
                    Err(e) => abort(e),
                    Ok(_) if active.len() != lambda.len() => {
                        abort(Error::Runtime("active mask length != λ length".into()))
                    }
                    Ok((shards, lo, hi)) => {
                        let spec = ScdRoundSpec {
                            lambda: &lambda,
                            active_mask: &active,
                            sparse_q,
                            reduce,
                        };
                        Msg::ScdPartial(scd_round_chunk(
                            source,
                            shards,
                            lo,
                            hi,
                            &spec,
                            pool,
                            ScdRoundCtx::none(),
                        ))
                    }
                }
            }
            Msg::RankTask { geo, lo, hi, lambda } => {
                match check_task(source, geo, lo, hi, &lambda) {
                    Err(e) => abort(e),
                    Ok((shards, lo, hi)) => {
                        Msg::RankPartial(rank_chunk(source, shards, lo, hi, &lambda, pool))
                    }
                }
            }
            Msg::Shutdown => return Ok(()),
            other => abort(Error::Runtime(format!(
                "unexpected {} frame from the leader",
                other.name()
            ))),
        };
        let task_dur = if time_task { clock.now_ns().saturating_sub(t0) } else { 0 };
        if time_task {
            if crate::obs::metrics_enabled() {
                tasks_total.inc();
                task_ns.observe(task_dur);
            }
            crate::obs::complete(Track::Worker(0), names::TASK, t0, task_dur, round, task_lo);
        }
        // an oversized partial (exact-mode threshold lists at extreme N)
        // must become a diagnosable Abort, not a torn connection the
        // leader would misread as a dead worker and cascade through the
        // fleet
        let mut reply = reply;
        let mut payload = reply.encode();
        if payload.len() as u64 > frames::MAX_PAYLOAD {
            reply = abort(Error::Runtime(format!(
                "chunk partial of {} bytes exceeds the {} B frame cap — use \
                 ReduceMode::Bucketed (§5.2) for distributed solves at this scale",
                payload.len(),
                frames::MAX_PAYLOAD
            )));
            payload = reply.encode();
        }
        let is_abort = matches!(reply, Msg::Abort { .. });
        // ship our task span back in the reply's frame-header extension
        // when the leader asked for it (and the extension still fits)
        let ship = ship_span
            && is_task
            && !is_abort
            && payload.len() as u64 + frames::EXT_LEN as u64 <= frames::MAX_PAYLOAD;
        if ship {
            let ext = span_ext::encode_span(names::TASK, task_dur);
            frames::write_frame_ext(&mut stream, reply.kind(), &ext, &payload)?;
        } else {
            frames::write_frame(&mut stream, reply.kind(), &payload)?;
        }
        if is_abort {
            return Ok(());
        }
    }
}

fn abort(e: Error) -> Msg {
    Msg::Abort { message: e.to_string() }
}

/// Validate a task against the local store: the geometry must be sane and
/// describe this instance, the chunk must lie inside it, λ must be K-wide.
/// Every violation becomes an `Abort` reply (not a dropped connection), so
/// the leader reports the real defect instead of a chain of "dead"
/// workers. (A fingerprint-verified leader always passes; this guards the
/// session against protocol bugs without trusting the network.)
fn check_task<S: GroupSource + ?Sized>(
    source: &S,
    geo: crate::cluster::protocol::Geometry,
    lo: u64,
    hi: u64,
    lambda: &[f64],
) -> Result<(crate::instance::shard::Shards, usize, usize)> {
    let shards = geo.shards()?;
    let dims = source.dims();
    if shards.n_total() != dims.n_groups {
        return Err(Error::Runtime(format!(
            "task geometry covers {} groups, this store has {}",
            shards.n_total(),
            dims.n_groups
        )));
    }
    if lambda.len() != dims.n_global {
        return Err(Error::Runtime(format!(
            "task λ has {} entries, this store has K={}",
            lambda.len(),
            dims.n_global
        )));
    }
    let (lo, hi) = (lo as usize, hi as usize);
    if lo > hi || hi > shards.count() {
        return Err(Error::Runtime(format!(
            "task chunk [{lo}, {hi}) outside the {}-shard partition",
            shards.count()
        )));
    }
    Ok((shards, lo, hi))
}
