//! The worker process loop (`pallas worker --listen <addr> --store <dir>`).
//!
//! A worker memory-maps its replica of the shard store once, then serves
//! leader sessions one at a time: handshake (protocol version at the frame
//! layer, instance fingerprint here), then a stream of task frames, each
//! naming a chunk of the global shard partition plus the round's full
//! broadcast state. Workers are **stateless between frames** — that is
//! what makes leader-side re-dispatch after a failure safe — and survive
//! leader disconnects by returning to `accept`.
//!
//! The loop is generic over the [`NetListener`] seam: production workers
//! accept real TCP connections ([`serve`]/[`serve_source`]); the
//! deterministic simulator runs the *same* session code over in-memory
//! streams ([`serve_net`]), which is how chaos tests exercise this file
//! without sockets or wall-clock timeouts.
//!
//! **Relay mode.** A leader running the two-level reduce tier promotes a
//! worker to *relay* with a `RelayAssign` frame naming a subtree of leaf
//! worker addresses. The relay dials each leaf through the listener's
//! [`NetListener::dialer`] (refusing the assignment when the transport
//! cannot dial), and from then on fans every task frame out over the
//! subtree: the task's shard range is split on the *global* chunk grid
//! ([`crate::cluster::chunk_plan`]), sub-chunks are dealt round-robin over
//! `[self] + live leaves`, leaf partials are gathered concurrently, work
//! from a leaf that dies mid-task is recomputed locally (a `RelayPartial`
//! always covers the full assigned range), and the sub-partials are merged
//! **in ascending chunk order** — the same canonical order the leader's
//! flat gather uses, which is what keeps flat and two-level topologies
//! bit-identical. The merged aggregate goes back in a single
//! `RelayPartial` envelope carrying the indices of any leaves lost on the
//! way. Relay state is per-session: the subtree is released (leaf links
//! shut down so leaves return to `accept`) when the leader session ends.

use crate::cluster::clock::{Backoff, Clock};
use crate::cluster::frames;
use crate::cluster::leader::{ConnectOptions, ExchangeMode, RelayFanout};
use crate::cluster::membership::{NetCounters, WorkerLink};
use crate::cluster::protocol::{
    recv_msg, recv_msg_ext, send_msg, span_ext, InstanceFingerprint, Msg,
};
use crate::cluster::transport::{NetListener, NetStream, TcpNetListener, Transport};
use crate::obs::{names, Track};
use crate::error::{Error, Result};
use crate::instance::problem::GroupSource;
use crate::instance::store::MmapProblem;
use crate::mapreduce::Cluster;
use crate::solver::postprocess::rank_chunk;
use crate::solver::rounds::{evaluation_chunk, RustEvaluator};
use crate::solver::scd::{scd_round_chunk, ScdRoundCtx, ScdRoundSpec};
use std::net::TcpListener;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Open the store under `dir` and serve leader sessions on `listener`
/// forever (returns only if the listener itself fails, or on a store-open
/// error). `pool` is the worker's map thread pool; its size is what the
/// handshake advertises as capacity.
pub fn serve(listener: TcpListener, dir: &Path, pool: &Cluster) -> Result<()> {
    let problem = MmapProblem::open(dir)?;
    serve_source(listener, &problem, pool)
}

/// [`serve`] over an already-open source — what tests use to run loopback
/// workers in-thread against a store they just wrote.
pub fn serve_source<S: GroupSource + ?Sized>(
    listener: TcpListener,
    source: &S,
    pool: &Cluster,
) -> Result<()> {
    serve_net(&TcpNetListener::new(listener), source, pool)
}

/// The transport-generic accept loop: serve leader sessions until the
/// listener is retired (`accept_stream() == Ok(None)`, which TCP never
/// reports but the simulator does on shutdown).
pub fn serve_net<S: GroupSource + ?Sized>(
    listener: &dyn NetListener,
    source: &S,
    pool: &Cluster,
) -> Result<()> {
    source.validate()?;
    let fingerprint = InstanceFingerprint::of(source);
    let clock = listener.clock();
    let dialer = listener.dialer();
    // persistent accept failures (fd exhaustion, ...) must not become a
    // 100%-CPU spin; back off exponentially, reset on the next success
    let mut backoff =
        Backoff::new(std::time::Duration::from_millis(100), std::time::Duration::from_secs(5), 0);
    loop {
        match listener.accept_stream() {
            // a failed session (leader vanished, corrupt frame) ends the
            // connection, never the worker
            Ok(Some(stream)) => {
                backoff.reset();
                let _ = session(
                    stream,
                    source,
                    &fingerprint,
                    pool,
                    clock.as_ref(),
                    false,
                    dialer.clone(),
                );
            }
            Ok(None) => return Ok(()),
            Err(_) => backoff.wait(clock.as_ref()),
        }
    }
}

/// Dial a running leader's join listener and serve its session
/// (`bskp worker --join <addr>`): send `Join` with our capacity and
/// fingerprint, wait for `Admit`, then run the regular task loop with the
/// handshake already complete. Dial failures retry up to `dial_attempts`
/// times on the shared backoff helper — the leader may still be binding
/// its listener when the worker starts. The transport doubles as the
/// dialer for relay assignments: a joined worker can be promoted exactly
/// like a configured one.
pub fn join_net<S: GroupSource + ?Sized>(
    transport: Arc<dyn Transport>,
    leader: &str,
    source: &S,
    pool: &Cluster,
    dial_attempts: u32,
) -> Result<()> {
    source.validate()?;
    let fingerprint = InstanceFingerprint::of(source);
    let clock = transport.clock();
    let opts = ConnectOptions::from_env();
    let mut backoff = Backoff::new(
        std::time::Duration::from_millis(100),
        std::time::Duration::from_secs(5),
        0,
    );
    let mut last = String::new();
    for attempt in 0..dial_attempts.max(1) {
        if attempt > 0 {
            backoff.wait(clock.as_ref());
        }
        let mut stream = match transport.dial(leader, opts.connect_timeout) {
            Ok(s) => s,
            Err(e) => {
                last = e.to_string();
                continue;
            }
        };
        stream.set_write_timeout(Some(opts.connect_timeout))?;
        send_msg(
            &mut stream,
            &Msg::Join {
                threads: pool.workers() as u32,
                fingerprint: fingerprint.clone(),
                shard_lo: 0,
                shard_hi: u64::MAX,
            },
        )?;
        return serve_admitted(
            stream,
            source,
            &fingerprint,
            pool,
            clock.as_ref(),
            opts,
            Some(Arc::clone(&transport)),
        );
    }
    Err(Error::Runtime(format!("cannot join leader at {leader}: {last}")))
}

/// The worker half of an admission whose `Join` frame is already on the
/// wire: wait for `Admit` (or a typed refusal), then serve the session
/// with the handshake done. Split from [`join_net`] so the simulator can
/// dial and send `Join` synchronously at a planned round boundary and
/// run only this half on the joiner's thread.
pub(crate) fn serve_admitted<S: GroupSource + ?Sized>(
    mut stream: Box<dyn NetStream>,
    source: &S,
    fingerprint: &InstanceFingerprint,
    pool: &Cluster,
    clock: &dyn Clock,
    opts: ConnectOptions,
    dialer: Option<Arc<dyn Transport>>,
) -> Result<()> {
    stream.set_read_timeout(Some(opts.connect_timeout))?;
    let (reply, _) = recv_msg(&mut stream)?;
    match reply {
        Msg::Admit => {}
        Msg::Abort { message } => {
            return Err(Error::Runtime(format!("leader refused the join: {message}")))
        }
        other => {
            return Err(Error::Runtime(format!(
                "leader answered join with {}",
                other.name()
            )))
        }
    }
    // the session installs its own idle read timeout; writes go unbounded
    // like an accepted session's
    stream.set_write_timeout(None)?;
    session(stream, source, fingerprint, pool, clock, true, dialer)
}

/// Idle bound on one leader session: a leader that vanished without
/// FIN/RST (host power loss, network partition) must not wedge the
/// worker's single accept loop forever. Within a live solve the leader
/// sends the next task as soon as a reply lands, so real gaps are round-
/// scale, far below this. Override with `PALLAS_WORKER_IDLE_TIMEOUT_MS`.
const DEFAULT_IDLE_TIMEOUT_MS: u64 = 600_000;

/// Per-session relay state: the assigned subtree of leaf links, in
/// assignment order (`RelayPartial::lost` indexes into it), plus the
/// relay's own wire counters for leaf traffic.
struct RelayState {
    leaves: Vec<(String, Option<WorkerLink>)>,
    counters: NetCounters,
}

impl RelayState {
    fn new() -> Self {
        Self { leaves: Vec::new(), counters: NetCounters::default() }
    }

    fn live_count(&self) -> usize {
        self.leaves.iter().filter(|(_, l)| l.as_ref().is_some_and(|w| w.is_live())).count()
    }

    /// Apply a `RelayAssign`: keep live links whose address survives into
    /// the new set, dial the rest, shut down links no longer assigned.
    /// Idempotent; an empty `addrs` demotes the relay back to a plain
    /// worker. Returns the subtree's reachable leaf capacity and the
    /// per-address reached flags, in assignment order.
    fn assign(
        &mut self,
        dialer: &dyn Transport,
        addrs: &[String],
        fingerprint: &InstanceFingerprint,
        opts: ConnectOptions,
    ) -> (usize, Vec<bool>) {
        let mut old = std::mem::take(&mut self.leaves);
        let mut reached = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let kept = old
                .iter()
                .position(|(a, l)| a == addr && l.as_ref().is_some_and(|w| w.is_live()));
            let link = match kept {
                Some(i) => old.swap_remove(i).1,
                None => WorkerLink::connect(dialer, addr, fingerprint, opts).ok(),
            };
            reached.push(link.as_ref().is_some_and(|w| w.is_live()));
            self.leaves.push((addr.clone(), link));
        }
        for (_, link) in old.iter_mut() {
            if let Some(w) = link {
                w.shutdown();
            }
        }
        let threads = self
            .leaves
            .iter()
            .filter_map(|(_, l)| l.as_ref())
            .filter(|w| w.is_live())
            .map(|w| w.threads)
            .sum();
        (threads, reached)
    }

    /// Release the subtree so every leaf returns to `accept` (for the next
    /// leader session, or for re-parenting under another relay).
    fn shutdown_all(&mut self) {
        for (_, link) in self.leaves.iter_mut() {
            if let Some(w) = link {
                w.shutdown();
            }
        }
        self.leaves.clear();
    }
}

/// One leader session: loop over frames until shutdown, error, or idle
/// timeout (after which the worker returns to `accept`). Tasks are only
/// served after a successful `Hello` handshake — the fingerprint check
/// happens *before any work*, as the protocol spec requires. Sessions
/// reached through the `Join`/`Admit` admission start with `greeted`
/// already true (that handshake verified the fingerprint). However the
/// session ends, any relay subtree it held is released.
fn session<S: GroupSource + ?Sized>(
    stream: Box<dyn NetStream>,
    source: &S,
    fingerprint: &InstanceFingerprint,
    pool: &Cluster,
    clock: &dyn Clock,
    greeted: bool,
    dialer: Option<Arc<dyn Transport>>,
) -> Result<()> {
    let mut relay = RelayState::new();
    let out =
        session_loop(stream, source, fingerprint, pool, clock, greeted, dialer, &mut relay);
    relay.shutdown_all();
    out
}

#[allow(clippy::too_many_arguments)]
fn session_loop<S: GroupSource + ?Sized>(
    mut stream: Box<dyn NetStream>,
    source: &S,
    fingerprint: &InstanceFingerprint,
    pool: &Cluster,
    clock: &dyn Clock,
    greeted: bool,
    dialer: Option<Arc<dyn Transport>>,
    relay: &mut RelayState,
) -> Result<()> {
    let idle = crate::cluster::env_ms("PALLAS_WORKER_IDLE_TIMEOUT_MS", DEFAULT_IDLE_TIMEOUT_MS);
    stream.set_read_timeout(Some(idle))?;
    let obs = crate::obs::metrics::global();
    let (tasks_total, task_ns) =
        (obs.counter("bskp_worker_tasks_total"), obs.histogram("bskp_worker_task_ns"));
    let mut greeted = greeted;
    loop {
        let (msg, ext, _) = recv_msg_ext(&mut stream)?;
        // span-context frame extension: the round index this task belongs
        // to, and whether the leader wants our task span shipped back
        let (round, ship_span) = ext.as_ref().map(span_ext::decode_task).unwrap_or((0, false));
        if !greeted && !matches!(msg, Msg::Hello { .. } | Msg::Shutdown) {
            let abort = Msg::Abort {
                message: format!("{} frame before the hello handshake", msg.name()),
            };
            send_msg(&mut stream, &abort)?;
            return Ok(());
        }
        let is_task =
            matches!(msg, Msg::EvalTask { .. } | Msg::ScdTask { .. } | Msg::RankTask { .. });
        let task_lo = match &msg {
            Msg::EvalTask { lo, .. } | Msg::ScdTask { lo, .. } | Msg::RankTask { lo, .. } => *lo,
            _ => 0,
        };
        let fan_out = is_task && relay.live_count() > 0;
        let span_code = if fan_out { names::RELAY_FANIN } else { names::TASK };
        let time_task = is_task
            && (ship_span || crate::obs::trace_enabled() || crate::obs::metrics_enabled());
        let t0 = if time_task { clock.now_ns() } else { 0 };
        let reply = match msg {
            Msg::Hello { fingerprint: leaders } => {
                if &leaders != fingerprint {
                    let abort = Msg::Abort {
                        message: format!(
                            "instance fingerprint mismatch: leader has [{leaders}], this \
                             worker's store has [{fingerprint}]"
                        ),
                    };
                    send_msg(&mut stream, &abort)?;
                    return Ok(());
                }
                greeted = true;
                Msg::Welcome {
                    threads: pool.workers() as u32,
                    fingerprint: fingerprint.clone(),
                    shard_lo: 0,
                    shard_hi: u64::MAX,
                }
            }
            task @ (Msg::EvalTask { .. } | Msg::ScdTask { .. } | Msg::RankTask { .. }) => {
                if fan_out {
                    relay_exec(source, pool, relay, &task, round)
                } else {
                    exec_task(source, pool, &task)
                }
            }
            Msg::RelayAssign { leaves, connect_timeout_ms, exchange_timeout_ms } => {
                let Some(dialer) = dialer.as_deref() else {
                    let abort = Msg::Abort {
                        message: "this worker's transport cannot dial leaf workers — \
                                  relay assignment refused"
                            .into(),
                    };
                    send_msg(&mut stream, &abort)?;
                    return Ok(());
                };
                // leaf exchanges must carry a *finite* deadline: the relay
                // blocks on leaf replies while the leader blocks on the
                // relay, and only timeouts unwind that chain on a stall
                let leaf_opts = ConnectOptions {
                    connect_timeout: Duration::from_millis(connect_timeout_ms.max(1)),
                    exchange_timeout: Duration::from_millis(exchange_timeout_ms.max(1)),
                    exchange: ExchangeMode::Wave,
                    redial_budget: 0,
                    redial_backoff: Duration::from_millis(100),
                    min_workers: 1,
                    relay_fanout: RelayFanout::Flat,
                };
                let (leaf_threads, reached) =
                    relay.assign(dialer, &leaves, fingerprint, leaf_opts);
                crate::obs::instant(
                    clock,
                    Track::Worker(0),
                    names::RELAY_ASSIGN,
                    round,
                    leaves.len() as u64,
                );
                Msg::RelayReady {
                    threads: (pool.workers() + leaf_threads) as u32,
                    reached,
                }
            }
            Msg::Shutdown => return Ok(()),
            other => abort(Error::Runtime(format!(
                "unexpected {} frame from the leader",
                other.name()
            ))),
        };
        let task_dur = if time_task { clock.now_ns().saturating_sub(t0) } else { 0 };
        if time_task {
            if crate::obs::metrics_enabled() {
                tasks_total.inc();
                task_ns.observe(task_dur);
            }
            crate::obs::complete(Track::Worker(0), span_code, t0, task_dur, round, task_lo);
        }
        // an oversized partial (exact-mode threshold lists at extreme N)
        // must become a diagnosable Abort, not a torn connection the
        // leader would misread as a dead worker and cascade through the
        // fleet
        let mut reply = reply;
        let mut payload = reply.encode();
        if payload.len() as u64 > frames::MAX_PAYLOAD {
            reply = abort(Error::Runtime(format!(
                "chunk partial of {} bytes exceeds the {} B frame cap — use \
                 ReduceMode::Bucketed (§5.2) for distributed solves at this scale",
                payload.len(),
                frames::MAX_PAYLOAD
            )));
            payload = reply.encode();
        }
        let is_abort = matches!(reply, Msg::Abort { .. });
        // ship our task span back in the reply's frame-header extension
        // when the leader asked for it (and the extension still fits)
        let ship = ship_span
            && is_task
            && !is_abort
            && payload.len() as u64 + frames::EXT_LEN as u64 <= frames::MAX_PAYLOAD;
        if ship {
            let ext = span_ext::encode_span(span_code, task_dur);
            frames::write_frame_ext(&mut stream, reply.kind(), &ext, &payload)?;
        } else {
            frames::write_frame(&mut stream, reply.kind(), &payload)?;
        }
        if is_abort {
            return Ok(());
        }
    }
}

/// Execute one task frame locally: validate against the store, fold the
/// chunk on the worker's own pool. Shared by the plain session path and
/// the relay's self/recompute queues.
fn exec_task<S: GroupSource + ?Sized>(source: &S, pool: &Cluster, task: &Msg) -> Msg {
    match task {
        Msg::EvalTask { geo, lo, hi, lambda } => {
            match check_task(source, *geo, *lo, *hi, lambda) {
                Err(e) => abort(e),
                Ok((shards, lo, hi)) => {
                    let kk = source.dims().n_global;
                    Msg::EvalPartial(evaluation_chunk(
                        &RustEvaluator::new(source),
                        shards,
                        lo,
                        hi,
                        kk,
                        lambda,
                        pool,
                    ))
                }
            }
        }
        Msg::ScdTask { geo, lo, hi, lambda, active, sparse_q, reduce } => {
            match check_task(source, *geo, *lo, *hi, lambda) {
                Err(e) => abort(e),
                Ok(_) if active.len() != lambda.len() => {
                    abort(Error::Runtime("active mask length != λ length".into()))
                }
                Ok((shards, lo, hi)) => {
                    let spec = ScdRoundSpec {
                        lambda: lambda.as_slice(),
                        active_mask: active.as_slice(),
                        sparse_q: *sparse_q,
                        reduce: *reduce,
                    };
                    Msg::ScdPartial(scd_round_chunk(
                        source,
                        shards,
                        lo,
                        hi,
                        &spec,
                        pool,
                        ScdRoundCtx::none(),
                    ))
                }
            }
        }
        Msg::RankTask { geo, lo, hi, lambda } => {
            match check_task(source, *geo, *lo, *hi, lambda) {
                Err(e) => abort(e),
                Ok((shards, lo, hi)) => {
                    Msg::RankPartial(rank_chunk(source, shards, lo, hi, lambda, pool))
                }
            }
        }
        other => abort(Error::Runtime(format!(
            "unexpected {} frame from the leader",
            other.name()
        ))),
    }
}

/// The same task frame with a narrowed shard range — how a relay deals
/// sub-chunks of its assigned range to leaves (and to itself).
fn sub_task(task: &Msg, lo: usize, hi: usize) -> Msg {
    let (lo, hi) = (lo as u64, hi as u64);
    match task {
        Msg::EvalTask { geo, lambda, .. } => {
            Msg::EvalTask { geo: *geo, lo, hi, lambda: lambda.clone() }
        }
        Msg::RankTask { geo, lambda, .. } => {
            Msg::RankTask { geo: *geo, lo, hi, lambda: lambda.clone() }
        }
        Msg::ScdTask { geo, lambda, active, sparse_q, reduce, .. } => Msg::ScdTask {
            geo: *geo,
            lo,
            hi,
            lambda: lambda.clone(),
            active: active.clone(),
            sparse_q: *sparse_q,
            reduce: *reduce,
        },
        other => unreachable!("sub_task of a {} frame", other.name()),
    }
}

/// Merge two same-kind chunk partials, the earlier chunk on the left —
/// exactly the leader's per-chunk merge discipline, so a relay-side merge
/// followed by the leader's merge is bit-identical to the leader merging
/// every chunk itself.
fn merge_partials(a: Msg, b: Msg) -> Result<Msg> {
    Ok(match (a, b) {
        (Msg::EvalPartial(x), Msg::EvalPartial(y)) => Msg::EvalPartial(x.merge(y)),
        (Msg::ScdPartial(x), Msg::ScdPartial(y)) => Msg::ScdPartial(x.merge(y)),
        (Msg::RankPartial(mut x), Msg::RankPartial(y)) => {
            x.extend(y);
            Msg::RankPartial(x)
        }
        (a, b) => {
            return Err(Error::Runtime(format!(
                "relay cannot merge a {} with a {}",
                a.name(),
                b.name()
            )))
        }
    })
}

/// Fan one task out over the relay's subtree and merge the sub-partials
/// into a single [`Msg::RelayPartial`].
///
/// The task's range is split on the global chunk grid so every sub-chunk
/// is exactly a chunk the leader's flat deal would have produced;
/// sub-chunks go round-robin over `[self] + live leaves`; each leaf's
/// queue is driven by its own thread (strict send/recv per sub-chunk,
/// matching the worker session contract) while this thread folds its own
/// queue; any leaf failure retires the leaf and moves its unfinished
/// sub-chunks to a local recompute pass. The reply therefore always
/// covers the full assigned range — the leader needs no sub-chunk
/// re-dispatch for leaf-level failures, only for relay-level ones.
fn relay_exec<S: GroupSource + ?Sized>(
    source: &S,
    pool: &Cluster,
    relay: &mut RelayState,
    task: &Msg,
    round: u64,
) -> Msg {
    let (geo, lo, hi, lambda) = match task {
        Msg::EvalTask { geo, lo, hi, lambda }
        | Msg::RankTask { geo, lo, hi, lambda }
        | Msg::ScdTask { geo, lo, hi, lambda, .. } => (*geo, *lo, *hi, lambda),
        other => return abort(Error::Runtime(format!("relay cannot fan out {}", other.name()))),
    };
    let (shards, lo, hi) = match check_task(source, geo, lo, hi, lambda) {
        Err(e) => return abort(e),
        Ok(ok) => ok,
    };
    let (per, _) = crate::cluster::chunk_plan(shards.count(), crate::cluster::CHUNKS_PER_ROUND);
    // sub-ranges of [lo, hi) on the global chunk grid, ascending
    let mut subs: Vec<(usize, usize)> = Vec::new();
    let mut c = lo / per;
    loop {
        let start = (c * per).max(lo);
        if start >= hi {
            break;
        }
        subs.push((start, ((c + 1) * per).min(hi)));
        c += 1;
    }
    if subs.is_empty() {
        subs.push((lo, hi)); // an empty range still owes one (empty) partial
    }
    let n_sub = subs.len();
    let parts = 1 + relay.live_count();
    let results: Mutex<Vec<Option<Msg>>> = Mutex::new((0..n_sub).map(|_| None).collect());
    let retry: Mutex<Vec<usize>> = Mutex::new(Vec::new());
    let lost: Mutex<Vec<u32>> = Mutex::new(Vec::new());
    let RelayState { leaves, counters } = relay;
    let (results, retry, lost, counters, subs) = (&results, &retry, &lost, &*counters, &subs);
    std::thread::scope(|scope| {
        let mut p = 0usize;
        for (i, (_, slot)) in leaves.iter_mut().enumerate() {
            let Some(link) = slot.as_mut().filter(|w| w.is_live()) else { continue };
            p += 1;
            let my_p = p; // participant 0 is the relay itself
            let queue: Vec<usize> = (my_p..n_sub).step_by(parts).collect();
            if queue.is_empty() {
                continue;
            }
            scope.spawn(move || {
                for (qi, &j) in queue.iter().enumerate() {
                    let (s, e) = subs[j];
                    let msg = sub_task(task, s, e);
                    let outcome = link
                        .send_task(&msg, &span_ext::encode_task(round, false), counters)
                        .and_then(|()| link.recv_partial(counters));
                    match outcome {
                        Ok((
                            reply @ (Msg::EvalPartial(_)
                            | Msg::ScdPartial(_)
                            | Msg::RankPartial(_)),
                            _,
                            _,
                        )) => {
                            results.lock().unwrap()[j] = Some(reply);
                        }
                        Ok(_) | Err(_) => {
                            // leaf died or refused: retire it, recompute
                            // its unfinished queue locally after the joins
                            link.kill();
                            lost.lock().unwrap().push(i as u32);
                            retry.lock().unwrap().extend(queue[qi..].iter().copied());
                            return;
                        }
                    }
                }
            });
        }
        // the relay's own queue folds on the session thread, overlapped
        // with the leaf exchanges
        for j in (0..n_sub).step_by(parts) {
            let (s, e) = subs[j];
            let reply = exec_task(source, pool, &sub_task(task, s, e));
            results.lock().unwrap()[j] = Some(reply);
        }
    });
    // leaf threads are joined: drain whatever failed leaves abandoned
    let retry = std::mem::take(&mut *retry.lock().unwrap());
    for j in retry {
        let (s, e) = subs[j];
        let reply = exec_task(source, pool, &sub_task(task, s, e));
        results.lock().unwrap()[j] = Some(reply);
    }
    let mut collected = Vec::with_capacity(n_sub);
    for r in std::mem::take(&mut *results.lock().unwrap()) {
        match r {
            Some(Msg::Abort { message }) => return Msg::Abort { message },
            Some(m) => collected.push(m),
            None => {
                return abort(Error::Runtime(
                    "relay sub-chunk went uncomputed — dealing bug".into(),
                ))
            }
        }
    }
    let mut it = collected.into_iter();
    let first = it.next().expect("n_sub >= 1");
    match it.try_fold(first, merge_partials) {
        Ok(inner) => {
            let mut lost = std::mem::take(&mut *lost.lock().unwrap());
            lost.sort_unstable();
            Msg::RelayPartial { lost, inner: Box::new(inner) }
        }
        Err(e) => abort(e),
    }
}

fn abort(e: Error) -> Msg {
    Msg::Abort { message: e.to_string() }
}

/// Validate a task against the local store: the geometry must be sane and
/// describe this instance, the chunk must lie inside it, λ must be K-wide.
/// Every violation becomes an `Abort` reply (not a dropped connection), so
/// the leader reports the real defect instead of a chain of "dead"
/// workers. (A fingerprint-verified leader always passes; this guards the
/// session against protocol bugs without trusting the network.)
fn check_task<S: GroupSource + ?Sized>(
    source: &S,
    geo: crate::cluster::protocol::Geometry,
    lo: u64,
    hi: u64,
    lambda: &[f64],
) -> Result<(crate::instance::shard::Shards, usize, usize)> {
    let shards = geo.shards()?;
    let dims = source.dims();
    if shards.n_total() != dims.n_groups {
        return Err(Error::Runtime(format!(
            "task geometry covers {} groups, this store has {}",
            shards.n_total(),
            dims.n_groups
        )));
    }
    if lambda.len() != dims.n_global {
        return Err(Error::Runtime(format!(
            "task λ has {} entries, this store has K={}",
            lambda.len(),
            dims.n_global
        )));
    }
    let (lo, hi) = (lo as usize, hi as usize);
    if lo > hi || hi > shards.count() {
        return Err(Error::Runtime(format!(
            "task chunk [{lo}, {hi}) outside the {}-shard partition",
            shards.count()
        )));
    }
    Ok((shards, lo, hi))
}
