//! Leader-side worker membership: one link per configured worker address,
//! with handshake, liveness and best-effort shutdown.
//!
//! Links are generic over the [`Transport`] seam: a link holds a boxed
//! [`NetStream`](crate::cluster::transport::NetStream) and never names
//! TCP — the same handshake and exchange discipline runs on production
//! sockets and on the deterministic simulator.

use crate::cluster::frames::EXT_LEN;
use crate::cluster::leader::ConnectOptions;
use crate::cluster::protocol::{
    recv_msg, recv_msg_ext, send_msg, send_msg_ext, InstanceFingerprint, Msg,
};
use crate::cluster::transport::{NetStream, Transport};
use crate::error::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared wire counters (updated by every link, read by
/// [`super::leader::RemoteCluster::stats`]). All loads/stores are relaxed:
/// the counters are telemetry, not synchronization.
#[derive(Debug, Default)]
pub(crate) struct NetCounters {
    pub(crate) bytes_sent: AtomicU64,
    pub(crate) bytes_received: AtomicU64,
    /// Data-plane frames (tasks out / partials in) — relay control frames
    /// are excluded, so `frames_received / rounds` is exactly the leader's
    /// per-round fan-in: O(workers) flat, O(relays) two-level.
    pub(crate) frames_sent: AtomicU64,
    pub(crate) frames_received: AtomicU64,
    pub(crate) rounds: AtomicU64,
    pub(crate) round_us: AtomicU64,
    pub(crate) redispatches: AtomicU64,
    pub(crate) workers_lost: AtomicU64,
    pub(crate) redials: AtomicU64,
    pub(crate) joins: AtomicU64,
}

impl NetCounters {
    pub(crate) fn count(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }
}

/// One leader→worker connection. Dead links keep their slot (and their
/// address, for reporting); under a redial budget
/// ([`ConnectOptions::redial_budget`]) a *transiently*-dead link — one
/// killed by an I/O error or timeout — can be re-dialed and
/// re-handshaken at a round boundary via [`WorkerLink::redial`].
/// Permanent deaths (the worker answered and refused: fingerprint
/// mismatch, session refusal, exhausted budget) never resurrect;
/// re-dispatch moves their work to survivors instead.
pub(crate) struct WorkerLink {
    pub(crate) addr: String,
    pub(crate) threads: usize,
    /// The shard-index span `[lo, hi)` the worker's store replica covers,
    /// from its `Welcome`/`Join` (today always `(0, u64::MAX)`; the relay
    /// placement prefers relays whose span covers their subtree).
    pub(crate) span: (u64, u64),
    /// The slot serves through a relay's subtree: its leader stream is
    /// intentionally closed, but the worker is alive and counted.
    pub(crate) delegated: bool,
    stream: Option<Box<dyn NetStream>>,
    /// Consecutive failed redial attempts since the link last died
    /// (resets on a successful redial — each outage gets a fresh
    /// backoff schedule).
    pub(crate) attempts: u32,
    /// Total redials attempted over the whole session; never resets, so
    /// a link that keeps flapping (crash → redial → crash …) exhausts
    /// [`ConnectOptions::redial_budget`] instead of looping forever.
    pub(crate) redials_spent: u32,
    /// Clock deadline before which no redial is attempted (exponential
    /// backoff + deterministic jitter; virtual time under the simulator).
    pub(crate) next_redial_at_ns: u64,
    /// The peer answered and refused — never redial this link.
    pub(crate) permanent: bool,
}

impl WorkerLink {
    /// Dial through `transport` and run the `Hello`/`Welcome` handshake:
    /// protocol version is enforced by the frame layer, the instance
    /// fingerprint here — a worker serving a different store is refused
    /// before any task. `opts.connect_timeout` bounds the dial + handshake
    /// (short, so planning reaches its fallback promptly);
    /// `opts.exchange_timeout` is the per-task bound installed for the
    /// rest of the session.
    pub(crate) fn connect(
        transport: &dyn Transport,
        addr: &str,
        fingerprint: &InstanceFingerprint,
        opts: ConnectOptions,
    ) -> Result<Self> {
        let stream = transport.dial(addr, opts.connect_timeout)?;
        let (threads, span, stream) = Self::handshake(stream, addr, fingerprint, opts)?;
        Ok(Self {
            addr: addr.to_string(),
            threads,
            span,
            delegated: false,
            stream: Some(stream),
            attempts: 0,
            redials_spent: 0,
            next_redial_at_ns: 0,
            permanent: false,
        })
    }

    /// A link over an already-handshaken stream — how a mid-solve
    /// `Join`/`Admit` admission becomes a slot (the join handshake
    /// replaced `Hello`/`Welcome`; exchange timeouts are already set).
    pub(crate) fn admitted(
        addr: String,
        threads: usize,
        span: (u64, u64),
        stream: Box<dyn NetStream>,
    ) -> Self {
        Self {
            addr,
            threads: threads.max(1),
            span,
            delegated: false,
            stream: Some(stream),
            attempts: 0,
            redials_spent: 0,
            next_redial_at_ns: 0,
            permanent: false,
        }
    }

    /// The `Hello`/`Welcome` exchange on a fresh stream, shared by
    /// [`WorkerLink::connect`] and [`WorkerLink::redial`]. On success the
    /// exchange timeouts are installed and the advertised capacity
    /// returned with the stream.
    fn handshake(
        mut stream: Box<dyn NetStream>,
        addr: &str,
        fingerprint: &InstanceFingerprint,
        opts: ConnectOptions,
    ) -> Result<(usize, (u64, u64), Box<dyn NetStream>)> {
        stream.set_read_timeout(Some(opts.connect_timeout))?;
        stream.set_write_timeout(Some(opts.connect_timeout))?;
        send_msg(&mut stream, &Msg::Hello { fingerprint: fingerprint.clone() })?;
        let (reply, _) = recv_msg(&mut stream)?;
        stream.set_read_timeout(Some(opts.exchange_timeout))?;
        stream.set_write_timeout(Some(opts.exchange_timeout))?;
        match reply {
            Msg::Welcome { threads, fingerprint: theirs, shard_lo, shard_hi } => {
                if &theirs != fingerprint {
                    return Err(Error::Runtime(format!(
                        "worker {addr} serves a different instance: leader has \
                         [{fingerprint}], worker has [{theirs}]"
                    )));
                }
                Ok((threads.max(1) as usize, (shard_lo, shard_hi), stream))
            }
            Msg::Abort { message } => {
                Err(Error::Runtime(format!("worker {addr} refused the session: {message}")))
            }
            other => Err(Error::Runtime(format!(
                "worker {addr} answered hello with {}",
                other.name()
            ))),
        }
    }

    /// Re-dial a transiently-dead link and re-run the fingerprint
    /// handshake; on success the link serves tasks again with a fresh
    /// backoff schedule. Failure classification: a *dial* failure (the
    /// peer is unreachable — still restarting, still partitioned) stays
    /// transient and merely consumes a redial attempt; a *handshake*
    /// failure means the peer answered and refused — that is permanent
    /// and the link is retired for the session.
    pub(crate) fn redial(
        &mut self,
        transport: &dyn Transport,
        fingerprint: &InstanceFingerprint,
        opts: ConnectOptions,
    ) -> Result<()> {
        debug_assert!(self.stream.is_none(), "redial of a live link");
        let stream = transport.dial(&self.addr, opts.connect_timeout)?;
        match Self::handshake(stream, &self.addr, fingerprint, opts) {
            Ok((threads, span, stream)) => {
                self.threads = threads;
                self.span = span;
                self.stream = Some(stream);
                self.delegated = false;
                self.attempts = 0;
                self.next_redial_at_ns = 0;
                Ok(())
            }
            Err(e) => {
                self.permanent = true;
                Err(e)
            }
        }
    }

    pub(crate) fn is_live(&self) -> bool {
        self.stream.is_some()
    }

    /// Alive for quorum and capacity purposes: the leader holds its
    /// stream, *or* the worker serves through a relay subtree (the stream
    /// was intentionally handed off, not lost).
    pub(crate) fn is_alive(&self) -> bool {
        self.stream.is_some() || self.delegated
    }

    /// Re-bound the per-task read/write deadline on a live stream (the
    /// leader doubles a relay's deadline: a relay exchange includes leaf
    /// recovery and local recompute in the worst case).
    pub(crate) fn set_exchange_deadline(&mut self, t: std::time::Duration) {
        if let Some(stream) = self.stream.as_mut() {
            let _ = stream.set_read_timeout(Some(t));
            let _ = stream.set_write_timeout(Some(t));
        }
    }

    /// Drop the connection; the link stays dead until (and unless) a
    /// round-boundary redial revives it.
    pub(crate) fn kill(&mut self) {
        self.stream = None;
        self.delegated = false;
    }

    /// Send one task frame without waiting for the reply, split from the
    /// receive half so the overlapped gather can keep a bounded pipeline
    /// of tasks in flight per link. Every `send_task` must be balanced by
    /// exactly one [`WorkerLink::recv_partial`] (the protocol stays strict
    /// request/response on the wire; only the leader's waiting overlaps).
    /// The span-context frame extension (round index + trace-wanted flag)
    /// rides the frame header, never the message body.
    pub(crate) fn send_task(
        &mut self,
        msg: &Msg,
        ext: &[u8; EXT_LEN],
        counters: &NetCounters,
    ) -> Result<()> {
        let stream = self
            .stream
            .as_mut()
            .ok_or_else(|| Error::Runtime(format!("worker {} is dead", self.addr)))?;
        let sent = send_msg_ext(stream, msg, ext)?;
        counters.count(&counters.bytes_sent, sent as u64);
        counters.count(&counters.frames_sent, 1);
        Ok(())
    }

    /// Receive one reply frame — the read half of a task exchange.
    /// Replies arrive in task order (the worker serves one frame at a
    /// time), so the caller matches them to its in-flight queue FIFO.
    /// Returns the reply, its span-context extension when the matching
    /// task asked for tracing, and the frame's size on the wire.
    pub(crate) fn recv_partial(
        &mut self,
        counters: &NetCounters,
    ) -> Result<(Msg, Option<[u8; EXT_LEN]>, usize)> {
        let stream = self
            .stream
            .as_mut()
            .ok_or_else(|| Error::Runtime(format!("worker {} is dead", self.addr)))?;
        let (reply, ext, received) = recv_msg_ext(stream)?;
        counters.count(&counters.bytes_received, received as u64);
        counters.count(&counters.frames_received, 1);
        Ok((reply, ext, received))
    }

    /// Send one control-plane message (relay assignment) — counted in
    /// bytes but not in data-plane frames, so `frames_* / rounds` stays a
    /// pure fan-in measure.
    pub(crate) fn send_control(&mut self, msg: &Msg, counters: &NetCounters) -> Result<()> {
        let stream = self
            .stream
            .as_mut()
            .ok_or_else(|| Error::Runtime(format!("worker {} is dead", self.addr)))?;
        let sent = send_msg(stream, msg)?;
        counters.count(&counters.bytes_sent, sent as u64);
        Ok(())
    }

    /// Receive one control-plane reply (`RelayReady`/`Abort`).
    pub(crate) fn recv_control(&mut self, counters: &NetCounters) -> Result<Msg> {
        let stream = self
            .stream
            .as_mut()
            .ok_or_else(|| Error::Runtime(format!("worker {} is dead", self.addr)))?;
        let (reply, received) = recv_msg(stream)?;
        counters.count(&counters.bytes_received, received as u64);
        Ok(reply)
    }

    /// Best-effort session close so the worker returns to accepting.
    pub(crate) fn shutdown(&mut self) {
        if let Some(stream) = self.stream.as_mut() {
            let _ = send_msg(stream, &Msg::Shutdown);
        }
        self.stream = None;
    }
}
