//! Leader-side worker membership: one link per configured worker address,
//! with handshake, liveness and best-effort shutdown.
//!
//! Links are generic over the [`Transport`] seam: a link holds a boxed
//! [`NetStream`](crate::cluster::transport::NetStream) and never names
//! TCP — the same handshake and exchange discipline runs on production
//! sockets and on the deterministic simulator.

use crate::cluster::frames::EXT_LEN;
use crate::cluster::leader::ConnectOptions;
use crate::cluster::protocol::{
    recv_msg, recv_msg_ext, send_msg, send_msg_ext, InstanceFingerprint, Msg,
};
use crate::cluster::transport::{NetStream, Transport};
use crate::error::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared wire counters (updated by every link, read by
/// [`super::leader::RemoteCluster::stats`]). All loads/stores are relaxed:
/// the counters are telemetry, not synchronization.
#[derive(Debug, Default)]
pub(crate) struct NetCounters {
    pub(crate) bytes_sent: AtomicU64,
    pub(crate) bytes_received: AtomicU64,
    pub(crate) rounds: AtomicU64,
    pub(crate) round_us: AtomicU64,
    pub(crate) redispatches: AtomicU64,
    pub(crate) workers_lost: AtomicU64,
}

impl NetCounters {
    pub(crate) fn count(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }
}

/// One leader→worker connection. Dead links keep their slot (and their
/// address, for reporting) but `stream` is gone; a link never resurrects
/// within a session — re-dispatch moves work to survivors instead.
pub(crate) struct WorkerLink {
    pub(crate) addr: String,
    pub(crate) threads: usize,
    stream: Option<Box<dyn NetStream>>,
}

impl WorkerLink {
    /// Dial through `transport` and run the `Hello`/`Welcome` handshake:
    /// protocol version is enforced by the frame layer, the instance
    /// fingerprint here — a worker serving a different store is refused
    /// before any task. `opts.connect_timeout` bounds the dial + handshake
    /// (short, so planning reaches its fallback promptly);
    /// `opts.exchange_timeout` is the per-task bound installed for the
    /// rest of the session.
    pub(crate) fn connect(
        transport: &dyn Transport,
        addr: &str,
        fingerprint: &InstanceFingerprint,
        opts: ConnectOptions,
    ) -> Result<Self> {
        let mut stream = transport.dial(addr, opts.connect_timeout)?;
        stream.set_read_timeout(Some(opts.connect_timeout))?;
        stream.set_write_timeout(Some(opts.connect_timeout))?;
        send_msg(&mut stream, &Msg::Hello { fingerprint: fingerprint.clone() })?;
        let (reply, _) = recv_msg(&mut stream)?;
        stream.set_read_timeout(Some(opts.exchange_timeout))?;
        stream.set_write_timeout(Some(opts.exchange_timeout))?;
        match reply {
            Msg::Welcome { threads, fingerprint: theirs } => {
                if &theirs != fingerprint {
                    return Err(Error::Runtime(format!(
                        "worker {addr} serves a different instance: leader has \
                         [{fingerprint}], worker has [{theirs}]"
                    )));
                }
                Ok(Self {
                    addr: addr.to_string(),
                    threads: threads.max(1) as usize,
                    stream: Some(stream),
                })
            }
            Msg::Abort { message } => {
                Err(Error::Runtime(format!("worker {addr} refused the session: {message}")))
            }
            other => Err(Error::Runtime(format!(
                "worker {addr} answered hello with {}",
                other.name()
            ))),
        }
    }

    pub(crate) fn is_live(&self) -> bool {
        self.stream.is_some()
    }

    /// Drop the connection; the link stays dead for the session.
    pub(crate) fn kill(&mut self) {
        self.stream = None;
    }

    /// Send one task frame without waiting for the reply, split from the
    /// receive half so the overlapped gather can keep a bounded pipeline
    /// of tasks in flight per link. Every `send_task` must be balanced by
    /// exactly one [`WorkerLink::recv_partial`] (the protocol stays strict
    /// request/response on the wire; only the leader's waiting overlaps).
    /// The span-context frame extension (round index + trace-wanted flag)
    /// rides the frame header, never the message body.
    pub(crate) fn send_task(
        &mut self,
        msg: &Msg,
        ext: &[u8; EXT_LEN],
        counters: &NetCounters,
    ) -> Result<()> {
        let stream = self
            .stream
            .as_mut()
            .ok_or_else(|| Error::Runtime(format!("worker {} is dead", self.addr)))?;
        let sent = send_msg_ext(stream, msg, ext)?;
        counters.count(&counters.bytes_sent, sent as u64);
        Ok(())
    }

    /// Receive one reply frame — the read half of a task exchange.
    /// Replies arrive in task order (the worker serves one frame at a
    /// time), so the caller matches them to its in-flight queue FIFO.
    /// Returns the reply, its span-context extension when the matching
    /// task asked for tracing, and the frame's size on the wire.
    pub(crate) fn recv_partial(
        &mut self,
        counters: &NetCounters,
    ) -> Result<(Msg, Option<[u8; EXT_LEN]>, usize)> {
        let stream = self
            .stream
            .as_mut()
            .ok_or_else(|| Error::Runtime(format!("worker {} is dead", self.addr)))?;
        let (reply, ext, received) = recv_msg_ext(stream)?;
        counters.count(&counters.bytes_received, received as u64);
        Ok((reply, ext, received))
    }

    /// Best-effort session close so the worker returns to accepting.
    pub(crate) fn shutdown(&mut self) {
        if let Some(stream) = self.stream.as_mut() {
            let _ = send_msg(stream, &Msg::Shutdown);
        }
        self.stream = None;
    }
}
