//! Frame layer: length-prefixed, checksummed messages on a byte stream.
//!
//! ```text
//! offset  size  field
//! 0       4     magic      0x53_4C_4C_50 ("PLLS" little-endian)
//! 4       2     version    wire protocol version (2)
//! 6       2     kind       message kind (see protocol::Msg)
//! 8       8     len        payload length in bytes
//! 16      len   payload    message body (little-endian, wire::Enc)
//! 16+len  8     checksum   XXH64(payload, seed = kind)
//! ```
//!
//! The checksum reuses the shard store's XXH64
//! ([`crate::instance::store::xxh64`]) with the message kind as the seed,
//! so a payload replayed under the wrong kind fails verification too.
//! Checksum or header violations are hard errors: the leader treats them
//! as a lost worker (the chunk is re-dispatched elsewhere), the worker
//! drops the connection.
//!
//! Frames are written to any `io::Write` and read from any `io::Read` —
//! the transport seam ([`super::transport`]) decides whether those are
//! TCP sockets or the deterministic simulator's in-memory streams; the
//! bytes are identical either way, and the simulator's corruption faults
//! are what exercise the checksum rejection path end to end
//! (`docs/simulation.md`).

use crate::error::{Error, Result};
use crate::instance::store::xxh64;
use std::io::{Read, Write};

/// `"PLLS"` as a little-endian u32.
pub(crate) const MAGIC: u32 = u32::from_le_bytes(*b"PLLS");

/// Wire protocol version. Bump on any frame- or message-layout change;
/// the handshake refuses mismatched peers. Version 2 widened the
/// handshake fingerprint display to the full 64-bit hashes, added the
/// shard-replica span to `Welcome`/`Join`, and introduced the
/// relay-tier kinds 13–15.
pub(crate) const VERSION: u16 = 2;

/// Upper bound on a frame payload (1 GiB). Real partials are far smaller;
/// the cap stops a corrupt length prefix from provoking an absurd
/// allocation.
pub(crate) const MAX_PAYLOAD: u64 = 1 << 30;

const HEADER_LEN: usize = 16;

/// Frame kinds of the serve plane (`bskp serve`, [`crate::serve`]). The
/// worker plane owns kinds 1–15 ([`super::protocol::Msg`]); serve kinds
/// start at 32 so the two request vocabularies can never be confused —
/// and because the kind seeds the frame checksum, a frame replayed across
/// planes fails verification outright.
pub(crate) mod serve_kind {
    /// Client → server: describe the hosted instance and warm-λ state.
    pub const INFO: u16 = 32;
    /// Server → client: instance fingerprint, dims, warm-λ summary.
    pub const INFO_REPLY: u16 = 33;
    /// Client → server: run a solve / warm re-solve (budget scaling,
    /// warm-λ reuse, progress tag).
    pub const SOLVE: u16 = 34;
    /// Server → client: the finished [`crate::solve::SolveReport`].
    pub const SOLVE_REPLY: u16 = 35;
    /// Client → server: batched point query — per-group allocations under
    /// the server's current λ.
    pub const QUERY: u16 = 36;
    /// Server → client: the λ applied plus one allocation per group.
    pub const QUERY_REPLY: u16 = 37;
    /// Client → server: poll progress events for a tagged solve.
    pub const PROGRESS: u16 = 38;
    /// Server → client: progress events after the polled offset.
    pub const PROGRESS_REPLY: u16 = 39;
    /// Server → client: admission control refused the solve (typed
    /// backpressure, never an unbounded queue).
    pub const BUSY: u16 = 40;
    /// Server → client: typed request failure (message text).
    pub const ABORT: u16 = 41;
    /// Client → server: scrape the process metrics registry.
    pub const METRICS: u16 = 42;
    /// Server → client: Prometheus text exposition of the registry.
    pub const METRICS_REPLY: u16 = 43;
    /// Client → server: snapshot the span flight recorder.
    pub const TRACE: u16 = 44;
    /// Server → client: Chrome trace-event JSON document.
    pub const TRACE_REPLY: u16 = 45;
}

/// Kind-field flag bit: the frame payload begins with a fixed 16-byte
/// header extension (span-context propagation, `docs/cluster-protocol.md`
/// §extensions). The extension rides *inside* the checksummed payload and
/// the flagged kind seeds the checksum, so corruption of the extension —
/// or replay of an extended frame as a plain one — fails verification
/// like any other tampering. Peers that do not expect an extension
/// ([`read_frame`]) reject extended frames with a typed error.
pub(crate) const KIND_EXT_FLAG: u16 = 0x4000;

/// Size of the fixed frame-header extension.
pub(crate) const EXT_LEN: usize = 16;

/// Write one frame; returns the total bytes put on the wire. Enforces the
/// same payload cap the reader does, so an oversized message fails at the
/// sender (where it can be reported) instead of poisoning the peer's
/// stream.
pub(crate) fn write_frame<W: Write>(w: &mut W, kind: u16, payload: &[u8]) -> Result<usize> {
    if payload.len() as u64 > MAX_PAYLOAD {
        return Err(Error::Runtime(format!(
            "cluster wire: refusing to send a {}-byte payload (cap {MAX_PAYLOAD})",
            payload.len()
        )));
    }
    let mut header = [0u8; HEADER_LEN];
    header[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    header[4..6].copy_from_slice(&VERSION.to_le_bytes());
    header[6..8].copy_from_slice(&kind.to_le_bytes());
    header[8..16].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.write_all(&xxh64(payload, kind as u64).to_le_bytes())?;
    w.flush()?;
    Ok(HEADER_LEN + payload.len() + 8)
}

/// Write one frame whose payload is prefixed by a 16-byte header
/// extension. The extension is part of the checksummed payload and the
/// wire kind carries [`KIND_EXT_FLAG`]; returns total bytes written.
pub(crate) fn write_frame_ext<W: Write>(
    w: &mut W,
    kind: u16,
    ext: &[u8; EXT_LEN],
    payload: &[u8],
) -> Result<usize> {
    debug_assert_eq!(kind & KIND_EXT_FLAG, 0, "kind {kind} collides with the ext flag");
    let mut body = Vec::with_capacity(EXT_LEN + payload.len());
    body.extend_from_slice(ext);
    body.extend_from_slice(payload);
    write_frame(w, kind | KIND_EXT_FLAG, &body)
}

/// Read one frame that may carry a header extension; returns
/// `(kind, extension, payload, bytes_read)` with [`KIND_EXT_FLAG`]
/// stripped from the kind.
pub(crate) fn read_frame_ext<R: Read>(
    r: &mut R,
) -> Result<(u16, Option<[u8; EXT_LEN]>, Vec<u8>, usize)> {
    let (wire_kind, mut payload, n) = read_frame_inner(r)?;
    if wire_kind & KIND_EXT_FLAG == 0 {
        return Ok((wire_kind, None, payload, n));
    }
    let kind = wire_kind & !KIND_EXT_FLAG;
    if payload.len() < EXT_LEN {
        return Err(Error::Runtime(format!(
            "cluster wire: extended kind-{kind} frame too short for its {EXT_LEN}-byte \
             header extension ({} payload bytes)",
            payload.len()
        )));
    }
    let mut ext = [0u8; EXT_LEN];
    ext.copy_from_slice(&payload[..EXT_LEN]);
    payload.drain(..EXT_LEN);
    Ok((kind, Some(ext), payload, n))
}

/// Read one frame; returns `(kind, payload, bytes_read)` after verifying
/// magic, version, length bound and checksum. Rejects extended frames —
/// planes that never negotiate span shipping (the serve plane) must not
/// silently swallow an extension as payload.
pub(crate) fn read_frame<R: Read>(r: &mut R) -> Result<(u16, Vec<u8>, usize)> {
    let (wire_kind, payload, n) = read_frame_inner(r)?;
    if wire_kind & KIND_EXT_FLAG != 0 {
        return Err(Error::Runtime(format!(
            "cluster wire: unexpected header extension on kind-{} frame",
            wire_kind & !KIND_EXT_FLAG
        )));
    }
    Ok((wire_kind, payload, n))
}

fn read_frame_inner<R: Read>(r: &mut R) -> Result<(u16, Vec<u8>, usize)> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(Error::Runtime(format!(
            "cluster wire: bad frame magic {magic:#010x} (not a pallas peer?)"
        )));
    }
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(Error::Runtime(format!(
            "cluster wire: protocol version {version} (this binary speaks {VERSION})"
        )));
    }
    let kind = u16::from_le_bytes(header[6..8].try_into().unwrap());
    let len = u64::from_le_bytes(header[8..16].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(Error::Runtime(format!(
            "cluster wire: frame payload of {len} bytes exceeds the {MAX_PAYLOAD} cap"
        )));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let mut sum = [0u8; 8];
    r.read_exact(&mut sum)?;
    let expect = u64::from_le_bytes(sum);
    let got = xxh64(&payload, kind as u64);
    if got != expect {
        return Err(Error::Runtime(format!(
            "cluster wire: payload checksum mismatch (got {got:#018x}, frame says \
             {expect:#018x}) — corrupt or truncated frame"
        )));
    }
    Ok((kind, payload, HEADER_LEN + len as usize + 8))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, 7, b"payload bytes").unwrap();
        assert_eq!(n, buf.len());
        let (kind, payload, read) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(kind, 7);
        assert_eq!(payload, b"payload bytes");
        assert_eq!(read, n);
    }

    #[test]
    fn empty_payload_roundtrips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, b"").unwrap();
        let (kind, payload, _) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(kind, 1);
        assert!(payload.is_empty());
    }

    #[test]
    fn detects_payload_corruption() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 3, b"sensitive numbers").unwrap();
        buf[HEADER_LEN + 4] ^= 0x40;
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn detects_kind_replay() {
        // same payload re-framed under a different kind must not verify,
        // because the kind seeds the checksum
        let mut buf = Vec::new();
        write_frame(&mut buf, 3, b"task body").unwrap();
        buf[6] = 4; // kind 3 → 4
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn rejects_bad_magic_version_and_giant_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, b"x").unwrap();
        let mut bad = buf.clone();
        bad[0] = 0;
        assert!(read_frame(&mut bad.as_slice()).is_err());
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(read_frame(&mut bad.as_slice())
            .unwrap_err()
            .to_string()
            .contains("version"));
        let mut bad = buf;
        bad[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(read_frame(&mut bad.as_slice()).unwrap_err().to_string().contains("cap"));
    }

    #[test]
    fn extension_roundtrips_and_plain_readers_reject_it() {
        let ext = [7u8; EXT_LEN];
        let mut buf = Vec::new();
        let n = write_frame_ext(&mut buf, 5, &ext, b"task body").unwrap();
        assert_eq!(n, buf.len());
        let (kind, got_ext, payload, read) = read_frame_ext(&mut buf.as_slice()).unwrap();
        assert_eq!(kind, 5);
        assert_eq!(got_ext, Some(ext));
        assert_eq!(payload, b"task body");
        assert_eq!(read, n);
        // a reader that never negotiated extensions must reject, not
        // swallow the extension as payload
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("extension"), "{err}");
    }

    #[test]
    fn ext_reader_passes_plain_frames_through() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 9, b"plain").unwrap();
        let (kind, ext, payload, _) = read_frame_ext(&mut buf.as_slice()).unwrap();
        assert_eq!(kind, 9);
        assert_eq!(ext, None);
        assert_eq!(payload, b"plain");
    }

    #[test]
    fn corrupting_the_extension_fails_the_checksum() {
        let mut buf = Vec::new();
        write_frame_ext(&mut buf, 5, &[1u8; EXT_LEN], b"body").unwrap();
        buf[HEADER_LEN + 3] ^= 0x10; // inside the extension bytes
        let err = read_frame_ext(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn truncated_stream_is_io_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 2, b"abcdef").unwrap();
        let err = read_frame(&mut &buf[..buf.len() - 3]).unwrap_err();
        assert!(matches!(err, Error::Io(_)));
    }
}
