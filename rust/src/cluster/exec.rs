//! The executor seam between the solvers and the two runtimes.
//!
//! Every solver round is one of three map shapes (evaluate, SCD
//! threshold-emit, §5.4 rank). [`Exec`] dispatches each shape either to
//! the in-process thread pool — exactly the code path the solvers always
//! had — or to a [`RemoteCluster`] of worker processes. The drivers
//! (`solve_scd_exec`, `solve_dd_exec`) are written against this seam and
//! do not know which one they are on. A `RemoteCluster` itself speaks
//! through the transport seam ([`super::transport`]), so `Exec::Remote`
//! covers both production TCP fleets and the deterministic simulator's
//! in-process fleets ([`super::sim`]) without the drivers changing.

use crate::cluster::leader::RemoteCluster;
use crate::error::Result;
use crate::instance::problem::GroupSource;
use crate::instance::shard::Shards;
use crate::mapreduce::Cluster;
use crate::solver::postprocess;
use crate::solver::rounds::{evaluation_chunk, RoundAgg, RustEvaluator};
use crate::solver::scd::{scd_round_chunk, ScdAcc, ScdRoundCtx, ScdRoundSpec};

/// Where map rounds run: the in-process pool or a TCP worker fleet.
///
/// With `Local`, `source` is read by the pool's threads directly. With
/// `Remote`, `source` is the **leader's replica** of the instance (used
/// only for leader-local phases); the heavy per-group reads happen on the
/// workers' own memory-mapped stores, verified equal by the handshake
/// fingerprint.
pub enum Exec<'e> {
    /// The single-box thread pool.
    Local(&'e Cluster),
    /// A connected worker fleet.
    Remote(&'e RemoteCluster),
}

impl Exec<'_> {
    /// Map parallelism for shard planning: pool threads, or the fleet's
    /// advertised thread capacity.
    pub fn map_parallelism(&self) -> usize {
        match self {
            Exec::Local(c) => c.workers(),
            Exec::Remote(r) => r.capacity(),
        }
    }

    /// The pool for work that stays on the leader regardless of executor
    /// (§5.3 pre-solve sampling, §5.4's sequential drop walk).
    pub fn local_pool(&self) -> &Cluster {
        match self {
            Exec::Local(c) => c,
            Exec::Remote(r) => r.leader_pool(),
        }
    }

    /// Short name for plans and logs.
    pub fn name(&self) -> &'static str {
        match self {
            Exec::Local(_) => "in-process",
            Exec::Remote(_) => "distributed",
        }
    }

    /// One full evaluation round at fixed λ.
    pub(crate) fn eval_round<S: GroupSource + ?Sized>(
        &self,
        source: &S,
        shards: Shards,
        kk: usize,
        lambda: &[f64],
    ) -> Result<RoundAgg> {
        match self {
            Exec::Local(c) => Ok(evaluation_chunk(
                &RustEvaluator::new(source),
                shards,
                0,
                shards.count(),
                kk,
                lambda,
                c,
            )),
            Exec::Remote(r) => r.eval_round(shards, kk, lambda),
        }
    }

    /// One full SCD round. `ctx` carries the leader-local λ-stability
    /// cache and buffer arena; it is consumed by the in-process path only
    /// (remote workers are stateless between frames, and replay vs.
    /// recompute is bit-identical, so results agree across executors).
    pub(crate) fn scd_round<S: GroupSource + ?Sized>(
        &self,
        source: &S,
        shards: Shards,
        spec: &ScdRoundSpec<'_>,
        ctx: ScdRoundCtx<'_>,
    ) -> Result<ScdAcc> {
        match self {
            Exec::Local(c) => Ok(scd_round_chunk(source, shards, 0, shards.count(), spec, c, ctx)),
            Exec::Remote(r) => r.scd_round(shards, spec),
        }
    }

    /// One full §5.4 ranking round.
    pub(crate) fn rank_round<S: GroupSource + ?Sized>(
        &self,
        source: &S,
        shards: Shards,
        lambda: &[f64],
    ) -> Result<Vec<(f32, u32)>> {
        match self {
            Exec::Local(c) => {
                Ok(postprocess::rank_chunk(source, shards, 0, shards.count(), lambda, c))
            }
            Exec::Remote(r) => r.rank_round(shards, lambda),
        }
    }
}
