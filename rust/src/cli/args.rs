//! Tiny argv parser: `bskp <subcommand> [--flag value | --switch]...`.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Switches that take no value.
const SWITCHES: &[&str] =
    &["quiet", "no-postprocess", "no-fastpath", "track-history", "verify", "plan-only", "wait"];

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    sub: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse argv (element 0 = program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut it = argv.into_iter().skip(1);
        let sub = it.next().unwrap_or_default();
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        while let Some(tok) = it.next() {
            let Some(name) = tok.strip_prefix("--") else {
                return Err(Error::Usage(format!("expected --flag, got {tok:?}")));
            };
            if SWITCHES.contains(&name) {
                switches.push(name.to_string());
            } else {
                let val = it
                    .next()
                    .ok_or_else(|| Error::Usage(format!("--{name} requires a value")))?;
                flags.insert(name.to_string(), val);
            }
        }
        Ok(Self { sub, flags, switches })
    }

    /// The subcommand (may be empty).
    pub fn subcommand(&self) -> &str {
        &self.sub
    }

    /// A typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name}: cannot parse {v:?}"))),
        }
    }

    /// An optional typed flag.
    pub fn get_opt<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::Usage(format!("--{name}: cannot parse {v:?}"))),
        }
    }

    /// Raw string flag.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Boolean switch presence.
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = parse("bskp solve --n 100 --class sparse --quiet").unwrap();
        assert_eq!(a.subcommand(), "solve");
        assert_eq!(a.get::<usize>("n", 0).unwrap(), 100);
        assert_eq!(a.get_str("class", "dense"), "sparse");
        assert!(a.has("quiet"));
        assert!(!a.has("no-postprocess"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("bskp solve").unwrap();
        assert_eq!(a.get::<usize>("n", 42).unwrap(), 42);
        assert_eq!(a.get_opt::<f64>("tol").unwrap(), None);
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse("bskp solve --n").is_err());
        assert!(parse("bskp solve n 5").is_err());
        assert!(parse("bskp solve --n five").unwrap().get::<usize>("n", 0).is_err());
    }
}
