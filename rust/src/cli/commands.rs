//! CLI subcommands.

use crate::cli::args::Args;
use crate::coordinator::{Algorithm, Backend};
use crate::error::{Error, Result};
use crate::instance::generator::{GeneratorConfig, SyntheticProblem};
use crate::instance::laminar::LaminarProfile;
use crate::instance::problem::{GroupBuf, GroupSource};
use crate::instance::store::MmapProblem;
use crate::lp::lp_upper_bound;
use crate::mapreduce::Cluster;
use crate::metrics::{plan_to_json, report_to_json, JsonValue};
use crate::solve::{ScaledBudgets, Solve, WarmStart, DEFAULT_CHECKPOINT_EVERY};
use crate::solver::config::{CdMode, PresolveConfig, ReduceMode, SolverConfig};

/// Usage text for `bskp help`.
pub const USAGE: &str = "\
bskp — billion-scale knapsack solver (WWW'20 reproduction)

SUBCOMMANDS
  gen        write a synthetic instance into an on-disk shard store
  solve      solve a synthetic instance, or an on-disk store via --from
  resolve    re-solve with a warm-started λ (requires --warm); the daily
             changed-budget production path, e.g. with --budget-scale
  worker     serve a shard-store replica to a cluster leader (L4)
  serve      long-lived solve-as-a-service daemon over a shard store:
             warm-λ re-solves, point queries, progress streaming
  request    one request against a running serve daemon
  trace      snapshot a running daemon's span flight recorder as Chrome
             trace-event JSON (loadable in Perfetto / chrome://tracing)
  lpbound    compute the LP-relaxation upper bound (Kelley cutting planes)
  inspect    print instance statistics and a sample group
  help       this text

INSTANCE FLAGS (gen / solve / lpbound / inspect)
  --n <int>            groups (default 100000)
  --m <int>            items per group (default 10)
  --k <int>            global constraints (default 10)
  --class sparse|dense cost class (default sparse)
  --locals single:<cap>|c223|taxonomy:<levels>   (default single:1)
  --tightness <f>      budget tightness (default 0.25)
  --seed <int>         instance seed (default 0)

GEN FLAGS
  --out <dir>          store directory to create (required)
  --shard <int>        groups per shard file (default 65536)

STORE FLAGS (solve / lpbound / inspect)
  --from <dir>         read the instance from a shard store (out-of-core);
                       replaces the instance flags above
  --verify             checksum every shard file before using it

SOLVER FLAGS (solve / resolve)
  --algo scd|dd        algorithm (default scd)
  --backend rust|xla   map-phase backend (default rust; unsupported
                       combinations fall back with a plan note)
  --artifacts <dir>    artifact dir for --backend xla (default artifacts)
  --iters <int>        max iterations (default 60)
  --tol <f>            convergence tolerance (default 1e-4)
  --alpha <f>          DD learning rate (default 1e-3)
  --lambda0 <f>        initial multipliers (default 1.0)
  --presolve <n>       §5.3 pre-solve with n sampled groups
  --bucketed <delta>   §5.2 bucketed reduce with finest width delta
  --cd sync|cyclic|block:<n>   coordinate schedule (default sync)
  --damping <f>        under-relaxation in (0,1]
  --workers <int>      map workers (default: $PALLAS_WORKERS, else all
                       cores; also sizes a worker process's pool)
  --shard <int>        shard size override
  --cluster <addrs>    run the map rounds on pallas worker processes at
                       host:port[,host:port...]; requires --from (workers
                       mmap their replica of the same store). Unreachable
                       fleet => in-process fallback with a plan note
  --join-listen <addr> with --cluster: bind a join listener so fresh
                       `bskp worker --join` processes are admitted
                       mid-solve (elasticity; the actual address is
                       announced on stdout). Redial/quorum knobs:
                       PALLAS_CLUSTER_REDIALS, PALLAS_CLUSTER_REDIAL_BACKOFF_MS,
                       PALLAS_MIN_WORKERS (docs/solve-api.md)
  --track-history      record the per-iteration series in the report JSON
  --trace <path>       force span tracing on for this run and write the
                       flight recorder as Chrome trace-event JSON
                       (docs/observability.md; PALLAS_TRACE=1 traces
                       without writing a file)
  --json <path|->      write {plan, report} JSON to a file, or - for
                       stdout (- implies --quiet so stdout stays JSON)
  --plan-only          print the plan (and its JSON) without solving
  --no-postprocess     skip §5.4 feasibility projection
  --no-fastpath        disable Algorithm 5 (use Algorithm 3 everywhere)
  --quiet              suppress the human-readable plan and summary

WARM START / CHECKPOINT FLAGS (solve / resolve)
  --warm <file>        seed λ from a checkpoint file (required by resolve)
  --budget-scale <f>   scale all budgets by f (changed-budget re-solve)
  --checkpoint <path|auto>   write periodic λ checkpoints; auto puts
                       lambda.ckpt next to the --from shard store
  --checkpoint-every <n>     checkpoint cadence in rounds (default 5)

WORKER FLAGS
  --listen <addr>      bind address (default 127.0.0.1:0; the actual
                       address is announced on stdout)
  --store <dir>        shard-store replica to serve (required)
  --workers <int>      map threads to advertise (default as above)
  --join <addr>        instead of listening, dial a running leader's
                       --join-listen address and serve it mid-solve
                       (chunks arrive from the next round boundary)
  --join-attempts <n>  dial retries (with backoff) before giving up
                       when joining (default 5)

SERVE FLAGS (see docs/serve-api.md)
  --store <dir>        shard store to host (required; mmapped once)
  --listen <addr>      bind address (default 127.0.0.1:0; the actual
                       address is announced on stdout)
  --admission <int>    concurrent-solve bound (default 2); excess
                       solves get a typed busy reply
  --workers <int>      map threads per solve (default as above)

REQUEST FLAGS
  --to <addr>          serve daemon address (required)
  --op <op>            info|solve|resolve|query|progress|metrics|trace
                       (default info); resolve = solve seeded from the
                       server's warm λ; metrics = Prometheus text scrape;
                       trace = flight-recorder snapshot (Chrome JSON)
  --algo scd|dd        solve/resolve algorithm (default scd)
  --iters/--tol/--alpha/--shard   as under SOLVER FLAGS
  --budget-scale <f>   scale the hosted budgets for this solve
  --tag <int>          progress tag: on solve, register the round series
                       under it; on --op progress, poll it
  --after <int>        first progress event to return (default 0)
  --groups <ids>       comma-separated group ids for --op query
  --wait               on a busy reply, retry after the daemon's
                       retry-after hint instead of failing
  --json <path|->      write the reply JSON to a file, or - for stdout
  --quiet              suppress the human-readable summary

TRACE FLAGS
  --to <addr>          serve daemon address (required)
  --out <path|->       where to write the JSON (default -, stdout)

LPBOUND FLAGS
  --lp-tol <f>         Kelley gap tolerance (default 1e-4)
  --cuts <int>         max cuts (default 200)
";

/// Build the group source: `--from <dir>` opens an on-disk shard store
/// (optionally checksum-verified), otherwise the synthetic instance flags
/// apply.
pub fn source_from_args(args: &Args) -> Result<Box<dyn GroupSource>> {
    match args.get_opt::<String>("from")? {
        Some(dir) => {
            let p = if args.has("verify") {
                MmapProblem::open_verified(&dir)?
            } else {
                MmapProblem::open(&dir)?
            };
            Ok(Box::new(p))
        }
        None => Ok(Box::new(instance_from_args(args)?)),
    }
}

/// Build the instance described by the shared flags.
pub fn instance_from_args(args: &Args) -> Result<SyntheticProblem> {
    let n = args.get("n", 100_000usize)?;
    let m = args.get("m", 10usize)?;
    let k = args.get("k", 10usize)?;
    let class = args.get_str("class", "sparse");
    let locals = parse_locals(&args.get_str("locals", "single:1"), m)?;
    let mut cfg = match class.as_str() {
        "sparse" => GeneratorConfig::sparse(n, m, k),
        "dense" => GeneratorConfig::dense(n, m, k),
        other => return Err(Error::Usage(format!("--class must be sparse|dense, got {other}"))),
    };
    cfg = cfg
        .with_locals(locals)
        .with_tightness(args.get("tightness", 0.25f64)?)
        .with_seed(args.get("seed", 0u64)?);
    Ok(SyntheticProblem::new(cfg))
}

fn parse_locals(spec: &str, m: usize) -> Result<LaminarProfile> {
    if let Some(cap) = spec.strip_prefix("single:") {
        let cap: u32 =
            cap.parse().map_err(|_| Error::Usage(format!("bad cap in --locals {spec}")))?;
        return Ok(LaminarProfile::single(m, cap));
    }
    if spec == "c223" {
        return Ok(LaminarProfile::scenario_c223(m));
    }
    if let Some(levels) = spec.strip_prefix("taxonomy:") {
        let levels: usize =
            levels.parse().map_err(|_| Error::Usage(format!("bad levels in --locals {spec}")))?;
        return LaminarProfile::taxonomy(m, levels);
    }
    Err(Error::Usage(format!("--locals must be single:<cap>|c223|taxonomy:<levels>, got {spec}")))
}

/// Build the solver config from flags.
pub fn solver_config_from_args(args: &Args) -> Result<SolverConfig> {
    let mut cfg = SolverConfig {
        max_iters: args.get("iters", 60usize)?,
        tol: args.get("tol", 1e-4f64)?,
        lambda0: args.get("lambda0", 1.0f64)?,
        dd_alpha: args.get("alpha", 1e-3f64)?,
        postprocess: !args.has("no-postprocess"),
        use_sparse_fast_path: !args.has("no-fastpath"),
        shard_size: args.get_opt("shard")?,
        damping: args.get_opt("damping")?,
        // the CLI keeps reports lean unless the series is asked for
        // (library default is on; see SolverConfig::track_history)
        track_history: args.has("track-history"),
        ..SolverConfig::default()
    };
    if let Some(sample) = args.get_opt::<usize>("presolve")? {
        cfg.presolve = Some(PresolveConfig { sample, ..Default::default() });
    }
    if let Some(delta) = args.get_opt::<f64>("bucketed")? {
        cfg.reduce = ReduceMode::Bucketed { delta };
    }
    cfg.cd = match args.get_str("cd", "sync").as_str() {
        "sync" => CdMode::Synchronous,
        "cyclic" => CdMode::Cyclic,
        other => {
            if let Some(bs) = other.strip_prefix("block:") {
                CdMode::Block {
                    block_size: bs
                        .parse()
                        .map_err(|_| Error::Usage(format!("bad --cd block size {bs}")))?,
                }
            } else {
                return Err(Error::Usage(format!("--cd must be sync|cyclic|block:<n>, got {other}")));
            }
        }
    };
    // config mistakes come from flags here — surface them as usage errors
    cfg.validate().map_err(|e| Error::Usage(e.to_string()))?;
    Ok(cfg)
}

fn cluster_from_args(args: &Args) -> Result<Cluster> {
    Ok(match args.get_opt::<usize>("workers")? {
        Some(w) => Cluster::new(w),
        None => Cluster::configured(),
    })
}

/// `bskp worker`: bind, announce the actual address on stdout (so scripts
/// can use `--listen 127.0.0.1:0` for an ephemeral port), then serve the
/// store replica to leader sessions until killed. With `--join <addr>`
/// the worker instead dials a *running* leader's join listener and is
/// dealt chunks from the next round on (mid-solve admission; see
/// `docs/cluster-protocol.md`).
pub fn cmd_worker(args: &Args) -> Result<()> {
    let store = args.get_opt::<String>("store")?.ok_or_else(|| {
        Error::Usage("worker requires --store <dir> (a shard-store replica)".into())
    })?;
    let pool = cluster_from_args(args)?;
    if let Some(leader) = args.get_opt::<String>("join")? {
        let attempts = args.get("join-attempts", 5u32)?;
        let problem = MmapProblem::open(&store)?;
        println!(
            "pallas worker joining leader at {leader} (store {store}, {} map threads)",
            pool.workers()
        );
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        return crate::cluster::worker::join_net(
            std::sync::Arc::new(crate::cluster::TcpTransport),
            &leader,
            &problem,
            &pool,
            attempts,
        );
    }
    let listen = args.get_str("listen", "127.0.0.1:0");
    let listener = std::net::TcpListener::bind(&listen)
        .map_err(|e| Error::Runtime(format!("cannot listen on {listen}: {e}")))?;
    let addr = listener.local_addr()?;
    println!(
        "pallas worker listening on {addr} (store {store}, {} map threads)",
        pool.workers()
    );
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    crate::cluster::worker::serve(listener, std::path::Path::new(&store), &pool)
}

/// `bskp serve`: bind, announce the actual address on stdout, then host
/// the shard store as a solve-as-a-service daemon until killed
/// (`docs/serve-api.md`).
pub fn cmd_serve(args: &Args) -> Result<()> {
    let store = args
        .get_opt::<String>("store")?
        .ok_or_else(|| Error::Usage("serve requires --store <dir> (a shard store)".into()))?;
    let listen = args.get_str("listen", "127.0.0.1:0");
    let opts = crate::serve::ServeOptions {
        admission: args.get("admission", 2usize)?,
        threads: args.get_opt::<usize>("workers")?.unwrap_or(0),
    };
    let listener = std::net::TcpListener::bind(&listen)
        .map_err(|e| Error::Runtime(format!("cannot listen on {listen}: {e}")))?;
    let addr = listener.local_addr()?;
    println!(
        "pallas serve listening on {addr} (store {store}, admission {})",
        opts.admission.max(1)
    );
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    crate::serve::serve(listener, std::path::Path::new(&store), &opts)
}

/// `bskp request`: one request/reply against a running serve daemon.
pub fn cmd_request(args: &Args) -> Result<()> {
    use crate::serve::{ServeClient, SolveOutcome, SolveSpec};

    let to = args
        .get_opt::<String>("to")?
        .ok_or_else(|| Error::Usage("request requires --to <addr> (a serve daemon)".into()))?;
    let op = args.get_str("op", "info");
    let known = ["info", "solve", "resolve", "query", "progress", "metrics", "trace"];
    if !known.contains(&op.as_str()) {
        return Err(Error::Usage(format!(
            "--op must be info|solve|resolve|query|progress|metrics|trace, got {op}"
        )));
    }
    let json_dest = args.get_opt::<String>("json")?;
    let quiet = args.has("quiet") || json_dest.as_deref() == Some("-");
    let mut client = ServeClient::connect_tcp(&to)?;

    match op.as_str() {
        "info" => {
            let info = client.info()?;
            if !quiet {
                println!("serve daemon at {to}");
                println!("  instance     : {}", info.fingerprint);
                println!(
                    "  warm λ       : {}",
                    if info.warm_lambda.is_empty() { "none".to_string() } else { format!("{:?}", info.warm_lambda) }
                );
                println!("  solves       : {}/{} running", info.active, info.limit);
            }
            if let Some(dest) = &json_dest {
                emit_json(
                    quiet,
                    dest,
                    JsonValue::Object(vec![
                        ("fingerprint".to_string(), JsonValue::Str(info.fingerprint.to_string())),
                        (
                            "warm_lambda".to_string(),
                            JsonValue::Array(
                                info.warm_lambda.iter().map(|&l| JsonValue::Num(l)).collect(),
                            ),
                        ),
                        ("active".to_string(), JsonValue::Num(info.active as f64)),
                        ("limit".to_string(), JsonValue::Num(info.limit as f64)),
                    ]),
                )?;
            }
            Ok(())
        }
        "solve" | "resolve" => {
            let defaults = SolveSpec::default();
            let spec = SolveSpec {
                tag: args.get("tag", 0u64)?,
                algorithm: match args.get_str("algo", "scd").as_str() {
                    "scd" => 0,
                    "dd" => 1,
                    other => {
                        return Err(Error::Usage(format!("--algo must be scd|dd, got {other}")))
                    }
                },
                budget_scale: args.get("budget-scale", 1.0f64)?,
                // a resolve without the server's warm λ is just a solve
                warm: op == "resolve",
                max_iters: args.get("iters", 60u64)?,
                tol: args.get("tol", defaults.tol)?,
                dd_alpha: args.get("alpha", defaults.dd_alpha)?,
                shard_size: args.get("shard", 0u64)?,
            };
            let wait = args.has("wait");
            let served = loop {
                match client.solve(spec.clone())? {
                    SolveOutcome::Done(s) => break s,
                    SolveOutcome::Busy { active, limit, retry_after_ms } if wait => {
                        // honor the daemon's cadence-derived hint instead
                        // of polling blindly
                        if !quiet {
                            eprintln!(
                                "server busy ({active}/{limit} solves running); \
                                 retrying in {retry_after_ms} ms"
                            );
                        }
                        std::thread::sleep(std::time::Duration::from_millis(retry_after_ms));
                    }
                    SolveOutcome::Busy { active, limit, retry_after_ms } => {
                        return Err(Error::Runtime(format!(
                            "server busy: {active}/{limit} solves running — retry in \
                             ~{retry_after_ms} ms, or pass --wait to let bskp do it"
                        )))
                    }
                }
            };
            let report = &served.report;
            if !quiet {
                println!(
                    "served {op} from {to}{}",
                    if served.warm_used { " (warm λ)" } else { "" }
                );
                println!(
                    "  iterations      : {}{}",
                    report.iterations,
                    if report.converged { " (converged)" } else { " (iteration cap)" }
                );
                println!("  primal value    : {:.4}", report.primal_value);
                println!("  dual value      : {:.4}", report.dual_value);
                println!("  duality gap     : {:.4}", report.duality_gap());
                println!("  selected items  : {}", report.n_selected);
            }
            if let Some(dest) = &json_dest {
                emit_json(
                    quiet,
                    dest,
                    JsonValue::Object(vec![
                        ("warm_used".to_string(), JsonValue::Bool(served.warm_used)),
                        ("report".to_string(), report_to_json(report)),
                    ]),
                )?;
            }
            Ok(())
        }
        "query" => {
            let spec = args.get_opt::<String>("groups")?.ok_or_else(|| {
                Error::Usage("request --op query needs --groups <id,id,...>".into())
            })?;
            let mut groups = Vec::new();
            for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                groups.push(
                    part.parse::<u64>()
                        .map_err(|_| Error::Usage(format!("bad group id in --groups: {part}")))?,
                );
            }
            let (lambda, allocs) = client.query(&groups)?;
            if !quiet {
                let primal: f64 = allocs.iter().map(|a| a.primal).sum();
                let picked: usize =
                    allocs.iter().map(|a| a.x.iter().filter(|&&b| b != 0).count()).sum();
                println!("{} groups under λ={lambda:?}", allocs.len());
                println!("  Σ primal     : {primal:.4}");
                println!("  items picked : {picked}");
            }
            if let Some(dest) = &json_dest {
                let allocs_json = allocs
                    .iter()
                    .map(|a| {
                        JsonValue::Object(vec![
                            ("group".to_string(), JsonValue::Num(a.group as f64)),
                            (
                                "x".to_string(),
                                JsonValue::Array(
                                    a.x.iter().map(|&b| JsonValue::Num(b as f64)).collect(),
                                ),
                            ),
                            ("primal".to_string(), JsonValue::Num(a.primal)),
                            ("dual_inner".to_string(), JsonValue::Num(a.dual_inner)),
                            (
                                "consumption".to_string(),
                                JsonValue::Array(
                                    a.consumption.iter().map(|&c| JsonValue::Num(c)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect();
                emit_json(
                    quiet,
                    dest,
                    JsonValue::Object(vec![
                        (
                            "lambda".to_string(),
                            JsonValue::Array(lambda.iter().map(|&l| JsonValue::Num(l)).collect()),
                        ),
                        ("allocations".to_string(), JsonValue::Array(allocs_json)),
                    ]),
                )?;
            }
            Ok(())
        }
        "progress" => {
            let tag = args.get_opt::<u64>("tag")?.ok_or_else(|| {
                Error::Usage("request --op progress needs --tag <int>".into())
            })?;
            let after = args.get("after", 0u64)?;
            let snap = client.progress(tag, after)?;
            if !quiet {
                println!(
                    "tag {tag}: {} events{}",
                    snap.total,
                    if snap.done { " (done)" } else { "" }
                );
                for (i, ev) in snap.events.iter().enumerate() {
                    println!(
                        "  [{}] iter {} primal {:.4} dual {:.4} viol {:.3e} Δλ {:.3e}",
                        after as usize + i,
                        ev.iter,
                        ev.primal,
                        ev.dual,
                        ev.max_violation_ratio,
                        ev.lambda_change
                    );
                }
            }
            if let Some(dest) = &json_dest {
                let events = snap
                    .events
                    .iter()
                    .map(|ev| {
                        JsonValue::Object(vec![
                            ("iter".to_string(), JsonValue::Num(ev.iter as f64)),
                            ("primal".to_string(), JsonValue::Num(ev.primal)),
                            ("dual".to_string(), JsonValue::Num(ev.dual)),
                            (
                                "max_violation_ratio".to_string(),
                                JsonValue::Num(ev.max_violation_ratio),
                            ),
                            ("lambda_change".to_string(), JsonValue::Num(ev.lambda_change)),
                        ])
                    })
                    .collect();
                emit_json(
                    quiet,
                    dest,
                    JsonValue::Object(vec![
                        ("total".to_string(), JsonValue::Num(snap.total as f64)),
                        ("done".to_string(), JsonValue::Bool(snap.done)),
                        ("events".to_string(), JsonValue::Array(events)),
                    ]),
                )?;
            }
            Ok(())
        }
        "metrics" => {
            // Prometheus text is the payload; print it verbatim so the
            // output pipes straight into promtool / a scrape file
            print!("{}", client.scrape()?);
            Ok(())
        }
        "trace" => {
            let json = client.trace_snapshot()?;
            match json_dest.as_deref() {
                None | Some("-") => println!("{json}"),
                Some(dest) => {
                    std::fs::write(dest, &json)?;
                    if !quiet {
                        println!("trace written: {dest} ({} bytes)", json.len());
                    }
                }
            }
            Ok(())
        }
        _ => unreachable!("op validated above"),
    }
}

/// `bskp trace`: snapshot a running serve daemon's span flight recorder
/// as Chrome trace-event JSON — shorthand for `request --op trace`.
pub fn cmd_trace(args: &Args) -> Result<()> {
    use crate::serve::ServeClient;

    let to = args
        .get_opt::<String>("to")?
        .ok_or_else(|| Error::Usage("trace requires --to <addr> (a serve daemon)".into()))?;
    let out = args.get_str("out", "-");
    let mut client = ServeClient::connect_tcp(&to)?;
    let json = client.trace_snapshot()?;
    if out == "-" {
        println!("{json}");
    } else {
        std::fs::write(&out, &json)?;
        println!("trace written: {out} ({} bytes)", json.len());
    }
    Ok(())
}

/// `bskp gen`: stream a synthetic instance into an on-disk shard store.
pub fn cmd_gen(args: &Args) -> Result<()> {
    let problem = instance_from_args(args)?;
    let out = args
        .get_opt::<String>("out")?
        .ok_or_else(|| Error::Usage("gen requires --out <dir>".into()))?;
    let shard = args.get("shard", 65_536usize)?;
    if shard == 0 {
        return Err(Error::Usage("--shard must be positive".into()));
    }
    let cluster = cluster_from_args(args)?;
    let t0 = std::time::Instant::now();
    let summary = problem.write_shards(&out, shard, &cluster)?;
    if !args.has("quiet") {
        let dims = problem.dims();
        println!(
            "wrote N={} M={} K={} ({} class) to {}",
            dims.n_groups,
            dims.n_items,
            dims.n_global,
            if problem.is_dense() { "dense" } else { "sparse" },
            summary.dir.display()
        );
        println!("  shard files     : {} × {} groups", summary.n_shards, shard);
        println!("  bytes on disk   : {}", summary.bytes);
        println!("  wall time       : {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
        println!("  solve it with   : bskp solve --from {out}");
    }
    Ok(())
}

/// `bskp solve`.
pub fn cmd_solve(args: &Args) -> Result<()> {
    cmd_solve_impl(args, false)
}

/// `bskp resolve`: a warm-started re-solve — `solve` with `--warm`
/// mandatory, because resolving without yesterday's λ is just a solve.
pub fn cmd_resolve(args: &Args) -> Result<()> {
    cmd_solve_impl(args, true)
}

fn cmd_solve_impl(args: &Args, require_warm: bool) -> Result<()> {
    // `--trace` overrides PALLAS_TRACE before any instrumented work runs
    // (staging in plan() already records io spans)
    let trace_dest = args.get_opt::<String>("trace")?;
    if trace_dest.is_some() {
        crate::obs::force_trace(true);
    }
    let problem = source_from_args(args)?;
    let config = solver_config_from_args(args)?;
    let cluster = cluster_from_args(args)?;
    let algorithm = match args.get_str("algo", "scd").as_str() {
        "scd" => Algorithm::Scd,
        "dd" => Algorithm::Dd,
        other => return Err(Error::Usage(format!("--algo must be scd|dd, got {other}"))),
    };
    let backend = match args.get_str("backend", "rust").as_str() {
        "rust" => Backend::Rust,
        "xla" => Backend::Xla { artifacts_dir: args.get_str("artifacts", "artifacts").into() },
        other => return Err(Error::Usage(format!("--backend must be rust|xla, got {other}"))),
    };

    let warm = match args.get_opt::<String>("warm")? {
        Some(path) => {
            Some(WarmStart::from_checkpoint(&path).map_err(|e| Error::Usage(e.to_string()))?)
        }
        None if require_warm => {
            return Err(Error::Usage(
                "resolve requires --warm <checkpoint> (a prior solve's λ); \
                 use `bskp solve --checkpoint ...` to produce one"
                    .into(),
            ))
        }
        None => None,
    };

    // budget-perturbed view (the changed-budget re-solve path)
    let scaled;
    let source: &dyn GroupSource = match args.get_opt::<f64>("budget-scale")? {
        Some(f) if f != 1.0 => {
            scaled = ScaledBudgets::uniform(problem.as_ref(), f)
                .map_err(|e| Error::Usage(e.to_string()))?;
            &scaled
        }
        _ => problem.as_ref(),
    };

    let mut session = Solve::on(source)
        .algorithm(algorithm)
        .backend(backend)
        .config(config)
        .cluster(cluster);
    if let Some(spec) = args.get_opt::<String>("cluster")? {
        let addrs: Vec<String> = spec
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if addrs.is_empty() {
            return Err(Error::Usage("--cluster needs host:port[,host:port...]".into()));
        }
        session = session.distributed(addrs);
        // a bound join listener admits `bskp worker --join` processes
        // mid-solve; announced like the worker's --listen so scripts can
        // bind port 0 and read the address back
        if let Some(bind) = args.get_opt::<String>("join-listen")? {
            let listener = std::net::TcpListener::bind(&bind)
                .map_err(|e| Error::Runtime(format!("cannot listen on {bind}: {e}")))?;
            let addr = listener.local_addr()?;
            println!("pallas leader join listener on {addr}");
            use std::io::Write as _;
            std::io::stdout().flush().ok();
            session = session
                .join_listener(Box::new(crate::cluster::TcpNetListener::new(listener)));
        }
    } else if args.get_opt::<String>("join-listen")?.is_some() {
        return Err(Error::Usage(
            "--join-listen only makes sense with --cluster (mid-solve admission \
             needs an attached worker fleet)"
                .into(),
        ));
    }
    if let Some(w) = warm {
        session = session.warm(w);
    }
    let every = args.get("checkpoint-every", DEFAULT_CHECKPOINT_EVERY)?;
    match args.get_opt::<String>("checkpoint")?.as_deref() {
        Some("auto") => session = session.checkpoint_auto(every),
        Some(path) => session = session.checkpoint_to(path, every),
        None => {}
    }

    let plan = session.plan()?;
    let json_dest = args.get_opt::<String>("json")?;
    // `--json -` owns stdout: suppress the human-readable plan/summary so
    // the stream stays parseable without also passing --quiet
    let quiet = args.has("quiet") || json_dest.as_deref() == Some("-");
    if !quiet {
        print!("{plan}");
    }
    let plan_json = plan_to_json(&plan);
    if args.has("plan-only") {
        if let Some(dest) = &json_dest {
            emit_json(quiet, dest, JsonValue::Object(vec![("plan".to_string(), plan_json)]))?;
        }
        return Ok(());
    }

    let dims = source.dims();
    // keep a fleet handle so wire statistics survive the consuming run()
    let remote = plan.remote_handle();
    let report = plan.run()?;

    if !quiet {
        println!(
            "solved N={} M={} K={} ({} decision variables)",
            dims.n_groups,
            dims.n_items,
            dims.n_global,
            dims.n_vars()
        );
        println!(
            "  iterations      : {}{}",
            report.iterations,
            if report.converged { " (converged)" } else { " (iteration cap)" }
        );
        println!("  primal value    : {:.4}", report.primal_value);
        println!("  dual value      : {:.4}", report.dual_value);
        println!("  duality gap     : {:.4}", report.duality_gap());
        println!("  max violation   : {:.6}", report.max_violation_ratio());
        println!("  selected items  : {}", report.n_selected);
        println!("  dropped groups  : {}", report.dropped_groups);
        println!("  wall time       : {:.1} ms", report.wall_ms);
        println!(
            "  phase breakdown : map {:.1} ms, reduce {:.1} ms, final eval {:.1} ms{}",
            report.phases.map_ms,
            report.phases.reduce_ms,
            report.phases.final_eval_ms,
            if report.phases.walks_total > 0 {
                format!(
                    ", λ-skip {:.1}% of {} walks",
                    100.0 * report.phases.skip_rate(),
                    report.phases.walks_total
                )
            } else {
                String::new()
            }
        );
        if let Some(r) = &remote {
            let s = r.stats();
            let mut extras = String::new();
            if s.redispatches > 0 {
                extras.push_str(&format!(", {} chunks re-dispatched", s.redispatches));
            }
            if s.redials > 0 {
                extras.push_str(&format!(", {} redials", s.redials));
            }
            if s.joins > 0 {
                extras.push_str(&format!(", {} joined mid-solve", s.joins));
            }
            if s.relays > 0 {
                extras.push_str(&format!(", {} relays", s.relays));
            }
            println!(
                "  cluster         : {}/{} workers live, {} rounds, {} B out / {} B in{}",
                s.workers_live, s.workers_total, s.rounds, s.bytes_sent, s.bytes_received, extras
            );
            for ev in &report.membership {
                println!(
                    "  membership      : round {} {} — {}",
                    ev.round,
                    ev.change.label(),
                    ev.detail
                );
            }
        }
    }
    if let Some(dest) = &trace_dest {
        let events = crate::obs::recorder::snapshot();
        std::fs::write(dest, crate::obs::chrome::render(&events))?;
        if !quiet {
            println!("  trace written   : {dest} ({} events)", events.len());
        }
    }
    if let Some(dest) = &json_dest {
        let mut out = vec![
            ("plan".to_string(), plan_json),
            ("report".to_string(), report_to_json(&report)),
        ];
        if let Some(r) = &remote {
            out.push(("cluster".to_string(), crate::metrics::cluster_to_json(&r.stats())));
        }
        emit_json(quiet, dest, JsonValue::Object(out))?;
    }
    Ok(())
}

/// Write JSON to a file, or to stdout when the destination is `-`.
fn emit_json(quiet: bool, dest: &str, value: JsonValue) -> Result<()> {
    if dest == "-" {
        println!("{value}");
    } else {
        std::fs::write(dest, value.to_string())?;
        if !quiet {
            println!("  json written    : {dest}");
        }
    }
    Ok(())
}

/// `bskp lpbound`.
pub fn cmd_lpbound(args: &Args) -> Result<()> {
    let problem = source_from_args(args)?;
    let cluster = cluster_from_args(args)?;
    let tol = args.get("lp-tol", 1e-4f64)?;
    let cuts = args.get("cuts", 200usize)?;
    let bound = lp_upper_bound(problem.as_ref(), &cluster, tol, cuts)?;
    println!("LP upper bound : {:.6}", bound.value);
    println!("lower certificate: {:.6} (gap {:.3e})", bound.lower, bound.gap());
    println!("cuts           : {}", bound.cuts);
    println!("lambda         : {:?}", bound.lambda);
    Ok(())
}

/// `bskp inspect`.
pub fn cmd_inspect(args: &Args) -> Result<()> {
    let problem = source_from_args(args)?;
    let dims = problem.dims();
    problem.validate()?;
    println!("instance: N={} M={} K={}", dims.n_groups, dims.n_items, dims.n_global);
    println!("  class        : {}", if problem.is_dense() { "dense" } else { "sparse" });
    println!("  vars         : {}", dims.n_vars());
    println!("  local caps   : {:?}", problem
        .locals()
        .constraints()
        .iter()
        .map(|c| (c.items.len(), c.cap))
        .collect::<Vec<_>>());
    println!("  max selected : {}", problem.locals().max_selected(dims.n_items));
    println!("  budgets[0..4]: {:?}", &problem.budgets()[..dims.n_global.min(4)]);
    let mut buf = GroupBuf::new(dims, problem.is_dense());
    problem.fill_group(0, &mut buf);
    println!("  group 0 p    : {:?}", &buf.profits[..dims.n_items.min(8)]);
    Ok(())
}
