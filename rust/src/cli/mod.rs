//! Command-line interface (hand-rolled — the offline registry has no clap).
//!
//! ```text
//! bskp gen     --n 10000000 --m 10 --k 10 --out /data/store [...]
//! bskp solve   --n 1000000 --m 10 --k 10 --class sparse --algo scd [...]
//! bskp solve   --from /data/store --checkpoint auto [...]
//! bskp worker  --listen 0.0.0.0:7400 --store /data/store
//! bskp solve   --from /data/store --cluster host1:7400,host2:7400 [...]
//! bskp serve   --listen 0.0.0.0:7500 --store /data/store --admission 2
//! bskp request --to host:7500 --op resolve --budget-scale 1.05 --json -
//! bskp resolve --from /data/store --warm /data/store/lambda.ckpt \
//!              --budget-scale 1.05 [...]
//! bskp solve   --from /data/store --trace trace.json [...]
//! bskp trace   --to host:7500 --out trace.json
//! bskp lpbound --n 10000 --m 10 --k 5 [...]
//! bskp inspect --n 100 --m 10 --k 10 --class dense [...]
//! bskp help
//! ```

mod args;
mod commands;

pub use args::Args;

use crate::error::{Error, Result};

/// Entry point for `main`: parse argv and dispatch. Returns the process
/// exit code.
pub fn run<I: IntoIterator<Item = String>>(argv: I) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(Error::Usage(msg)) => {
            eprintln!("usage error: {msg}\n");
            eprintln!("{}", commands::USAGE);
            2
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn dispatch<I: IntoIterator<Item = String>>(argv: I) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand() {
        "gen" => commands::cmd_gen(&args),
        "solve" => commands::cmd_solve(&args),
        "resolve" => commands::cmd_resolve(&args),
        "worker" => commands::cmd_worker(&args),
        "serve" => commands::cmd_serve(&args),
        "request" => commands::cmd_request(&args),
        "trace" => commands::cmd_trace(&args),
        "lpbound" => commands::cmd_lpbound(&args),
        "inspect" => commands::cmd_inspect(&args),
        "help" | "" => {
            println!("{}", commands::USAGE);
            Ok(())
        }
        other => Err(Error::Usage(format!("unknown subcommand {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn help_runs() {
        assert_eq!(run(argv("bskp help")), 0);
        assert_eq!(run(argv("bskp")), 0);
    }

    #[test]
    fn unknown_subcommand_is_usage_error() {
        assert_eq!(run(argv("bskp frobnicate")), 2);
    }

    #[test]
    fn tiny_solve_roundtrip() {
        assert_eq!(
            run(argv("bskp solve --n 500 --m 6 --k 6 --class sparse --iters 10 --quiet")),
            0
        );
    }

    #[test]
    fn inspect_runs() {
        assert_eq!(run(argv("bskp inspect --n 10 --m 4 --k 4 --class dense")), 0);
    }

    #[test]
    fn bad_flag_value_is_usage_error() {
        assert_eq!(run(argv("bskp solve --n banana")), 2);
    }

    #[test]
    fn gen_requires_out() {
        assert_eq!(run(argv("bskp gen --n 100")), 2);
    }

    #[test]
    fn resolve_requires_warm() {
        assert_eq!(run(argv("bskp resolve --n 100 --m 4 --k 4 --quiet")), 2);
    }

    #[test]
    fn worker_requires_store() {
        assert_eq!(run(argv("bskp worker")), 2);
    }

    #[test]
    fn serve_requires_store() {
        assert_eq!(run(argv("bskp serve")), 2);
    }

    #[test]
    fn request_requires_to() {
        assert_eq!(run(argv("bskp request --op info")), 2);
    }

    #[test]
    fn trace_requires_to() {
        assert_eq!(run(argv("bskp trace")), 2);
    }

    #[test]
    fn solve_with_trace_writes_chrome_json() {
        let path =
            std::env::temp_dir().join(format!("bskp_cli_trace_{}.json", std::process::id()));
        let p = path.display().to_string();
        assert_eq!(
            run(argv(&format!("bskp solve --n 300 --m 4 --k 4 --iters 5 --trace {p} --quiet"))),
            0
        );
        let text = std::fs::read_to_string(&path).unwrap();
        // concurrent unit tests may toggle the global trace gate, so only
        // the container shape is asserted here; ci/obs_smoke.sh validates
        // span content in a process of its own
        assert!(text.starts_with("{\"traceEvents\":["), "not a chrome trace: {text:.40}");
        assert!(text.ends_with("]}\n") || text.ends_with("]}"), "unterminated trace");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn request_rejects_unknown_op() {
        // op validation happens before the dial, so no daemon is needed
        assert_eq!(run(argv("bskp request --to 127.0.0.1:1 --op frob --quiet")), 2);
    }

    #[test]
    fn cluster_on_synthetic_source_falls_back_in_process() {
        // no shard store → the plan notes the fallback and solves locally
        assert_eq!(
            run(argv(
                "bskp solve --n 300 --m 4 --k 4 --iters 5 --cluster 127.0.0.1:9 --quiet"
            )),
            0
        );
    }

    #[test]
    fn plan_only_does_not_solve() {
        assert_eq!(run(argv("bskp solve --n 200 --m 4 --k 4 --plan-only --quiet")), 0);
    }

    #[test]
    fn gen_then_solve_from_store() {
        let dir = std::env::temp_dir().join(format!("bskp_cli_store_{}", std::process::id()));
        let dir_s = dir.display().to_string();
        assert_eq!(
            run(argv(&format!("bskp gen --n 600 --m 6 --k 6 --shard 128 --out {dir_s} --quiet"))),
            0
        );
        assert_eq!(
            run(argv(&format!("bskp solve --from {dir_s} --verify --iters 10 --quiet"))),
            0
        );
        assert_eq!(run(argv(&format!("bskp inspect --from {dir_s}"))), 0);
        // a store that does not exist is a clean error, not a panic
        assert_eq!(run(argv("bskp solve --from /nonexistent_bskp_store --quiet")), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
