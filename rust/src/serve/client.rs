//! Client side of the serve plane: a thin, blocking request/reply
//! wrapper over one connection to a `bskp serve` daemon.
//!
//! One [`ServeClient`] owns one stream; requests are sequential on it
//! (the protocol is strict request → reply). Concurrency is a matter of
//! opening more clients — which is exactly how the admission-control
//! tests provoke a typed `Busy`. An `Abort` reply surfaces as
//! [`crate::error::Error::Runtime`] prefixed with `server:`; a `Busy`
//! reply to a solve is *not* an error — it is the typed
//! [`SolveOutcome::Busy`] variant, so callers can back off and retry.

use crate::cluster::transport::{NetStream, Transport};
use crate::cluster::{InstanceFingerprint, TcpTransport};
use crate::error::{Error, Result};
use crate::serve::protocol::{recv_serve, send_serve, ProgressEvent, ServeMsg, SolveSpec};
use crate::solver::pointquery::GroupAllocation;
use crate::solver::stats::SolveReport;
use std::time::Duration;

/// What the daemon said about itself ([`ServeClient::info`]).
#[derive(Debug, Clone)]
pub struct ServeInfo {
    /// Fingerprint of the hosted instance.
    pub fingerprint: InstanceFingerprint,
    /// The server's current warm λ (empty = no converged solve yet).
    pub warm_lambda: Vec<f64>,
    /// Solves running right now.
    pub active: u32,
    /// The admission bound.
    pub limit: u32,
}

/// A completed served solve.
#[derive(Debug, Clone)]
pub struct ServedSolve {
    /// Whether the server's warm λ seeded it.
    pub warm_used: bool,
    /// The report, bit-identical to a local solve's (history and phase
    /// timings stay server-side).
    pub report: SolveReport,
}

/// Reply to a solve request: done, or typed admission backpressure.
#[derive(Debug, Clone)]
pub enum SolveOutcome {
    /// The solve ran to completion.
    Done(ServedSolve),
    /// Admission control refused it; retry after a running solve ends.
    Busy {
        /// Solves running at refusal time.
        active: u32,
        /// The admission bound.
        limit: u32,
        /// The daemon's retry hint, milliseconds — derived from its
        /// observed per-round solve cadence (`bskp request --wait`
        /// honors it instead of polling blindly).
        retry_after_ms: u64,
    },
}

/// A progress poll's answer ([`ServeClient::progress`]).
#[derive(Debug, Clone)]
pub struct ProgressSnapshot {
    /// Events recorded so far for the tag.
    pub total: u64,
    /// Whether the tagged solve has finished (either way).
    pub done: bool,
    /// The events from the polled offset on.
    pub events: Vec<ProgressEvent>,
}

/// One blocking connection to a serve daemon.
pub struct ServeClient {
    stream: Box<dyn NetStream>,
}

impl ServeClient {
    /// Dial `addr` through `transport` (production: [`TcpTransport`];
    /// tests: the simulator's). `timeout` bounds the dial and every
    /// subsequent read — pass the longest a solve may take, or `None`
    /// reads forever.
    pub fn connect(
        transport: &dyn Transport,
        addr: &str,
        dial_timeout: Duration,
        read_timeout: Option<Duration>,
    ) -> Result<Self> {
        let mut stream = transport.dial(addr, dial_timeout)?;
        stream.set_read_timeout(read_timeout)?;
        Ok(Self { stream })
    }

    /// [`ServeClient::connect`] over production TCP with a 5 s dial bound
    /// and no read bound (solves may run long).
    pub fn connect_tcp(addr: &str) -> Result<Self> {
        Self::connect(&TcpTransport, addr, Duration::from_secs(5), None)
    }

    fn roundtrip(&mut self, req: &ServeMsg) -> Result<ServeMsg> {
        send_serve(&mut self.stream, req)?;
        let (reply, _) = recv_serve(&mut self.stream)?;
        if let ServeMsg::Abort { message } = reply {
            return Err(Error::Runtime(format!("server: {message}")));
        }
        Ok(reply)
    }

    fn unexpected(&self, got: &ServeMsg, wanted: &str) -> Error {
        Error::Runtime(format!(
            "server replied {} where a {wanted} was expected",
            got.name()
        ))
    }

    /// Ask the daemon what it hosts and how busy it is.
    pub fn info(&mut self) -> Result<ServeInfo> {
        match self.roundtrip(&ServeMsg::Info)? {
            ServeMsg::InfoReply { fingerprint, warm_lambda, active, limit } => {
                Ok(ServeInfo { fingerprint, warm_lambda, active, limit })
            }
            other => Err(self.unexpected(&other, "info-reply")),
        }
    }

    /// Run a solve (blocks until the report, a `Busy`, or an error).
    pub fn solve(&mut self, spec: SolveSpec) -> Result<SolveOutcome> {
        match self.roundtrip(&ServeMsg::Solve { spec })? {
            ServeMsg::SolveReply { warm_used, report } => {
                Ok(SolveOutcome::Done(ServedSolve { warm_used, report }))
            }
            ServeMsg::Busy { active, limit, retry_after_ms } => {
                Ok(SolveOutcome::Busy { active, limit, retry_after_ms })
            }
            other => Err(self.unexpected(&other, "solve-reply")),
        }
    }

    /// Batched point query: allocations of `groups` at the server's
    /// current λ. Returns `(λ, allocations)`, in request order.
    pub fn query(&mut self, groups: &[u64]) -> Result<(Vec<f64>, Vec<GroupAllocation>)> {
        match self.roundtrip(&ServeMsg::Query { groups: groups.to_vec() })? {
            ServeMsg::QueryReply { lambda, allocations } => Ok((lambda, allocations)),
            other => Err(self.unexpected(&other, "query-reply")),
        }
    }

    /// Poll progress events of the solve tagged `tag`, starting at event
    /// index `after`.
    pub fn progress(&mut self, tag: u64, after: u64) -> Result<ProgressSnapshot> {
        match self.roundtrip(&ServeMsg::Progress { tag, after })? {
            ServeMsg::ProgressReply { total, done, events } => {
                Ok(ProgressSnapshot { total, done, events })
            }
            other => Err(self.unexpected(&other, "progress-reply")),
        }
    }

    /// Scrape the daemon's metric registry as Prometheus text exposition.
    pub fn scrape(&mut self) -> Result<String> {
        match self.roundtrip(&ServeMsg::Metrics)? {
            ServeMsg::MetricsReply { text } => Ok(text),
            other => Err(self.unexpected(&other, "metrics-reply")),
        }
    }

    /// Snapshot the daemon's span flight recorder as Chrome trace-event
    /// JSON (an empty trace when the daemon runs without `PALLAS_TRACE`).
    pub fn trace_snapshot(&mut self) -> Result<String> {
        match self.roundtrip(&ServeMsg::Trace)? {
            ServeMsg::TraceReply { json } => Ok(json),
            other => Err(self.unexpected(&other, "trace-reply")),
        }
    }
}
