//! `bskp serve` — the long-lived solve-as-a-service daemon.
//!
//! The paper's production loop re-solves the *same* instance daily as
//! budgets drift a few percent (§6: warm-started re-solves converge in a
//! fraction of the cold rounds). This module hosts that loop as a
//! daemon: mmap the shard store **once**, keep the last converged λ per
//! instance fingerprint, and answer these request kinds over the cluster
//! frame layer (kinds 32–45; see [`protocol`] and `docs/serve-api.md`):
//!
//! * **Solve / warm re-solve** — a [`protocol::SolveSpec`] names the
//!   algorithm, a uniform budget scale (served through
//!   [`crate::solve::ScaledBudgets`], which keeps the fingerprint —
//!   budgets are not part of instance identity) and whether to seed from
//!   the server's warm λ ([`crate::solve::WarmStart`]).
//! * **Point queries** — per-group allocations under the current λ, one
//!   greedy pass per group through the PR-4 row kernels
//!   ([`crate::solver::pointquery`]); batched, bounded by
//!   [`protocol::MAX_QUERY_BATCH`].
//! * **Progress streaming** — a client-tagged solve publishes per-round
//!   events into a registry; any connection can poll them while the
//!   solve runs.
//! * **Observability** — `Metrics` scrapes the [`crate::obs`] registry in
//!   Prometheus text; `Trace` snapshots the span flight recorder as
//!   Chrome trace-event JSON (see `docs/observability.md`).
//!
//! **Admission control**: at most `ServeOptions::admission` solves run
//! concurrently; an excess solve gets a typed `Busy` reply immediately —
//! never an unbounded queue, never a dropped connection. Info, queries
//! and progress polls are cheap and always served.
//!
//! The loop is generic over the PR-5 transport seam: production is
//! byte-for-byte [`crate::cluster::TcpTransport`]/`SystemClock`
//! ([`serve`]/[`serve_source`]); the chaos suite drives the *same*
//! session code in-process over [`crate::cluster::SimNet`] with virtual
//! time ([`serve_net`]), which is how drops, corruption, client crashes
//! and stalls are replayed from a seed.

pub mod client;
pub mod protocol;

pub use client::{ProgressSnapshot, ServeClient, ServeInfo, ServedSolve, SolveOutcome};
pub use protocol::{ProgressEvent, SolveSpec, MAX_QUERY_BATCH};

use crate::cluster::transport::{NetListener, NetStream, TcpNetListener};
use crate::cluster::{Backoff, Clock, InstanceFingerprint};
use crate::coordinator::Algorithm;
use crate::error::{Error, Result};
use crate::instance::problem::GroupSource;
use crate::instance::store::MmapProblem;
use crate::mapreduce::Cluster;
use crate::obs::metrics::{Counter, Gauge, Histogram};
use crate::obs::{self, names, Track};
use crate::solve::{
    default_checkpoint_path, ChainObserver, ScaledBudgets, Solve, WarmStart,
    DEFAULT_CHECKPOINT_EVERY,
};
use crate::solver::config::SolverConfig;
use crate::solver::pointquery::allocations_at;
use crate::solver::stats::{ObserverControl, RoundEvent, SolveObserver, SolveReport};
use protocol::{recv_serve, send_serve, ProgressEvent as Ev, ServeMsg, SolveSpec as Spec};
use std::collections::HashMap;
use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Concurrent-solve bound; the `admission + 1`-th concurrent solve
    /// gets a typed `Busy` reply. Clamped to ≥ 1.
    pub admission: usize,
    /// Map-phase thread-pool size; 0 = [`Cluster::configured`] (all
    /// hardware threads unless `PALLAS_WORKERS` says otherwise).
    pub threads: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { admission: 2, threads: 0 }
    }
}

/// Idle bound on one client session: a client that vanished without
/// FIN/RST must not hold a session thread forever. Override with
/// `PALLAS_SERVE_IDLE_TIMEOUT_MS`.
const DEFAULT_IDLE_TIMEOUT_MS: u64 = 600_000;

/// `Busy.retry_after_ms` before the daemon has completed any solve (no
/// cadence observed yet).
const DEFAULT_RETRY_AFTER_MS: u64 = 1_000;

/// Bounds on the cadence-derived retry hint: never tighter than 100 ms
/// (a poll that fast is pure load), never looser than a minute (clients
/// deserve progress even when rounds are glacial).
const RETRY_AFTER_BOUNDS_MS: (u64, u64) = (100, 60_000);

/// The retry hint is this many observed round-times: a freed admission
/// slot is only useful if the running solve actually retired some rounds
/// meanwhile, and hammering every round-time doubles the daemon's frame
/// load for nothing.
const RETRY_AFTER_ROUNDS: u64 = 8;

/// Open the store under `dir` and serve clients on `listener` until the
/// listener fails (TCP never retires cleanly; the simulator does).
pub fn serve(listener: TcpListener, dir: &Path, opts: &ServeOptions) -> Result<()> {
    let problem = MmapProblem::open(dir)?;
    serve_source(listener, &problem, opts)
}

/// [`serve`] over an already-open source — what tests use to host an
/// instance they just wrote (or generated) without a store round-trip.
pub fn serve_source<S: GroupSource>(
    listener: TcpListener,
    source: &S,
    opts: &ServeOptions,
) -> Result<()> {
    serve_net(&TcpNetListener::new(listener), source, opts)
}

/// The transport-generic daemon loop: serve client sessions concurrently
/// (one scoped thread each — concurrency is what admission control
/// bounds, so it must exist) until the listener is retired
/// (`accept_stream() == Ok(None)`). Every session thread is joined
/// before this returns, so a simulator shutdown leaves nothing running.
pub fn serve_net(
    listener: &dyn NetListener,
    source: &dyn GroupSource,
    opts: &ServeOptions,
) -> Result<()> {
    source.validate()?;
    let fingerprint = InstanceFingerprint::of(source);
    let pool =
        if opts.threads == 0 { Cluster::configured() } else { Cluster::new(opts.threads) };
    let clock = listener.clock();
    let state = ServeState::new(opts.admission.max(1));
    std::thread::scope(|scope| {
        let mut backoff =
            Backoff::new(Duration::from_millis(100), Duration::from_secs(5), 0);
        loop {
            match listener.accept_stream() {
                Ok(Some(stream)) => {
                    backoff.reset();
                    // a failed session (client vanished, corrupt frame)
                    // ends that connection, never the daemon
                    let (state, fp, pool) = (&state, &fingerprint, &pool);
                    let clock = Arc::clone(&clock);
                    scope.spawn(move || {
                        let _ = session(stream, source, fp, pool, state, clock);
                    });
                }
                Ok(None) => break,
                Err(_) => {
                    // persistent accept failure must not become a
                    // 100%-CPU spin; back off (capped exponential,
                    // through the clock seam), then retry
                    backoff.wait(clock.as_ref());
                }
            }
        }
    });
    Ok(())
}

/// Progress registry entry for one tagged solve.
#[derive(Default)]
struct ProgressState {
    events: Vec<Ev>,
    done: bool,
}

/// Shared daemon state: the admission counter, the warm-λ store keyed by
/// instance fingerprint, and the progress registry.
struct ServeState {
    limit: usize,
    active: Mutex<usize>,
    /// Tiny association list, not a map: the daemon hosts one store, so
    /// this holds the hosted fingerprint plus its budget-scaled aliases
    /// (which share it — budgets are excluded from identity). Kept in
    /// most-recently-used order and capped at [`ServeState::warm_cap`]
    /// entries (`PALLAS_WARM_CACHE`, default 64): each entry is a full
    /// λ vector, so an adversarial stream of distinct budget aliases
    /// must evict, not grow without bound.
    warm: Mutex<Vec<(InstanceFingerprint, Vec<f64>)>>,
    warm_cap: usize,
    progress: Mutex<HashMap<u64, ProgressState>>,
    /// Mean per-round wall time of the most recent completed solve,
    /// nanoseconds (0 until one completes) — the cadence behind the
    /// `Busy.retry_after_ms` hint.
    round_ns: AtomicU64,
    /// Registry mirror of the admission counter, for scrapes.
    active_gauge: Arc<Gauge>,
    requests: Arc<Counter>,
    busy_total: Arc<Counter>,
    resumes: Arc<Counter>,
    warm_evictions: Arc<Counter>,
    request_ns: Arc<Histogram>,
}

impl ServeState {
    fn new(limit: usize) -> Self {
        let reg = obs::metrics::global();
        Self {
            limit,
            active: Mutex::new(0),
            warm: Mutex::new(Vec::new()),
            warm_cap: crate::cluster::env_count("PALLAS_WARM_CACHE", 64).max(1) as usize,
            progress: Mutex::new(HashMap::new()),
            round_ns: AtomicU64::new(0),
            active_gauge: reg.gauge("bskp_serve_active"),
            requests: reg.counter("bskp_serve_requests_total"),
            busy_total: reg.counter("bskp_serve_busy_total"),
            resumes: reg.counter("bskp_serve_resumes_total"),
            warm_evictions: reg.counter("bskp_serve_warm_evictions_total"),
            request_ns: reg.histogram("bskp_serve_request_ns"),
        }
    }

    /// Record a completed solve's cadence for later `Busy` hints.
    fn note_cadence(&self, solve_ns: u64, rounds: u64) {
        if rounds > 0 {
            self.round_ns.store(solve_ns / rounds, Ordering::Relaxed);
        }
    }

    /// The `Busy.retry_after_ms` hint: [`RETRY_AFTER_ROUNDS`] observed
    /// round-times, clamped to [`RETRY_AFTER_BOUNDS_MS`];
    /// [`DEFAULT_RETRY_AFTER_MS`] before any solve has completed.
    fn retry_after_ms(&self) -> u64 {
        match self.round_ns.load(Ordering::Relaxed) {
            0 => DEFAULT_RETRY_AFTER_MS,
            per_round => ((per_round / 1_000_000) * RETRY_AFTER_ROUNDS)
                .clamp(RETRY_AFTER_BOUNDS_MS.0, RETRY_AFTER_BOUNDS_MS.1),
        }
    }

    /// Admit a solve, or report the live count for a `Busy` reply.
    fn try_admit(&self) -> std::result::Result<AdmitGuard<'_>, usize> {
        let mut a = self.active.lock().unwrap();
        if *a < self.limit {
            *a += 1;
            self.active_gauge.set(*a as i64);
            Ok(AdmitGuard { state: self })
        } else {
            Err(*a)
        }
    }

    fn active(&self) -> usize {
        *self.active.lock().unwrap()
    }

    /// A hit is also a *use*: the entry moves to the front so the cap
    /// evicts the coldest fingerprint, not the oldest-inserted one.
    fn warm_for(&self, fp: &InstanceFingerprint) -> Option<Vec<f64>> {
        let mut w = self.warm.lock().unwrap();
        let i = w.iter().position(|(f, _)| f == fp)?;
        let hit = w.remove(i);
        let lambda = hit.1.clone();
        w.insert(0, hit);
        Some(lambda)
    }

    fn store_warm(&self, fp: &InstanceFingerprint, lambda: Vec<f64>) {
        let mut w = self.warm.lock().unwrap();
        if let Some(i) = w.iter().position(|(f, _)| f == fp) {
            w.remove(i);
        }
        w.insert(0, (fp.clone(), lambda));
        while w.len() > self.warm_cap {
            w.pop();
            self.warm_evictions.inc();
        }
    }

    fn mark_done(&self, tag: u64) {
        if tag != 0 {
            if let Some(p) = self.progress.lock().unwrap().get_mut(&tag) {
                p.done = true;
            }
        }
    }
}

/// RAII admission slot: released even when a solve errors or panics.
struct AdmitGuard<'a> {
    state: &'a ServeState,
}

impl Drop for AdmitGuard<'_> {
    fn drop(&mut self) {
        let mut a = self.state.active.lock().unwrap();
        *a -= 1;
        self.state.active_gauge.set(*a as i64);
    }
}

/// Feeds a tagged solve's rounds into the progress registry.
struct RegistryObserver<'a> {
    state: &'a ServeState,
    tag: u64,
}

impl SolveObserver for RegistryObserver<'_> {
    fn on_round(&mut self, event: &RoundEvent<'_>) -> ObserverControl {
        if self.tag != 0 {
            if let Some(p) = self.state.progress.lock().unwrap().get_mut(&self.tag) {
                p.events.push(Ev {
                    iter: event.iter as u64,
                    primal: event.primal,
                    dual: event.dual,
                    max_violation_ratio: event.max_violation_ratio,
                    lambda_change: event.lambda_change,
                });
            }
        }
        ObserverControl::Continue
    }

    fn on_complete(&mut self, _report: &SolveReport) {
        self.state.mark_done(self.tag);
    }
}

/// One client session: loop over request frames until the client hangs
/// up, the idle bound fires, or a write fails. Unlike a worker session,
/// an `Abort` reply is a *per-request* error — the session stays open so
/// the client can correct and retry (e.g. query again after a solve).
fn session(
    mut stream: Box<dyn NetStream>,
    source: &dyn GroupSource,
    fp: &InstanceFingerprint,
    pool: &Cluster,
    state: &ServeState,
    clock: Arc<dyn Clock>,
) -> Result<()> {
    let idle = crate::cluster::env_ms("PALLAS_SERVE_IDLE_TIMEOUT_MS", DEFAULT_IDLE_TIMEOUT_MS);
    stream.set_read_timeout(Some(idle))?;
    loop {
        // a dead/corrupt/idle client ends the session, never the daemon
        let msg = match recv_serve(&mut stream) {
            Ok((msg, _)) => msg,
            Err(_) => return Ok(()),
        };
        let req_kind = msg.kind();
        let t0 = clock.now_ns();
        let reply = match msg {
            ServeMsg::Info => ServeMsg::InfoReply {
                fingerprint: fp.clone(),
                warm_lambda: state.warm_for(fp).unwrap_or_default(),
                active: state.active() as u32,
                limit: state.limit as u32,
            },
            ServeMsg::Solve { spec } => handle_solve(&spec, source, fp, pool, state, &clock),
            ServeMsg::Query { groups } => handle_query(&groups, source, fp, state),
            ServeMsg::Progress { tag, after } => handle_progress(tag, after, state),
            ServeMsg::Metrics => ServeMsg::MetricsReply { text: obs::prom::render() },
            ServeMsg::Trace => {
                ServeMsg::TraceReply { json: obs::chrome::render(&obs::recorder::snapshot()) }
            }
            other => ServeMsg::Abort {
                message: format!("unexpected {} frame from a client", other.name()),
            },
        };
        let dur_ns = clock.now_ns().saturating_sub(t0);
        if obs::metrics_enabled() {
            state.requests.inc();
            state.request_ns.observe(dur_ns);
            if matches!(reply, ServeMsg::Busy { .. }) {
                state.busy_total.inc();
            }
        }
        obs::complete(Track::Serve, names::SERVE_REQUEST, t0, dur_ns, req_kind as u64, 0);
        send_serve(&mut stream, &reply)?;
    }
}

fn handle_solve(
    spec: &Spec,
    source: &dyn GroupSource,
    fp: &InstanceFingerprint,
    pool: &Cluster,
    state: &ServeState,
    clock: &Arc<dyn Clock>,
) -> ServeMsg {
    let _guard = match state.try_admit() {
        Ok(g) => g,
        Err(active) => {
            return ServeMsg::Busy {
                active: active as u32,
                limit: state.limit as u32,
                retry_after_ms: state.retry_after_ms(),
            }
        }
    };
    // the tag goes live before any solve work so a concurrent poller can
    // observe admission deterministically
    if spec.tag != 0 {
        state.progress.lock().unwrap().insert(spec.tag, ProgressState::default());
    }
    let t0 = clock.now_ns();
    let out = run_solve(spec, source, fp, pool, state, clock);
    state.mark_done(spec.tag);
    let dur_ns = clock.now_ns().saturating_sub(t0);
    obs::complete(Track::Serve, names::SERVE_SOLVE, t0, dur_ns, spec.tag, 0);
    match out {
        Ok((warm_used, report)) => {
            state.note_cadence(dur_ns, report.iterations as u64);
            ServeMsg::SolveReply { warm_used, report }
        }
        Err(e) => ServeMsg::Abort { message: e.to_string() },
    }
}

fn run_solve(
    spec: &Spec,
    source: &dyn GroupSource,
    fp: &InstanceFingerprint,
    pool: &Cluster,
    state: &ServeState,
    clock: &Arc<dyn Clock>,
) -> Result<(bool, SolveReport)> {
    let algorithm = match spec.algorithm {
        0 => Algorithm::Scd,
        1 => Algorithm::Dd,
        a => {
            return Err(Error::InvalidConfig(format!(
                "solve spec algorithm {a} (0 = scd, 1 = dd)"
            )))
        }
    };
    let config = SolverConfig {
        max_iters: spec.max_iters as usize,
        tol: spec.tol,
        dd_alpha: spec.dd_alpha,
        shard_size: (spec.shard_size != 0).then_some(spec.shard_size as usize),
        track_history: false,
        ..Default::default()
    };
    // a budget-scaled view keeps the fingerprint (budgets are excluded
    // from identity), so its warm λ and the store's are the same slot
    let scaled;
    let src: &dyn GroupSource = if spec.budget_scale != 1.0 {
        scaled = ScaledBudgets::uniform(source, spec.budget_scale)?;
        &scaled
    } else {
        source
    };
    let warm = if spec.warm { state.warm_for(fp) } else { None };
    let warm_used = warm.is_some();
    let warm_start =
        warm.map(|lambda| WarmStart { lambda, provenance: "server warm λ".into() });

    let mut last = LastLambda::default();
    let first = attempt_solve(spec, src, algorithm, &config, pool, state, clock, warm_start, &mut last);
    let report = match first {
        Ok(r) => r,
        // a runtime / I/O fault mid-solve (lost fleet, vanished
        // artifacts, disk hiccup) is worth exactly one automatic resume:
        // re-run the session warm from the freshest λ recoverable — the
        // store's checkpoint when there is one, else the last in-memory
        // round λ the observer saw. Config and data errors re-fail
        // identically, so they are not retried.
        Err(e @ (Error::Runtime(_) | Error::Io(_))) => {
            let Some(recovered) = recover_warm(src, &last) else { return Err(e) };
            if obs::metrics_enabled() {
                state.resumes.inc();
            }
            let mut resumed = LastLambda::default();
            attempt_solve(
                spec,
                src,
                algorithm,
                &config,
                pool,
                state,
                clock,
                Some(recovered),
                &mut resumed,
            )?
        }
        Err(e) => return Err(e),
    };
    // only a *converged* λ becomes the warm seed — a cancelled or
    // iteration-capped λ would poison every later warm re-solve
    if report.converged {
        state.store_warm(fp, report.lambda.clone());
    }
    Ok((warm_used, report))
}

/// One solve attempt: a store-backed instance checkpoints λ as it goes
/// (so an interrupted attempt resumes from disk, not round zero), and
/// `last` shadows every round's λ in memory for sources with no store.
#[allow(clippy::too_many_arguments)]
fn attempt_solve(
    spec: &Spec,
    src: &dyn GroupSource,
    algorithm: Algorithm,
    config: &SolverConfig,
    pool: &Cluster,
    state: &ServeState,
    clock: &Arc<dyn Clock>,
    warm: Option<WarmStart>,
    last: &mut LastLambda,
) -> Result<SolveReport> {
    let mut session = Solve::on(src)
        .cluster(pool.clone())
        .config(config.clone())
        .algorithm(algorithm)
        .clock(Arc::clone(clock));
    if src.store_dir().is_some() {
        session = session.checkpoint_auto(DEFAULT_CHECKPOINT_EVERY);
    }
    if let Some(w) = warm {
        session = session.warm(w);
    }
    let mut registry = RegistryObserver { state, tag: spec.tag };
    let mut chain = ChainObserver::new();
    chain.push(last);
    chain.push(&mut registry);
    session.run_observed(&mut chain)
}

/// Captures the most recent round's λ of a running solve, so an attempt
/// that dies mid-flight can be resumed warm even when the instance has
/// no on-disk checkpoint home.
#[derive(Default)]
struct LastLambda {
    lambda: Vec<f64>,
    rounds: u64,
}

impl SolveObserver for LastLambda {
    fn on_round(&mut self, event: &RoundEvent<'_>) -> ObserverControl {
        self.lambda = event.lambda.to_vec();
        self.rounds = event.iter as u64 + 1;
        ObserverControl::Continue
    }
}

/// The freshest λ recoverable after a failed attempt: the store's
/// checkpoint file when one exists (written by the attempt itself or a
/// predecessor), else the last in-memory round λ. `None` — no resume —
/// when the attempt died before its first round with nothing on disk.
fn recover_warm(src: &dyn GroupSource, last: &LastLambda) -> Option<WarmStart> {
    if let Some(dir) = src.store_dir() {
        if let Ok(w) = WarmStart::from_checkpoint(default_checkpoint_path(&dir)) {
            return Some(w);
        }
    }
    (!last.lambda.is_empty()).then(|| WarmStart {
        lambda: last.lambda.clone(),
        provenance: format!("auto-resume after {} in-memory rounds", last.rounds),
    })
}

fn handle_query(
    groups: &[u64],
    source: &dyn GroupSource,
    fp: &InstanceFingerprint,
    state: &ServeState,
) -> ServeMsg {
    if groups.len() > protocol::MAX_QUERY_BATCH {
        return ServeMsg::Abort {
            message: format!(
                "point-query batch of {} groups exceeds the {} cap — split the batch",
                groups.len(),
                protocol::MAX_QUERY_BATCH
            ),
        };
    }
    let Some(lambda) = state.warm_for(fp) else {
        return ServeMsg::Abort {
            message: "no converged λ yet — run a solve before point queries".into(),
        };
    };
    match allocations_at(source, &lambda, groups) {
        Ok(allocations) => ServeMsg::QueryReply { lambda, allocations },
        Err(e) => ServeMsg::Abort { message: e.to_string() },
    }
}

fn handle_progress(tag: u64, after: u64, state: &ServeState) -> ServeMsg {
    let reg = state.progress.lock().unwrap();
    match reg.get(&tag) {
        Some(p) => {
            let after = (after as usize).min(p.events.len());
            ServeMsg::ProgressReply {
                total: p.events.len() as u64,
                done: p.done,
                events: p.events[after..].to_vec(),
            }
        }
        // a tag the daemon has not seen yet: empty, not-done — pollers
        // racing the solve's admission just poll again
        None => ServeMsg::ProgressReply { total: 0, done: false, events: Vec::new() },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};

    #[test]
    fn retry_hint_defaults_then_follows_cadence_within_bounds() {
        let state = ServeState::new(2);
        assert_eq!(state.retry_after_ms(), DEFAULT_RETRY_AFTER_MS, "no cadence observed yet");

        // 5 ms rounds: 8 round-times = 40 ms, clamped up to the 100 ms floor
        state.note_cadence(50_000_000, 10);
        assert_eq!(state.retry_after_ms(), RETRY_AFTER_BOUNDS_MS.0);

        // 40 ms rounds: 8 round-times = 320 ms, inside the bounds
        state.note_cadence(400_000_000, 10);
        assert_eq!(state.retry_after_ms(), 320);

        // glacial 60 s rounds: clamped down to the minute ceiling
        state.note_cadence(600_000_000_000, 10);
        assert_eq!(state.retry_after_ms(), RETRY_AFTER_BOUNDS_MS.1);

        // a zero-round solve must not divide by zero or clobber the cadence
        state.note_cadence(1_000_000, 0);
        assert_eq!(state.retry_after_ms(), RETRY_AFTER_BOUNDS_MS.1);
    }

    #[test]
    fn warm_cache_is_a_capped_lru_and_counts_evictions() {
        let mut state = ServeState::new(2);
        state.warm_cap = 3;
        let fp = |seed: u64| InstanceFingerprint {
            n_groups: seed,
            n_items: 1,
            n_global: 1,
            dense: false,
            locals_hash: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            sample_hash: !seed,
        };
        let before = state.warm_evictions.get();

        for s in 0..3 {
            state.store_warm(&fp(s), vec![s as f64]);
        }
        assert_eq!(state.warm.lock().unwrap().len(), 3);
        assert_eq!(state.warm_evictions.get(), before, "no eviction below the cap");

        // touch the oldest entry so it becomes the most recent...
        assert_eq!(state.warm_for(&fp(0)), Some(vec![0.0]), "hit must return the stored λ");
        // ...then overflow: the cap must evict the coldest (1), not the
        // oldest-inserted (0)
        state.store_warm(&fp(3), vec![3.0]);
        assert_eq!(state.warm.lock().unwrap().len(), 3, "cap must hold");
        assert_eq!(state.warm_evictions.get(), before + 1, "the eviction must be counted");
        assert_eq!(state.warm_for(&fp(1)), None, "the coldest entry must be gone");
        assert_eq!(state.warm_for(&fp(0)), Some(vec![0.0]), "the touched entry must survive");
        assert_eq!(state.warm_for(&fp(3)), Some(vec![3.0]));

        // re-storing an existing fingerprint updates in place: no
        // growth, no eviction
        state.store_warm(&fp(0), vec![0.5]);
        assert_eq!(state.warm.lock().unwrap().len(), 3);
        assert_eq!(state.warm_evictions.get(), before + 1);
        assert_eq!(state.warm_for(&fp(0)), Some(vec![0.5]));
    }

    #[test]
    fn recover_warm_falls_back_from_checkpoint_to_memory_to_none() {
        let src = SyntheticProblem::new(GeneratorConfig::dense(50, 3, 3).with_seed(5));

        // nothing on disk (synthetic sources have no store), nothing in
        // memory: the attempt died before round one — no resume
        assert!(recover_warm(&src, &LastLambda::default()).is_none());

        // with in-memory rounds the last λ seeds the retry
        let last = LastLambda { lambda: vec![0.5, 0.25, 0.125], rounds: 7 };
        let w = recover_warm(&src, &last).expect("in-memory λ must recover");
        assert_eq!(w.lambda, last.lambda);
        assert!(
            w.provenance.contains("7 in-memory rounds"),
            "provenance must say where the λ came from: {}",
            w.provenance
        );
    }
}
