//! Message vocabulary of the serve plane (`bskp serve`).
//!
//! Fourteen message kinds ride the same frame layer as the worker
//! protocol ([`crate::cluster`]'s frames: magic, version, kind, length,
//! payload, kind-seeded XXH64 trailer) under kinds 32–45
//! (`frames::serve_kind`) — disjoint from the worker plane's 1–10, and
//! since the kind seeds the checksum, a frame replayed across planes
//! fails verification outright. `docs/serve-api.md` is the normative
//! spec; `docs/cluster-protocol.md` §serve cross-references it.
//!
//! Requests are *self-contained* (a [`SolveSpec`] carries every solver
//! parameter the server honors) and every request gets exactly one reply
//! frame: the matching `*Reply`, `Busy` (typed admission backpressure on
//! solves), or `Abort` (typed failure). Floats travel as raw IEEE-754
//! bits, so a served [`SolveReport`] is bit-identical to the one a local
//! solve returns — the differential tests assert exactly that.

use crate::cluster::frames::{self, serve_kind as k};
use crate::cluster::wire::{corrupt, Dec, Enc};
use crate::cluster::InstanceFingerprint;
use crate::error::Result;
use crate::solver::pointquery::GroupAllocation;
use crate::solver::stats::SolveReport;
use std::io::{Read, Write};

/// Largest point-query batch one `Query` frame may carry. Far above any
/// sensible interactive batch; bounds the per-request allocation the same
/// way the frame cap bounds payload bytes.
pub const MAX_QUERY_BATCH: usize = 4096;

/// Everything the server honors about one solve request. Budgets scale
/// against the hosted store ([`crate::solve::ScaledBudgets`]); `warm`
/// asks for the server's last converged λ as the starting point
/// ([`crate::solve::WarmStart`]) — silently a cold start when the server
/// has none yet (the reply says which happened).
#[derive(Debug, Clone, PartialEq)]
pub struct SolveSpec {
    /// Client-chosen progress tag: `Progress { tag }` polls this solve's
    /// per-round events while it runs. 0 = no progress wanted.
    pub tag: u64,
    /// 0 = SCD (Algorithm 4, the default), 1 = DD (Algorithm 2).
    pub algorithm: u8,
    /// Uniform budget scale (1.0 = the store's budgets as written).
    pub budget_scale: f64,
    /// Reuse the server's warm λ for this fingerprint, if any.
    pub warm: bool,
    /// `SolverConfig::max_iters`.
    pub max_iters: u64,
    /// `SolverConfig::tol`.
    pub tol: f64,
    /// `SolverConfig::dd_alpha` (DD only).
    pub dd_alpha: f64,
    /// `SolverConfig::shard_size` override; 0 = the planner's choice.
    pub shard_size: u64,
}

impl Default for SolveSpec {
    fn default() -> Self {
        let cfg = crate::solver::config::SolverConfig::default();
        Self {
            tag: 0,
            algorithm: 0,
            budget_scale: 1.0,
            warm: true,
            max_iters: cfg.max_iters as u64,
            tol: cfg.tol,
            dd_alpha: cfg.dd_alpha,
            shard_size: 0,
        }
    }
}

impl SolveSpec {
    fn encode(&self, e: &mut Enc) {
        e.u64(self.tag)
            .u8(self.algorithm)
            .f64(self.budget_scale)
            .u8(self.warm as u8)
            .u64(self.max_iters)
            .f64(self.tol)
            .f64(self.dd_alpha)
            .u64(self.shard_size);
    }

    fn decode(d: &mut Dec<'_>) -> Result<Self> {
        Ok(Self {
            tag: d.u64()?,
            algorithm: d.u8()?,
            budget_scale: d.f64()?,
            warm: d.u8()? != 0,
            max_iters: d.u64()?,
            tol: d.f64()?,
            dd_alpha: d.f64()?,
            shard_size: d.u64()?,
        })
    }
}

/// One per-round progress sample, as streamed to `Progress` pollers.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgressEvent {
    /// Iteration index (0-based).
    pub iter: u64,
    /// Primal objective at the round's starting λ.
    pub primal: f64,
    /// Dual objective at the round's starting λ.
    pub dual: f64,
    /// Max violation ratio at the round's starting λ.
    pub max_violation_ratio: f64,
    /// Convergence residual of the round's λ update.
    pub lambda_change: f64,
}

impl ProgressEvent {
    fn encode(&self, e: &mut Enc) {
        e.u64(self.iter)
            .f64(self.primal)
            .f64(self.dual)
            .f64(self.max_violation_ratio)
            .f64(self.lambda_change);
    }

    fn decode(d: &mut Dec<'_>) -> Result<Self> {
        Ok(Self {
            iter: d.u64()?,
            primal: d.f64()?,
            dual: d.f64()?,
            max_violation_ratio: d.f64()?,
            lambda_change: d.f64()?,
        })
    }
}

fn encode_report(r: &SolveReport, e: &mut Enc) {
    e.f64s(&r.lambda);
    e.u64(r.iterations as u64).u8(r.converged as u8);
    e.f64(r.primal_value).f64(r.dual_value);
    e.f64s(&r.consumption).f64s(&r.budgets);
    e.u64(r.n_selected).u64(r.dropped_groups).f64(r.wall_ms);
}

/// History and the phase breakdown stay server-side: they are observer /
/// diagnostics surface, not part of the solution contract the
/// determinism tests compare.
fn decode_report(d: &mut Dec<'_>) -> Result<SolveReport> {
    Ok(SolveReport {
        lambda: d.f64s()?,
        iterations: d.u64()? as usize,
        converged: d.u8()? != 0,
        primal_value: d.f64()?,
        dual_value: d.f64()?,
        consumption: d.f64s()?,
        budgets: d.f64s()?,
        n_selected: d.u64()?,
        dropped_groups: d.u64()?,
        wall_ms: d.f64()?,
        history: Vec::new(),
        phases: Default::default(),
        membership: Vec::new(),
    })
}

fn encode_alloc(a: &GroupAllocation, e: &mut Enc) {
    e.u64(a.group);
    e.u64(a.x.len() as u64);
    for &x in &a.x {
        e.u8(x);
    }
    e.f64(a.primal).f64(a.dual_inner).f64s(&a.consumption);
}

fn decode_alloc(d: &mut Dec<'_>) -> Result<GroupAllocation> {
    let group = d.u64()?;
    let m = d.len()?;
    let x = (0..m).map(|_| d.u8()).collect::<Result<Vec<u8>>>()?;
    Ok(GroupAllocation {
        group,
        x,
        primal: d.f64()?,
        dual_inner: d.f64()?,
        consumption: d.f64s()?,
    })
}

/// A serve-plane message (request or reply). See the module docs for the
/// one-reply-per-request discipline.
#[derive(Debug, Clone)]
pub(crate) enum ServeMsg {
    /// What instance does this daemon host, and in what state?
    Info,
    /// The hosted instance plus serving state.
    InfoReply {
        fingerprint: InstanceFingerprint,
        /// The server's current warm λ for the hosted fingerprint
        /// (empty = no converged solve yet).
        warm_lambda: Vec<f64>,
        /// Admission: solves currently running / the concurrent bound.
        active: u32,
        limit: u32,
    },
    /// Run a solve (cold, warm, budget-scaled — see [`SolveSpec`]).
    Solve { spec: SolveSpec },
    /// The finished solve.
    SolveReply {
        /// Whether the server's warm λ actually seeded this solve.
        warm_used: bool,
        report: SolveReport,
    },
    /// Batched point query: allocations of these groups at the current λ.
    Query { groups: Vec<u64> },
    /// The λ the query was answered at, plus one allocation per queried
    /// group (in request order).
    QueryReply { lambda: Vec<f64>, allocations: Vec<GroupAllocation> },
    /// Poll progress events of the solve tagged `tag`, starting at event
    /// index `after`.
    Progress { tag: u64, after: u64 },
    /// Snapshot: total events so far, whether the solve finished, and the
    /// events from `after` on.
    ProgressReply { total: u64, done: bool, events: Vec<ProgressEvent> },
    /// Admission control refused the solve; retry after a running solve
    /// finishes. `retry_after_ms` is the daemon's hint for when that is
    /// worth trying, derived from the observed per-round cadence of its
    /// recent solves (a fixed default when it has not completed one yet).
    Busy { active: u32, limit: u32, retry_after_ms: u64 },
    /// Typed request failure.
    Abort { message: String },
    /// Scrape the daemon's metric registry ([`crate::obs::metrics`]).
    Metrics,
    /// Prometheus text exposition of every registered metric.
    MetricsReply { text: String },
    /// Snapshot the daemon's span flight recorder.
    Trace,
    /// Chrome trace-event JSON of the recorder snapshot (empty array
    /// when tracing is off — the daemon decides via `PALLAS_TRACE`).
    TraceReply { json: String },
}

impl ServeMsg {
    pub(crate) fn kind(&self) -> u16 {
        match self {
            ServeMsg::Info => k::INFO,
            ServeMsg::InfoReply { .. } => k::INFO_REPLY,
            ServeMsg::Solve { .. } => k::SOLVE,
            ServeMsg::SolveReply { .. } => k::SOLVE_REPLY,
            ServeMsg::Query { .. } => k::QUERY,
            ServeMsg::QueryReply { .. } => k::QUERY_REPLY,
            ServeMsg::Progress { .. } => k::PROGRESS,
            ServeMsg::ProgressReply { .. } => k::PROGRESS_REPLY,
            ServeMsg::Busy { .. } => k::BUSY,
            ServeMsg::Abort { .. } => k::ABORT,
            ServeMsg::Metrics => k::METRICS,
            ServeMsg::MetricsReply { .. } => k::METRICS_REPLY,
            ServeMsg::Trace => k::TRACE,
            ServeMsg::TraceReply { .. } => k::TRACE_REPLY,
        }
    }

    /// Human name, for diagnostics.
    pub(crate) fn name(&self) -> &'static str {
        match self {
            ServeMsg::Info => "info",
            ServeMsg::InfoReply { .. } => "info-reply",
            ServeMsg::Solve { .. } => "solve",
            ServeMsg::SolveReply { .. } => "solve-reply",
            ServeMsg::Query { .. } => "query",
            ServeMsg::QueryReply { .. } => "query-reply",
            ServeMsg::Progress { .. } => "progress",
            ServeMsg::ProgressReply { .. } => "progress-reply",
            ServeMsg::Busy { .. } => "busy",
            ServeMsg::Abort { .. } => "abort",
            ServeMsg::Metrics => "metrics",
            ServeMsg::MetricsReply { .. } => "metrics-reply",
            ServeMsg::Trace => "trace",
            ServeMsg::TraceReply { .. } => "trace-reply",
        }
    }

    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            ServeMsg::Info => {}
            ServeMsg::InfoReply { fingerprint, warm_lambda, active, limit } => {
                fingerprint.encode(&mut e);
                e.f64s(warm_lambda).u32(*active).u32(*limit);
            }
            ServeMsg::Solve { spec } => spec.encode(&mut e),
            ServeMsg::SolveReply { warm_used, report } => {
                e.u8(*warm_used as u8);
                encode_report(report, &mut e);
            }
            ServeMsg::Query { groups } => {
                e.u64(groups.len() as u64);
                for &g in groups {
                    e.u64(g);
                }
            }
            ServeMsg::QueryReply { lambda, allocations } => {
                e.f64s(lambda);
                e.u64(allocations.len() as u64);
                for a in allocations {
                    encode_alloc(a, &mut e);
                }
            }
            ServeMsg::Progress { tag, after } => {
                e.u64(*tag).u64(*after);
            }
            ServeMsg::ProgressReply { total, done, events } => {
                e.u64(*total).u8(*done as u8);
                e.u64(events.len() as u64);
                for ev in events {
                    ev.encode(&mut e);
                }
            }
            ServeMsg::Busy { active, limit, retry_after_ms } => {
                e.u32(*active).u32(*limit).u64(*retry_after_ms);
            }
            ServeMsg::Abort { message } => {
                e.str(message);
            }
            ServeMsg::Metrics | ServeMsg::Trace => {}
            ServeMsg::MetricsReply { text } => {
                e.str(text);
            }
            ServeMsg::TraceReply { json } => {
                e.str(json);
            }
        }
        e.into_bytes()
    }

    pub(crate) fn decode(kind: u16, payload: &[u8]) -> Result<ServeMsg> {
        let mut d = Dec::new(payload);
        let msg = match kind {
            k::INFO => ServeMsg::Info,
            k::INFO_REPLY => ServeMsg::InfoReply {
                fingerprint: InstanceFingerprint::decode(&mut d)?,
                warm_lambda: d.f64s()?,
                active: d.u32()?,
                limit: d.u32()?,
            },
            k::SOLVE => ServeMsg::Solve { spec: SolveSpec::decode(&mut d)? },
            k::SOLVE_REPLY => ServeMsg::SolveReply {
                warm_used: d.u8()? != 0,
                report: decode_report(&mut d)?,
            },
            k::QUERY => {
                let n = d.len_of(8)?;
                let groups = (0..n).map(|_| d.u64()).collect::<Result<Vec<u64>>>()?;
                ServeMsg::Query { groups }
            }
            k::QUERY_REPLY => {
                let lambda = d.f64s()?;
                let n = d.len()?;
                let allocations =
                    (0..n).map(|_| decode_alloc(&mut d)).collect::<Result<Vec<_>>>()?;
                ServeMsg::QueryReply { lambda, allocations }
            }
            k::PROGRESS => ServeMsg::Progress { tag: d.u64()?, after: d.u64()? },
            k::PROGRESS_REPLY => {
                let total = d.u64()?;
                let done = d.u8()? != 0;
                let n = d.len()?;
                let events =
                    (0..n).map(|_| ProgressEvent::decode(&mut d)).collect::<Result<Vec<_>>>()?;
                ServeMsg::ProgressReply { total, done, events }
            }
            k::BUSY => ServeMsg::Busy {
                active: d.u32()?,
                limit: d.u32()?,
                retry_after_ms: d.u64()?,
            },
            k::ABORT => ServeMsg::Abort { message: d.str()? },
            k::METRICS => ServeMsg::Metrics,
            k::METRICS_REPLY => ServeMsg::MetricsReply { text: d.str()? },
            k::TRACE => ServeMsg::Trace,
            k::TRACE_REPLY => ServeMsg::TraceReply { json: d.str()? },
            other => return Err(corrupt(&format!("unknown serve message kind {other}"))),
        };
        d.finish()?;
        Ok(msg)
    }
}

/// Send one serve message as a frame; returns the bytes written.
pub(crate) fn send_serve<W: Write>(w: &mut W, msg: &ServeMsg) -> Result<usize> {
    frames::write_frame(w, msg.kind(), &msg.encode())
}

/// Receive one serve message; returns it with the bytes read.
pub(crate) fn recv_serve<R: Read>(r: &mut R) -> Result<(ServeMsg, usize)> {
    let (kind, payload, n) = frames::read_frame(r)?;
    Ok((ServeMsg::decode(kind, &payload)?, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};

    fn roundtrip(msg: &ServeMsg) -> ServeMsg {
        let mut buf = Vec::new();
        send_serve(&mut buf, msg).unwrap();
        let (got, n) = recv_serve(&mut buf.as_slice()).unwrap();
        assert_eq!(n, buf.len());
        got
    }

    #[test]
    fn all_kinds_roundtrip() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(20, 4, 4).with_seed(1));
        let fp = InstanceFingerprint::of(&p);
        let report = SolveReport {
            lambda: vec![0.5, -0.0],
            iterations: 7,
            converged: true,
            primal_value: 10.0,
            dual_value: 11.0,
            consumption: vec![5.0, f64::NEG_INFINITY],
            budgets: vec![6.0, 1.0],
            n_selected: 3,
            dropped_groups: 1,
            history: Vec::new(),
            wall_ms: 1.25,
            phases: Default::default(),
            membership: Vec::new(),
        };
        let alloc = GroupAllocation {
            group: 9,
            x: vec![1, 0, 1],
            primal: 2.5,
            dual_inner: 2.0,
            consumption: vec![0.5, 0.25],
        };
        let msgs = [
            ServeMsg::Info,
            ServeMsg::InfoReply {
                fingerprint: fp,
                warm_lambda: vec![0.1, 0.2],
                active: 1,
                limit: 2,
            },
            ServeMsg::Solve { spec: SolveSpec { tag: 42, warm: false, ..Default::default() } },
            ServeMsg::SolveReply { warm_used: true, report },
            ServeMsg::Query { groups: vec![0, 9, 3] },
            ServeMsg::QueryReply { lambda: vec![0.5, 0.5], allocations: vec![alloc] },
            ServeMsg::Progress { tag: 42, after: 3 },
            ServeMsg::ProgressReply {
                total: 5,
                done: false,
                events: vec![ProgressEvent {
                    iter: 4,
                    primal: 1.0,
                    dual: 2.0,
                    max_violation_ratio: 0.1,
                    lambda_change: 1e-3,
                }],
            },
            ServeMsg::Busy { active: 2, limit: 2, retry_after_ms: 1_500 },
            ServeMsg::Abort { message: "nope".into() },
            ServeMsg::Metrics,
            ServeMsg::MetricsReply { text: "# TYPE bskp_x counter\nbskp_x 1\n".into() },
            ServeMsg::Trace,
            ServeMsg::TraceReply { json: "{\"traceEvents\":[]}".into() },
        ];
        for m in &msgs {
            let got = roundtrip(m);
            assert_eq!(got.kind(), m.kind(), "{}", m.name());
            // re-encoding the decoded message must reproduce the original
            // payload byte-for-byte (fields compared through the codec)
            assert_eq!(got.encode(), m.encode(), "{}", m.name());
        }
    }

    #[test]
    fn report_floats_survive_bit_exact() {
        let report = SolveReport {
            lambda: vec![f64::from_bits(0x7FF0_0000_0000_0001)], // a NaN payload
            iterations: 1,
            converged: false,
            primal_value: -0.0,
            dual_value: 1e-308,
            consumption: vec![],
            budgets: vec![],
            n_selected: 0,
            dropped_groups: 0,
            history: Vec::new(),
            wall_ms: 0.0,
            phases: Default::default(),
            membership: Vec::new(),
        };
        let m = ServeMsg::SolveReply { warm_used: false, report };
        let got = roundtrip(&m);
        let (ServeMsg::SolveReply { report: a, .. }, ServeMsg::SolveReply { report: b, .. }) =
            (&m, &got)
        else {
            panic!("kind changed in roundtrip")
        };
        assert_eq!(a.lambda[0].to_bits(), b.lambda[0].to_bits());
        assert_eq!(a.primal_value.to_bits(), b.primal_value.to_bits());
        assert_eq!(a.dual_value.to_bits(), b.dual_value.to_bits());
    }

    #[test]
    fn worker_plane_frame_is_rejected_by_checksum() {
        // a serve-kind frame re-tagged as a worker kind must fail the
        // kind-seeded checksum, not decode as something else
        let mut buf = Vec::new();
        send_serve(&mut buf, &ServeMsg::Progress { tag: 1, after: 0 }).unwrap();
        buf[6] = 2; // kind PROGRESS(38) → worker kind 2
        buf[7] = 0;
        let err = recv_serve(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }
}
