//! The scoped map/combine execution engine.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A simulated cluster: `workers` map workers plus the calling thread as
/// leader. Phases use `std::thread::scope`, so map closures may borrow the
/// problem data; spawn cost (~tens of µs) is negligible against a map round
/// over millions of groups.
#[derive(Debug, Clone)]
pub struct Cluster {
    workers: usize,
}

impl Cluster {
    /// A cluster with `workers` map workers (≥ 1).
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// Single-worker cluster (sequential semantics, same code path).
    pub fn single() -> Self {
        Self::new(1)
    }

    /// One worker per available hardware thread.
    pub fn available() -> Self {
        Self::new(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    /// The environment-configured pool: `PALLAS_WORKERS=<n>` overrides the
    /// hardware-thread default (useful for pinning worker processes to a
    /// core budget, and for reproducing a fixed-parallelism run). Ignores
    /// unparsable or zero values and falls back to [`Cluster::available`].
    pub fn configured() -> Self {
        Self::from_env_override(std::env::var("PALLAS_WORKERS").ok().as_deref())
    }

    /// [`Cluster::configured`]'s parsing, separated so tests never have to
    /// mutate the process environment (set_var racing getenv is UB on
    /// glibc).
    fn from_env_override(value: Option<&str>) -> Self {
        match value.and_then(|v| v.parse::<usize>().ok()) {
            Some(n) if n > 0 => Self::new(n),
            _ => Self::available(),
        }
    }

    /// Number of map workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Map phase: apply `map` to every shard index in `[0, n_shards)`,
    /// returning results **in shard order**. Work-stealing via an atomic
    /// cursor balances skewed shards.
    pub fn map_shards<T, F>(&self, n_shards: usize, map: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n_shards == 0 {
            return Vec::new();
        }
        let cursor = AtomicUsize::new(0);
        let results: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n_shards));
        let workers = self.workers.min(n_shards);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let idx = cursor.fetch_add(1, Ordering::Relaxed);
                        if idx >= n_shards {
                            break;
                        }
                        local.push((idx, map(idx)));
                    }
                    results.lock().unwrap().extend(local);
                });
            }
        });
        let mut out = results.into_inner().unwrap();
        out.sort_unstable_by_key(|(i, _)| *i);
        out.into_iter().map(|(_, t)| t).collect()
    }

    /// Map + map-side combine: each worker folds its shards into a private
    /// accumulator (`init` per worker, `fold(acc, shard_idx)` per shard);
    /// the leader then merges the per-worker accumulators **in worker-rank
    /// order** with `merge`. This is the shape of every solver round: the
    /// shuffle volume is O(workers · K), independent of N.
    pub fn map_combine<A, I, F, G>(&self, n_shards: usize, init: I, fold: F, mut merge: G) -> A
    where
        A: Send,
        I: Fn() -> A + Sync,
        F: Fn(&mut A, usize) + Sync,
        G: FnMut(A, A) -> A,
    {
        if n_shards == 0 {
            return init();
        }
        let cursor = AtomicUsize::new(0);
        let workers = self.workers.min(n_shards);
        let partials: Mutex<Vec<(usize, A)>> = Mutex::new(Vec::with_capacity(workers));
        std::thread::scope(|s| {
            for rank in 0..workers {
                let partials = &partials;
                let cursor = &cursor;
                let init = &init;
                let fold = &fold;
                s.spawn(move || {
                    let mut acc = init();
                    loop {
                        let idx = cursor.fetch_add(1, Ordering::Relaxed);
                        if idx >= n_shards {
                            break;
                        }
                        fold(&mut acc, idx);
                    }
                    partials.lock().unwrap().push((rank, acc));
                });
            }
        });
        let mut parts = partials.into_inner().unwrap();
        parts.sort_unstable_by_key(|(r, _)| *r);
        let mut iter = parts.into_iter().map(|(_, a)| a);
        // never panic on an empty reduce: a worker that observed the
        // cursor already exhausted contributes nothing, so fall back to
        // the identity accumulator rather than trusting `workers ≥ 1`
        let first = iter.next().unwrap_or_else(|| init());
        iter.fold(first, |a, b| merge(a, b))
    }
}

impl Default for Cluster {
    fn default() -> Self {
        Self::available()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_shards_preserves_order() {
        let c = Cluster::new(4);
        let out = c.map_shards(100, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_shards_empty() {
        let c = Cluster::new(4);
        let out: Vec<usize> = c.map_shards(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn map_combine_zero_shards_returns_identity() {
        let c = Cluster::new(4);
        let total = c.map_combine(0, || 41u64, |acc, idx| *acc += idx as u64, |a, b| a + b);
        assert_eq!(total, 41, "an empty round must reduce to the identity accumulator");
    }

    #[test]
    fn map_combine_sums_once_per_shard() {
        let c = Cluster::new(3);
        let total = c.map_combine(
            1000,
            || 0u64,
            |acc, idx| *acc += idx as u64,
            |a, b| a + b,
        );
        assert_eq!(total, (0..1000u64).sum());
    }

    #[test]
    fn deterministic_across_worker_counts() {
        // compensated-sum shaped reduction must not depend on worker count
        let run = |w: usize| -> Vec<f64> {
            Cluster::new(w).map_combine(
                64,
                || vec![0.0f64; 4],
                |acc, idx| {
                    for (k, a) in acc.iter_mut().enumerate() {
                        *a += ((idx * 7 + k) % 13) as f64;
                    }
                },
                |mut a, b| {
                    for (x, y) in a.iter_mut().zip(b) {
                        *x += y;
                    }
                    a
                },
            )
        };
        let expect = run(1);
        for w in [2, 3, 8, 17] {
            assert_eq!(run(w), expect, "worker count {w}");
        }
    }

    #[test]
    fn single_worker_matches_parallel() {
        let f = |i: usize| (i as f64).sqrt();
        let a = Cluster::single().map_shards(50, f);
        let b = Cluster::new(8).map_shards(50, f);
        assert_eq!(a, b);
    }

    #[test]
    fn borrows_caller_data() {
        let data: Vec<u64> = (0..100).collect();
        let c = Cluster::new(4);
        let out = c.map_shards(10, |i| data[i * 10..(i + 1) * 10].iter().sum::<u64>());
        assert_eq!(out.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn env_override_parsing() {
        // exercised through the pure helper so the parallel test runner
        // never mutates the process environment
        assert_eq!(Cluster::from_env_override(Some("3")).workers(), 3);
        let cores = Cluster::available().workers();
        assert_eq!(Cluster::from_env_override(Some("zero?")).workers(), cores);
        assert_eq!(Cluster::from_env_override(Some("0")).workers(), cores);
        assert_eq!(Cluster::from_env_override(None).workers(), cores);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        Cluster::new(2).map_shards(4, |i| {
            if i == 3 {
                panic!("boom")
            }
            i
        });
    }
}
