//! A persistent thread pool for `'static` background jobs.
//!
//! The synchronous solver rounds use the scoped engine
//! ([`super::Cluster`]) so closures can borrow the problem; this pool
//! serves the asynchronous pieces — artifact prewarming, metrics flushing,
//! the CLI's concurrent instance generation — where jobs own their data.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed-size pool; jobs run FIFO; `join` drains.
pub struct ThreadPool {
    tx: Sender<Message>,
    handles: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    /// Spawn `n` workers (≥ 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let handles = (0..n)
            .map(|_| {
                let rx: Arc<Mutex<Receiver<Message>>> = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job)) => {
                            job();
                            let (lock, cv) = &*pending;
                            let mut p = lock.lock().unwrap();
                            *p -= 1;
                            if *p == 0 {
                                cv.notify_all();
                            }
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        Self { tx, handles, pending }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.send(Message::Run(Box::new(f))).expect("pool alive");
    }

    /// Block until every enqueued job has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Message::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
        assert_eq!(pool.size(), 2);
    }

    #[test]
    fn reusable_after_join() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn zero_requested_becomes_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
