//! MapReduce-style execution substrate.
//!
//! The paper describes its algorithms in MapReduce semantics and notes
//! (§4, footnote 2) that any distributed framework works. This module is
//! that framework for a single box: a leader (the caller's thread) drives
//! synchronous *map → combine → reduce* rounds over shards of groups,
//! executed by a pool of workers with work stealing. The observable
//! semantics match the paper's Spark deployment:
//!
//! * mappers see disjoint shards of groups and emit per-knapsack partials;
//! * per-worker **combiners** pre-aggregate before the shuffle (what Spark
//!   calls map-side combine) so reduce input is O(workers), not O(N);
//! * the reduce + multiplier update happen on the leader between rounds
//!   (a synchronous barrier, as in Algorithm 2/4).
//!
//! Determinism: shard results are merged in shard order, and floating-point
//! reductions use compensated sums, so solver output is reproducible for
//! any worker count.
//!
//! The multi-machine sibling lives in [`crate::cluster`]: the same
//! map→combine→reduce contract over TCP worker processes, selected per
//! solve through [`crate::cluster::Exec`].

mod engine;
mod pool;

pub use engine::Cluster;
pub use pool::ThreadPool;
