//! Built-in observers for the session API.
//!
//! [`crate::solver::stats::SolveObserver`] is the one per-round hook; these
//! are the stock implementations the session wires in:
//! [`CheckpointObserver`] (periodic λ checkpoints so interrupted
//! out-of-core solves resume), [`StopAfter`] (cooperative cancellation
//! after a round budget) and [`ChainObserver`] (fan-out to several
//! observers — how a user observer composes with checkpointing).
//! History recording lives next to the trait as
//! [`crate::solver::stats::HistoryObserver`].

use crate::error::Error;
use crate::solve::warm::write_checkpoint;
use crate::solver::stats::{ObserverControl, RoundEvent, SolveObserver, SolveReport};
use std::path::PathBuf;

/// Writes a λ checkpoint every `every` rounds, and a final one when the
/// solve completes. Checkpoint I/O failures never abort the solve — the
/// first one is reported on stderr and kept in
/// [`CheckpointObserver::last_error`].
#[derive(Debug)]
pub struct CheckpointObserver {
    path: PathBuf,
    every: usize,
    written: usize,
    last_error: Option<Error>,
}

impl CheckpointObserver {
    /// Checkpoint to `path` every `every` rounds (`every = 0` means only
    /// the final checkpoint is written).
    pub fn new<P: Into<PathBuf>>(path: P, every: usize) -> Self {
        Self { path: path.into(), every, written: 0, last_error: None }
    }

    /// The checkpoint file path.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// How many checkpoints were written so far.
    pub fn written(&self) -> usize {
        self.written
    }

    /// The first I/O error hit while checkpointing, if any.
    pub fn last_error(&self) -> Option<&Error> {
        self.last_error.as_ref()
    }

    fn write(&mut self, iter: usize, lambda: &[f64]) {
        match write_checkpoint(&self.path, iter, lambda) {
            Ok(()) => self.written += 1,
            Err(e) => {
                // a failed checkpoint must not kill a long solve, but a
                // user who asked for resumability needs to hear about it
                // once — otherwise the resume they rely on never exists
                if self.last_error.is_none() {
                    eprintln!(
                        "warning: λ checkpoint to {} failed ({e}); solve continues \
                         without resumability",
                        self.path.display()
                    );
                    self.last_error = Some(e);
                }
            }
        }
    }
}

impl SolveObserver for CheckpointObserver {
    fn on_round(&mut self, event: &RoundEvent<'_>) -> ObserverControl {
        if self.every > 0 && (event.iter + 1) % self.every == 0 {
            self.write(event.iter, event.lambda);
        }
        ObserverControl::Continue
    }

    fn on_complete(&mut self, report: &SolveReport) {
        // `iterations` counts executed rounds; the stored iter index is the
        // last round's 0-based index
        let iter = report.iterations.saturating_sub(1);
        self.write(iter, &report.lambda);
    }
}

/// Cancels the solve after `rounds` rounds — the cooperative-cancellation
/// primitive (also what the tests use to simulate an interrupted solve).
#[derive(Debug, Clone)]
pub struct StopAfter {
    rounds: usize,
    seen: usize,
}

impl StopAfter {
    /// Stop once `rounds` rounds have run.
    pub fn new(rounds: usize) -> Self {
        Self { rounds, seen: 0 }
    }

    /// Rounds observed so far.
    pub fn seen(&self) -> usize {
        self.seen
    }
}

impl SolveObserver for StopAfter {
    fn on_round(&mut self, _event: &RoundEvent<'_>) -> ObserverControl {
        self.seen += 1;
        if self.seen >= self.rounds {
            ObserverControl::Stop
        } else {
            ObserverControl::Continue
        }
    }
}

/// Fans events out to several observers. The solve stops as soon as *any*
/// part requests it (remaining parts still see the round first).
#[derive(Default)]
pub struct ChainObserver<'a> {
    parts: Vec<&'a mut dyn SolveObserver>,
}

impl<'a> ChainObserver<'a> {
    /// Empty chain; [`ChainObserver::push`] parts in call order.
    pub fn new() -> Self {
        Self { parts: Vec::new() }
    }

    /// Append an observer.
    pub fn push(&mut self, obs: &'a mut dyn SolveObserver) {
        self.parts.push(obs);
    }

    /// True when no observers are chained.
    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }
}

impl SolveObserver for ChainObserver<'_> {
    fn on_round(&mut self, event: &RoundEvent<'_>) -> ObserverControl {
        let mut control = ObserverControl::Continue;
        for part in &mut self.parts {
            if part.on_round(event) == ObserverControl::Stop {
                control = ObserverControl::Stop;
            }
        }
        control
    }

    fn on_complete(&mut self, report: &SolveReport) {
        for part in &mut self.parts {
            part.on_complete(report);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solve::warm::read_checkpoint;
    use crate::solver::stats::HistoryObserver;

    fn event(iter: usize, lambda: &[f64]) -> RoundEvent<'_> {
        RoundEvent {
            iter,
            primal: 1.0,
            dual: 2.0,
            max_violation_ratio: 0.0,
            lambda_change: 0.5,
            wall_ms: 0.1,
            map_ms: 0.08,
            reduce_ms: 0.01,
            skip_rate: 0.0,
            lambda,
        }
    }

    #[test]
    fn stop_after_counts_rounds() {
        let mut s = StopAfter::new(2);
        let l = [1.0];
        assert_eq!(s.on_round(&event(0, &l)), ObserverControl::Continue);
        assert_eq!(s.on_round(&event(1, &l)), ObserverControl::Stop);
        assert_eq!(s.seen(), 2);
    }

    #[test]
    fn checkpoint_observer_writes_on_cadence() {
        let path = std::env::temp_dir()
            .join(format!("bskp_obs_ckpt_{}.ckpt", std::process::id()));
        let mut c = CheckpointObserver::new(&path, 2);
        let l = [0.5, 0.25];
        c.on_round(&event(0, &l)); // (0+1) % 2 != 0 → no write
        assert_eq!(c.written(), 0);
        c.on_round(&event(1, &l));
        assert_eq!(c.written(), 1);
        let ckpt = read_checkpoint(&path).unwrap();
        assert_eq!(ckpt.iter, 1);
        assert_eq!(ckpt.lambda, vec![0.5, 0.25]);
        assert!(c.last_error().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checkpoint_errors_do_not_stop_the_solve() {
        let mut c = CheckpointObserver::new("/nonexistent_dir_bskp/x.ckpt", 1);
        let l = [1.0];
        assert_eq!(c.on_round(&event(0, &l)), ObserverControl::Continue);
        assert_eq!(c.written(), 0);
        assert!(c.last_error().is_some());
    }

    #[test]
    fn chain_fans_out_and_stops_on_any() {
        let mut hist = HistoryObserver::new();
        let mut stop = StopAfter::new(1);
        let mut chain = ChainObserver::new();
        chain.push(&mut hist);
        chain.push(&mut stop);
        let l = [1.0];
        assert_eq!(chain.on_round(&event(0, &l)), ObserverControl::Stop);
        assert_eq!(hist.history.len(), 1);
    }
}
