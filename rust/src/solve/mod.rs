//! The solve session API: staged entry path for every solve.
//!
//! ```text
//! Solve::on(&source)        // bind an instance (any GroupSource)
//!     .algorithm(..)        // request DD / SCD        (default SCD)
//!     .backend(..)          // request rust / XLA maps (default rust)
//!     .config(..)           // solver parameters
//!     .warm(..)             // seed λ from a prior solve / checkpoint
//!     .checkpoint_auto(5)   // periodic λ checkpoints next to the store
//!     .plan()?              // -> SolvePlan: inspectable, with fallback
//!                           //    reasons for every unsupported combo
//!     .run()                // or .run_observed(&mut observer)
//! ```
//!
//! Planning is *capability-based*: a requested backend that cannot handle
//! the instance shape (or is not compiled in, or has no artifacts) falls
//! back to one that can, and the plan records a [`PlanNote`] saying why —
//! the old `Coordinator::solve` behavior of erroring on unsupported
//! combinations is gone from this path. (Genuine runtime faults after
//! planning — PJRT init failure, artifacts deleted mid-session, I/O —
//! still surface as errors from `run()`; dispatch itself never
//! mismatches.) Warm starts ([`WarmStart`]) seed λ from a
//! prior [`SolveReport`] or a checkpoint file; per-round
//! [`SolveObserver`]s carry history recording, progress, cancellation and
//! periodic λ checkpoints ([`CheckpointObserver`]) so interrupted
//! out-of-core solves resume with `WarmStart::from_checkpoint`.
//!
//! The free functions `solve_scd` / `solve_dd` remain as thin wrappers
//! for benchmarks that need tight control of a single algorithm.

pub mod observers;
pub mod plan;
pub mod scaled;
pub mod warm;

pub use observers::{ChainObserver, CheckpointObserver, StopAfter};
pub use plan::{CheckpointPlan, PlanNote, PlannedBackend, PlannedIo, SolvePlan};
pub use scaled::ScaledBudgets;
pub use warm::{
    default_checkpoint_path, read_checkpoint, write_checkpoint, Checkpoint, WarmStart,
    CHECKPOINT_FILE,
};

// the observer vocabulary lives next to the solvers; re-export it here so
// session users need only `use bskp::solve::*`
pub use crate::solver::stats::{
    HistoryObserver, MembershipChange, MembershipEvent, ObserverControl, RoundEvent,
    SolveObserver, SolveReport,
};

use crate::cluster::{
    Clock, ConnectOptions, NetListener, RemoteCluster, SystemClock, TcpTransport, Transport,
};
use crate::coordinator::{Algorithm, Backend};
use crate::error::Result;
use crate::instance::problem::GroupSource;
use crate::instance::shard::Shards;
use crate::instance::store::StagedProblem;
use crate::io::{prefetch_depth_from_env, IoMode};
use crate::mapreduce::Cluster;
use crate::solver::config::{ReduceMode, SolverConfig};
use crate::solver::sparse_q;
use std::path::PathBuf;
use std::sync::Arc;

/// Default checkpoint cadence (rounds) when none is given.
pub const DEFAULT_CHECKPOINT_EVERY: usize = 5;

/// Advisory threshold: above this many decision variables an `Exact`
/// reduce keeps every threshold emission in memory, which is usually the
/// wrong trade — the plan suggests §5.2 bucketing.
const EXACT_REDUCE_ADVISORY_VARS: usize = 50_000_000;

#[derive(Debug, Clone, PartialEq, Eq)]
enum CheckpointRequest {
    Off,
    /// Next to the source's shard store (disabled with a note when the
    /// source has no on-disk home).
    Auto { every: usize },
    To { path: PathBuf, every: usize },
}

/// Builder for one solve session. See the [module docs](self).
pub struct Solve<'a> {
    source: &'a dyn GroupSource,
    config: SolverConfig,
    cluster: Option<Cluster>,
    cluster_addrs: Vec<String>,
    transport: Option<Arc<dyn Transport>>,
    connect_opts: Option<ConnectOptions>,
    join: Option<Box<dyn NetListener>>,
    algorithm: Algorithm,
    backend: Backend,
    warm: Option<WarmStart>,
    checkpoint: CheckpointRequest,
    clock: Option<Arc<dyn Clock>>,
    io: IoMode,
}

impl<'a> Solve<'a> {
    /// Start a session on an instance (any [`GroupSource`]: synthetic,
    /// materialized, or an out-of-core
    /// [`crate::instance::store::MmapProblem`]).
    pub fn on(source: &'a dyn GroupSource) -> Self {
        Self {
            source,
            config: SolverConfig::default(),
            cluster: None,
            cluster_addrs: Vec::new(),
            transport: None,
            connect_opts: None,
            join: None,
            algorithm: Algorithm::Scd,
            backend: Backend::Rust,
            warm: None,
            checkpoint: CheckpointRequest::Off,
            clock: None,
            io: IoMode::Auto,
        }
    }

    /// Request DD or SCD (default: SCD, the paper's production choice).
    pub fn algorithm(mut self, a: Algorithm) -> Self {
        self.algorithm = a;
        self
    }

    /// Request a map-phase backend (default: pure rust). Unsupported
    /// combinations fall back with a plan note instead of erroring.
    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    /// Replace the solver configuration.
    pub fn config(mut self, c: SolverConfig) -> Self {
        self.config = c;
        self
    }

    /// Use this worker pool (default: [`Cluster::configured`], i.e. all
    /// hardware threads unless `PALLAS_WORKERS` says otherwise).
    pub fn cluster(mut self, c: Cluster) -> Self {
        self.cluster = Some(c);
        self
    }

    /// Run the map rounds on a fleet of `pallas worker` processes at these
    /// `host:port` addresses (each serving its replica of the instance's
    /// shard store). Planning is capability-based, like the backend: when
    /// the source has no on-disk store, or no worker is reachable, the
    /// plan falls back to the in-process pool and records a
    /// [`PlanNote`] saying why.
    pub fn distributed<I, A>(mut self, addrs: I) -> Self
    where
        I: IntoIterator<Item = A>,
        A: Into<String>,
    {
        self.cluster_addrs = addrs.into_iter().map(Into::into).collect();
        self
    }

    /// Dial [`Solve::distributed`] workers through this transport instead
    /// of TCP — how the deterministic simulator
    /// ([`crate::cluster::SimNet`]) runs a full planned session, capability
    /// checks included, without sockets. Production code never needs this:
    /// the default is [`crate::cluster::TcpTransport`].
    pub fn transport(mut self, t: Arc<dyn Transport>) -> Self {
        self.transport = Some(t);
        self
    }

    /// Admit fresh `bskp worker --join <addr>` processes mid-solve
    /// through this bound listener: the leader polls it (non-blocking)
    /// at every deal boundary and deals chunks to admitted workers from
    /// the next round on. Only meaningful together with
    /// [`Solve::distributed`]; without an attached fleet the listener is
    /// dropped and joiners see a closed connection. See
    /// `docs/cluster-protocol.md` ("Membership lifecycle").
    pub fn join_listener(mut self, l: Box<dyn NetListener>) -> Self {
        self.join = Some(l);
        self
    }

    /// Override the cluster session's dial/exchange timeout policy
    /// (default: the `PALLAS_CLUSTER_*_MS` environment knobs). Tests
    /// inject explicit values here so their behavior can never depend on
    /// what the host environment happens to export.
    pub fn connect_options(mut self, opts: ConnectOptions) -> Self {
        self.connect_opts = Some(opts);
        self
    }

    /// Read phase timings through this [`Clock`] instead of the system
    /// clock — how a daemon-hosted solve under the deterministic
    /// simulator reports *virtual* wall time. Production never needs
    /// this: the default is [`SystemClock`], byte-for-byte the old
    /// behavior.
    pub fn clock(mut self, c: Arc<dyn Clock>) -> Self {
        self.clock = Some(c);
        self
    }

    /// Request an I/O path for out-of-core serving (default:
    /// [`IoMode::Auto`], which follows `PALLAS_IO_BACKEND` and means
    /// borrow-only mmap when the variable is unset). Like every other
    /// capability, an unservable request falls back with a plan note:
    /// prefetch staging on a source with no shard store, or under a
    /// distributed executor (workers read their own replicas), keeps the
    /// existing path. See `docs/io.md`.
    pub fn io(mut self, mode: IoMode) -> Self {
        self.io = mode;
        self
    }

    /// Seed λ from a warm start (overrides `lambda0` and §5.3 presolve).
    pub fn warm(mut self, w: WarmStart) -> Self {
        self.warm = Some(w);
        self
    }

    /// Write λ checkpoints to `path` every `every` rounds (plus a final
    /// one on completion).
    pub fn checkpoint_to(mut self, path: impl Into<PathBuf>, every: usize) -> Self {
        self.checkpoint = CheckpointRequest::To { path: path.into(), every };
        self
    }

    /// Write λ checkpoints next to the source's shard store (the
    /// [`GroupSource::store_dir`]) every `every` rounds. When the source
    /// has no on-disk home, checkpointing is disabled with a plan note.
    pub fn checkpoint_auto(mut self, every: usize) -> Self {
        self.checkpoint = CheckpointRequest::Auto { every };
        self
    }

    /// Resolve the session into an inspectable [`SolvePlan`]: validate
    /// config and instance, length-check the warm start, pick a backend
    /// the shape supports (recording a [`PlanNote`] for every fallback),
    /// and fix the shard geometry.
    pub fn plan(self) -> Result<SolvePlan<'a>> {
        self.config.validate()?;
        self.source.validate()?;
        let dims = self.source.dims();
        let mut notes = Vec::new();

        // warm start: a K-mismatch or invalid multiplier is a data error,
        // not a fallback — the user pointed at the wrong instance or a
        // stale/corrupt λ source. Caught here so --plan-only never
        // advertises a plan that cannot run. Same validator as the
        // drivers (crate::solver::scd::check_warm_lambda), with the
        // provenance added for context.
        if let Some(w) = &self.warm {
            if let Err(m) = crate::solver::scd::check_warm_lambda(&w.lambda, dims.n_global) {
                return Err(crate::error::Error::InvalidConfig(format!(
                    "warm start ({}) {m} — wrong λ source for this instance?",
                    w.provenance
                )));
            }
            if self.config.presolve.is_some() {
                notes.push(PlanNote::new(
                    "presolve",
                    "§5.3 pre-solve configured but a warm start was supplied; \
                     the warm λ wins and the pre-solve is skipped",
                ));
            }
        }

        let mut backend = self.plan_backend(&mut notes);
        let cluster = self.cluster.unwrap_or_else(Cluster::configured);

        // distributed executor: capability-checked like the backend — every
        // reason it cannot run lands in the notes and the solve proceeds
        // in-process instead of erroring. The backend override happens only
        // once a fleet actually attaches, so a failed attach leaves the
        // planned (possibly XLA) backend intact for the in-process run.
        let mut remote: Option<Arc<RemoteCluster>> = None;
        if self.join.is_some() && self.cluster_addrs.is_empty() {
            notes.push(PlanNote::new(
                "executor",
                "a join listener was configured without distributed() worker addresses; \
                 mid-solve admission needs an attached fleet, so the listener is dropped",
            ));
        }
        if !self.cluster_addrs.is_empty() {
            if self.source.store_dir().is_none() {
                notes.push(PlanNote::new(
                    "executor",
                    "distributed solve requires an on-disk shard store (workers mmap their \
                     replica of it); this source has none — using the in-process pool",
                ));
            } else {
                let transport: Arc<dyn Transport> = match &self.transport {
                    Some(t) => Arc::clone(t),
                    None => Arc::new(TcpTransport),
                };
                let opts = self.connect_opts.unwrap_or_else(ConnectOptions::from_env);
                let connected = RemoteCluster::connect_elastic(
                    transport,
                    &self.cluster_addrs,
                    self.source,
                    opts,
                    self.join,
                );
                match connected {
                    Ok((rc, skipped)) => {
                        for s in skipped {
                            notes.push(PlanNote::new("executor", s));
                        }
                        if backend != PlannedBackend::Rust {
                            notes.push(PlanNote::new(
                                "executor",
                                format!(
                                    "distributed execution drives the pure-rust map phase; \
                                     overriding the planned {} backend",
                                    backend.name()
                                ),
                            ));
                            backend = PlannedBackend::Rust;
                        }
                        remote = Some(Arc::new(rc.with_leader_pool(cluster.clone())));
                    }
                    Err(e) => notes.push(PlanNote::new(
                        "executor",
                        format!("{e} — using the in-process pool"),
                    )),
                }
            }
        }

        if self.config.reduce == ReduceMode::Exact && dims.n_vars() >= EXACT_REDUCE_ADVISORY_VARS
        {
            let wire = if remote.is_some() {
                " — and, distributed, ships every emission over the wire \
                 (bucketed partials are O(K) per chunk, immune to the frame cap)"
            } else {
                ""
            };
            notes.push(PlanNote::new(
                "reduce",
                format!(
                    "exact reduce keeps every threshold emission for {} decision variables in \
                     memory{wire}; consider ReduceMode::Bucketed (§5.2) at this scale",
                    dims.n_vars()
                ),
            ));
        }

        let map_parallelism = remote.as_ref().map_or(cluster.workers(), |r| r.capacity());
        let shards = Shards::plan(
            dims.n_groups,
            map_parallelism,
            self.source.preferred_shard_size(),
            self.config.shard_size,
        );

        // I/O path: capability-planned like the backend and executor. Auto
        // resolves the PALLAS_IO_BACKEND knob (unset ⇒ mmap, note-free);
        // an explicit prefetch request that cannot be served falls back
        // with a note instead of erroring.
        let resolved_io = match self.io {
            IoMode::Auto => {
                let (m, note) = IoMode::resolve_auto();
                if let Some(n) = note {
                    notes.push(PlanNote::new("io", n));
                }
                m
            }
            m => m,
        };
        let mut planned_io = if self.source.store_dir().is_some() {
            PlannedIo::Mmap
        } else {
            PlannedIo::InMemory
        };
        // resolve the session clock before staging: the io plane's
        // read/wait accounting runs through the same seam as the solver's
        // phase timings
        let clock: Arc<dyn Clock> = self.clock.clone().unwrap_or_else(|| Arc::new(SystemClock));
        let mut staged = None;
        if let IoMode::Prefetch(kind) = resolved_io {
            match self.source.store_dir() {
                None => notes.push(PlanNote::new(
                    "io",
                    "prefetch staging requested but the source has no on-disk shard store; \
                     serving from memory",
                )),
                Some(_) if remote.is_some() => notes.push(PlanNote::new(
                    "io",
                    "prefetch staging requested but the map phase runs on remote workers \
                     (each reads its own store replica); leader keeps the borrow-only mmap \
                     path",
                )),
                Some(dir) => {
                    let depth = prefetch_depth_from_env();
                    let io_clock = Arc::clone(&clock);
                    let workers = cluster.workers();
                    match StagedProblem::open_clocked(&dir, kind, depth, workers, io_clock) {
                        Ok((sp, io_notes)) => {
                            for n in io_notes {
                                notes.push(PlanNote::new("io", n));
                            }
                            planned_io =
                                PlannedIo::Prefetched { backend: sp.backend_name(), depth };
                            staged = Some(sp);
                        }
                        Err(e) => notes.push(PlanNote::new(
                            "io",
                            format!(
                                "prefetch staging unavailable ({e}); keeping the borrow-only \
                                 mmap path"
                            ),
                        )),
                    }
                }
            }
        }

        let checkpoint = match self.checkpoint {
            CheckpointRequest::Off => None,
            CheckpointRequest::To { path, every } => Some(CheckpointPlan { path, every }),
            CheckpointRequest::Auto { every } => match self.source.store_dir() {
                Some(dir) => {
                    Some(CheckpointPlan { path: warm::default_checkpoint_path(&dir), every })
                }
                None => {
                    notes.push(PlanNote::new(
                        "checkpoint",
                        "checkpointing requested but the source has no on-disk store \
                         directory and no explicit path was given; checkpoints disabled \
                         (use checkpoint_to(path, every))",
                    ));
                    None
                }
            },
        };

        Ok(SolvePlan {
            source: self.source,
            cluster,
            remote,
            config: self.config,
            algorithm: self.algorithm,
            backend,
            shard_count: shards.count(),
            shard_size: shards.shard_size(),
            warm: self.warm,
            checkpoint,
            io: planned_io,
            staged,
            notes,
            clock,
        })
    }

    /// Capability-based backend selection: every unsupported request falls
    /// back to the pure-rust map phase with a note explaining why.
    fn plan_backend(&self, notes: &mut Vec<PlanNote>) -> PlannedBackend {
        let dims = self.source.dims();
        let artifacts_dir = match &self.backend {
            Backend::Rust => return PlannedBackend::Rust,
            Backend::Xla { artifacts_dir } => artifacts_dir.clone(),
        };
        if !cfg!(feature = "xla") {
            notes.push(PlanNote::new(
                "backend",
                "XLA backend requested but this build has no PJRT runtime (compile with \
                 --features xla and a vendored xla crate); using the pure-rust map phase",
            ));
            return PlannedBackend::Rust;
        }
        // the artifacts must exist before we commit the solve to them
        if let Err(e) = crate::runtime::ArtifactManifest::load(&artifacts_dir) {
            notes.push(PlanNote::new(
                "backend",
                format!(
                    "XLA backend requested but artifacts are unavailable ({e}); \
                     using the pure-rust map phase"
                ),
            ));
            return PlannedBackend::Rust;
        }
        match self.algorithm {
            Algorithm::Scd => {
                if sparse_q::xla_identity_eligible(self.source) {
                    PlannedBackend::XlaScdSparse { artifacts_dir }
                } else {
                    notes.push(PlanNote::new(
                        "backend",
                        format!(
                            "the SCD XLA map phase requires a sparse identity-mapped instance \
                             (M = K, single local cap); this instance is {} with M={} K={}; \
                             using the pure-rust map phase",
                            if self.source.is_dense() { "dense" } else { "sparse" },
                            dims.n_items,
                            dims.n_global
                        ),
                    ));
                    PlannedBackend::Rust
                }
            }
            Algorithm::Dd => {
                if self.source.is_dense() {
                    PlannedBackend::XlaDdDense { artifacts_dir }
                } else {
                    PlannedBackend::XlaDdSparse { artifacts_dir }
                }
            }
        }
    }

    /// [`Solve::plan`] + [`SolvePlan::run`] in one call.
    pub fn run(self) -> Result<SolveReport> {
        self.plan()?.run()
    }

    /// [`Solve::plan`] + [`SolvePlan::run_observed`] in one call.
    pub fn run_observed(self, observer: &mut dyn SolveObserver) -> Result<SolveReport> {
        self.plan()?.run_observed(observer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};

    #[test]
    fn default_plan_is_scd_rust() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(500, 6, 6).with_seed(1));
        let plan = Solve::on(&p).cluster(Cluster::new(2)).plan().unwrap();
        assert_eq!(plan.algorithm, Algorithm::Scd);
        assert_eq!(plan.backend, PlannedBackend::Rust);
        assert!(plan.notes.is_empty(), "unexpected notes: {:?}", plan.notes);
        assert!(plan.shard_count >= 1);
        let text = plan.to_string();
        assert!(text.contains("algorithm=scd"), "{text}");
        assert!(text.contains("backend=rust"), "{text}");
    }

    #[test]
    fn xla_request_falls_back_with_reason_not_error() {
        // dense instance, SCD, XLA backend: the old Coordinator errors on
        // this shape; the planner must fall back to rust with a note
        let p = SyntheticProblem::new(GeneratorConfig::dense(200, 4, 4).with_seed(2));
        let plan = Solve::on(&p)
            .cluster(Cluster::new(1))
            .backend(Backend::Xla { artifacts_dir: "artifacts".into() })
            .plan()
            .unwrap();
        assert_eq!(plan.backend, PlannedBackend::Rust);
        assert!(
            plan.notes.iter().any(|n| n.stage == "backend"),
            "missing backend fallback note: {:?}",
            plan.notes
        );
        let r = plan.run().unwrap();
        assert!(r.is_feasible());
    }

    #[test]
    fn warm_length_mismatch_is_a_clear_error() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(100, 4, 4).with_seed(3));
        let err = Solve::on(&p)
            .cluster(Cluster::new(1))
            .warm(WarmStart::from_lambda(vec![1.0; 3]))
            .plan()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("4 global constraints"), "{msg}");
        assert!(msg.contains('3'), "{msg}");
    }

    #[test]
    fn checkpoint_auto_without_store_is_noted_and_disabled() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(100, 4, 4).with_seed(4));
        let plan = Solve::on(&p).cluster(Cluster::new(1)).checkpoint_auto(5).plan().unwrap();
        assert!(plan.checkpoint.is_none());
        assert!(plan.notes.iter().any(|n| n.stage == "checkpoint"));
        // and the solve still runs fine
        assert!(plan.run().unwrap().is_feasible());
    }

    #[test]
    fn distributed_without_store_falls_back_with_note() {
        // synthetic sources have no on-disk store for workers to mmap, so
        // the planner must fall back in-process before touching the
        // network (the bogus address is never dialed)
        let p = SyntheticProblem::new(GeneratorConfig::sparse(200, 4, 4).with_seed(9));
        let plan = Solve::on(&p)
            .cluster(Cluster::new(1))
            .distributed(["127.0.0.1:9"])
            .plan()
            .unwrap();
        assert_eq!(plan.executor(), "in-process");
        assert!(plan.remote_handle().is_none());
        assert!(
            plan.notes.iter().any(|n| n.stage == "executor"),
            "missing executor note: {:?}",
            plan.notes
        );
        assert!(plan.run().unwrap().is_feasible());
    }

    #[test]
    fn run_observed_sees_every_round() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(400, 5, 5).with_seed(5));
        let mut hist = HistoryObserver::new();
        let cfg = SolverConfig { track_history: false, ..Default::default() };
        let r = Solve::on(&p)
            .cluster(Cluster::new(2))
            .config(cfg)
            .run_observed(&mut hist)
            .unwrap();
        assert!(r.history.is_empty(), "track_history off keeps the report lean");
        assert_eq!(hist.history.len(), r.iterations);
    }
}
