//! The inspectable solve plan: what will run, where, and why.
//!
//! [`crate::solve::Solve::plan`] resolves the requested algorithm ×
//! backend × instance shape into a concrete execution plan *before*
//! anything heavy happens. Unsupported combinations never error — the
//! planner falls back to a backend that can handle the shape and records
//! a human-readable [`PlanNote`] for every such decision (this replaces
//! the old `Coordinator` behavior of erroring on mismatch).

use crate::cluster::{Clock, Exec, RemoteCluster};
use crate::coordinator::Algorithm;
use crate::error::{Error, Result};
use crate::instance::problem::GroupSource;
use crate::instance::store::StagedProblem;
use crate::mapreduce::Cluster;
use crate::solve::observers::{ChainObserver, CheckpointObserver};
use crate::solve::warm::WarmStart;
use crate::solver::config::{ReduceMode, SolverConfig};
use crate::solver::stats::{SolveObserver, SolveReport};
use crate::solver::{dd, scd};
use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;

/// One planning decision worth telling the user about — most importantly
/// the reason for every fallback from a requested-but-unsupported
/// combination.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanNote {
    /// What the note is about: `"backend"`, `"warm"`, `"presolve"`,
    /// `"checkpoint"`, `"reduce"`.
    pub stage: &'static str,
    /// Human-readable explanation.
    pub message: String,
}

impl PlanNote {
    pub(crate) fn new(stage: &'static str, message: impl Into<String>) -> Self {
        Self { stage, message: message.into() }
    }
}

impl fmt::Display for PlanNote {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "note[{}]: {}", self.stage, self.message)
    }
}

/// The concrete map-phase backend the planner chose (the requested
/// [`crate::coordinator::Backend`] resolved against build features,
/// artifact availability and instance shape).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlannedBackend {
    /// Pure-rust greedy mappers (handles every instance shape).
    Rust,
    /// SCD map phase inside the `scd_sparse` AOT artifact (sparse
    /// identity-mapped instances: `M = K`, single local cap).
    XlaScdSparse {
        /// Directory holding `manifest.txt` + `*.hlo.txt`.
        artifacts_dir: PathBuf,
    },
    /// DD evaluation through the dense XLA artifact.
    XlaDdDense {
        /// Directory holding `manifest.txt` + `*.hlo.txt`.
        artifacts_dir: PathBuf,
    },
    /// DD evaluation through the sparse XLA artifact.
    XlaDdSparse {
        /// Directory holding `manifest.txt` + `*.hlo.txt`.
        artifacts_dir: PathBuf,
    },
}

impl PlannedBackend {
    /// Short name for logs and JSON.
    pub fn name(&self) -> &'static str {
        match self {
            PlannedBackend::Rust => "rust",
            PlannedBackend::XlaScdSparse { .. } => "xla-scd-sparse",
            PlannedBackend::XlaDdDense { .. } => "xla-dd-dense",
            PlannedBackend::XlaDdSparse { .. } => "xla-dd-sparse",
        }
    }
}

/// How the planner will serve group data to the map phase (the
/// [`crate::io::IoMode`] request resolved against the instance and
/// executor; see `docs/io.md`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlannedIo {
    /// The source is in memory; no I/O path applies.
    InMemory,
    /// Borrow-only memory-mapped serving (the out-of-core default,
    /// unchanged from PR 1).
    Mmap,
    /// Prefetch-staged serving through the async I/O subsystem: reads for
    /// upcoming shards overlap with compute.
    Prefetched {
        /// Backend name (`"threadpool"` / `"io_uring"`).
        backend: &'static str,
        /// Shards read ahead of the one being consumed.
        depth: usize,
    },
}

impl PlannedIo {
    /// Short name for logs and JSON.
    pub fn name(&self) -> &'static str {
        match self {
            PlannedIo::InMemory => "in-memory",
            PlannedIo::Mmap => "mmap",
            PlannedIo::Prefetched { .. } => "prefetched",
        }
    }
}

/// Planned periodic λ checkpointing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointPlan {
    /// Checkpoint file (written atomically; see [`crate::solve::warm`]).
    pub path: PathBuf,
    /// Write every this many rounds (a final checkpoint is always written
    /// on completion).
    pub every: usize,
}

/// A fully resolved solve: inspect it, print it, then [`SolvePlan::run`]
/// it.
pub struct SolvePlan<'a> {
    pub(crate) source: &'a dyn GroupSource,
    /// Worker pool the map phase will use (when no remote fleet is
    /// attached — and, either way, the pool for leader-local phases).
    pub cluster: Cluster,
    /// A connected `pallas worker` fleet, when the session asked for
    /// [`crate::solve::Solve::distributed`] and a worker was reachable.
    pub(crate) remote: Option<Arc<RemoteCluster>>,
    /// Solver parameters (as passed; warm start overrides its `lambda0`).
    pub config: SolverConfig,
    /// DD or SCD.
    pub algorithm: Algorithm,
    /// The chosen map-phase backend.
    pub backend: PlannedBackend,
    /// Number of map shards the solve will dispatch per round.
    pub shard_count: usize,
    /// Groups per map shard.
    pub shard_size: usize,
    /// Warm-start multipliers, if any (already length-checked against `K`).
    pub warm: Option<WarmStart>,
    /// Periodic λ checkpointing, if enabled and resolvable.
    pub checkpoint: Option<CheckpointPlan>,
    /// How group data reaches the map phase (mmap vs prefetch-staged).
    pub io: PlannedIo,
    /// The prefetch-staged source, when `io` is
    /// [`PlannedIo::Prefetched`] — the run serves blocks through it
    /// instead of `source` (bit-identical bytes, overlapped arrival).
    pub(crate) staged: Option<StagedProblem>,
    /// Every fallback / advisory decision the planner made.
    pub notes: Vec<PlanNote>,
    /// Clock the drivers read phase timings through (the system clock
    /// unless [`crate::solve::Solve::clock`] injected a virtual one).
    pub(crate) clock: Arc<dyn Clock>,
}

impl fmt::Display for SolvePlan<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let dims = self.source.dims();
        let algo = match self.algorithm {
            Algorithm::Scd => "scd",
            Algorithm::Dd => "dd",
        };
        let reduce = match self.config.reduce {
            ReduceMode::Exact => "exact".to_string(),
            ReduceMode::Bucketed { delta } => format!("bucketed(Δ={delta:e})"),
        };
        writeln!(
            f,
            "plan: algorithm={algo} backend={} reduce={reduce} shards={}×{} workers={} (N={} M={} K={})",
            self.backend.name(),
            self.shard_count,
            self.shard_size,
            self.cluster.workers(),
            dims.n_groups,
            dims.n_items,
            dims.n_global,
        )?;
        if let Some(r) = &self.remote {
            writeln!(
                f,
                "  executor: distributed ({} workers at [{}], capacity {})",
                r.workers(),
                r.addrs().join(", "),
                r.capacity()
            )?;
        }
        match &self.warm {
            Some(w) => writeln!(f, "  λ0: warm start from {}", w.provenance)?,
            None => match &self.config.presolve {
                Some(p) => writeln!(f, "  λ0: §5.3 pre-solve on {} sampled groups", p.sample)?,
                None => writeln!(f, "  λ0: cold start at {}", self.config.lambda0)?,
            },
        }
        if let Some(c) = &self.checkpoint {
            writeln!(f, "  checkpoint: {} every {} rounds", c.path.display(), c.every)?;
        }
        match &self.io {
            PlannedIo::InMemory => {}
            PlannedIo::Mmap => writeln!(f, "  io: borrow-only mmap")?,
            PlannedIo::Prefetched { backend, depth } => {
                writeln!(f, "  io: prefetch-staged ({backend}, depth {depth})")?
            }
        }
        for note in &self.notes {
            writeln!(f, "  {note}")?;
        }
        Ok(())
    }
}

impl<'a> SolvePlan<'a> {
    /// The SCD reduce mode the solve will use (from the config; exposed
    /// so the plan is self-describing).
    pub fn reduce(&self) -> ReduceMode {
        self.config.reduce
    }

    /// `"distributed"` when a worker fleet is attached, else
    /// `"in-process"`.
    pub fn executor(&self) -> &'static str {
        if self.remote.is_some() {
            "distributed"
        } else {
            "in-process"
        }
    }

    /// A handle on the attached worker fleet, if any — clone it before
    /// [`SolvePlan::run`] to read [`RemoteCluster::stats`] afterwards.
    pub fn remote_handle(&self) -> Option<Arc<RemoteCluster>> {
        self.remote.clone()
    }

    /// Execute the plan.
    ///
    /// Planning already verified backend capability (shape, build
    /// features, artifact presence), so dispatch itself cannot mismatch;
    /// what can still fail here are genuine runtime faults — PJRT
    /// initialization, artifacts deleted since planning, I/O — which
    /// surface as [`crate::error::Error::Runtime`], not as opaque
    /// shape errors.
    pub fn run(self) -> Result<SolveReport> {
        self.run_inner(None)
    }

    /// Execute the plan with a caller observer receiving per-round events
    /// (composed with the plan's own checkpoint observer, if any).
    pub fn run_observed(self, observer: &mut dyn SolveObserver) -> Result<SolveReport> {
        self.run_inner(Some(observer))
    }

    fn run_inner(self, user: Option<&mut dyn SolveObserver>) -> Result<SolveReport> {
        let mut ckpt =
            self.checkpoint.as_ref().map(|c| CheckpointObserver::new(c.path.clone(), c.every));
        let mut chain = ChainObserver::new();
        if let Some(c) = ckpt.as_mut() {
            chain.push(c);
        }
        if let Some(u) = user {
            chain.push(u);
        }
        let observer: Option<&mut dyn SolveObserver> =
            if chain.is_empty() { None } else { Some(&mut chain) };

        let init = self.warm.as_ref().map(|w| w.lambda.as_slice());
        // prefetch-staged serving swaps the block source; the bytes are
        // identical to the mmap path's, only their arrival overlaps with
        // compute
        let source: &dyn GroupSource = match &self.staged {
            Some(s) => s,
            None => self.source,
        };
        let (config, cluster) = (&self.config, &self.cluster);
        let clock = Arc::clone(&self.clock);
        let clock = clock.as_ref();
        // the planner only attaches a remote fleet to the pure-rust
        // backend; XLA paths below always run on the in-process pool
        let exec = match &self.remote {
            Some(r) => Exec::Remote(r.as_ref()),
            None => Exec::Local(cluster),
        };
        let result = match (self.algorithm, &self.backend) {
            (Algorithm::Scd, PlannedBackend::Rust) => {
                scd::solve_scd_exec_clocked(source, config, &exec, init, observer, clock)
            }
            (Algorithm::Dd, PlannedBackend::Rust) => {
                dd::solve_dd_exec_clocked(source, config, &exec, init, observer, clock)
            }
            (Algorithm::Scd, PlannedBackend::XlaScdSparse { artifacts_dir }) => {
                let manifest = crate::runtime::ArtifactManifest::load(artifacts_dir)?;
                let runtime = crate::runtime::Runtime::cpu()?;
                crate::runtime::solve_scd_xla_sparse_driven_clocked(
                    source, config, cluster, &runtime, &manifest, init, observer, clock,
                )
            }
            (Algorithm::Dd, PlannedBackend::XlaDdDense { artifacts_dir }) => {
                let manifest = crate::runtime::ArtifactManifest::load(artifacts_dir)?;
                let runtime = crate::runtime::Runtime::cpu()?;
                let eval = crate::runtime::XlaDenseEvaluator::new(source, &runtime, &manifest)?;
                dd::solve_dd_with_driven_clocked(
                    source, &eval, config, cluster, init, observer, clock,
                )
            }
            (Algorithm::Dd, PlannedBackend::XlaDdSparse { artifacts_dir }) => {
                let manifest = crate::runtime::ArtifactManifest::load(artifacts_dir)?;
                let runtime = crate::runtime::Runtime::cpu()?;
                let eval = crate::runtime::evaluator::XlaSparseEvaluator::new(
                    source, &runtime, &manifest,
                )?;
                dd::solve_dd_with_driven_clocked(
                    source, &eval, config, cluster, init, observer, clock,
                )
            }
            // the planner never produces these pairings; plan.backend is
            // pub, so a hand-mutated plan must fail loudly instead of
            // silently running the wrong algorithm
            (algo, backend) => Err(Error::InvalidConfig(format!(
                "plan pairs {algo:?} with backend {}, which cannot run it",
                backend.name()
            ))),
        };
        let mut report = result?;
        if let Some(r) = &self.remote {
            // membership changes (losses, redials, admissions,
            // degradations) in occurrence order — same annotation
            // discipline as the staged-I/O stats below
            report.membership = r.membership_events();
        }
        if let Some(staged) = &self.staged {
            // annotate the report with what the I/O plane did: wait_ms is
            // the compute-visible stall, read_ms the overlapped work
            let io = staged.io_stats();
            report.phases.io_read_ms = io.read_ms;
            report.phases.io_wait_ms = io.wait_ms;
            report.phases.io_bytes = io.bytes_read;
            report.phases.io_prefetch_hits = io.prefetch_hits;
            report.phases.io_prefetch_misses = io.prefetch_misses;
        }
        Ok(report)
    }
}
