//! Budget-perturbed view of an instance.
//!
//! The daily production pattern is "same items, new budgets": campaign
//! budgets and prices drift a few percent between runs. [`ScaledBudgets`]
//! wraps any [`GroupSource`] and replaces only `B_k` — group data is
//! untouched and still streams from the original source (in-memory or
//! out-of-core) — which is exactly the shape a warm-started re-solve
//! consumes.

use crate::error::{Error, Result};
use crate::instance::laminar::LaminarProfile;
use crate::instance::problem::{Dims, GroupBuf, GroupSource};

/// A [`GroupSource`] with its global budgets scaled (uniformly or per
/// constraint). Everything else delegates to the wrapped source.
pub struct ScaledBudgets<'a> {
    inner: &'a dyn GroupSource,
    budgets: Vec<f64>,
}

impl<'a> ScaledBudgets<'a> {
    /// Scale every budget by `factor` (> 0).
    pub fn uniform(inner: &'a dyn GroupSource, factor: f64) -> Result<Self> {
        if !(factor > 0.0) || !factor.is_finite() {
            return Err(Error::InvalidConfig(format!(
                "budget scale factor must be finite and > 0, got {factor}"
            )));
        }
        let budgets = inner.budgets().iter().map(|b| b * factor).collect();
        Ok(Self { inner, budgets })
    }

    /// Scale budget `k` by `factors[k]` (all > 0; length must be `K`).
    pub fn per_constraint(inner: &'a dyn GroupSource, factors: &[f64]) -> Result<Self> {
        let k = inner.dims().n_global;
        if factors.len() != k {
            return Err(Error::InvalidConfig(format!(
                "expected {k} budget factors, got {}",
                factors.len()
            )));
        }
        if let Some(bad) = factors.iter().find(|f| !(**f > 0.0) || !f.is_finite()) {
            return Err(Error::InvalidConfig(format!(
                "budget factors must be finite and > 0, got {bad}"
            )));
        }
        let budgets = inner.budgets().iter().zip(factors).map(|(b, f)| b * f).collect();
        Ok(Self { inner, budgets })
    }

    /// The wrapped source.
    pub fn inner(&self) -> &'a dyn GroupSource {
        self.inner
    }
}

impl GroupSource for ScaledBudgets<'_> {
    fn dims(&self) -> Dims {
        self.inner.dims()
    }

    fn is_dense(&self) -> bool {
        self.inner.is_dense()
    }

    fn locals(&self) -> &LaminarProfile {
        self.inner.locals()
    }

    fn budgets(&self) -> &[f64] {
        &self.budgets
    }

    fn fill_group(&self, i: usize, buf: &mut GroupBuf) {
        self.inner.fill_group(i, buf)
    }

    fn block_end(&self, start: usize, end: usize) -> usize {
        self.inner.block_end(start, end)
    }

    fn fill_block<'a>(
        &'a self,
        start: usize,
        end: usize,
        buf: &'a mut crate::instance::problem::BlockBuf,
    ) -> crate::instance::problem::GroupBlock<'a> {
        self.inner.fill_block(start, end, buf)
    }

    fn preferred_shard_size(&self) -> Option<usize> {
        self.inner.preferred_shard_size()
    }

    fn store_dir(&self) -> Option<std::path::PathBuf> {
        self.inner.store_dir()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};

    #[test]
    fn scales_budgets_only() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(100, 4, 4).with_seed(3));
        let s = ScaledBudgets::uniform(&p, 1.25).unwrap();
        assert_eq!(s.dims(), p.dims());
        assert_eq!(s.is_dense(), p.is_dense());
        for (a, b) in s.budgets().iter().zip(p.budgets()) {
            assert!((a - b * 1.25).abs() < 1e-12);
        }
        let mut b1 = GroupBuf::new(p.dims(), p.is_dense());
        let mut b2 = GroupBuf::new(p.dims(), p.is_dense());
        p.fill_group(7, &mut b1);
        s.fill_group(7, &mut b2);
        assert_eq!(b1.profits, b2.profits);
        s.validate().unwrap();
    }

    #[test]
    fn per_constraint_checks_inputs() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(50, 3, 3).with_seed(1));
        assert!(ScaledBudgets::per_constraint(&p, &[1.0, 1.0]).is_err());
        assert!(ScaledBudgets::per_constraint(&p, &[1.0, -1.0, 1.0]).is_err());
        assert!(ScaledBudgets::uniform(&p, 0.0).is_err());
        assert!(ScaledBudgets::uniform(&p, f64::NAN).is_err());
        let s = ScaledBudgets::per_constraint(&p, &[0.9, 1.0, 1.1]).unwrap();
        assert!((s.budgets()[2] - p.budgets()[2] * 1.1).abs() < 1e-12);
    }
}
