//! Warm starts and λ checkpoint files.
//!
//! Production re-solves the same instance daily as budgets and prices
//! drift; near-optimal λ varies smoothly with the budgets (Nakamura et
//! al.'s statistical-mechanics analysis of multi-dimensional knapsacks),
//! so yesterday's `λ*` is an excellent start for today's solve. A
//! [`WarmStart`] carries such a vector — taken from a prior
//! [`SolveReport`], a checkpoint file, or raw numbers — into
//! [`crate::solve::Solve`].
//!
//! The checkpoint file is a tiny self-describing text format (the offline
//! registry has no serde), XXH64-checksummed and written atomically
//! (temp file + rename), so a checkpoint interrupted mid-write can never
//! be mistaken for a valid one:
//!
//! ```text
//! bskp-lambda v1
//! iter 12
//! k 3
//! l 1.0
//! l 0.0
//! l 0.35
//! sum 1f2e3d4c5b6a7988
//! ```

use crate::error::{Error, Result};
use crate::instance::store::checksum::xxh64;
use crate::solver::stats::SolveReport;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Magic first line of a checkpoint file.
const MAGIC: &str = "bskp-lambda v1";
/// Seed for the checkpoint checksum (any fixed value works; distinct from
/// the shard-store seed so a file can't masquerade as both).
const SUM_SEED: u64 = 0x6c61_6d62_6461_3031; // "lambda01"

/// A λ vector to seed a solve with, plus human-readable provenance (shown
/// in the plan summary).
#[derive(Debug, Clone, PartialEq)]
pub struct WarmStart {
    /// The multipliers to start from (length must equal the instance's
    /// `K`; checked by [`crate::solve::Solve::plan`]).
    pub lambda: Vec<f64>,
    /// Where the vector came from, for plan notes (e.g. `"checkpoint
    /// /data/store/lambda.ckpt (round 12)"`).
    pub provenance: String,
}

impl WarmStart {
    /// Warm-start from a raw λ vector.
    pub fn from_lambda(lambda: Vec<f64>) -> Self {
        Self { lambda, provenance: "caller-supplied λ".into() }
    }

    /// Warm-start from a finished solve's final multipliers — the
    /// `resolve`-with-changed-budgets path.
    pub fn from_report(report: &SolveReport) -> Self {
        Self {
            lambda: report.lambda.clone(),
            provenance: format!("prior solve ({} rounds)", report.iterations),
        }
    }

    /// Warm-start from a checkpoint file written by
    /// [`write_checkpoint`] / [`crate::solve::CheckpointObserver`].
    pub fn from_checkpoint<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let ckpt = read_checkpoint(path)?;
        Ok(Self {
            lambda: ckpt.lambda,
            provenance: format!("checkpoint {} (round {})", path.display(), ckpt.iter),
        })
    }
}

/// A parsed checkpoint: the round it was taken after and the multipliers.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Iteration index the λ vector was adopted at (0-based).
    pub iter: usize,
    /// The multipliers `λ^{iter+1}`.
    pub lambda: Vec<f64>,
}

/// The canonical checkpoint file name inside a shard-store directory.
pub const CHECKPOINT_FILE: &str = "lambda.ckpt";

/// Default checkpoint path for a source that lives in `store_dir`.
pub fn default_checkpoint_path(store_dir: &Path) -> PathBuf {
    store_dir.join(CHECKPOINT_FILE)
}

fn body_text(iter: usize, lambda: &[f64]) -> String {
    let mut body = String::with_capacity(24 * lambda.len() + 64);
    let _ = writeln!(body, "iter {iter}");
    let _ = writeln!(body, "k {}", lambda.len());
    for l in lambda {
        // {:?} is rust's shortest-roundtrip float formatting: the parsed
        // value is bit-identical to the written one
        let _ = writeln!(body, "l {l:?}");
    }
    body
}

/// Write a λ checkpoint atomically: the content is written and fsynced to
/// a process-unique temp file, then renamed into place — readers only
/// ever see complete files, and concurrent writers to the same store
/// cannot interleave (last completed rename wins, each with valid
/// content).
pub fn write_checkpoint(path: &Path, iter: usize, lambda: &[f64]) -> Result<()> {
    if let Some(bad) = lambda.iter().find(|x| !x.is_finite()) {
        return Err(Error::InvalidConfig(format!("refusing to checkpoint non-finite λ = {bad}")));
    }
    let body = body_text(iter, lambda);
    let sum = xxh64(body.as_bytes(), SUM_SEED);
    let text = format!("{MAGIC}\n{body}sum {sum:016x}\n");
    // unique per process *and* per call, so concurrent sessions (across
    // or within a process) each stage their own file; the final rename
    // is atomic and last-writer-wins with valid content
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = path.with_extension(format!("ckpt.tmp.{}.{seq}", std::process::id()));
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(text.as_bytes())?;
        f.sync_all()?;
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        std::fs::remove_file(&tmp).ok();
        return Err(e.into());
    }
    Ok(())
}

fn malformed(path: &Path, why: impl std::fmt::Display) -> Error {
    Error::InvalidConfig(format!("malformed checkpoint {}: {why}", path.display()))
}

/// Read and verify a λ checkpoint written by [`write_checkpoint`].
pub fn read_checkpoint(path: &Path) -> Result<Checkpoint> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        Error::InvalidConfig(format!("cannot read checkpoint {}: {e}", path.display()))
    })?;
    let mut lines = text.lines();
    if lines.next() != Some(MAGIC) {
        return Err(malformed(path, format!("missing {MAGIC:?} header")));
    }
    let mut iter: Option<usize> = None;
    let mut k: Option<usize> = None;
    let mut lambda = Vec::new();
    let mut sum: Option<u64> = None;
    // the checksum covers the *literal* body lines (LF-normalized), not a
    // canonical re-serialization, so any writer whose float formatting
    // differs from rust's `{:?}` (e.g. the Python mirror's `repr`) still
    // produces checkpoints this reader accepts
    let mut body = String::new();
    for line in lines {
        let trimmed = line.trim();
        if let Some(v) = trimmed.strip_prefix("sum ") {
            sum =
                Some(u64::from_str_radix(v, 16).map_err(|_| malformed(path, "bad checksum"))?);
            break;
        }
        body.push_str(line);
        body.push('\n');
        let (key, val) = trimmed
            .split_once(' ')
            .ok_or_else(|| malformed(path, format!("bad line {trimmed:?}")))?;
        match key {
            "iter" => {
                iter = Some(val.parse().map_err(|_| malformed(path, "bad iter"))?);
            }
            "k" => {
                k = Some(val.parse().map_err(|_| malformed(path, "bad k"))?);
            }
            "l" => {
                lambda.push(val.parse().map_err(|_| malformed(path, "bad λ value"))?);
            }
            other => return Err(malformed(path, format!("unknown key {other:?}"))),
        }
    }
    let iter = iter.ok_or_else(|| malformed(path, "missing iter"))?;
    let k = k.ok_or_else(|| malformed(path, "missing k"))?;
    if lambda.len() != k {
        return Err(malformed(path, format!("declared k={k} but found {} λ lines", lambda.len())));
    }
    // same λ domain rule as the drivers (finite, ≥ 0) — one validator,
    // so the reader and initial_lambda can never drift
    if let Err(m) = crate::solver::scd::check_warm_lambda(&lambda, k) {
        return Err(malformed(path, format!("λ {m}")));
    }
    let sum = sum.ok_or_else(|| malformed(path, "missing checksum"))?;
    let expect = xxh64(body.as_bytes(), SUM_SEED);
    if sum != expect {
        return Err(malformed(
            path,
            format!("checksum mismatch (file {sum:016x}, computed {expect:016x})"),
        ));
    }
    Ok(Checkpoint { iter, lambda })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bskp_warm_{}_{name}", std::process::id()))
    }

    #[test]
    fn checkpoint_roundtrip_is_exact() {
        let path = tmp("roundtrip.ckpt");
        let lambda = vec![0.0, 1.0, 0.123456789012345, 1e-12, 3.5e8];
        write_checkpoint(&path, 7, &lambda).unwrap();
        let ckpt = read_checkpoint(&path).unwrap();
        assert_eq!(ckpt.iter, 7);
        assert_eq!(ckpt.lambda, lambda); // bit-exact via {:?} round-trip
        let warm = WarmStart::from_checkpoint(&path).unwrap();
        assert_eq!(warm.lambda, lambda);
        assert!(warm.provenance.contains("round 7"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let path = tmp("corrupt.ckpt");
        write_checkpoint(&path, 3, &[1.0, 2.0]).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text = text.replace("l 1.0", "l 1.5");
        std::fs::write(&path, text).unwrap();
        let err = read_checkpoint(&path).unwrap_err();
        assert!(err.to_string().contains("checksum"), "unexpected error: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_and_missing_files_are_clean_errors() {
        let path = tmp("garbage.ckpt");
        std::fs::write(&path, "not a checkpoint\n").unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
        assert!(read_checkpoint(Path::new("/nonexistent/bskp.ckpt")).is_err());
        assert!(WarmStart::from_checkpoint("/nonexistent/bskp.ckpt").is_err());
    }

    #[test]
    fn rejects_negative_and_nonfinite_lambda() {
        let path = tmp("neg.ckpt");
        assert!(write_checkpoint(&path, 0, &[f64::NAN]).is_err());
        // hand-craft a negative λ with a valid checksum: reader must still
        // refuse it
        let body = "iter 0\nk 1\nl -1.0\n";
        let sum = xxh64(body.as_bytes(), SUM_SEED);
        std::fs::write(&path, format!("{MAGIC}\n{body}sum {sum:016x}\n")).unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
