//! The span flight recorder: lock-free per-thread ring buffers.
//!
//! Every recording thread owns one fixed-size ring of 6-word slots
//! (sequence word + five payload words, all `AtomicU64`). The owning
//! thread is the only writer, so a push is five relaxed stores bracketed
//! by two sequence stores — no locks, no allocation, and a full ring
//! simply overwrites its oldest events (it is a *flight* recorder, not a
//! log). Readers ([`snapshot`], [`dump_text`]) validate each slot's
//! sequence word before and after reading the payload and skip torn
//! slots, seqlock-style; everything is atomics, so concurrent snapshots
//! are safe (merely approximate) while quiesced snapshots are exact.
//!
//! [`canonical`] is the replay-comparison form: the multiset of event
//! *identities* `(track, kind, code, a, b)`, sorted. Timestamps are
//! deliberately excluded — under the simulator the global virtual clock
//! is a running maximum over all links, so the instant at which a
//! causally-unrelated event reads it can differ between replays (the
//! same caveat `cluster::sim` documents for cross-direction event order).
//! The identity multiset is interleaving-independent, which is what the
//! sim-determinism property suite pins down.

use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Default events per thread ring (`PALLAS_TRACE_BUF` overrides).
pub const DEFAULT_RING_EVENTS: usize = 1 << 14;

/// The logical timeline an event belongs to. Tracks are assigned by the
/// *instrumentation site* (a leader round, a worker link slot, the io
/// layer), not by OS thread — thread scheduling must never leak into a
/// trace's shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Track {
    /// The solve driver / cluster leader.
    Leader,
    /// A worker process (index 0 when the worker cannot know its slot).
    Worker(u16),
    /// One leader↔worker link, by leader slot index.
    Link(u16),
    /// The async I/O subsystem.
    Io,
    /// The serve daemon's request plane.
    Serve,
}

impl Track {
    fn pack(self) -> u32 {
        match self {
            Track::Leader => 0,
            Track::Worker(i) => (1 << 16) | i as u32,
            Track::Link(i) => (2 << 16) | i as u32,
            Track::Io => 3 << 16,
            Track::Serve => 4 << 16,
        }
    }

    fn unpack(v: u32) -> Self {
        let idx = (v & 0xFFFF) as u16;
        match v >> 16 {
            1 => Track::Worker(idx),
            2 => Track::Link(idx),
            3 => Track::Io,
            4 => Track::Serve,
            _ => Track::Leader,
        }
    }

    /// Stable numeric id (Chrome `tid`, canonical sort key).
    pub fn tid(self) -> u32 {
        self.pack()
    }

    /// Human label for dumps and Chrome thread names.
    pub fn label(self) -> String {
        match self {
            Track::Leader => "leader".into(),
            Track::Worker(i) => format!("worker/{i}"),
            Track::Link(i) => format!("link/{i}"),
            Track::Io => "io".into(),
            Track::Serve => "serve".into(),
        }
    }
}

/// What shape of event a record is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A duration: `[t_ns, t_ns + dur_ns)`.
    Span,
    /// A zero-duration marker.
    Instant,
}

impl EventKind {
    fn to_u8(self) -> u8 {
        match self {
            EventKind::Span => 0,
            EventKind::Instant => 1,
        }
    }

    fn from_u8(v: u8) -> Self {
        if v == 1 { EventKind::Instant } else { EventKind::Span }
    }
}

/// One recorded event (the decoded form of a ring slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EventRecord {
    /// Logical timeline.
    pub track: Track,
    /// Span or instant.
    pub kind: EventKind,
    /// Name code ([`crate::obs::names`]).
    pub code: u16,
    /// Start time, clock nanoseconds.
    pub t_ns: u64,
    /// Duration, nanoseconds (0 for instants).
    pub dur_ns: u64,
    /// First argument word (site-defined; round index, shard index, …).
    pub a: u64,
    /// Second argument word (site-defined; chunk lo, byte count, …).
    pub b: u64,
}

const WORDS: usize = 6;

struct Slot {
    w: [AtomicU64; WORDS],
}

struct Ring {
    slots: Box<[Slot]>,
    /// Events ever pushed this epoch (single writer; readers load it).
    head: AtomicU64,
}

impl Ring {
    fn new(cap: usize) -> Self {
        let slots = (0..cap.max(16))
            .map(|_| Slot { w: std::array::from_fn(|_| AtomicU64::new(0)) })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Self { slots, head: AtomicU64::new(0) }
    }

    /// Owner-thread push (the sole writer of this ring).
    fn push(&self, e: &EventRecord) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(h as usize) % self.slots.len()];
        // invalidate, write payload, publish with the new sequence
        slot.w[0].store(0, Ordering::Release);
        fence(Ordering::Release);
        let meta = (e.code as u64)
            | ((e.kind.to_u8() as u64) << 16)
            | ((e.track.pack() as u64) << 32);
        slot.w[1].store(meta, Ordering::Relaxed);
        slot.w[2].store(e.t_ns, Ordering::Relaxed);
        slot.w[3].store(e.dur_ns, Ordering::Relaxed);
        slot.w[4].store(e.a, Ordering::Relaxed);
        slot.w[5].store(e.b, Ordering::Relaxed);
        slot.w[0].store(h + 1, Ordering::Release);
        self.head.store(h + 1, Ordering::Release);
    }

    fn read_into(&self, out: &mut Vec<EventRecord>) {
        for slot in self.slots.iter() {
            let seq = slot.w[0].load(Ordering::Acquire);
            if seq == 0 {
                continue;
            }
            let meta = slot.w[1].load(Ordering::Relaxed);
            let t_ns = slot.w[2].load(Ordering::Relaxed);
            let dur_ns = slot.w[3].load(Ordering::Relaxed);
            let a = slot.w[4].load(Ordering::Relaxed);
            let b = slot.w[5].load(Ordering::Relaxed);
            fence(Ordering::Acquire);
            if slot.w[0].load(Ordering::Relaxed) != seq {
                continue; // torn: the writer lapped us mid-read
            }
            out.push(EventRecord {
                track: Track::unpack((meta >> 32) as u32),
                kind: EventKind::from_u8((meta >> 16) as u8),
                code: meta as u16,
                t_ns,
                dur_ns,
                a,
                b,
            });
        }
    }

    fn clear(&self) {
        for slot in self.slots.iter() {
            slot.w[0].store(0, Ordering::Release);
        }
        self.head.store(0, Ordering::Release);
    }

    fn dropped(&self) -> u64 {
        self.head.load(Ordering::Acquire).saturating_sub(self.slots.len() as u64)
    }
}

fn registry() -> &'static Mutex<Vec<Arc<Ring>>> {
    static R: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Vec::new()))
}

fn ring_events_from_env() -> usize {
    std::env::var("PALLAS_TRACE_BUF")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(DEFAULT_RING_EVENTS)
}

thread_local! {
    static RING: std::cell::OnceCell<Arc<Ring>> = const { std::cell::OnceCell::new() };
}

/// Record one event into the calling thread's ring (creating and
/// registering the ring on first use). Callers gate on
/// [`crate::obs::trace_enabled`]; this function itself never checks.
pub(crate) fn record_event(e: EventRecord) {
    RING.with(|cell| {
        let ring = cell.get_or_init(|| {
            let r = Arc::new(Ring::new(ring_events_from_env()));
            registry().lock().unwrap().push(Arc::clone(&r));
            r
        });
        ring.push(&e);
    });
}

/// Every currently-readable event across all thread rings, in no
/// particular order. Exact when writers are quiesced; torn slots (a
/// writer lapping the reader mid-slot) are skipped.
pub fn snapshot() -> Vec<EventRecord> {
    let rings: Vec<Arc<Ring>> = registry().lock().unwrap().iter().cloned().collect();
    let mut out = Vec::new();
    for ring in rings {
        ring.read_into(&mut out);
    }
    out
}

/// Total events overwritten by ring wraparound since the last [`reset`].
/// Replay-comparison suites assert this is 0 (otherwise the multiset
/// comparison would depend on *which* events each ring dropped).
pub fn dropped() -> u64 {
    registry().lock().unwrap().iter().map(|r| r.dropped()).sum()
}

/// Clear every ring (head and sequence words). Callers must quiesce
/// recording threads first — a concurrent writer may land events across
/// the reset boundary.
pub fn reset() {
    for ring in registry().lock().unwrap().iter() {
        ring.clear();
    }
}

/// The canonical, replay-comparable form of `events`: the identity
/// multiset `(track tid, kind, code, a, b)`, sorted on all fields.
/// Timestamps and durations are excluded by design (see the module docs).
pub fn canonical(events: &[EventRecord]) -> Vec<(u32, u8, u16, u64, u64)> {
    let mut keys: Vec<(u32, u8, u16, u64, u64)> = events
        .iter()
        .map(|e| (e.track.tid(), e.kind.to_u8(), e.code, e.a, e.b))
        .collect();
    keys.sort_unstable();
    keys
}

/// [`canonical`] over a fresh [`snapshot`], rendered one event per line
/// (for assertions and replay diffs).
pub fn canonical_text() -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for (tid, kind, code, a, b) in canonical(&snapshot()) {
        let _ = writeln!(
            out,
            "{} {} {}({code}) a={a} b={b}",
            Track::unpack(tid).label(),
            if kind == 1 { "instant" } else { "span" },
            crate::obs::names::name_of(code),
        );
    }
    out
}

/// The most recent `max_events` events rendered one per line, newest
/// last — the forensic dump chained onto panics and the simulator's
/// hang guard.
pub fn dump_text(max_events: usize) -> String {
    use std::fmt::Write as _;
    let mut events = snapshot();
    events.sort_by_key(|e| (e.t_ns, e.track.tid(), e.code));
    let skip = events.len().saturating_sub(max_events);
    let mut out = String::new();
    for e in events.into_iter().skip(skip) {
        let _ = writeln!(
            out,
            "{:>12}ns +{:<10} {:<9} {:<12} a={} b={}",
            e.t_ns,
            format!("{}ns", e.dur_ns),
            e.track.label(),
            format!("{}({})", crate::obs::names::name_of(e.code), e.code),
            e.a,
            e.b,
        );
    }
    if out.is_empty() {
        out.push_str("(flight recorder empty)\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let ring = Ring::new(16);
        for i in 0..40u64 {
            ring.push(&EventRecord {
                track: Track::Io,
                kind: EventKind::Instant,
                code: 1,
                t_ns: i,
                dur_ns: 0,
                a: i,
                b: 0,
            });
        }
        let mut out = Vec::new();
        ring.read_into(&mut out);
        assert_eq!(out.len(), 16, "ring holds exactly its capacity");
        let min_a = out.iter().map(|e| e.a).min().unwrap();
        assert_eq!(min_a, 24, "oldest events overwritten first");
        assert_eq!(ring.dropped(), 24);
        ring.clear();
        out.clear();
        ring.read_into(&mut out);
        assert!(out.is_empty());
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn track_roundtrips_through_packing() {
        for t in [
            Track::Leader,
            Track::Worker(0),
            Track::Worker(513),
            Track::Link(7),
            Track::Io,
            Track::Serve,
        ] {
            assert_eq!(Track::unpack(t.pack()), t, "{t:?}");
        }
    }

    #[test]
    fn canonical_is_order_independent() {
        let e1 = EventRecord {
            track: Track::Leader,
            kind: EventKind::Span,
            code: 2,
            t_ns: 100,
            dur_ns: 5,
            a: 0,
            b: 0,
        };
        let e2 = EventRecord { track: Track::Link(1), t_ns: 7, a: 3, ..e1 };
        // different timestamps, same identities: canonical forms agree
        let c1 = canonical(&[e1, e2]);
        let c2 = canonical(&[EventRecord { t_ns: 999, dur_ns: 1, ..e2 }, e1]);
        assert_eq!(c1, c2);
    }
}
