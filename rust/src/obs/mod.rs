//! L7 — structured observability: a span **flight recorder**, a
//! **metrics registry**, and **exposition** in Prometheus text and Chrome
//! trace-event JSON.
//!
//! Everything here is zero-dependency and *near-free when disabled*:
//!
//! * Tracing is off by default. The hot check ([`trace_enabled`]) is a
//!   single relaxed atomic load; the `PALLAS_TRACE` knob is resolved
//!   exactly once, like the cluster's `ConnectOptions`, and a disabled
//!   [`span`] never reads the clock or touches a ring.
//! * Metrics are on by default (they back `SolveReport::phases` and the
//!   serve daemon's scrape) and cost one atomic RMW per bump; the
//!   `PALLAS_METRICS` knob turns the per-event histogram work off.
//!
//! Spans are timestamped through the [`Clock`] seam, so a solve driven
//! under the deterministic simulator records *virtual*-time spans and two
//! replays of the same `(seed, FaultPlan)` produce the identical
//! [`recorder::canonical`] trace. The [`recorder`] holds events in
//! lock-free per-thread ring buffers — a crashing run still has its last
//! moments on record ([`install_panic_hook`], and the simulator's hang
//! guard dumps it too).
//!
//! `docs/observability.md` is the user guide.

pub mod chrome;
pub mod metrics;
pub mod prom;
pub mod recorder;

pub use recorder::{EventKind, EventRecord, Track};

use crate::cluster::Clock;
use std::sync::atomic::{AtomicU8, Ordering};

/// Well-known span/event codes. Codes are stable u16s because worker-side
/// spans cross the wire inside L4 frame-header extensions (see
/// `docs/cluster-protocol.md`); [`names::name_of`] maps them back for
/// exposition.
pub mod names {
    /// One whole solve (session root).
    pub const SESSION: u16 = 1;
    /// One solver round; `a` = round index.
    pub const ROUND: u16 = 2;
    /// Round phase: leader-side broadcast bookkeeping.
    pub const BROADCAST: u16 = 3;
    /// Round phase: the map (chunk fan-out / in-process fold).
    pub const MAP: u16 = 4;
    /// Round phase: threshold / gradient reduce + λ update.
    pub const REDUCE: u16 = 5;
    /// The self-consistency re-evaluation at the final λ.
    pub const FINAL_EVAL: u16 = 6;
    /// Feasibility post-processing.
    pub const POSTPROCESS: u16 = 7;
    /// One leader↔worker chunk exchange; `a` = round, `b` = chunk lo.
    pub const EXCHANGE: u16 = 8;
    /// One task executed worker-side; `a` = round, `b` = chunk lo.
    pub const TASK: u16 = 9;
    /// A demand wait on a prefetched shard; `a` = shard index.
    pub const IO_WAIT: u16 = 10;
    /// One backend shard read; `a` = byte offset, `b` = length.
    pub const IO_READ: u16 = 11;
    /// One serve-plane request; `a` = frame kind.
    pub const SERVE_REQUEST: u16 = 12;
    /// A daemon-hosted solve; `a` = session tag.
    pub const SERVE_SOLVE: u16 = 13;
    /// Instant: a chunk went back on the deal queue; `a` = round,
    /// `b` = chunk lo.
    pub const REDEAL: u16 = 14;
    /// Instant: a transiently-dead worker was redialed back into the
    /// deal; `a` = round, `b` = worker slot.
    pub const REDIAL: u16 = 15;
    /// Instant: a fresh worker was admitted mid-solve through the join
    /// listener; `a` = round, `b` = worker slot.
    pub const JOIN: u16 = 16;
    /// Instant: the solve transitioned to a degraded fleet strength;
    /// `a` = round, `b` = live workers.
    pub const DEGRADED: u16 = 17;
    /// A relay (re)assignment: the leader dealt a worker its subtree;
    /// `a` = round, `b` = subtree size (leaf count).
    pub const RELAY_ASSIGN: u16 = 18;
    /// One relay-side fan-in: sub-deal, leaf gather and merge of a task
    /// over a subtree; `a` = round, `b` = chunk lo.
    pub const RELAY_FANIN: u16 = 19;

    /// Human name for a code (unknown codes render as `event/<code>`
    /// would — callers show the number alongside).
    pub fn name_of(code: u16) -> &'static str {
        match code {
            SESSION => "session",
            ROUND => "round",
            BROADCAST => "broadcast",
            MAP => "map",
            REDUCE => "reduce",
            FINAL_EVAL => "final_eval",
            POSTPROCESS => "postprocess",
            EXCHANGE => "exchange",
            TASK => "task",
            IO_WAIT => "io_wait",
            IO_READ => "io_read",
            SERVE_REQUEST => "serve_request",
            SERVE_SOLVE => "serve_solve",
            REDEAL => "redeal",
            REDIAL => "redial",
            JOIN => "join",
            DEGRADED => "degraded",
            RELAY_ASSIGN => "relay_assign",
            RELAY_FANIN => "relay_fanin",
            _ => "event",
        }
    }
}

const UNRESOLVED: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

static TRACE: AtomicU8 = AtomicU8::new(UNRESOLVED);
static METRICS: AtomicU8 = AtomicU8::new(UNRESOLVED);

fn env_flag(var: &str, default_on: bool) -> bool {
    match std::env::var(var) {
        Ok(v) => !matches!(v.as_str(), "" | "0" | "off" | "false"),
        Err(_) => default_on,
    }
}

#[cold]
fn resolve(cell: &AtomicU8, var: &str, default_on: bool) -> bool {
    let on = env_flag(var, default_on);
    // first resolver wins; a concurrent force_* call is not overwritten
    let _ = cell.compare_exchange(
        UNRESOLVED,
        if on { ON } else { OFF },
        Ordering::Relaxed,
        Ordering::Relaxed,
    );
    cell.load(Ordering::Relaxed) == ON
}

/// Is span tracing on? One relaxed load on the hot path; `PALLAS_TRACE`
/// is consulted once, on the first call.
#[inline]
pub fn trace_enabled() -> bool {
    match TRACE.load(Ordering::Relaxed) {
        UNRESOLVED => resolve(&TRACE, "PALLAS_TRACE", false),
        v => v == ON,
    }
}

/// Is per-event metric recording on? (Registry handles always exist and
/// counters always count — this gates the histogram work.) `PALLAS_METRICS`
/// is consulted once; the default is on.
#[inline]
pub fn metrics_enabled() -> bool {
    match METRICS.load(Ordering::Relaxed) {
        UNRESOLVED => resolve(&METRICS, "PALLAS_METRICS", true),
        v => v == ON,
    }
}

/// Force tracing on/off, overriding `PALLAS_TRACE` — `solve --trace` and
/// tests use this.
pub fn force_trace(on: bool) {
    TRACE.store(if on { ON } else { OFF }, Ordering::Relaxed);
}

/// Force metric recording on/off, overriding `PALLAS_METRICS`.
pub fn force_metrics(on: bool) {
    METRICS.store(if on { ON } else { OFF }, Ordering::Relaxed);
}

/// Open a span on `track`: records a [`EventKind::Span`] event with the
/// clocked duration when the guard drops. Disabled tracing returns an
/// inert guard without reading the clock.
pub fn span<'c>(clock: &'c dyn Clock, track: Track, code: u16) -> SpanGuard<'c> {
    if !trace_enabled() {
        return SpanGuard { clock: None, track, code, t0: 0, a: 0, b: 0 };
    }
    SpanGuard { clock: Some(clock), track, code, t0: clock.now_ns(), a: 0, b: 0 }
}

/// A live (or inert) span; see [`span`].
pub struct SpanGuard<'c> {
    clock: Option<&'c dyn Clock>,
    track: Track,
    code: u16,
    t0: u64,
    a: u64,
    b: u64,
}

impl SpanGuard<'_> {
    /// Attach the two argument words (builder form).
    pub fn args(mut self, a: u64, b: u64) -> Self {
        self.a = a;
        self.b = b;
        self
    }

    /// Attach the argument words on an already-held guard.
    pub fn set_args(&mut self, a: u64, b: u64) {
        self.a = a;
        self.b = b;
    }

    /// Whether this guard will record on drop.
    pub fn is_live(&self) -> bool {
        self.clock.is_some()
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(clock) = self.clock {
            let t1 = clock.now_ns();
            recorder::record_event(EventRecord {
                track: self.track,
                kind: EventKind::Span,
                code: self.code,
                t_ns: self.t0,
                dur_ns: t1.saturating_sub(self.t0),
                a: self.a,
                b: self.b,
            });
        }
    }
}

/// Record a completed span from explicit clock readings (for call sites
/// that already hold a stopwatch and must not read the clock twice).
pub fn complete(track: Track, code: u16, t0_ns: u64, dur_ns: u64, a: u64, b: u64) {
    if trace_enabled() {
        recorder::record_event(EventRecord {
            track,
            kind: EventKind::Span,
            code,
            t_ns: t0_ns,
            dur_ns,
            a,
            b,
        });
    }
}

/// Record a zero-duration marker event.
pub fn instant(clock: &dyn Clock, track: Track, code: u16, a: u64, b: u64) {
    if trace_enabled() {
        recorder::record_event(EventRecord {
            track,
            kind: EventKind::Instant,
            code,
            t_ns: clock.now_ns(),
            dur_ns: 0,
            a,
            b,
        });
    }
}

/// Chain a flight-recorder dump onto the process panic hook, so a crash
/// with tracing on leaves the last recorded events on stderr (the CLI
/// installs this; the simulator's hang guard dumps independently).
pub fn install_panic_hook() {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        prev(info);
        if trace_enabled() {
            eprintln!("--- flight recorder (most recent spans) ---");
            eprintln!("{}", recorder::dump_text(64));
        }
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::VirtualClock;

    #[test]
    fn disabled_span_is_inert_and_forced_span_records() {
        // a code no production site uses, so concurrent unit tests that
        // also record into the global rings cannot collide with this one
        const TEST_CODE: u16 = 0x7E57;
        force_trace(false);
        let clock = VirtualClock::new();
        {
            let g = span(clock.as_ref(), Track::Leader, TEST_CODE).args(1, 2);
            assert!(!g.is_live());
        }
        force_trace(true);
        clock.advance_to(5_000);
        {
            let mut g = span(clock.as_ref(), Track::Leader, TEST_CODE);
            assert!(g.is_live());
            g.set_args(424_242, 0);
            clock.advance_to(9_000);
        }
        force_trace(false);
        let events = recorder::snapshot();
        let e = events
            .iter()
            .find(|e| e.code == TEST_CODE && e.a == 424_242)
            .expect("forced span recorded");
        assert_eq!(e.t_ns, 5_000);
        assert_eq!(e.dur_ns, 4_000);
        assert_eq!(e.kind, EventKind::Span);
    }

    #[test]
    fn every_named_code_has_a_label() {
        for code in 1..=19u16 {
            assert_ne!(names::name_of(code), "event", "code {code} unnamed");
        }
        assert_eq!(names::name_of(9999), "event");
    }
}
