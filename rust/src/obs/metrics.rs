//! The metrics registry: counters, gauges and log-bucketed histograms,
//! all plain atomics, registered under stable names and exposed through
//! [`crate::obs::prom`].
//!
//! Handles are `Arc`s resolved once per instrumentation site (a struct
//! field or a local at setup time), so the steady state is one atomic
//! RMW per bump — no name lookups on hot paths. Histograms bucket by
//! powers of two ([`Histogram::bucket_index`]), which makes merges
//! element-wise sums: associative and commutative by construction, a
//! property the proptest suite pins down (partials can therefore be
//! merged in any deal order without perturbing the scrape).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotone counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// An up/down gauge (instantaneous level: active sessions, ring
/// occupancy, queue depth).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Add `n` (may be negative).
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Set the level outright.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bucket count: powers of two from `1` up to `2^(N_BUCKETS-2)`, plus a
/// final overflow bucket. 2^42 ns ≈ 73 min — ample for latencies; byte
/// sizes past 4 TiB land in the overflow bucket.
pub const N_BUCKETS: usize = 44;

/// A log₂-bucketed histogram over `u64` observations.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// The bucket an observation lands in: bucket `i` covers
    /// `(2^(i-1), 2^i]` (bucket 0 holds 0 and 1), the last bucket holds
    /// everything beyond `2^(N_BUCKETS-2)`.
    pub fn bucket_index(v: u64) -> usize {
        let bits = (64 - v.leading_zeros()) as usize; // 0 for v=0
        bits.saturating_sub(if v.is_power_of_two() { 1 } else { 0 }).min(N_BUCKETS - 1)
    }

    /// The inclusive upper bound of bucket `i` (`u64::MAX` for the
    /// overflow bucket).
    pub fn upper_bound(i: usize) -> u64 {
        if i >= N_BUCKETS - 1 { u64::MAX } else { 1u64 << i }
    }

    /// Record one observation.
    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observations.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Immutable copy of the current state.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count(),
            sum: self.sum(),
        }
    }

    /// Fold another histogram's current state into this one (element-wise
    /// sums — the associative/commutative merge).
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
    }
}

/// A plain-value histogram state, for merge-law tests and exposition.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistogramSnapshot {
    /// Per-bucket counts ([`Histogram::bucket_index`] layout).
    pub buckets: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of observations.
    pub sum: u64,
}

impl HistogramSnapshot {
    /// The pure merge the atomic [`Histogram::merge_from`] implements.
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        let n = self.buckets.len().max(other.buckets.len());
        let get = |v: &[u64], i: usize| v.get(i).copied().unwrap_or(0);
        HistogramSnapshot {
            buckets: (0..n).map(|i| get(&self.buckets, i) + get(&other.buckets, i)).collect(),
            count: self.count + other.count,
            sum: self.sum + other.sum,
        }
    }
}

/// One registered metric.
#[derive(Debug, Clone)]
pub enum Metric {
    /// Monotone counter.
    Counter(Arc<Counter>),
    /// Up/down gauge.
    Gauge(Arc<Gauge>),
    /// Log-bucketed histogram.
    Histogram(Arc<Histogram>),
}

/// A named collection of metrics. Lookup happens at instrumentation
/// *setup* (handles are cached); the scrape path walks the sorted map.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<&'static str, Metric>>,
}

impl Registry {
    /// Fresh, empty registry (tests; production uses [`global`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// The counter registered under `name`, creating it on first use.
    /// Panics if `name` is already registered as a different kind — two
    /// sites disagreeing on a metric's type is a programming error.
    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        let mut map = self.inner.lock().unwrap();
        match map.entry(name).or_insert_with(|| Metric::Counter(Arc::new(Counter::default()))) {
            Metric::Counter(c) => Arc::clone(c),
            other => panic!("metric {name} already registered as {}", kind_name(other)),
        }
    }

    /// The gauge registered under `name`, creating it on first use.
    pub fn gauge(&self, name: &'static str) -> Arc<Gauge> {
        let mut map = self.inner.lock().unwrap();
        match map.entry(name).or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default()))) {
            Metric::Gauge(g) => Arc::clone(g),
            other => panic!("metric {name} already registered as {}", kind_name(other)),
        }
    }

    /// The histogram registered under `name`, creating it on first use.
    pub fn histogram(&self, name: &'static str) -> Arc<Histogram> {
        let mut map = self.inner.lock().unwrap();
        match map
            .entry(name)
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::default())))
        {
            Metric::Histogram(h) => Arc::clone(h),
            other => panic!("metric {name} already registered as {}", kind_name(other)),
        }
    }

    /// Visit every metric in name order.
    pub fn visit(&self, mut f: impl FnMut(&'static str, &Metric)) {
        for (name, metric) in self.inner.lock().unwrap().iter() {
            f(name, metric);
        }
    }

    /// Number of registered metrics.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Whether nothing is registered yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn kind_name(m: &Metric) -> &'static str {
    match m {
        Metric::Counter(_) => "counter",
        Metric::Gauge(_) => "gauge",
        Metric::Histogram(_) => "histogram",
    }
}

/// The process-wide registry every production site registers into (the
/// serve daemon scrapes it; `metrics::report_to_json` mirrors it).
pub fn global() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_brackets_powers_of_two() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(5), 3);
        assert_eq!(Histogram::bucket_index(1024), 10);
        assert_eq!(Histogram::bucket_index(u64::MAX), N_BUCKETS - 1);
        // every observation lands at or below its bucket's upper bound
        for v in [0u64, 1, 2, 3, 7, 8, 9, 1 << 20, (1 << 20) + 1] {
            let i = Histogram::bucket_index(v);
            assert!(v <= Histogram::upper_bound(i), "v={v} i={i}");
            if i > 0 {
                assert!(v > Histogram::upper_bound(i - 1), "v={v} below bucket {i}");
            }
        }
    }

    #[test]
    fn histogram_observe_and_merge() {
        let a = Histogram::default();
        let b = Histogram::default();
        for v in [1u64, 5, 5, 1000] {
            a.observe(v);
        }
        b.observe(7);
        a.merge_from(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.sum(), 1 + 5 + 5 + 1000 + 7);
        let snap = a.snapshot();
        assert_eq!(snap.buckets.iter().sum::<u64>(), 5);
    }

    #[test]
    fn registry_hands_back_the_same_handle() {
        let r = Registry::new();
        let c1 = r.counter("x_total");
        let c2 = r.counter("x_total");
        c1.inc();
        c2.add(2);
        assert_eq!(c1.get(), 3);
        assert!(Arc::ptr_eq(&c1, &c2));
        let g = r.gauge("depth");
        g.add(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
        assert_eq!(r.len(), 2);
        // visit order is name order
        let mut names = Vec::new();
        r.visit(|n, _| names.push(n));
        assert_eq!(names, vec!["depth", "x_total"]);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter("m");
        let _ = r.gauge("m");
    }
}
