//! Chrome trace-event JSON export — `solve --trace out.json`, `bskp
//! trace`, and the serve daemon's `ServeMsg::Trace` snapshot all emit
//! this format, loadable in Perfetto / `chrome://tracing`.
//!
//! Spans become balanced `"B"`/`"E"` pairs (instants become `"i"`), one
//! Chrome `tid` per [`Track`]. Within a track the events are emitted by
//! a stack sweep over the spans in start order, so the file is valid by
//! construction: per-tid `B`/`E` nest properly and timestamps are
//! monotone non-decreasing in file order (`ci/obs_smoke.sh` validates
//! exactly these properties). A child span that leaks past its parent's
//! end (clock re-basing of shipped worker spans can round that way) is
//! clamped to the parent, preferring a well-formed file over a
//! nanosecond of tail.

use crate::obs::names;
use crate::obs::recorder::{EventKind, EventRecord, Track};
use std::fmt::Write as _;

/// Timestamp in Chrome's microsecond ticks, 3 decimals (nanosecond
/// resolution survives).
fn ts_us(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1e3)
}

fn push_event(
    out: &mut Vec<(u64, String)>,
    at_ns: u64,
    ph: char,
    tid: u32,
    code: u16,
    args: Option<(u64, u64)>,
) {
    let mut line = format!(
        "{{\"name\":\"{}\",\"cat\":\"bskp\",\"ph\":\"{ph}\",\"ts\":{},\"pid\":0,\"tid\":{tid}",
        names::name_of(code),
        ts_us(at_ns),
    );
    if ph == 'i' {
        line.push_str(",\"s\":\"t\"");
    }
    if let Some((a, b)) = args {
        let _ = write!(line, ",\"args\":{{\"code\":{code},\"a\":{a},\"b\":{b}}}");
    }
    line.push('}');
    out.push((at_ns, line));
}

/// Render `events` as a complete Chrome trace-event JSON document.
pub fn render(events: &[EventRecord]) -> String {
    // group by track
    let mut tracks: Vec<Track> = events.iter().map(|e| e.track).collect();
    tracks.sort_unstable();
    tracks.dedup();

    let mut entries: Vec<(u64, String)> = Vec::with_capacity(events.len() * 2 + tracks.len());
    let mut meta = Vec::new();
    for &track in &tracks {
        let tid = track.tid();
        meta.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            track.label()
        ));

        let mut spans: Vec<&EventRecord> = events
            .iter()
            .filter(|e| e.track == track && e.kind == EventKind::Span)
            .collect();
        // start order; at equal starts the longer span is the parent
        spans.sort_by_key(|e| (e.t_ns, u64::MAX - e.dur_ns));

        // stack sweep: close every span that ends at or before the next
        // span's start, clamp children into their parents
        let mut stack: Vec<u64> = Vec::new(); // open span end times
        for e in &spans {
            while let Some(&end) = stack.last() {
                if end <= e.t_ns {
                    entries_push_end(&mut entries, end, tid);
                    stack.pop();
                } else {
                    break;
                }
            }
            let mut end = e.t_ns.saturating_add(e.dur_ns);
            if let Some(&parent_end) = stack.last() {
                end = end.min(parent_end);
            }
            push_event(&mut entries, e.t_ns, 'B', tid, e.code, Some((e.a, e.b)));
            stack.push(end);
        }
        while let Some(end) = stack.pop() {
            entries_push_end(&mut entries, end, tid);
        }

        for e in events.iter().filter(|e| e.track == track && e.kind == EventKind::Instant) {
            push_event(&mut entries, e.t_ns, 'i', tid, e.code, Some((e.a, e.b)));
        }
    }

    // global stable sort by timestamp: per-tid emission order (already
    // monotone) is preserved, tracks interleave chronologically
    entries.sort_by_key(|(at, _)| *at);

    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    for line in meta.into_iter().chain(entries.into_iter().map(|(_, l)| l)) {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&line);
    }
    out.push_str("\n]}\n");
    out
}

fn entries_push_end(out: &mut Vec<(u64, String)>, at_ns: u64, tid: u32) {
    out.push((at_ns, format!("{{\"ph\":\"E\",\"ts\":{},\"pid\":0,\"tid\":{tid}}}", ts_us(at_ns))));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(track: Track, code: u16, t: u64, dur: u64) -> EventRecord {
        EventRecord { track, kind: EventKind::Span, code, t_ns: t, dur_ns: dur, a: 0, b: 0 }
    }

    /// B/E balance + nesting + monotone ts — the same checks obs_smoke
    /// runs on a real trace.
    fn validate(json: &str) {
        let mut stacks: std::collections::HashMap<String, u64> = Default::default();
        let mut last_ts = f64::NEG_INFINITY;
        for line in json.lines() {
            let line = line.trim().trim_end_matches(',');
            if !line.starts_with('{') || line.contains("\"ph\":\"M\"") {
                continue;
            }
            let field = |key: &str| -> Option<&str> {
                let pat = format!("\"{key}\":");
                let at = line.find(&pat)? + pat.len();
                let rest = &line[at..];
                let end = rest.find(|c: char| c == ',' || c == '}').unwrap_or(rest.len());
                Some(rest[..end].trim_matches('"'))
            };
            let (Some(ph), Some(ts), Some(tid)) = (field("ph"), field("ts"), field("tid"))
            else {
                continue;
            };
            let ts: f64 = ts.parse().unwrap();
            assert!(ts >= last_ts, "timestamps regressed: {ts} < {last_ts}");
            last_ts = ts;
            let depth = stacks.entry(tid.to_string()).or_insert(0);
            match ph {
                "B" => *depth += 1,
                "E" => {
                    assert!(*depth > 0, "E without open B on tid {tid}");
                    *depth -= 1;
                }
                _ => {}
            }
        }
        for (tid, depth) in stacks {
            assert_eq!(depth, 0, "unbalanced B/E on tid {tid}");
        }
    }

    #[test]
    fn nested_spans_emit_balanced_monotone_pairs() {
        let events = vec![
            span(Track::Leader, names::SESSION, 0, 100),
            span(Track::Leader, names::ROUND, 10, 30),
            span(Track::Leader, names::ROUND, 50, 20),
            span(Track::Leader, names::MAP, 12, 20),
            span(Track::Link(0), names::EXCHANGE, 15, 10),
            EventRecord {
                track: Track::Leader,
                kind: EventKind::Instant,
                code: names::REDEAL,
                t_ns: 60,
                dur_ns: 0,
                a: 1,
                b: 2,
            },
        ];
        let json = render(&events);
        validate(&json);
        assert!(json.contains("\"name\":\"session\""), "{json}");
        assert!(json.contains("\"name\":\"exchange\""), "{json}");
        assert!(json.contains("\"ph\":\"i\""), "{json}");
        assert!(json.contains("thread_name"), "{json}");
    }

    #[test]
    fn child_overhang_is_clamped_into_the_parent() {
        // child [10, 200) leaks past parent [0, 100): must clamp, not
        // emit a crossing E
        let events =
            vec![span(Track::Io, names::IO_READ, 0, 100), span(Track::Io, names::IO_WAIT, 10, 190)];
        validate(&render(&events));
    }

    #[test]
    fn empty_snapshot_renders_an_empty_valid_document() {
        let json = render(&[]);
        validate(&json);
        assert!(json.starts_with("{\"traceEvents\":["), "{json}");
    }
}
