//! Prometheus text exposition (format 0.0.4) over a metrics
//! [`Registry`] — what a `bskp serve` daemon answers to a
//! `ServeMsg::Metrics` scrape.
//!
//! Deliberately the plain-text subset: `# TYPE` lines, cumulative
//! `_bucket{le="..."}` series for histograms, sorted by metric name (the
//! registry's own order), no timestamps. Zero dependencies — the format
//! is line-oriented text.

use crate::obs::metrics::{Histogram, Metric, Registry, N_BUCKETS};
use std::fmt::Write as _;

/// Render `registry` in Prometheus text format.
pub fn render_registry(registry: &Registry) -> String {
    let mut out = String::new();
    registry.visit(|name, metric| match metric {
        Metric::Counter(c) => {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", c.get());
        }
        Metric::Gauge(g) => {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", g.get());
        }
        Metric::Histogram(h) => {
            let _ = writeln!(out, "# TYPE {name} histogram");
            let snap = h.snapshot();
            let mut cum = 0u64;
            for (i, &n) in snap.buckets.iter().enumerate() {
                cum += n;
                // skip interior empty buckets to keep scrapes compact;
                // always emit +Inf (required) and any populated bound
                if n == 0 && i < N_BUCKETS - 1 {
                    continue;
                }
                if i >= N_BUCKETS - 1 {
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
                } else {
                    let _ =
                        writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", Histogram::upper_bound(i));
                }
            }
            if snap.buckets.len() < N_BUCKETS {
                let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
            }
            let _ = writeln!(out, "{name}_sum {}", snap.sum);
            let _ = writeln!(out, "{name}_count {}", snap.count);
        }
    });
    out
}

/// [`render_registry`] over the process-wide [`crate::obs::metrics::global`]
/// registry.
pub fn render() -> String {
    render_registry(crate::obs::metrics::global())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Registry;

    #[test]
    fn exposition_is_sorted_typed_and_cumulative() {
        let r = Registry::new();
        r.counter("bskp_rounds_total").add(3);
        r.gauge("bskp_serve_active").set(2);
        let h = r.histogram("bskp_exchange_ns");
        h.observe(3); // bucket 2 (le=4)
        h.observe(100); // bucket 7 (le=128)
        let text = render_registry(&r);

        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.contains(&"# TYPE bskp_rounds_total counter"), "{text}");
        assert!(lines.contains(&"bskp_rounds_total 3"), "{text}");
        assert!(lines.contains(&"# TYPE bskp_serve_active gauge"), "{text}");
        assert!(lines.contains(&"bskp_serve_active 2"), "{text}");
        assert!(lines.contains(&"bskp_exchange_ns_bucket{le=\"4\"} 1"), "{text}");
        assert!(lines.contains(&"bskp_exchange_ns_bucket{le=\"128\"} 2"), "{text}");
        assert!(lines.contains(&"bskp_exchange_ns_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(lines.contains(&"bskp_exchange_ns_sum 103"), "{text}");
        assert!(lines.contains(&"bskp_exchange_ns_count 2"), "{text}");
        // name-sorted: the histogram series precede the counter lines
        let hist_at = lines.iter().position(|l| l.contains("exchange_ns_count")).unwrap();
        let ctr_at = lines.iter().position(|l| *l == "bskp_rounds_total 3").unwrap();
        assert!(hist_at < ctr_at, "{text}");
    }
}
