//! The legacy leader façade: binds an algorithm (DD / SCD), a map backend
//! (pure rust / XLA artifacts) and a cluster, and drives a solve.
//!
//! **Prefer [`crate::solve::Solve`]** — the session API that replaced this
//! as the application entry point (the CLI and the examples go through
//! it). `Coordinator` keeps its original strict semantics for existing
//! callers: it *errors* on an algorithm×backend×shape combination it
//! cannot run, where `Solve::plan()` falls back with a recorded reason.
//! The [`Algorithm`] and [`Backend`] enums defined here are shared by
//! both paths. See `docs/solve-api.md` for migration notes.

use crate::error::{Error, Result};
use crate::instance::problem::GroupSource;
use crate::mapreduce::Cluster;
use crate::runtime::{ArtifactManifest, Runtime, XlaDenseEvaluator};
use crate::solver::config::SolverConfig;
use crate::solver::stats::SolveReport;
use crate::solver::{dd, scd};
use std::path::PathBuf;

/// Which of the paper's two distributed algorithms to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Algorithm 4 — synchronous coordinate descent (the paper's choice
    /// for production).
    Scd,
    /// Algorithm 2 — dual descent with learning rate `α`.
    Dd,
}

/// Where the map phase executes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust greedy mappers (works for every instance shape).
    Rust,
    /// AOT XLA artifacts via PJRT (dense single-cap or sparse
    /// identity-mapped shapes; others fall back to rust with a notice).
    Xla {
        /// Directory holding `manifest.txt` + `*.hlo.txt`.
        artifacts_dir: PathBuf,
    },
}

/// Leader configuration.
pub struct Coordinator {
    /// Worker pool.
    pub cluster: Cluster,
    /// Solver parameters.
    pub config: SolverConfig,
    /// DD or SCD.
    pub algorithm: Algorithm,
    /// Map-phase backend.
    pub backend: Backend,
}

impl Coordinator {
    /// A rust-backend SCD coordinator with default parameters.
    pub fn new(cluster: Cluster) -> Self {
        Self {
            cluster,
            config: SolverConfig::default(),
            algorithm: Algorithm::Scd,
            backend: Backend::Rust,
        }
    }

    /// Select the algorithm (builder style).
    pub fn with_algorithm(mut self, a: Algorithm) -> Self {
        self.algorithm = a;
        self
    }

    /// Select the backend.
    pub fn with_backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    /// Replace the solver config.
    pub fn with_config(mut self, c: SolverConfig) -> Self {
        self.config = c;
        self
    }

    /// Solve `source`, dispatching on algorithm × backend × instance shape.
    pub fn solve(&self, source: &dyn GroupSource) -> Result<SolveReport> {
        match (&self.algorithm, &self.backend) {
            (Algorithm::Scd, Backend::Rust) => scd::solve_scd(source, &self.config, &self.cluster),
            (Algorithm::Dd, Backend::Rust) => dd::solve_dd(source, &self.config, &self.cluster),
            (Algorithm::Scd, Backend::Xla { artifacts_dir }) => {
                // shape gate first: the guidance error must fire whether or
                // not the artifacts directory is present
                if !crate::solver::sparse_q::xla_identity_eligible(source) {
                    return Err(Error::Runtime(
                        "SCD XLA backend requires a sparse identity-mapped instance \
                         (M = K, single local cap); use Backend::Rust for this shape"
                            .into(),
                    ));
                }
                let manifest = ArtifactManifest::load(artifacts_dir)?;
                let runtime = Runtime::cpu()?;
                crate::runtime::solve_scd_xla_sparse(
                    source,
                    &self.config,
                    &self.cluster,
                    &runtime,
                    &manifest,
                )
            }
            (Algorithm::Dd, Backend::Xla { artifacts_dir }) => {
                let manifest = ArtifactManifest::load(artifacts_dir)?;
                let runtime = Runtime::cpu()?;
                if source.is_dense() {
                    let eval = XlaDenseEvaluator::new(source, &runtime, &manifest)?;
                    dd::solve_dd_with(source, &eval, &self.config, &self.cluster)
                } else {
                    let eval = crate::runtime::evaluator::XlaSparseEvaluator::new(
                        source, &runtime, &manifest,
                    )?;
                    dd::solve_dd_with(source, &eval, &self.config, &self.cluster)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};

    #[test]
    fn scd_rust_via_coordinator() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_000, 8, 8).with_seed(1));
        let coord = Coordinator::new(Cluster::new(2));
        let r = coord.solve(&p).unwrap();
        assert!(r.is_feasible());
        assert!(r.primal_value > 0.0);
    }

    #[test]
    fn dd_rust_via_coordinator() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_000, 8, 8).with_seed(2));
        let coord = Coordinator::new(Cluster::new(2)).with_algorithm(Algorithm::Dd);
        let r = coord.solve(&p).unwrap();
        assert!(r.is_feasible());
    }

    #[test]
    fn xla_backend_rejects_ineligible_shapes() {
        // dense instance on the SCD XLA path must error with guidance; the
        // shape gate fires before any artifact loading, so the message is
        // deterministic even when no artifacts directory exists
        let p = SyntheticProblem::new(GeneratorConfig::dense(100, 4, 4));
        let coord = Coordinator::new(Cluster::new(1))
            .with_backend(Backend::Xla { artifacts_dir: "artifacts".into() });
        let err = coord.solve(&p).expect_err("ineligible shape must be rejected");
        let msg = err.to_string();
        assert!(
            msg.contains("sparse identity-mapped") && msg.contains("Backend::Rust"),
            "missing guidance in error: {msg}"
        );
    }
}
