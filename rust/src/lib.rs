//! # bskp — Billion-Scale Knapsack Solver
//!
//! Reproduction of *"Solving Billion-Scale Knapsack Problems"* (Zhang, Qi,
//! Hua, Yang — WWW 2020): distributed dual-decomposition solvers (dual
//! descent and synchronous coordinate descent) for generalized knapsack
//! problems with global knapsack constraints and hierarchical (laminar)
//! per-group local constraints.
//!
//! The crate is the **Layer-3 rust coordinator** of a four-layer stack:
//!
//! * **L4 ([`cluster`])** — the distributed runtime: `pallas worker`
//!   processes serving their shard-store replicas over a checksummed TCP
//!   wire protocol, driven by a leader that re-dispatches work around
//!   failures. `bskp solve --cluster host:port,...` runs the same solvers
//!   across machines. The runtime is generic over a transport seam, so the
//!   identical code also runs on a deterministic in-memory simulator
//!   ([`cluster::SimNet`]) with seeded fault injection and a virtual
//!   clock — every distributed failure is replayable from a seed
//!   (`docs/simulation.md`). The same frame layer hosts the [`serve`]
//!   plane: `bskp serve` keeps a store mmapped and the last converged λ
//!   warm, answering solve/resolve, point-query and progress requests
//!   (`docs/serve-api.md`).
//! * **L3 (this crate)** — problem model, MapReduce-style execution engine,
//!   the paper's algorithms (Alg 1–5 plus the §5 speedups), LP-relaxation
//!   bound, metrics and a CLI.
//! * **L2 (python/compile/model.py)** — JAX compute graph for the dense map
//!   phase, AOT-lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (interpret mode) for
//!   the adjusted-profit contraction / top-C selection / consumption.
//!
//! At solve time only rust runs; [`runtime`] loads the AOT artifacts through
//! the PJRT C API (`xla` crate, behind the `xla` cargo feature — the
//! default build has zero external dependencies and uses the pure-rust map
//! phase) and executes them from the map workers.
//!
//! Instances larger than RAM solve through the out-of-core shard store
//! ([`instance::store`]): `bskp gen --out <dir>` writes checksummed
//! columnar shard files, `bskp solve --from <dir>` memory-maps them and
//! runs the same solvers off disk.
//!
//! ## Quickstart
//!
//! Every solve goes through the staged session API in [`solve`]: bind an
//! instance, `plan()` (inspectable dispatch with a recorded reason for
//! every fallback), then `run()`:
//!
//! ```no_run
//! use bskp::instance::generator::{GeneratorConfig, SyntheticProblem};
//! use bskp::mapreduce::Cluster;
//! use bskp::solve::Solve;
//!
//! let gen = GeneratorConfig::sparse(100_000, 10, 10).with_seed(7);
//! let problem = SyntheticProblem::new(gen);
//! let plan = Solve::on(&problem).cluster(Cluster::new(8)).plan().unwrap();
//! println!("{plan}"); // algorithm/backend/reduce/shards + fallback notes
//! let report = plan.run().unwrap();
//! println!("primal={} gap={}", report.primal_value, report.duality_gap());
//! ```
//!
//! Daily production re-solves warm-start from yesterday's multipliers and
//! checkpoint λ next to the shard store so interrupted solves resume:
//!
//! ```no_run
//! # use bskp::instance::generator::{GeneratorConfig, SyntheticProblem};
//! # use bskp::solve::{Solve, WarmStart};
//! # let problem = SyntheticProblem::new(GeneratorConfig::sparse(1000, 10, 10));
//! # let yesterday = Solve::on(&problem).run().unwrap();
//! let report = Solve::on(&problem)
//!     .warm(WarmStart::from_report(&yesterday))
//!     .checkpoint_auto(5)
//!     .run()
//!     .unwrap();
//! ```
//!
//! The free functions `solver::scd::solve_scd` / `solver::dd::solve_dd`
//! remain as thin wrappers for benchmarks that need tight control.

pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod error;
pub mod exact;
pub mod instance;
pub mod io;
pub mod lp;
pub mod mapreduce;
pub mod metrics;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod solve;
pub mod solver;
pub mod util;

pub use error::{Error, Result};
