//! `bskp` binary: the L3 leader CLI.

fn main() {
    let code = bskp::cli::run(std::env::args());
    std::process::exit(code);
}
