//! `bskp` binary: the L3 leader CLI.

fn main() {
    // a crash with PALLAS_TRACE on leaves the flight recorder's last
    // spans on stderr
    bskp::obs::install_panic_hook();
    let code = bskp::cli::run(std::env::args());
    std::process::exit(code);
}
