//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry carries no `rand`, so we implement the two
//! standard small generators the solver needs:
//!
//! * [`SplitMix64`] — stateless-style stream used to derive per-group seeds
//!   (`hash(seed, group_id)`), which is what lets [`crate::instance::generator`]
//!   materialize any group of a billion-group instance independently (the
//!   property the paper's mappers rely on).
//! * [`Xoshiro256pp`] — the general-purpose generator for sampling,
//!   shuffling and the property-test harness.
//!
//! Both match the published reference outputs (tested below).

/// SplitMix64 (Steele, Lea, Flood 2014). Also used to seed xoshiro.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a stream from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniform random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// One-shot avalanche mix of two words; used to derive independent
/// per-group seeds from `(instance_seed, group_id)`.
#[inline]
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut s = SplitMix64::new(a ^ b.wrapping_mul(0x9E3779B97F4A7C15));
    // two rounds decorrelate consecutive group ids thoroughly
    s.next_u64();
    s.next_u64()
}

/// xoshiro256++ 1.0 (Blackman & Vigna 2019).
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next 64 uniform random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // use the top 53 bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n). Lemire's multiply-shift rejection-free
    /// variant is unnecessary here; modulo bias is irrelevant for n ≪ 2^64
    /// in test/sampling use, but we still debias via widening multiply.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference outputs for seed 1234567 (from the public C reference).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_decorrelates_seeds() {
        let a: Vec<u64> = {
            let mut r = Xoshiro256pp::new(1);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256pp::new(2);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Xoshiro256pp::new(42);
        for _ in 0..10_000 {
            let x = r.uniform(2.0, 5.0);
            assert!((2.0..5.0).contains(&x));
            let y = r.next_f32();
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn uniform_mean_is_centred() {
        let mut r = Xoshiro256pp::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Xoshiro256pp::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Xoshiro256pp::new(3);
        for _ in 0..100 {
            let s = r.sample_distinct(50, 10);
            assert_eq!(s.len(), 10);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn mix64_spreads_group_ids() {
        // consecutive group ids must produce uncorrelated seeds
        let s0 = mix64(99, 0);
        let s1 = mix64(99, 1);
        assert_ne!(s0, s1);
        // crude avalanche check: at least 16 differing bits
        assert!((s0 ^ s1).count_ones() >= 16);
    }

    #[test]
    fn determinism() {
        let mut a = Xoshiro256pp::new(5);
        let mut b = Xoshiro256pp::new(5);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
