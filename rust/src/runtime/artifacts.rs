//! Artifact registry: the manifest written by `python/compile/aot.py`.
//!
//! Format (tab-separated): `name  entry  n  m  k  cap  filename`.

use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled entry point with its baked shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    /// Artifact name (`eval_dense_n2048_m10_k10_c1`).
    pub name: String,
    /// Entry point: `eval_dense`, `eval_sparse` or `scd_sparse`.
    pub entry: String,
    /// Shard batch size baked into the artifact.
    pub n: usize,
    /// Items per group.
    pub m: usize,
    /// Global constraints.
    pub k: usize,
    /// Local cap (`C` / `Q`).
    pub cap: u32,
    /// HLO text file, absolute.
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    entries: Vec<ArtifactEntry>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.txt")).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {}/manifest.txt (run `make artifacts` first): {e}",
                dir.display()
            ))
        })?;
        let mut entries = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split('\t').collect();
            if parts.len() != 7 {
                return Err(Error::Runtime(format!(
                    "manifest line {} malformed: {line:?}",
                    ln + 1
                )));
            }
            let parse = |s: &str| -> Result<usize> {
                s.parse().map_err(|_| Error::Runtime(format!("bad number {s:?} on line {}", ln + 1)))
            };
            entries.push(ArtifactEntry {
                name: parts[0].to_string(),
                entry: parts[1].to_string(),
                n: parse(parts[2])?,
                m: parse(parts[3])?,
                k: parse(parts[4])?,
                cap: parse(parts[5])? as u32,
                path: dir.join(parts[6]),
            });
        }
        Ok(Self { entries })
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Find an artifact for the given entry point and problem shape.
    pub fn find(&self, entry: &str, m: usize, k: usize, cap: u32) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.entry == entry && e.m == m && e.k == k && e.cap == cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, content: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        f.write_all(content.as_bytes()).unwrap();
    }

    #[test]
    fn parses_and_finds() {
        let dir = std::env::temp_dir().join(format!("bskp_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            "eval_dense_n2048_m10_k10_c1\teval_dense\t2048\t10\t10\t1\teval.hlo.txt\n\
             scd_sparse_n4096_m10_k10_c1\tscd_sparse\t4096\t10\t10\t1\tscd.hlo.txt\n",
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.entries().len(), 2);
        let e = m.find("eval_dense", 10, 10, 1).unwrap();
        assert_eq!(e.n, 2048);
        assert!(e.path.ends_with("eval.hlo.txt"));
        assert!(m.find("eval_dense", 11, 10, 1).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = ArtifactManifest::load("/nonexistent_dir_xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn malformed_line_rejected() {
        let dir = std::env::temp_dir().join(format!("bskp_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, "only\tthree\tfields\n");
        assert!(ArtifactManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
