//! PJRT client wrapper: compile-once executables with serialized execution.

use crate::error::{Error, Result};
use crate::runtime::artifacts::ArtifactEntry;
use std::sync::Mutex;

/// A PJRT CPU client plus the executables loaded through it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU client (one per process is plenty).
    pub fn cpu() -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt cpu client: {e}")))?;
        Ok(Self { client })
    }

    /// Backend platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load(&self, entry: &ArtifactEntry) -> Result<LoadedExecutable> {
        let path = entry.path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| Error::Runtime(format!("parse {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e}", entry.name)))?;
        Ok(LoadedExecutable { exe: Mutex::new(exe), entry: entry.clone() })
    }
}

/// A compiled artifact. Execution is serialized through the mutex (the
/// `xla` wrappers are not `Sync`; XLA's CPU runtime parallelizes
/// internally), while input marshaling stays on the calling worker.
pub struct LoadedExecutable {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    entry: ArtifactEntry,
}

// SAFETY: the wrapped PJRT objects are only touched while the mutex is
// held; PJRT itself is a thread-safe C API and the CPU client outlives the
// executable (owned by the same struct that owns the Runtime).
unsafe impl Send for LoadedExecutable {}
unsafe impl Sync for LoadedExecutable {}

impl LoadedExecutable {
    /// The artifact metadata this executable was compiled from.
    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Execute with f32 input arrays (shape-checked against `dims`),
    /// returning every output flattened to `Vec<f32>`.
    pub fn execute_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let exe = self.exe.lock().map_err(|_| Error::Runtime("executable mutex poisoned".into()))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expect: i64 = dims.iter().product();
            if expect as usize != data.len() {
                return Err(Error::Runtime(format!(
                    "input length {} does not match shape {dims:?}",
                    data.len()
                )));
            }
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| Error::Runtime(format!("reshape to {dims:?}: {e}")))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.entry.name)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
        // aot.py lowers with return_tuple=True
        let parts = out.to_tuple().map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
        parts
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| Error::Runtime(format!("to_vec: {e}"))))
            .collect()
    }
}
