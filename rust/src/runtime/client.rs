//! PJRT client wrapper: compile-once executables with serialized execution.
//!
//! The real implementation wraps the `xla` crate (PJRT C API) and is gated
//! behind the `xla` cargo feature, which the offline registry cannot
//! satisfy by default. Without the feature an API-identical stub compiles
//! in whose [`Runtime::cpu`] returns a descriptive error, so every caller
//! (the coordinator's `Backend::Xla` arm) degrades gracefully and the rest
//! of the crate builds with zero external dependencies.

use crate::error::{Error, Result};
use crate::runtime::artifacts::ArtifactEntry;
#[cfg(feature = "xla")]
use std::sync::Mutex;

/// A PJRT CPU client plus the executables loaded through it.
#[cfg(feature = "xla")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "xla")]
impl Runtime {
    /// Create the CPU client (one per process is plenty).
    pub fn cpu() -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt cpu client: {e}")))?;
        Ok(Self { client })
    }

    /// Backend platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load(&self, entry: &ArtifactEntry) -> Result<LoadedExecutable> {
        let path = entry.path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| Error::Runtime(format!("parse {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e}", entry.name)))?;
        Ok(LoadedExecutable { exe: Mutex::new(exe), entry: entry.clone() })
    }
}

/// A compiled artifact. Execution is serialized through the mutex (the
/// `xla` wrappers are not `Sync`; XLA's CPU runtime parallelizes
/// internally), while input marshaling stays on the calling worker.
#[cfg(feature = "xla")]
pub struct LoadedExecutable {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    entry: ArtifactEntry,
}

// SAFETY: the wrapped PJRT objects are only touched while the mutex is
// held; PJRT itself is a thread-safe C API and the CPU client outlives the
// executable (owned by the same struct that owns the Runtime).
#[cfg(feature = "xla")]
unsafe impl Send for LoadedExecutable {}
#[cfg(feature = "xla")]
unsafe impl Sync for LoadedExecutable {}

#[cfg(feature = "xla")]
impl LoadedExecutable {
    /// The artifact metadata this executable was compiled from.
    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Execute with f32 input arrays (shape-checked against `dims`),
    /// returning every output flattened to `Vec<f32>`.
    pub fn execute_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let exe = self.exe.lock().map_err(|_| Error::Runtime("executable mutex poisoned".into()))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expect: i64 = dims.iter().product();
            if expect as usize != data.len() {
                return Err(Error::Runtime(format!(
                    "input length {} does not match shape {dims:?}",
                    data.len()
                )));
            }
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| Error::Runtime(format!("reshape to {dims:?}: {e}")))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.entry.name)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
        // aot.py lowers with return_tuple=True
        let parts = out.to_tuple().map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
        parts
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| Error::Runtime(format!("to_vec: {e}"))))
            .collect()
    }
}

/// Stub runtime used when the crate is built without the `xla` feature.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    _priv: (),
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    /// Always errors: the PJRT backend needs the `xla` feature, which in
    /// turn needs a vendored `xla` crate added to `[dependencies]` in
    /// `rust/Cargo.toml` (see the comment there). Use `Backend::Rust`
    /// otherwise.
    pub fn cpu() -> Result<Self> {
        Err(Error::Runtime(
            "bskp was built without the `xla` feature; the PJRT backend is \
             unavailable. Add a vendored `xla` crate to [dependencies] in \
             rust/Cargo.toml (see the comment there) and rebuild with \
             `--features xla`, or use the pure-rust backend"
                .into(),
        ))
    }

    /// Backend platform name (diagnostics).
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Unreachable in practice ([`Runtime::cpu`] never hands out a stub),
    /// but kept API-identical so callers compile unchanged.
    pub fn load(&self, _entry: &ArtifactEntry) -> Result<LoadedExecutable> {
        Err(Error::Runtime("xla feature disabled".into()))
    }
}

/// Stub executable mirroring the real API; never constructible because the
/// stub [`Runtime`] cannot be obtained.
#[cfg(not(feature = "xla"))]
pub struct LoadedExecutable {
    entry: ArtifactEntry,
}

#[cfg(not(feature = "xla"))]
impl LoadedExecutable {
    /// The artifact metadata this executable was compiled from.
    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Always errors (see [`Runtime::cpu`]).
    pub fn execute_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        Err(Error::Runtime("xla feature disabled".into()))
    }
}
