//! SCD with the XLA map phase: Algorithm 4 where each shard's evaluation
//! *and* Algorithm-5 candidate generation run inside the `scd_sparse` AOT
//! artifact. The reduce and the λ update stay on the rust leader — exactly
//! the paper's split between mappers and the driver.
//!
//! Applies to sparse identity-mapped instances (`M = K`, single local cap),
//! the paper's production shape. Everything else: use
//! [`crate::solver::scd::solve_scd`].

use crate::cluster::{Clock, SystemClock};
use crate::error::Result;
use crate::instance::problem::{GroupBuf, GroupSource};
use crate::instance::shard::Shards;
use crate::mapreduce::Cluster;
use crate::metrics::ClockStopwatch;
use crate::runtime::artifacts::ArtifactManifest;
use crate::runtime::client::Runtime;
use crate::runtime::evaluator::{marshal_sparse, sparse_artifact};
use crate::solver::bucketing::BucketHist;
use crate::solver::config::{ReduceMode, SolverConfig};
use crate::solver::postprocess;
use crate::solver::rounds::RoundAgg;
use crate::solver::scd::exact_threshold_reduce;
use crate::solver::stats::{
    max_violation_ratio, ObserverControl, PhaseTimings, RoundEvent, SolveObserver, SolveReport,
};
use crate::util::rel_change;

enum Thresholds {
    Exact(Vec<Vec<(f64, f64)>>),
    Bucketed(Vec<BucketHist>),
}

impl Thresholds {
    fn new(mode: ReduceMode, lambda: &[f64]) -> Self {
        match mode {
            ReduceMode::Exact => Thresholds::Exact(vec![Vec::new(); lambda.len()]),
            ReduceMode::Bucketed { delta } => {
                Thresholds::Bucketed(lambda.iter().map(|&c| BucketHist::new(c, delta)).collect())
            }
        }
    }
    fn add(&mut self, k: usize, v1: f64, v2: f64) {
        match self {
            Thresholds::Exact(v) => v[k].push((v1, v2)),
            Thresholds::Bucketed(h) => h[k].add(v1, v2),
        }
    }
    fn merge(&mut self, other: Thresholds) {
        match (self, other) {
            (Thresholds::Exact(a), Thresholds::Exact(b)) => {
                for (x, y) in a.iter_mut().zip(b) {
                    x.extend(y);
                }
            }
            (Thresholds::Bucketed(a), Thresholds::Bucketed(b)) => {
                for (x, y) in a.iter_mut().zip(&b) {
                    x.merge(y);
                }
            }
            _ => unreachable!(),
        }
    }
    fn reduce(&mut self, k: usize, budget: f64) -> f64 {
        match self {
            Thresholds::Exact(v) => exact_threshold_reduce(&mut v[k], budget),
            Thresholds::Bucketed(h) => h[k].reduce(budget),
        }
    }
}

/// Solve a sparse identity-mapped instance with SCD, running the map phase
/// through the `scd_sparse` AOT artifact.
pub fn solve_scd_xla_sparse<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
    runtime: &Runtime,
    manifest: &ArtifactManifest,
) -> Result<SolveReport> {
    solve_scd_xla_sparse_driven(source, config, cluster, runtime, manifest, None, None)
}

/// [`solve_scd_xla_sparse`] with the session-API hooks: an optional
/// warm-start λ and an optional per-round [`SolveObserver`].
pub fn solve_scd_xla_sparse_driven<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
    runtime: &Runtime,
    manifest: &ArtifactManifest,
    init: Option<&[f64]>,
    observer: Option<&mut dyn SolveObserver>,
) -> Result<SolveReport> {
    solve_scd_xla_sparse_driven_clocked(
        source,
        config,
        cluster,
        runtime,
        manifest,
        init,
        observer,
        &SystemClock,
    )
}

/// [`solve_scd_xla_sparse_driven`] with the phase timings read through an
/// explicit [`Clock`]: under [`SystemClock`] the behavior is byte-for-byte
/// the production one, under a virtual clock the reported
/// `wall_ms`/phases are virtual-time — nothing in the driver touches
/// `Instant` directly.
#[allow(clippy::too_many_arguments)]
pub fn solve_scd_xla_sparse_driven_clocked<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
    runtime: &Runtime,
    manifest: &ArtifactManifest,
    init: Option<&[f64]>,
    mut observer: Option<&mut dyn SolveObserver>,
    clock: &dyn Clock,
) -> Result<SolveReport> {
    config.validate()?;
    source.validate()?;
    let t0 = ClockStopwatch::start(clock);
    let dims = source.dims();
    let (m, kk) = (dims.n_items, dims.n_global);
    let budgets = source.budgets().to_vec();
    let entry = sparse_artifact(source, manifest, "scd_sparse")?;
    let exe = runtime.load(entry)?;
    let n_art = entry.n;
    let shards = match config.shard_size {
        Some(s) => Shards::new(dims.n_groups, s),
        None => {
            // whole artifact slabs per map shard; for a store-backed
            // source grow to the file-shard size (rounded up to whole
            // slabs) so the zero-padded final slab of every map shard
            // lands at a storage-shard boundary instead of mid-file
            let unit = match source.preferred_shard_size() {
                Some(u) if u >= n_art => u.div_ceil(n_art) * n_art,
                _ => n_art,
            };
            Shards::new(dims.n_groups, unit)
        }
    };

    let mut lambda = crate::solver::scd::initial_lambda(source, config, cluster, init)?;

    let mut history = Vec::new();
    let mut lambda_2ago: Option<Vec<f64>> = None;
    let mut converged = false;
    let mut stopped = false;
    let mut iterations = 0;
    let mut last_agg = RoundAgg::new(kk);
    let mut phases = PhaseTimings::default();

    for t in 0..config.max_iters {
        let it0 = ClockStopwatch::start(clock);
        let lam32: Vec<f32> = lambda.iter().map(|&l| l as f32).collect();

        let (round, mut thresholds) = cluster.map_combine(
            shards.count(),
            || (RoundAgg::new(kk), Thresholds::new(config.reduce, &lambda)),
            |(agg, th), idx| {
                let shard = shards.get(idx);
                let mut p = vec![0.0f32; n_art * m];
                let mut bd = vec![0.0f32; n_art * m];
                let mut buf = GroupBuf::new(dims, false);
                let mut start = shard.start;
                while start < shard.end {
                    let end = (start + n_art).min(shard.end);
                    marshal_sparse(source, start, end, m, &mut buf, &mut p, &mut bd);
                    let out = exe
                        .execute_f32(&[
                            (&p, &[n_art as i64, m as i64]),
                            (&bd, &[n_art as i64, m as i64]),
                            (&lam32, &[m as i64]),
                        ])
                        .expect("scd_sparse artifact execution failed");
                    // outputs: r[m], stats[3], v1[n,m], v2[n,m], valid[n,m]
                    for (sum, &v) in agg.consumption.iter_mut().zip(&out[0]) {
                        sum.add(v as f64);
                    }
                    agg.primal.add(out[1][0] as f64);
                    agg.dual_inner.add(out[1][1] as f64);
                    agg.n_selected += out[1][2].round() as u64;
                    let used = end - start;
                    let (v1, v2, valid) = (&out[2], &out[3], &out[4]);
                    for row in 0..used {
                        for j in 0..m {
                            let idx = row * m + j;
                            if valid[idx] > 0.5 {
                                th.add(j, v1[idx] as f64, v2[idx] as f64);
                            }
                        }
                    }
                    start = end;
                }
            },
            |(mut agg, mut th), (agg2, th2)| {
                agg = agg.merge(agg2);
                th.merge(th2);
                (agg, th)
            },
        );
        let map_ms = it0.elapsed_ms();
        phases.map_ms += map_ms;
        let r0 = ClockStopwatch::start(clock);
        let consumption = round.consumption_values();

        let mut new_lambda = lambda.clone();
        for k in 0..kk {
            new_lambda[k] = thresholds.reduce(k, budgets[k]);
        }
        let reduce_ms = r0.elapsed_ms();
        phases.reduce_ms += reduce_ms;

        iterations = t + 1;
        let residual = rel_change(&new_lambda, &lambda);
        let event = RoundEvent {
            iter: t,
            primal: round.primal.value(),
            dual: round.dual_value(&lambda, &budgets),
            max_violation_ratio: max_violation_ratio(&consumption, &budgets),
            lambda_change: residual,
            wall_ms: it0.elapsed_ms(),
            map_ms,
            reduce_ms,
            skip_rate: 0.0,
            lambda: &new_lambda,
        };
        if config.track_history {
            history.push(event.to_iter_stat());
        }
        last_agg = round;

        if let Some(obs) = observer.as_mut() {
            if obs.on_round(&event) == ObserverControl::Stop {
                lambda = new_lambda;
                stopped = true;
                break;
            }
        }

        if let Some(two_ago) = &lambda_2ago {
            if rel_change(&new_lambda, two_ago) < config.tol
                && residual >= config.tol
                && residual < 50.0 * config.tol
            {
                for (nl, &ol) in new_lambda.iter_mut().zip(lambda.iter()) {
                    *nl = nl.max(ol);
                }
                lambda = new_lambda;
                converged = true;
                break;
            }
        }
        lambda_2ago = Some(std::mem::replace(&mut lambda, new_lambda));
        if residual < config.tol {
            converged = true;
            break;
        }
    }

    // final evaluation at the converged (or cancellation-adopted) λ
    // through the rust evaluator — the report is the contract; keep it
    // backend-independent, f64-exact, and consistent with report.lambda
    let eval = crate::solver::rounds::RustEvaluator::new(source);
    let agg = if converged || stopped {
        let e0 = ClockStopwatch::start(clock);
        let agg = crate::solver::rounds::evaluation_round(
            &eval,
            Shards::plan(dims.n_groups, cluster.workers(), source.preferred_shard_size(), None),
            kk,
            &lambda,
            cluster,
        );
        phases.final_eval_ms = e0.elapsed_ms();
        agg
    } else {
        last_agg
    };

    let mut report = SolveReport {
        dual_value: agg.dual_value(&lambda, &budgets),
        primal_value: agg.primal.value(),
        consumption: agg.consumption_values(),
        lambda,
        iterations,
        converged,
        budgets,
        n_selected: agg.n_selected,
        dropped_groups: 0,
        history,
        wall_ms: 0.0,
        phases,
        membership: Vec::new(),
    };
    if config.postprocess && !report.is_feasible() {
        let exec = crate::cluster::Exec::Local(cluster);
        let p0 = ClockStopwatch::start(clock);
        postprocess::enforce_feasibility(source, &mut report, &exec)?;
        report.phases.postprocess_ms = p0.elapsed_ms();
    }
    report.wall_ms = t0.elapsed_ms();
    if let Some(obs) = observer.as_mut() {
        obs.on_complete(&report);
    }
    Ok(report)
}
