//! XLA-backed shard evaluators: the dense map phase executed through the
//! AOT artifacts instead of the pure-rust greedy.
//!
//! Supported shapes (anything else falls back to [`RustEvaluator`]):
//! * dense costs + one all-items local cap `c`  → `eval_dense` artifact;
//! * sparse identity-mapped costs (`M = K`) + cap `q` → `eval_sparse`.
//!
//! Shards are processed in artifact-sized slabs; the final partial slab is
//! zero-padded (zero profits give `p̃ = 0`, which the strict `> 0`
//! selection rule never picks, so padding contributes nothing).

use crate::error::{Error, Result};
use crate::instance::problem::{CostsBuf, GroupBuf, GroupSource};
use crate::instance::shard::ShardRange;
use crate::runtime::artifacts::ArtifactManifest;
use crate::runtime::client::{LoadedExecutable, Runtime};
use crate::solver::rounds::{RoundAgg, ShardEvaluator};
use crate::solver::sparse_q;

/// XLA evaluator for dense instances with a single local cap.
pub struct XlaDenseEvaluator<'a, S: GroupSource + ?Sized> {
    source: &'a S,
    exe: LoadedExecutable,
}

impl<'a, S: GroupSource + ?Sized> XlaDenseEvaluator<'a, S> {
    /// Build from a source + artifact manifest; errors when the instance
    /// shape has no matching artifact.
    pub fn new(source: &'a S, runtime: &Runtime, manifest: &ArtifactManifest) -> Result<Self> {
        let dims = source.dims();
        let locals = source.locals();
        if !source.is_dense() {
            return Err(Error::Runtime("XlaDenseEvaluator requires dense costs".into()));
        }
        if locals.len() != 1 || locals.constraints()[0].items.len() != dims.n_items {
            return Err(Error::Runtime(
                "XlaDenseEvaluator requires a single all-items local constraint".into(),
            ));
        }
        let cap = locals.constraints()[0].cap;
        let entry = manifest
            .find("eval_dense", dims.n_items, dims.n_global, cap)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no eval_dense artifact for M={m} K={k} C={cap}; re-run aot.py with \
                     --config eval_dense,<n>,{m},{k},{cap}",
                    m = dims.n_items,
                    k = dims.n_global,
                ))
            })?;
        let exe = runtime.load(entry)?;
        Ok(Self { source, exe })
    }

    /// The slab size baked into the artifact.
    pub fn slab(&self) -> usize {
        self.exe.entry().n
    }
}

impl<S: GroupSource + ?Sized> ShardEvaluator for XlaDenseEvaluator<'_, S> {
    fn eval_shard(&self, shard: ShardRange, lambda: &[f64], agg: &mut RoundAgg) {
        let dims = self.source.dims();
        let (n_art, m, k) = (self.exe.entry().n, dims.n_items, dims.n_global);
        let lam32: Vec<f32> = lambda.iter().map(|&l| l as f32).collect();
        let mut p = vec![0.0f32; n_art * m];
        let mut b = vec![0.0f32; n_art * m * k];
        let mut buf = GroupBuf::new(dims, true);
        let mut start = shard.start;
        while start < shard.end {
            let end = (start + n_art).min(shard.end);
            let used = end - start;
            p[used * m..].iter_mut().for_each(|v| *v = 0.0);
            b[used * m * k..].iter_mut().for_each(|v| *v = 0.0);
            for (row, i) in (start..end).enumerate() {
                self.source.fill_group(i, &mut buf);
                p[row * m..(row + 1) * m].copy_from_slice(&buf.profits);
                match &buf.costs {
                    CostsBuf::Dense(src) => {
                        b[row * m * k..(row + 1) * m * k].copy_from_slice(src)
                    }
                    _ => unreachable!("checked dense at construction"),
                }
            }
            let outputs = self
                .exe
                .execute_f32(&[
                    (&p, &[n_art as i64, m as i64]),
                    (&b, &[n_art as i64, m as i64, k as i64]),
                    (&lam32, &[k as i64]),
                ])
                .expect("artifact execution failed");
            accumulate_eval_outputs(&outputs[0], &outputs[1], agg);
            start = end;
        }
    }
}

/// XLA evaluator for sparse identity-mapped instances (`M = K`).
pub struct XlaSparseEvaluator<'a, S: GroupSource + ?Sized> {
    source: &'a S,
    exe: LoadedExecutable,
}

impl<'a, S: GroupSource + ?Sized> XlaSparseEvaluator<'a, S> {
    /// Build from a source + manifest (entry `eval_sparse`).
    pub fn new(source: &'a S, runtime: &Runtime, manifest: &ArtifactManifest) -> Result<Self> {
        let entry = sparse_artifact(source, manifest, "eval_sparse")?;
        let exe = runtime.load(entry)?;
        Ok(Self { source, exe })
    }
}

impl<S: GroupSource + ?Sized> ShardEvaluator for XlaSparseEvaluator<'_, S> {
    fn eval_shard(&self, shard: ShardRange, lambda: &[f64], agg: &mut RoundAgg) {
        let dims = self.source.dims();
        let (n_art, m) = (self.exe.entry().n, dims.n_items);
        let lam32: Vec<f32> = lambda.iter().map(|&l| l as f32).collect();
        let mut p = vec![0.0f32; n_art * m];
        let mut bd = vec![0.0f32; n_art * m];
        let mut buf = GroupBuf::new(dims, false);
        let mut start = shard.start;
        while start < shard.end {
            let end = (start + n_art).min(shard.end);
            marshal_sparse(self.source, start, end, m, &mut buf, &mut p, &mut bd);
            let outputs = self
                .exe
                .execute_f32(&[
                    (&p, &[n_art as i64, m as i64]),
                    (&bd, &[n_art as i64, m as i64]),
                    (&lam32, &[m as i64]),
                ])
                .expect("artifact execution failed");
            accumulate_eval_outputs(&outputs[0], &outputs[1], agg);
            start = end;
        }
    }
}

/// Check Algorithm-5-style eligibility and find the matching artifact.
pub(crate) fn sparse_artifact<'m, S: GroupSource + ?Sized>(
    source: &S,
    manifest: &'m ArtifactManifest,
    entry: &str,
) -> Result<&'m crate::runtime::artifacts::ArtifactEntry> {
    let dims = source.dims();
    if source.is_dense() {
        return Err(Error::Runtime("sparse evaluator requires the sparse layout".into()));
    }
    if dims.n_items != dims.n_global {
        return Err(Error::Runtime(format!(
            "sparse artifacts assume the identity mapping (M=K), got M={} K={}",
            dims.n_items, dims.n_global
        )));
    }
    let q = sparse_q::eligible(source).ok_or_else(|| {
        Error::Runtime("sparse evaluator requires a single all-items local cap".into())
    })?;
    manifest.find(entry, dims.n_items, dims.n_global, q).ok_or_else(|| {
        Error::Runtime(format!(
            "no {entry} artifact for M=K={} Q={q}; re-run aot.py with --config \
             {entry},<n>,{},{},{q}",
            dims.n_items, dims.n_items, dims.n_global
        ))
    })
}

/// Marshal `[start, end)` into padded `p` / `bd` slabs, verifying the
/// identity mapping.
pub(crate) fn marshal_sparse<S: GroupSource + ?Sized>(
    source: &S,
    start: usize,
    end: usize,
    m: usize,
    buf: &mut GroupBuf,
    p: &mut [f32],
    bd: &mut [f32],
) {
    let used = end - start;
    p[used * m..].iter_mut().for_each(|v| *v = 0.0);
    bd[used * m..].iter_mut().for_each(|v| *v = 0.0);
    for (row, i) in (start..end).enumerate() {
        source.fill_group(i, buf);
        p[row * m..(row + 1) * m].copy_from_slice(&buf.profits);
        match &buf.costs {
            CostsBuf::Sparse { knap, cost } => {
                debug_assert!(
                    knap.iter().enumerate().all(|(j, &kk)| kk as usize == j),
                    "sparse artifacts require the identity item→knapsack mapping"
                );
                bd[row * m..(row + 1) * m].copy_from_slice(cost);
            }
            _ => unreachable!("checked sparse at construction"),
        }
    }
}

/// Fold (r, stats) artifact outputs into a [`RoundAgg`].
fn accumulate_eval_outputs(r: &[f32], stats: &[f32], agg: &mut RoundAgg) {
    debug_assert_eq!(stats.len(), 3);
    for (sum, &v) in agg.consumption.iter_mut().zip(r) {
        sum.add(v as f64);
    }
    agg.primal.add(stats[0] as f64);
    agg.dual_inner.add(stats[1] as f64);
    agg.n_selected += stats[2].round() as u64;
}
