//! PJRT runtime: load the AOT HLO artifacts produced by
//! ``python/compile/aot.py`` and execute them from the map workers.
//!
//! Python never runs at solve time — the artifacts are compiled once by
//! `make artifacts`; this module wraps the `xla` crate (PJRT C API) to
//! load the HLO *text*, compile it on the CPU client and evaluate shards.
//!
//! Thread-safety: the `xla` crate's wrappers hold raw pointers and are not
//! marked `Send`/`Sync`. Execution is serialized through a mutex per
//! executable (input marshaling still happens in parallel on the workers;
//! the XLA CPU runtime parallelizes internally).
//!
//! The `xla` crate is only linked behind the `xla` cargo feature (the
//! offline registry cannot supply it); the default build substitutes a
//! stub [`client::Runtime`] whose constructor returns a descriptive
//! error, so `Backend::Xla` degrades gracefully instead of failing the
//! build.

pub mod artifacts;
pub mod client;
pub mod evaluator;
pub mod scd_xla;

pub use artifacts::{ArtifactEntry, ArtifactManifest};
pub use client::{LoadedExecutable, Runtime};
pub use evaluator::XlaDenseEvaluator;
pub use scd_xla::{
    solve_scd_xla_sparse, solve_scd_xla_sparse_driven, solve_scd_xla_sparse_driven_clocked,
};
