//! **Algorithm 4** — synchronous coordinate descent (SCD).
//!
//! Each round, for every (active) coordinate `k`:
//!
//! * **Map** (per group): compute the candidate values of `λ_k` (Algorithm 3
//!   in general, Algorithm 5 on eligible sparse instances), walk them in
//!   decreasing order re-solving the greedy subproblem, and emit
//!   `(k, [v1, v2])` — the threshold and the *incremental* consumption of
//!   knapsack `k` gained as `λ_k` drops below `v1`.
//! * **Reduce** (per knapsack): pick the minimal threshold `v` such that the
//!   consumption of all emissions with `v1 ≥ v` stays within `B_k`
//!   (exactly, by sorting; or via the §5.2 bucketed histogram).
//! * **Leader**: `λ_k^{t+1} ←` the reduced threshold.
//!
//! No learning rate; each coordinate update is an exact line search, which
//! is why SCD's constraint violations are near-zero and smooth where DD's
//! are large and ragged (Figures 5–6).

use crate::cluster::Exec;
use crate::error::Result;
use crate::instance::problem::{GroupBuf, GroupSource};
use crate::instance::shard::Shards;
use crate::mapreduce::Cluster;
use crate::solver::adjusted::{accumulate_selection, adjusted_profits};
use crate::solver::bucketing::BucketHist;
use crate::solver::candidates::{candidate_lambdas, line_coefficients};
use crate::solver::cd_modes::{active_coords, sweep_len};
use crate::solver::config::{ReduceMode, SolverConfig};
use crate::solver::greedy::{greedy_select, greedy_select_warm, reset_order, GroupScratch};
use crate::solver::postprocess;
use crate::solver::rounds::RoundAgg;
use crate::solver::sparse_q::{self, SparseQScratch};
use crate::solver::stats::{
    max_violation_ratio, ObserverControl, RoundEvent, SolveObserver, SolveReport,
};
use crate::util::rel_change;

/// The one warm-start λ validator (length, finiteness, non-negativity) —
/// shared by [`initial_lambda`] and the session planner so the two stages
/// can never drift. Returns the defect description; callers add context.
pub(crate) fn check_warm_lambda(l: &[f64], kk: usize) -> std::result::Result<(), String> {
    if l.len() != kk {
        return Err(format!(
            "has {} multipliers but the instance has {kk} global constraints",
            l.len()
        ));
    }
    if let Some(bad) = l.iter().find(|x| !x.is_finite() || **x < 0.0) {
        return Err(format!("must be finite and ≥ 0, got {bad}"));
    }
    Ok(())
}

/// Resolve the starting multipliers shared by every driver: an explicit
/// warm-start vector wins over §5.3 pre-solving, which wins over the cold
/// `lambda0` fill. Errors when the warm vector fails [`check_warm_lambda`].
pub(crate) fn initial_lambda<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
    init: Option<&[f64]>,
) -> crate::error::Result<Vec<f64>> {
    let kk = source.dims().n_global;
    match init {
        Some(l) => {
            check_warm_lambda(l, kk)
                .map_err(|m| crate::error::Error::InvalidConfig(format!("warm-start λ {m}")))?;
            Ok(l.to_vec())
        }
        None => match &config.presolve {
            Some(p) => crate::solver::presolve::presolve_lambda(source, p, config, cluster),
            None => Ok(vec![config.lambda0; kk]),
        },
    }
}

/// The exact Algorithm-4 reduce: the minimal threshold `v` such that
/// `Σ_{v1 ≥ v} v2 ≤ budget`, i.e. the smallest emitted candidate that keeps
/// knapsack `k` feasible *when every item whose threshold ties with `v` is
/// counted as selected* (the paper's weak inequality — conservative under
/// greedy tie-breaking, which is what keeps SCD's violations at zero).
/// Returns 0 when everything fits (slack constraint ⇒ `λ_k = 0` by
/// complementary slackness).
pub fn exact_threshold_reduce(pairs: &mut [(f64, f64)], budget: f64) -> f64 {
    crate::util::sort_pairs_desc(pairs);
    let mut cum = 0.0f64;
    let mut prev_v1: Option<f64> = None;
    let mut i = 0usize;
    while i < pairs.len() {
        let v1 = pairs[i].0;
        let mut group = 0.0f64;
        while i < pairs.len() && pairs[i].0 == v1 {
            group += pairs[i].1;
            i += 1;
        }
        if cum + group > budget {
            // adding this threshold group would overflow: stay at the last
            // feasible candidate (or at the top one when nothing fits —
            // post-processing handles the degenerate single-group overshoot)
            return prev_v1.unwrap_or(v1);
        }
        cum += group;
        prev_v1 = Some(v1);
    }
    0.0
}

/// Per-coordinate threshold accumulators (the shuffle state). Crate-public
/// so the cluster wire protocol can ship a worker's partial back to the
/// leader ([`crate::cluster::protocol`]).
pub(crate) enum ThresholdAcc {
    /// Every `(v1, v2)` emission, per coordinate (exact Algorithm-4 reduce).
    Exact(Vec<Vec<(f64, f64)>>),
    /// §5.2 exponential histograms, per coordinate.
    Bucketed(Vec<BucketHist>),
}

impl ThresholdAcc {
    pub(crate) fn new(mode: ReduceMode, lambda: &[f64]) -> Self {
        match mode {
            ReduceMode::Exact => ThresholdAcc::Exact(vec![Vec::new(); lambda.len()]),
            ReduceMode::Bucketed { delta } => ThresholdAcc::Bucketed(
                lambda.iter().map(|&c| BucketHist::new(c, delta)).collect(),
            ),
        }
    }

    #[inline]
    fn add(&mut self, k: usize, v1: f64, v2: f64) {
        match self {
            ThresholdAcc::Exact(v) => v[k].push((v1, v2)),
            ThresholdAcc::Bucketed(h) => h[k].add(v1, v2),
        }
    }

    pub(crate) fn merge(&mut self, other: ThresholdAcc) {
        match (self, other) {
            (ThresholdAcc::Exact(a), ThresholdAcc::Exact(b)) => {
                for (x, y) in a.iter_mut().zip(b) {
                    x.extend(y);
                }
            }
            (ThresholdAcc::Bucketed(a), ThresholdAcc::Bucketed(b)) => {
                for (x, y) in a.iter_mut().zip(&b) {
                    x.merge(y);
                }
            }
            _ => unreachable!("reduce modes agree within a round"),
        }
    }

    fn reduce(&mut self, k: usize, budget: f64) -> f64 {
        match self {
            ThresholdAcc::Exact(v) => exact_threshold_reduce(&mut v[k], budget),
            ThresholdAcc::Bucketed(h) => h[k].reduce(budget),
        }
    }
}

/// One SCD map partial: evaluation aggregate plus threshold emissions.
/// This is the map→combine unit for both executors — an in-process worker
/// thread folds shards into one, and a remote worker ships one per chunk.
pub(crate) struct ScdAcc {
    pub(crate) round: RoundAgg,
    pub(crate) thresholds: ThresholdAcc,
}

impl ScdAcc {
    pub(crate) fn new(reduce: ReduceMode, lambda: &[f64]) -> Self {
        Self {
            round: RoundAgg::new(lambda.len()),
            thresholds: ThresholdAcc::new(reduce, lambda),
        }
    }

    /// Merge `other` into `self` (call in shard/chunk order for
    /// reproducible floating-point results).
    pub(crate) fn merge(mut self, other: ScdAcc) -> Self {
        self.round = std::mem::replace(&mut self.round, RoundAgg::new(0)).merge(other.round);
        self.thresholds.merge(other.thresholds);
        self
    }
}

/// Everything a mapper needs to know about one SCD round beyond the shard
/// geometry: the broadcast λ, the active-coordinate mask, the Algorithm-5
/// eligibility decision and the reduce mode. The leader builds one per
/// round; the cluster protocol ships it verbatim so remote workers run the
/// exact computation the in-process pool would.
pub(crate) struct ScdRoundSpec<'a> {
    pub(crate) lambda: &'a [f64],
    pub(crate) active_mask: &'a [bool],
    pub(crate) sparse_q: Option<u32>,
    pub(crate) reduce: ReduceMode,
}

/// Map the contiguous shard chunk `[lo, hi)` of the global partition for
/// one SCD round — the unit a cluster worker executes for one SCD task
/// frame, and (with `lo = 0, hi = shards.count()`) the whole in-process
/// round.
pub(crate) fn scd_round_chunk<S: GroupSource + ?Sized>(
    source: &S,
    shards: Shards,
    lo: usize,
    hi: usize,
    spec: &ScdRoundSpec<'_>,
    cluster: &Cluster,
) -> ScdAcc {
    cluster.map_combine(
        hi.saturating_sub(lo),
        || ScdAcc::new(spec.reduce, spec.lambda),
        |acc, idx| {
            scd_map_shard(
                source,
                shards.get(lo + idx),
                spec.lambda,
                spec.active_mask,
                spec.sparse_q,
                acc,
            )
        },
        ScdAcc::merge,
    )
}

/// Solve with synchronous (or cyclic/block) coordinate descent.
pub fn solve_scd<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
) -> Result<SolveReport> {
    solve_scd_driven(source, config, cluster, None, None)
}

/// [`solve_scd`] with the session-API hooks: an optional warm-start λ
/// (overrides `lambda0` *and* pre-solving) and an optional per-round
/// [`SolveObserver`] (progress, checkpoints, cancellation).
pub fn solve_scd_driven<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
    init: Option<&[f64]>,
    observer: Option<&mut dyn SolveObserver>,
) -> Result<SolveReport> {
    solve_scd_exec(source, config, &Exec::Local(cluster), init, observer)
}

/// The full SCD driver, parameterized over the round executor: the same
/// map→combine→reduce contract runs on the in-process pool
/// ([`Exec::Local`]) or on a TCP worker fleet ([`Exec::Remote`]); the
/// leader-side λ update, convergence logic and reporting are identical.
pub fn solve_scd_exec<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    exec: &Exec<'_>,
    init: Option<&[f64]>,
    mut observer: Option<&mut dyn SolveObserver>,
) -> Result<SolveReport> {
    config.validate()?;
    source.validate()?;
    let t0 = std::time::Instant::now();
    let dims = source.dims();
    let kk = dims.n_global;
    let budgets = source.budgets().to_vec();
    // align map shards with the source's storage shards (no-op for
    // in-memory sources) so out-of-core workers touch whole files
    let shards = Shards::plan(
        dims.n_groups,
        exec.map_parallelism(),
        source.preferred_shard_size(),
        config.shard_size,
    );
    let sparse_q = if config.use_sparse_fast_path { sparse_q::eligible(source) } else { None };

    // §5.3 pre-solving samples a few thousand groups — always leader-local
    let mut lambda = initial_lambda(source, config, exec.local_pool(), init)?;

    // under-relaxation: dense instances couple every coordinate with every
    // other (an item consumes all K knapsacks), so the undamped synchronous
    // (Jacobi-style) update overshoots collectively and 2-cycles between
    // extremes. β = 1/K makes the joint step a convex combination of
    // single-coordinate exact minimizations, which is monotone for the
    // convex dual. Sparse instances have disjoint coordinate support and
    // take the full step (the paper's setting).
    let beta = config
        .damping
        .unwrap_or(if source.is_dense() { 1.0 / (kk.max(2) as f64) } else { 1.0 });
    // damped steps shrink the per-iteration λ movement by β; scale the
    // convergence threshold accordingly so damping cannot fake convergence
    let conv_tol = config.tol * beta;

    let sweep = sweep_len(config.cd, kk);
    let mut sweep_start_lambda = lambda.clone();
    let mut lambda_2ago: Option<Vec<f64>> = None;
    let mut history = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    let mut final_agg: Option<RoundAgg> = None;

    for t in 0..config.max_iters {
        let it0 = std::time::Instant::now();
        let active = active_coords(config.cd, t, kk);
        let mut active_mask = vec![false; kk];
        for &k in &active {
            active_mask[k] = true;
        }

        let spec = ScdRoundSpec {
            lambda: &lambda,
            active_mask: &active_mask,
            sparse_q,
            reduce: config.reduce,
        };
        let acc = exec.scd_round(source, shards, &spec)?;
        let ScdAcc { round, mut thresholds } = acc;
        let consumption = round.consumption_values();

        let mut new_lambda = lambda.clone();
        for &k in &active {
            let reduced = thresholds.reduce(k, budgets[k]);
            new_lambda[k] = (lambda[k] + beta * (reduced - lambda[k])).max(0.0);
        }

        iterations = t + 1;
        let residual = rel_change(&new_lambda, &lambda);
        let event = RoundEvent {
            iter: t,
            primal: round.primal.value(),
            dual: round.dual_value(&lambda, &budgets),
            max_violation_ratio: max_violation_ratio(&consumption, &budgets),
            lambda_change: residual,
            wall_ms: it0.elapsed().as_secs_f64() * 1e3,
            lambda: &new_lambda,
        };
        if config.track_history {
            history.push(event.to_iter_stat());
        }
        if let Some(obs) = observer.as_mut() {
            if obs.on_round(&event) == ObserverControl::Stop {
                // adopt the round's update so a checkpoint written from
                // this event resumes exactly where the solve stopped
                lambda = new_lambda;
                final_agg = Some(round);
                break;
            }
        }
        final_agg = Some(round);

        // 2-cycle detection: near the optimum the exact coordinate search
        // can alternate between two adjacent candidate thresholds; settle
        // on the elementwise max of the cycle pair (the conservative,
        // feasibility-preserving iterate — post-processing cleans the rest).
        // Only *small-amplitude* cycles count: a large oscillation is the
        // solver still hunting, not terminal flicker.
        if let Some(two_ago) = &lambda_2ago {
            let amplitude = rel_change(&new_lambda, &lambda);
            if rel_change(&new_lambda, two_ago) < conv_tol
                && amplitude >= conv_tol
                && amplitude < 50.0 * conv_tol
            {
                for (nl, &ol) in new_lambda.iter_mut().zip(lambda.iter()) {
                    *nl = nl.max(ol);
                }
                lambda = new_lambda;
                converged = true;
                break;
            }
        }
        lambda_2ago = Some(std::mem::replace(&mut lambda, new_lambda));

        // declare convergence only on sweep boundaries (cyclic/block update
        // a subset per round; a full sweep must be quiet)
        if (t + 1) % sweep == 0 {
            let sweep_residual = rel_change(&lambda, &sweep_start_lambda);
            if sweep_residual < conv_tol {
                converged = true;
                break;
            }
            sweep_start_lambda = lambda.clone();
        }
    }

    // the recorded aggregate is for λ^{T-1}; re-evaluate at the final λ so
    // the report is self-consistent
    let agg = if converged && iterations > 0 {
        // λ barely moved; the last aggregate is within tolerance, but the
        // final evaluation keeps the primal/consumption exactly matched to
        // the reported λ
        exec.eval_round(source, shards, kk, &lambda)?
    } else {
        match final_agg {
            Some(_) => exec.eval_round(source, shards, kk, &lambda)?,
            None => RoundAgg::new(kk),
        }
    };

    let mut report = SolveReport {
        dual_value: agg.dual_value(&lambda, &budgets),
        primal_value: agg.primal.value(),
        consumption: agg.consumption_values(),
        lambda,
        iterations,
        converged,
        budgets,
        n_selected: agg.n_selected,
        dropped_groups: 0,
        history,
        wall_ms: 0.0,
    };
    if config.postprocess && !report.is_feasible() {
        postprocess::enforce_feasibility(source, &mut report, exec)?;
    }
    report.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    if let Some(obs) = observer.as_mut() {
        obs.on_complete(&report);
    }
    Ok(report)
}

/// Map one shard: evaluate at `λ^t` (stats) and emit threshold candidates
/// for the active coordinates.
fn scd_map_shard<S: GroupSource + ?Sized>(
    source: &S,
    shard: crate::instance::shard::ShardRange,
    lambda: &[f64],
    active_mask: &[bool],
    sparse_q: Option<u32>,
    acc: &mut ScdAcc,
) {
    let dims = source.dims();
    let locals = source.locals();
    let kk = dims.n_global;
    thread_local! {
        static SCRATCH: std::cell::RefCell<Option<ScdScratch>> =
            const { std::cell::RefCell::new(None) };
    }
    SCRATCH.with(|cell| {
        let mut slot = cell.borrow_mut();
        let fresh = match slot.as_ref() {
            Some(s) => {
                s.buf.profits.len() != dims.n_items
                    || s.buf.costs.is_dense() != source.is_dense()
                    || s.acc_cons.len() != kk
            }
            None => true,
        };
        if fresh {
            *slot = Some(ScdScratch::new(dims.n_items, kk, source.is_dense()));
        }
        let s = slot.as_mut().unwrap();
        for i in shard.iter() {
            source.fill_group(i, &mut s.buf);

            // --- stats / consumption at the current λ ---
            adjusted_profits(&s.buf, lambda, &mut s.greedy.ptilde);
            greedy_select(locals, &mut s.greedy);
            s.acc_cons.iter_mut().for_each(|a| *a = 0.0);
            let (primal, dual) =
                accumulate_selection(&s.buf, &s.greedy.ptilde, &s.greedy.x, &mut s.acc_cons);
            for (sum, &a) in acc.round.consumption.iter_mut().zip(s.acc_cons.iter()) {
                sum.add(a);
            }
            acc.round.primal.add(primal);
            acc.round.dual_inner.add(dual);
            acc.round.n_selected += s.greedy.x.iter().map(|&x| x as u64).sum::<u64>();

            // --- candidate emissions ---
            match sparse_q {
                Some(q) => {
                    sparse_q::emit_candidates(&s.buf, lambda, q, &mut s.sparse, |k, v1, v2| {
                        if active_mask[k] {
                            acc.thresholds.add(k, v1, v2);
                        }
                    });
                }
                None => {
                    for k in 0..kk {
                        if !active_mask[k] {
                            continue;
                        }
                        line_coefficients(&s.buf, lambda, k, &mut s.a, &mut s.s);
                        candidate_lambdas(&s.a, &s.s, &mut s.cand);
                        // walk with a warm sort order: adjacent candidates
                        // differ by ~one transposition
                        reset_order(&mut s.greedy);
                        // walk candidate *intervals* from high λ_k to low.
                        // The greedy solution is constant on the open
                        // interval between consecutive candidates, so we
                        // evaluate at each interval's midpoint (evaluating
                        // exactly at a candidate would let tie-breaking mask
                        // the transition) and emit the increment with the
                        // interval's upper endpoint as the threshold.
                        let mut prev = 0.0f64;
                        for ci in 0..s.cand.len() {
                            let hi = s.cand[ci];
                            let lo = s.cand.get(ci + 1).copied().unwrap_or(0.0);
                            let mid = 0.5 * (hi + lo);
                            for j in 0..dims.n_items {
                                s.greedy.ptilde[j] = s.a[j] - mid * s.s[j];
                            }
                            greedy_select_warm(locals, &mut s.greedy);
                            let cur: f64 = (0..dims.n_items)
                                .filter(|&j| s.greedy.x[j] != 0)
                                .map(|j| s.s[j])
                                .sum();
                            if cur > prev {
                                acc.thresholds.add(k, hi, cur - prev);
                                prev = cur;
                            }
                        }
                    }
                }
            }
        }
    });
}

struct ScdScratch {
    buf: GroupBuf,
    greedy: GroupScratch,
    sparse: SparseQScratch,
    acc_cons: Vec<f64>,
    a: Vec<f64>,
    s: Vec<f64>,
    cand: Vec<f64>,
}

impl ScdScratch {
    fn new(m: usize, k: usize, dense: bool) -> Self {
        Self {
            buf: GroupBuf::new(
                crate::instance::problem::Dims { n_groups: 1, n_items: m, n_global: k },
                dense,
            ),
            greedy: GroupScratch::new(m),
            sparse: SparseQScratch::default(),
            acc_cons: vec![0.0; k],
            a: vec![0.0; m],
            s: vec![0.0; m],
            cand: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};
    use crate::instance::laminar::LaminarProfile;
    use crate::solver::config::CdMode;

    #[test]
    fn exact_reduce_semantics() {
        // thresholds 3,2,1 each consuming 4; budget 7: Σ_{v1≥3}=4 fits,
        // Σ_{v1≥2}=8 does not → minimal feasible threshold is 3
        let mut pairs = vec![(3.0, 4.0), (1.0, 4.0), (2.0, 4.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 7.0), 3.0);
        // budget 8 → {3,2} fit exactly, adding 1 overflows → 2
        let mut pairs = vec![(3.0, 4.0), (1.0, 4.0), (2.0, 4.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 8.0), 2.0);
        // budget 100 → everything fits → 0
        let mut pairs = vec![(3.0, 4.0), (1.0, 4.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 100.0), 0.0);
        // budget 2 → even the top threshold overflows → stay at it
        let mut pairs = vec![(3.0, 4.0), (1.0, 4.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 2.0), 3.0);
        // equal thresholds group atomically: {2,2} consumes 6 > 5 → no
        // feasible candidate below the top → stay at 2
        let mut pairs = vec![(2.0, 3.0), (2.0, 3.0), (1.0, 1.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 5.0), 2.0);
        assert_eq!(exact_threshold_reduce(&mut [], 5.0), 0.0);
    }

    #[test]
    fn scd_converges_and_is_feasible_sparse() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(3_000, 10, 10).with_seed(4));
        let cfg = SolverConfig::default();
        let r = solve_scd(&p, &cfg, &Cluster::new(4)).unwrap();
        assert!(r.converged, "SCD should converge in {} iters", cfg.max_iters);
        assert!(r.is_feasible());
        assert!(r.primal_value > 0.0);
        // duality gap small relative to primal (paper: nearly optimal)
        assert!(r.duality_gap() >= -1e-6);
        assert!(r.duality_gap() / r.primal_value < 0.05, "gap ratio too big: {}", r.duality_gap() / r.primal_value);
    }

    #[test]
    fn scd_dense_with_hierarchy() {
        let p = SyntheticProblem::new(
            GeneratorConfig::dense(800, 10, 5)
                .with_locals(LaminarProfile::scenario_c223(10))
                .with_seed(5),
        );
        let r = solve_scd(&p, &SolverConfig::default(), &Cluster::new(4)).unwrap();
        assert!(r.is_feasible());
        assert!(r.primal_value > 0.0);
        assert!(r.duality_gap() / r.primal_value < 0.1);
    }

    #[test]
    fn sparse_fast_path_matches_general_path() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_500, 8, 8).with_seed(6));
        let fast = solve_scd(
            &p,
            &SolverConfig { use_sparse_fast_path: true, ..Default::default() },
            &Cluster::new(4),
        )
        .unwrap();
        let slow = solve_scd(
            &p,
            &SolverConfig { use_sparse_fast_path: false, ..Default::default() },
            &Cluster::new(4),
        )
        .unwrap();
        // same mathematics; Algorithm 5 computes thresholds through f32
        // adjusted profits while Algorithm 3 stays in f64, so allow
        // rounding-level drift
        for (a, b) in fast.lambda.iter().zip(&slow.lambda) {
            assert!(
                (a - b).abs() < 1e-4 * a.abs().max(1.0),
                "λ mismatch: {:?} vs {:?}",
                fast.lambda,
                slow.lambda
            );
        }
        let rel = (fast.primal_value - slow.primal_value).abs() / slow.primal_value;
        assert!(rel < 1e-3, "primal drift {rel}");
    }

    #[test]
    fn cyclic_and_block_also_converge() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_000, 6, 6).with_seed(8));
        for cd in [CdMode::Cyclic, CdMode::Block { block_size: 2 }] {
            let cfg = SolverConfig { cd, max_iters: 200, ..Default::default() };
            let r = solve_scd(&p, &cfg, &Cluster::new(4)).unwrap();
            assert!(r.is_feasible(), "{cd:?} infeasible");
            assert!(r.primal_value > 0.0);
        }
    }

    #[test]
    fn deterministic_across_workers() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_000, 5, 5).with_seed(10));
        let cfg = SolverConfig { max_iters: 6, ..Default::default() };
        let a = solve_scd(&p, &cfg, &Cluster::new(1)).unwrap();
        let b = solve_scd(&p, &cfg, &Cluster::new(6)).unwrap();
        assert_eq!(a.lambda, b.lambda);
        assert_eq!(a.primal_value, b.primal_value);
        assert_eq!(a.n_selected, b.n_selected);
    }

    #[test]
    fn bucketed_reduce_close_to_exact() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(2_000, 10, 10).with_seed(12));
        let exact = solve_scd(&p, &SolverConfig::default(), &Cluster::new(4)).unwrap();
        let bucketed = solve_scd(
            &p,
            &SolverConfig { reduce: ReduceMode::Bucketed { delta: 1e-5 }, ..Default::default() },
            &Cluster::new(4),
        )
        .unwrap();
        let rel = (bucketed.primal_value - exact.primal_value).abs() / exact.primal_value;
        assert!(rel < 0.02, "bucketed drifted {rel}");
        assert!(bucketed.is_feasible());
    }
}
