//! **Algorithm 4** — synchronous coordinate descent (SCD).
//!
//! Each round, for every (active) coordinate `k`:
//!
//! * **Map** (per group): compute the candidate values of `λ_k` (Algorithm 3
//!   in general, Algorithm 5 on eligible sparse instances), walk them in
//!   decreasing order re-solving the greedy subproblem, and emit
//!   `(k, [v1, v2])` — the threshold and the *incremental* consumption of
//!   knapsack `k` gained as `λ_k` drops below `v1`.
//! * **Reduce** (per knapsack): pick the minimal threshold `v` such that the
//!   consumption of all emissions with `v1 ≥ v` stays within `B_k`
//!   (exactly, by sorting; or via the §5.2 bucketed histogram).
//! * **Leader**: `λ_k^{t+1} ←` the reduced threshold.
//!
//! No learning rate; each coordinate update is an exact line search, which
//! is why SCD's constraint violations are near-zero and smooth where DD's
//! are large and ragged (Figures 5–6).

use crate::cluster::{Clock, Exec, SystemClock};
use crate::error::Result;
use crate::metrics::ClockStopwatch;
use crate::instance::problem::{for_each_row, BlockBuf, GroupSource, RowCosts};
use crate::instance::shard::Shards;
use crate::mapreduce::Cluster;
use crate::obs::{self, names, Track};
use crate::solver::adjusted::{accumulate_selection_row, adjusted_profits_row};
use crate::solver::bucketing::BucketHist;
use crate::solver::candidates::{candidate_lambdas, line_coefficients_row};
use crate::solver::cd_modes::{active_coords, sweep_len};
use crate::solver::config::{ReduceMode, SolverConfig};
use crate::solver::greedy::{greedy_select, greedy_select_warm, reset_order, GroupScratch};
use crate::solver::postprocess;
use crate::solver::rounds::RoundAgg;
use crate::solver::sparse_q::{self, SparseQScratch};
use crate::solver::stability::ScdStability;
use crate::solver::stats::{
    max_violation_ratio, ObserverControl, PhaseTimings, RoundEvent, SolveObserver, SolveReport,
};
use crate::util::rel_change;
use std::sync::Mutex;

/// The one warm-start λ validator (length, finiteness, non-negativity) —
/// shared by [`initial_lambda`] and the session planner so the two stages
/// can never drift. Returns the defect description; callers add context.
pub(crate) fn check_warm_lambda(l: &[f64], kk: usize) -> std::result::Result<(), String> {
    if l.len() != kk {
        return Err(format!(
            "has {} multipliers but the instance has {kk} global constraints",
            l.len()
        ));
    }
    if let Some(bad) = l.iter().find(|x| !x.is_finite() || **x < 0.0) {
        return Err(format!("must be finite and ≥ 0, got {bad}"));
    }
    Ok(())
}

/// Resolve the starting multipliers shared by every driver: an explicit
/// warm-start vector wins over §5.3 pre-solving, which wins over the cold
/// `lambda0` fill. Errors when the warm vector fails [`check_warm_lambda`].
pub(crate) fn initial_lambda<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
    init: Option<&[f64]>,
) -> crate::error::Result<Vec<f64>> {
    let kk = source.dims().n_global;
    match init {
        Some(l) => {
            check_warm_lambda(l, kk)
                .map_err(|m| crate::error::Error::InvalidConfig(format!("warm-start λ {m}")))?;
            Ok(l.to_vec())
        }
        None => match &config.presolve {
            Some(p) => crate::solver::presolve::presolve_lambda(source, p, config, cluster),
            None => Ok(vec![config.lambda0; kk]),
        },
    }
}

/// The exact Algorithm-4 reduce: the minimal threshold `v` such that
/// `Σ_{v1 ≥ v} v2 ≤ budget`, i.e. the smallest emitted candidate that keeps
/// knapsack `k` feasible *when every item whose threshold ties with `v` is
/// counted as selected* (the paper's weak inequality — conservative under
/// greedy tie-breaking, which is what keeps SCD's violations at zero).
/// Returns 0 when everything fits (slack constraint ⇒ `λ_k = 0` by
/// complementary slackness).
pub fn exact_threshold_reduce(pairs: &mut [(f64, f64)], budget: f64) -> f64 {
    crate::util::sort_pairs_desc(pairs);
    let mut cum = 0.0f64;
    let mut prev_v1: Option<f64> = None;
    let mut i = 0usize;
    while i < pairs.len() {
        let v1 = pairs[i].0;
        let mut group = 0.0f64;
        while i < pairs.len() && pairs[i].0 == v1 {
            group += pairs[i].1;
            i += 1;
        }
        if cum + group > budget {
            // adding this threshold group would overflow: stay at the last
            // feasible candidate (or at the top one when nothing fits —
            // post-processing handles the degenerate single-group overshoot)
            return prev_v1.unwrap_or(v1);
        }
        cum += group;
        prev_v1 = Some(v1);
    }
    0.0
}

/// A recycling arena for the exact reduce's `(v1, v2)` pair buffers. The
/// per-worker accumulators and the leader's merged accumulator used to be
/// re-allocated every round (`K` vectors per worker per round, growing to
/// the round's full emission volume); the pool hands the same warmed
/// buffers back out round after round, so the steady-state hot path makes
/// zero pair-buffer allocations. Leader-local: never crosses the wire.
pub(crate) struct PairPool(Mutex<Vec<Vec<(f64, f64)>>>);

impl PairPool {
    /// Empty pool.
    pub(crate) fn new() -> Self {
        Self(Mutex::new(Vec::new()))
    }

    /// Take `n` cleared buffers (allocating only what the pool lacks).
    fn take_n(&self, n: usize) -> Vec<Vec<(f64, f64)>> {
        let mut pool = self.0.lock().unwrap();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(pool.pop().unwrap_or_default());
        }
        out
    }

    /// Return one buffer (cleared, capacity kept).
    fn put(&self, mut v: Vec<(f64, f64)>) {
        v.clear();
        self.0.lock().unwrap().push(v);
    }

    /// Return many buffers at once.
    fn put_all<I: IntoIterator<Item = Vec<(f64, f64)>>>(&self, vs: I) {
        let mut pool = self.0.lock().unwrap();
        for mut v in vs {
            v.clear();
            pool.push(v);
        }
    }
}

/// Leader-local context for one SCD map round — state that never crosses
/// the wire: the λ-stability cache and the pair-buffer arena. Remote
/// workers run with [`ScdRoundCtx::none`] (they are stateless between
/// frames by design; replay vs. recompute is bit-identical either way).
#[derive(Clone, Copy)]
pub(crate) struct ScdRoundCtx<'a> {
    pub(crate) stability: Option<&'a ScdStability>,
    pub(crate) pool: Option<&'a PairPool>,
}

impl ScdRoundCtx<'_> {
    /// The stateless context (worker processes, tests).
    pub(crate) fn none() -> Self {
        Self { stability: None, pool: None }
    }
}

/// Per-coordinate threshold accumulators (the shuffle state). Crate-public
/// so the cluster wire protocol can ship a worker's partial back to the
/// leader ([`crate::cluster::protocol`]).
pub(crate) enum ThresholdAcc {
    /// Every `(v1, v2)` emission, per coordinate (exact Algorithm-4 reduce).
    Exact(Vec<Vec<(f64, f64)>>),
    /// §5.2 exponential histograms, per coordinate.
    Bucketed(Vec<BucketHist>),
}

impl ThresholdAcc {
    pub(crate) fn new(mode: ReduceMode, lambda: &[f64]) -> Self {
        Self::new_pooled(mode, lambda, None)
    }

    fn new_pooled(mode: ReduceMode, lambda: &[f64], pool: Option<&PairPool>) -> Self {
        match mode {
            ReduceMode::Exact => ThresholdAcc::Exact(match pool {
                Some(p) => p.take_n(lambda.len()),
                None => vec![Vec::new(); lambda.len()],
            }),
            ReduceMode::Bucketed { delta } => ThresholdAcc::Bucketed(
                lambda.iter().map(|&c| BucketHist::new(c, delta)).collect(),
            ),
        }
    }

    #[inline]
    fn add(&mut self, k: usize, v1: f64, v2: f64) {
        match self {
            ThresholdAcc::Exact(v) => v[k].push((v1, v2)),
            ThresholdAcc::Bucketed(h) => h[k].add(v1, v2),
        }
    }

    pub(crate) fn merge(&mut self, other: ThresholdAcc) {
        self.merge_pooled(other, None)
    }

    /// [`ThresholdAcc::merge`], recycling the drained right-hand buffers
    /// into `pool` instead of dropping their allocations. Emission order
    /// is preserved exactly (left's pairs, then right's), so pooling never
    /// perturbs the reduce inputs.
    fn merge_pooled(&mut self, other: ThresholdAcc, pool: Option<&PairPool>) {
        match (self, other) {
            (ThresholdAcc::Exact(a), ThresholdAcc::Exact(b)) => {
                for (x, mut y) in a.iter_mut().zip(b) {
                    if x.is_empty() && y.capacity() > x.capacity() {
                        std::mem::swap(x, &mut y);
                    } else {
                        x.append(&mut y);
                    }
                    if let Some(p) = pool {
                        p.put(y);
                    }
                }
            }
            (ThresholdAcc::Bucketed(a), ThresholdAcc::Bucketed(b)) => {
                for (x, y) in a.iter_mut().zip(&b) {
                    x.merge(y);
                }
            }
            _ => unreachable!("reduce modes agree within a round"),
        }
    }

    /// Hand every pair buffer back to the arena after the leader's reduce
    /// consumed the round.
    fn recycle(self, pool: &PairPool) {
        if let ThresholdAcc::Exact(vs) = self {
            pool.put_all(vs);
        }
    }

    fn reduce(&mut self, k: usize, budget: f64) -> f64 {
        match self {
            ThresholdAcc::Exact(v) => exact_threshold_reduce(&mut v[k], budget),
            ThresholdAcc::Bucketed(h) => h[k].reduce(budget),
        }
    }
}

/// One SCD map partial: evaluation aggregate plus threshold emissions.
/// This is the map→combine unit for both executors — an in-process worker
/// thread folds shards into one, and a remote worker ships one per chunk.
pub(crate) struct ScdAcc {
    pub(crate) round: RoundAgg,
    pub(crate) thresholds: ThresholdAcc,
}

impl ScdAcc {
    pub(crate) fn new(reduce: ReduceMode, lambda: &[f64]) -> Self {
        Self::new_pooled(reduce, lambda, None)
    }

    fn new_pooled(reduce: ReduceMode, lambda: &[f64], pool: Option<&PairPool>) -> Self {
        Self {
            round: RoundAgg::new(lambda.len()),
            thresholds: ThresholdAcc::new_pooled(reduce, lambda, pool),
        }
    }

    /// Merge `other` into `self` (call in shard/chunk order for
    /// reproducible floating-point results).
    pub(crate) fn merge(self, other: ScdAcc) -> Self {
        self.merge_pooled(other, None)
    }

    fn merge_pooled(mut self, other: ScdAcc, pool: Option<&PairPool>) -> Self {
        self.round = std::mem::replace(&mut self.round, RoundAgg::new(0)).merge(other.round);
        self.thresholds.merge_pooled(other.thresholds, pool);
        self
    }
}

/// Everything a mapper needs to know about one SCD round beyond the shard
/// geometry: the broadcast λ, the active-coordinate mask, the Algorithm-5
/// eligibility decision and the reduce mode. The leader builds one per
/// round; the cluster protocol ships it verbatim so remote workers run the
/// exact computation the in-process pool would.
pub(crate) struct ScdRoundSpec<'a> {
    pub(crate) lambda: &'a [f64],
    pub(crate) active_mask: &'a [bool],
    pub(crate) sparse_q: Option<u32>,
    pub(crate) reduce: ReduceMode,
}

/// Map the contiguous shard chunk `[lo, hi)` of the global partition for
/// one SCD round — the unit a cluster worker executes for one SCD task
/// frame, and (with `lo = 0, hi = shards.count()`) the whole in-process
/// round. `ctx` carries the leader-local λ-stability cache and buffer
/// arena (use [`ScdRoundCtx::none`] on worker processes).
pub(crate) fn scd_round_chunk<S: GroupSource + ?Sized>(
    source: &S,
    shards: Shards,
    lo: usize,
    hi: usize,
    spec: &ScdRoundSpec<'_>,
    cluster: &Cluster,
    ctx: ScdRoundCtx<'_>,
) -> ScdAcc {
    cluster.map_combine(
        hi.saturating_sub(lo),
        || ScdAcc::new_pooled(spec.reduce, spec.lambda, ctx.pool),
        |acc, idx| scd_map_shard(source, shards.get(lo + idx), lo + idx, spec, ctx.stability, acc),
        |a, b| a.merge_pooled(b, ctx.pool),
    )
}

/// Solve with synchronous (or cyclic/block) coordinate descent.
pub fn solve_scd<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
) -> Result<SolveReport> {
    solve_scd_driven(source, config, cluster, None, None)
}

/// [`solve_scd`] with the session-API hooks: an optional warm-start λ
/// (overrides `lambda0` *and* pre-solving) and an optional per-round
/// [`SolveObserver`] (progress, checkpoints, cancellation).
pub fn solve_scd_driven<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
    init: Option<&[f64]>,
    observer: Option<&mut dyn SolveObserver>,
) -> Result<SolveReport> {
    solve_scd_exec(source, config, &Exec::Local(cluster), init, observer)
}

/// The full SCD driver, parameterized over the round executor: the same
/// map→combine→reduce contract runs on the in-process pool
/// ([`Exec::Local`]) or on a TCP worker fleet ([`Exec::Remote`]); the
/// leader-side λ update, convergence logic and reporting are identical.
pub fn solve_scd_exec<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    exec: &Exec<'_>,
    init: Option<&[f64]>,
    observer: Option<&mut dyn SolveObserver>,
) -> Result<SolveReport> {
    solve_scd_exec_clocked(source, config, exec, init, observer, &SystemClock)
}

/// [`solve_scd_exec`] with the phase timings read through an explicit
/// [`Clock`]: under [`SystemClock`] the behavior is byte-for-byte the
/// production one, under a virtual clock the reported `wall_ms`/phases
/// are virtual-time — nothing in the driver touches `Instant` directly.
/// (The serve daemon passes its listener's clock here, so daemon-hosted
/// solves are fully virtual-time testable under the simulator.)
pub fn solve_scd_exec_clocked<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    exec: &Exec<'_>,
    init: Option<&[f64]>,
    mut observer: Option<&mut dyn SolveObserver>,
    clock: &dyn Clock,
) -> Result<SolveReport> {
    config.validate()?;
    source.validate()?;
    let t0 = ClockStopwatch::start(clock);
    let dims = source.dims();
    let kk = dims.n_global;
    let budgets = source.budgets().to_vec();
    // align map shards with the source's storage shards (no-op for
    // in-memory sources) so out-of-core workers touch whole files
    let shards = Shards::plan(
        dims.n_groups,
        exec.map_parallelism(),
        source.preferred_shard_size(),
        config.shard_size,
    );
    let sparse_q = if config.use_sparse_fast_path { sparse_q::eligible(source) } else { None };

    // §5.3 pre-solving samples a few thousand groups — always leader-local
    let mut lambda = initial_lambda(source, config, exec.local_pool(), init)?;

    // λ-stability cache: in-process Algorithm-3 rounds only (remote
    // workers are stateless between frames; Algorithm 5's emissions depend
    // on the full λ vector, so there is nothing provably stable to replay)
    let mut stability = if config.lambda_skip
        && sparse_q.is_none()
        && matches!(exec, Exec::Local(_))
    {
        ScdStability::try_new(shards, kk)
    } else {
        None
    };
    // registry handles for the λ-stability cache (resolved once; the
    // per-round bump is two relaxed adds)
    let walk_counters = stability.as_ref().map(|_| {
        let reg = obs::metrics::global();
        (reg.counter("bskp_scd_walks_total"), reg.counter("bskp_scd_walks_skipped_total"))
    });
    // the λ the previous round was mapped at (bit-equality tracking)
    let mut last_broadcast: Option<Vec<f64>> = None;
    // the pair-buffer arena only cycles on the in-process executor — the
    // remote path builds its accumulators worker-side, so recycling into
    // a pool nothing ever drains would just grow leader memory per round
    let pool = match exec {
        Exec::Local(_) => Some(PairPool::new()),
        Exec::Remote(_) => None,
    };
    let mut phases = PhaseTimings::default();

    // under-relaxation: dense instances couple every coordinate with every
    // other (an item consumes all K knapsacks), so the undamped synchronous
    // (Jacobi-style) update overshoots collectively and 2-cycles between
    // extremes. β = 1/K makes the joint step a convex combination of
    // single-coordinate exact minimizations, which is monotone for the
    // convex dual. Sparse instances have disjoint coordinate support and
    // take the full step (the paper's setting).
    let beta = config
        .damping
        .unwrap_or(if source.is_dense() { 1.0 / (kk.max(2) as f64) } else { 1.0 });
    // damped steps shrink the per-iteration λ movement by β; scale the
    // convergence threshold accordingly so damping cannot fake convergence
    let conv_tol = config.tol * beta;

    let sweep = sweep_len(config.cd, kk);
    let mut sweep_start_lambda = lambda.clone();
    let mut lambda_2ago: Option<Vec<f64>> = None;
    let mut history = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    let mut final_agg: Option<RoundAgg> = None;

    for t in 0..config.max_iters {
        let it0 = ClockStopwatch::start(clock);
        let active = active_coords(config.cd, t, kk);
        let mut active_mask = vec![false; kk];
        for &k in &active {
            active_mask[k] = true;
        }

        let spec = ScdRoundSpec {
            lambda: &lambda,
            active_mask: &active_mask,
            sparse_q,
            reduce: config.reduce,
        };
        if let Some(st) = stability.as_mut() {
            st.begin_round(last_broadcast.as_deref(), &lambda);
            last_broadcast = Some(lambda.clone());
        }
        let bcast_ns = it0.elapsed_ns();
        phases.broadcast_ms += bcast_ns as f64 / 1e6;
        obs::complete(Track::Leader, names::BROADCAST, it0.start_ns(), bcast_ns, t as u64, 0);

        let m0 = ClockStopwatch::start(clock);
        let ctx = ScdRoundCtx { stability: stability.as_ref(), pool: pool.as_ref() };
        let acc = exec.scd_round(source, shards, &spec, ctx)?;
        let map_ns = m0.elapsed_ns();
        let map_ms = map_ns as f64 / 1e6;
        phases.map_ms += map_ms;
        obs::complete(Track::Leader, names::MAP, m0.start_ns(), map_ns, t as u64, 0);
        let (walks, skipped) = stability.as_ref().map_or((0, 0), |st| st.take_counts());
        phases.walks_total += walks;
        phases.walks_skipped += skipped;
        if let Some((wt, ws)) = &walk_counters {
            if obs::metrics_enabled() {
                wt.add(walks);
                ws.add(skipped);
            }
        }
        let skip_rate = if walks == 0 { 0.0 } else { skipped as f64 / walks as f64 };

        let r0 = ClockStopwatch::start(clock);
        let ScdAcc { round, mut thresholds } = acc;
        let consumption = round.consumption_values();

        let mut new_lambda = lambda.clone();
        for &k in &active {
            let reduced = thresholds.reduce(k, budgets[k]);
            new_lambda[k] = (lambda[k] + beta * (reduced - lambda[k])).max(0.0);
        }
        if let Some(p) = &pool {
            thresholds.recycle(p);
        }
        let reduce_ns = r0.elapsed_ns();
        let reduce_ms = reduce_ns as f64 / 1e6;
        phases.reduce_ms += reduce_ms;
        obs::complete(Track::Leader, names::REDUCE, r0.start_ns(), reduce_ns, t as u64, 0);

        iterations = t + 1;
        let round_ns = it0.elapsed_ns();
        obs::complete(Track::Leader, names::ROUND, it0.start_ns(), round_ns, t as u64, 0);
        let residual = rel_change(&new_lambda, &lambda);
        let event = RoundEvent {
            iter: t,
            primal: round.primal.value(),
            dual: round.dual_value(&lambda, &budgets),
            max_violation_ratio: max_violation_ratio(&consumption, &budgets),
            lambda_change: residual,
            wall_ms: round_ns as f64 / 1e6,
            map_ms,
            reduce_ms,
            skip_rate,
            lambda: &new_lambda,
        };
        if config.track_history {
            history.push(event.to_iter_stat());
        }
        if let Some(obs) = observer.as_mut() {
            if obs.on_round(&event) == ObserverControl::Stop {
                // adopt the round's update so a checkpoint written from
                // this event resumes exactly where the solve stopped
                lambda = new_lambda;
                final_agg = Some(round);
                break;
            }
        }
        final_agg = Some(round);

        // 2-cycle detection: near the optimum the exact coordinate search
        // can alternate between two adjacent candidate thresholds; settle
        // on the elementwise max of the cycle pair (the conservative,
        // feasibility-preserving iterate — post-processing cleans the rest).
        // Only *small-amplitude* cycles count: a large oscillation is the
        // solver still hunting, not terminal flicker.
        if let Some(two_ago) = &lambda_2ago {
            let amplitude = rel_change(&new_lambda, &lambda);
            if rel_change(&new_lambda, two_ago) < conv_tol
                && amplitude >= conv_tol
                && amplitude < 50.0 * conv_tol
            {
                for (nl, &ol) in new_lambda.iter_mut().zip(lambda.iter()) {
                    *nl = nl.max(ol);
                }
                lambda = new_lambda;
                converged = true;
                break;
            }
        }
        lambda_2ago = Some(std::mem::replace(&mut lambda, new_lambda));

        // declare convergence only on sweep boundaries (cyclic/block update
        // a subset per round; a full sweep must be quiet)
        if (t + 1) % sweep == 0 {
            let sweep_residual = rel_change(&lambda, &sweep_start_lambda);
            if sweep_residual < conv_tol {
                converged = true;
                break;
            }
            sweep_start_lambda = lambda.clone();
        }
    }

    // the recorded aggregate is for λ^{T-1}; re-evaluate at the final λ so
    // the report is self-consistent
    let e0 = ClockStopwatch::start(clock);
    let agg = if converged && iterations > 0 {
        // λ barely moved; the last aggregate is within tolerance, but the
        // final evaluation keeps the primal/consumption exactly matched to
        // the reported λ
        exec.eval_round(source, shards, kk, &lambda)?
    } else {
        match final_agg {
            Some(_) => exec.eval_round(source, shards, kk, &lambda)?,
            None => RoundAgg::new(kk),
        }
    };
    let final_ns = e0.elapsed_ns();
    phases.final_eval_ms = final_ns as f64 / 1e6;
    obs::complete(Track::Leader, names::FINAL_EVAL, e0.start_ns(), final_ns, iterations as u64, 0);

    let mut report = SolveReport {
        dual_value: agg.dual_value(&lambda, &budgets),
        primal_value: agg.primal.value(),
        consumption: agg.consumption_values(),
        lambda,
        iterations,
        converged,
        budgets,
        n_selected: agg.n_selected,
        dropped_groups: 0,
        history,
        wall_ms: 0.0,
        phases,
        membership: Vec::new(),
    };
    if config.postprocess && !report.is_feasible() {
        let p0 = ClockStopwatch::start(clock);
        postprocess::enforce_feasibility(source, &mut report, exec)?;
        let post_ns = p0.elapsed_ns();
        report.phases.postprocess_ms = post_ns as f64 / 1e6;
        obs::complete(Track::Leader, names::POSTPROCESS, p0.start_ns(), post_ns, 0, 0);
    }
    let wall_ns = t0.elapsed_ns();
    report.wall_ms = wall_ns as f64 / 1e6;
    obs::complete(Track::Leader, names::SESSION, t0.start_ns(), wall_ns, iterations as u64, 0);
    crate::metrics::record_phase_timings(&report.phases);
    if let Some(obs) = observer.as_mut() {
        obs.on_complete(&report);
    }
    Ok(report)
}

/// Map one shard: evaluate at `λ^t` (stats) and emit threshold candidates
/// for the active coordinates. Groups stream through the zero-copy block
/// path ([`GroupSource::fill_block`]); all scratch is arena-reused across
/// groups, blocks and rounds. `shard_idx` is the shard's global index in
/// the round's partition (the λ-stability cache is keyed by it).
fn scd_map_shard<S: GroupSource + ?Sized>(
    source: &S,
    shard: crate::instance::shard::ShardRange,
    shard_idx: usize,
    spec: &ScdRoundSpec<'_>,
    stability: Option<&ScdStability>,
    acc: &mut ScdAcc,
) {
    let dims = source.dims();
    let locals = source.locals();
    let kk = dims.n_global;
    let (lambda, active_mask) = (spec.lambda, spec.active_mask);
    thread_local! {
        static SCRATCH: std::cell::RefCell<Option<ScdScratch>> =
            const { std::cell::RefCell::new(None) };
    }
    SCRATCH.with(|cell| {
        let mut slot = cell.borrow_mut();
        let fresh = match slot.as_ref() {
            Some(s) => s.greedy.ptilde.len() != dims.n_items || s.acc_cons.len() != kk,
            None => true,
        };
        if fresh {
            *slot = Some(ScdScratch::new(dims.n_items, kk));
        }
        let ScdScratch { block, greedy, sparse, acc_cons, a, s: slopes, cand, emits } =
            slot.as_mut().unwrap();
        let mut guard = stability.map(|st| st.shard(shard_idx));

        for_each_row(source, shard.start, shard.end, block, |i, row| {
            // --- stats / consumption at the current λ ---
            adjusted_profits_row(row, lambda, &mut greedy.ptilde);
            greedy_select(locals, greedy);
            acc_cons.iter_mut().for_each(|v| *v = 0.0);
            let (primal, dual) = accumulate_selection_row(row, &greedy.ptilde, &greedy.x, acc_cons);
            for (sum, &v) in acc.round.consumption.iter_mut().zip(acc_cons.iter()) {
                sum.add(v);
            }
            acc.round.primal.add(primal);
            acc.round.dual_inner.add(dual);
            acc.round.n_selected += greedy.x.iter().map(|&x| x as u64).sum::<u64>();

            // --- candidate emissions ---
            match spec.sparse_q {
                Some(q) => {
                    let (knap, cost) = match row.costs {
                        RowCosts::Sparse { knap, cost } => (knap, cost),
                        RowCosts::Dense(_) => {
                            unreachable!("Algorithm 5 requires the sparse layout")
                        }
                    };
                    sparse_q::emit_candidates_row(
                        row.profits,
                        knap,
                        cost,
                        lambda,
                        q,
                        sparse,
                        |k, v1, v2| {
                            if active_mask[k] {
                                acc.thresholds.add(k, v1, v2);
                            }
                        },
                    );
                }
                None => {
                    for k in 0..kk {
                        if !active_mask[k] {
                            continue;
                        }
                        // λ-stability: replay the cached walk when no
                        // *other* coordinate moved since it was taken
                        if let Some(gd) = guard.as_mut() {
                            if gd.replay(i, k, |v1, v2| acc.thresholds.add(k, v1, v2)) {
                                continue;
                            }
                        }
                        line_coefficients_row(row, lambda, k, a, slopes);
                        candidate_lambdas(a, slopes, cand);
                        // walk with a warm sort order: adjacent candidates
                        // differ by ~one transposition
                        reset_order(greedy);
                        // capture emissions only when a cache exists AND
                        // caching this coordinate can pay off (λ_{-k} was
                        // quiet) — stateless workers and churning
                        // coordinates skip the bookkeeping entirely
                        let caching = guard.as_ref().is_some_and(|g| g.store_useful(k));
                        if caching {
                            emits.clear();
                        }
                        // walk candidate *intervals* from high λ_k to low.
                        // The greedy solution is constant on the open
                        // interval between consecutive candidates, so we
                        // evaluate at each interval's midpoint (evaluating
                        // exactly at a candidate would let tie-breaking mask
                        // the transition) and emit the increment with the
                        // interval's upper endpoint as the threshold.
                        let mut prev = 0.0f64;
                        for ci in 0..cand.len() {
                            let hi = cand[ci];
                            let lo = cand.get(ci + 1).copied().unwrap_or(0.0);
                            let mid = 0.5 * (hi + lo);
                            for (pt, (&aj, &sj)) in
                                greedy.ptilde.iter_mut().zip(a.iter().zip(slopes.iter()))
                            {
                                *pt = aj - mid * sj;
                            }
                            greedy_select_warm(locals, greedy);
                            let cur: f64 = (0..dims.n_items)
                                .filter(|&j| greedy.x[j] != 0)
                                .map(|j| slopes[j])
                                .sum();
                            if cur > prev {
                                acc.thresholds.add(k, hi, cur - prev);
                                if caching {
                                    emits.push((hi, cur - prev));
                                }
                                prev = cur;
                            }
                        }
                        if caching {
                            if let Some(gd) = guard.as_mut() {
                                gd.store(i, k, emits);
                            }
                        }
                    }
                }
            }
        });
    });
}

struct ScdScratch {
    block: BlockBuf,
    greedy: GroupScratch,
    sparse: SparseQScratch,
    acc_cons: Vec<f64>,
    a: Vec<f64>,
    s: Vec<f64>,
    cand: Vec<f64>,
    emits: Vec<(f64, f64)>,
}

impl ScdScratch {
    fn new(m: usize, k: usize) -> Self {
        Self {
            block: BlockBuf::new(),
            greedy: GroupScratch::new(m),
            sparse: SparseQScratch::default(),
            acc_cons: vec![0.0; k],
            a: vec![0.0; m],
            s: vec![0.0; m],
            cand: Vec::new(),
            emits: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};
    use crate::instance::laminar::LaminarProfile;
    use crate::solver::config::CdMode;

    #[test]
    fn exact_reduce_semantics() {
        // thresholds 3,2,1 each consuming 4; budget 7: Σ_{v1≥3}=4 fits,
        // Σ_{v1≥2}=8 does not → minimal feasible threshold is 3
        let mut pairs = vec![(3.0, 4.0), (1.0, 4.0), (2.0, 4.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 7.0), 3.0);
        // budget 8 → {3,2} fit exactly, adding 1 overflows → 2
        let mut pairs = vec![(3.0, 4.0), (1.0, 4.0), (2.0, 4.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 8.0), 2.0);
        // budget 100 → everything fits → 0
        let mut pairs = vec![(3.0, 4.0), (1.0, 4.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 100.0), 0.0);
        // budget 2 → even the top threshold overflows → stay at it
        let mut pairs = vec![(3.0, 4.0), (1.0, 4.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 2.0), 3.0);
        // equal thresholds group atomically: {2,2} consumes 6 > 5 → no
        // feasible candidate below the top → stay at 2
        let mut pairs = vec![(2.0, 3.0), (2.0, 3.0), (1.0, 1.0)];
        assert_eq!(exact_threshold_reduce(&mut pairs, 5.0), 2.0);
        assert_eq!(exact_threshold_reduce(&mut [], 5.0), 0.0);
    }

    #[test]
    fn scd_converges_and_is_feasible_sparse() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(3_000, 10, 10).with_seed(4));
        let cfg = SolverConfig::default();
        let r = solve_scd(&p, &cfg, &Cluster::new(4)).unwrap();
        assert!(r.converged, "SCD should converge in {} iters", cfg.max_iters);
        assert!(r.is_feasible());
        assert!(r.primal_value > 0.0);
        // duality gap small relative to primal (paper: nearly optimal)
        assert!(r.duality_gap() >= -1e-6);
        assert!(r.duality_gap() / r.primal_value < 0.05, "gap ratio too big: {}", r.duality_gap() / r.primal_value);
    }

    #[test]
    fn scd_dense_with_hierarchy() {
        let p = SyntheticProblem::new(
            GeneratorConfig::dense(800, 10, 5)
                .with_locals(LaminarProfile::scenario_c223(10))
                .with_seed(5),
        );
        let r = solve_scd(&p, &SolverConfig::default(), &Cluster::new(4)).unwrap();
        assert!(r.is_feasible());
        assert!(r.primal_value > 0.0);
        assert!(r.duality_gap() / r.primal_value < 0.1);
    }

    #[test]
    fn sparse_fast_path_matches_general_path() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_500, 8, 8).with_seed(6));
        let fast = solve_scd(
            &p,
            &SolverConfig { use_sparse_fast_path: true, ..Default::default() },
            &Cluster::new(4),
        )
        .unwrap();
        let slow = solve_scd(
            &p,
            &SolverConfig { use_sparse_fast_path: false, ..Default::default() },
            &Cluster::new(4),
        )
        .unwrap();
        // same mathematics; Algorithm 5 computes thresholds through f32
        // adjusted profits while Algorithm 3 stays in f64, so allow
        // rounding-level drift
        for (a, b) in fast.lambda.iter().zip(&slow.lambda) {
            assert!(
                (a - b).abs() < 1e-4 * a.abs().max(1.0),
                "λ mismatch: {:?} vs {:?}",
                fast.lambda,
                slow.lambda
            );
        }
        let rel = (fast.primal_value - slow.primal_value).abs() / slow.primal_value;
        assert!(rel < 1e-3, "primal drift {rel}");
    }

    #[test]
    fn cyclic_and_block_also_converge() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_000, 6, 6).with_seed(8));
        for cd in [CdMode::Cyclic, CdMode::Block { block_size: 2 }] {
            let cfg = SolverConfig { cd, max_iters: 200, ..Default::default() };
            let r = solve_scd(&p, &cfg, &Cluster::new(4)).unwrap();
            assert!(r.is_feasible(), "{cd:?} infeasible");
            assert!(r.primal_value > 0.0);
        }
    }

    #[test]
    fn deterministic_across_workers() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_000, 5, 5).with_seed(10));
        let cfg = SolverConfig { max_iters: 6, ..Default::default() };
        let a = solve_scd(&p, &cfg, &Cluster::new(1)).unwrap();
        let b = solve_scd(&p, &cfg, &Cluster::new(6)).unwrap();
        assert_eq!(a.lambda, b.lambda);
        assert_eq!(a.primal_value, b.primal_value);
        assert_eq!(a.n_selected, b.n_selected);
    }

    #[test]
    fn bucketed_reduce_close_to_exact() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(2_000, 10, 10).with_seed(12));
        let exact = solve_scd(&p, &SolverConfig::default(), &Cluster::new(4)).unwrap();
        let bucketed = solve_scd(
            &p,
            &SolverConfig { reduce: ReduceMode::Bucketed { delta: 1e-5 }, ..Default::default() },
            &Cluster::new(4),
        )
        .unwrap();
        let rel = (bucketed.primal_value - exact.primal_value).abs() / exact.primal_value;
        assert!(rel < 0.02, "bucketed drifted {rel}");
        assert!(bucketed.is_feasible());
    }
}
