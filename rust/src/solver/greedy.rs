//! **Algorithm 1** — greedy per-group IP subproblem solver for hierarchical
//! local constraints, provably optimal (paper Proposition 4.1):
//!
//! ```text
//! initialize x_j = 1 iff p̃_j > 0
//! sort items by p̃ non-increasing
//! for each S_l in topological (children-first) order:
//!     among currently-selected items of S_l, keep the top C_l by p̃
//! ```

use crate::instance::laminar::LaminarProfile;

/// Reusable per-worker scratch for the greedy solve — the hot loop makes
/// zero allocations per group.
#[derive(Debug, Clone)]
pub struct GroupScratch {
    /// Adjusted profits `p̃_j`.
    pub ptilde: Vec<f64>,
    /// Selection `x_j ∈ {0,1}`.
    pub x: Vec<u8>,
    /// Item rank by descending `p̃` (`rank[j] = position of j`).
    pub rank: Vec<u32>,
    order: Vec<u32>,
    sel: Vec<(u32, u16)>,
}

impl GroupScratch {
    /// Scratch for groups of `m` items.
    pub fn new(m: usize) -> Self {
        Self {
            ptilde: vec![0.0; m],
            x: vec![0; m],
            rank: vec![0; m],
            order: Vec::with_capacity(m),
            sel: Vec::with_capacity(m),
        }
    }
}

/// Stable insertion sort of `order` by descending `ptilde` (index-ascending
/// on ties, because insertion is stable over the initial 0..m order).
/// The subproblems have tiny `M` (≤ ~100, usually ≤ 16); insertion beats
/// the general-purpose sort's dispatch overhead on the SCD candidate walk,
/// which re-sorts per candidate.
#[inline]
fn insertion_sort_desc(order: &mut [u32], ptilde: &[f64]) {
    for i in 1..order.len() {
        let cur = order[i];
        let key = ptilde[cur as usize];
        let mut j = i;
        while j > 0 && ptilde[order[j - 1] as usize] < key {
            order[j] = order[j - 1];
            j -= 1;
        }
        order[j] = cur;
    }
}

/// Run Algorithm 1 on the adjusted profits already stored in
/// `scratch.ptilde`, writing the optimal selection into `scratch.x`.
///
/// Ties in `p̃` are broken by ascending item index (deterministic).
pub fn greedy_select(locals: &LaminarProfile, scratch: &mut GroupScratch) {
    let m = scratch.ptilde.len();
    // fresh identity presort: deterministic tie-breaking by item index
    scratch.order.clear();
    scratch.order.extend(0..m as u32);
    greedy_select_warm(locals, scratch);
}

/// [`greedy_select`] variant that reuses `scratch.order` as the insertion
/// sort's starting permutation. The SCD candidate walk calls this once per
/// candidate: adjacent candidates differ by ~one adjacent transposition, so
/// the nearly-sorted insertion is O(M) instead of O(M log M)-with-constant.
/// Callers must seed the order once per group (e.g. via [`greedy_select`])
/// — tie-breaking then follows the warm order rather than the item index,
/// which only matters on exact `p̃` ties (the walk evaluates at interval
/// midpoints, where ties have measure zero).
pub fn greedy_select_warm(locals: &LaminarProfile, scratch: &mut GroupScratch) {
    let m = scratch.ptilde.len();
    debug_assert_eq!(scratch.order.len(), m, "seed scratch.order before warm calls");
    // init: select iff p̃ > 0 — branchless byte stores, bounds checks
    // elided by the zip (this runs once per candidate on the SCD walk)
    for (x, &pt) in scratch.x.iter_mut().zip(scratch.ptilde.iter()) {
        *x = (pt > 0.0) as u8;
    }
    if locals.is_empty() {
        return;
    }
    insertion_sort_desc(&mut scratch.order, &scratch.ptilde);
    for (pos, &j) in scratch.order.iter().enumerate() {
        scratch.rank[j as usize] = pos as u32;
    }
    // children-first truncation
    for c in locals.topo_iter() {
        scratch.sel.clear();
        for &j in &c.items {
            if scratch.x[j as usize] != 0 {
                scratch.sel.push((scratch.rank[j as usize], j));
            }
        }
        if scratch.sel.len() > c.cap as usize {
            scratch.sel.sort_unstable();
            for &(_, j) in &scratch.sel[c.cap as usize..] {
                scratch.x[j as usize] = 0;
            }
        }
    }
}

/// Objective value of the selection in `p̃` terms (`Σ p̃_j x_j`) — the
/// group's contribution to the dual objective.
pub fn selection_value(scratch: &GroupScratch) -> f64 {
    scratch
        .ptilde
        .iter()
        .zip(&scratch.x)
        .filter(|(_, &x)| x != 0)
        .map(|(&p, _)| p)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::laminar::{LaminarProfile, LocalConstraint};

    fn solve(ptilde: &[f64], locals: &LaminarProfile) -> Vec<u8> {
        let mut s = GroupScratch::new(ptilde.len());
        s.ptilde.copy_from_slice(ptilde);
        greedy_select(locals, &mut s);
        s.x.clone()
    }

    #[test]
    fn selects_only_positive() {
        let locals = LaminarProfile::single(4, 4);
        assert_eq!(solve(&[1.0, -0.5, 0.0, 2.0], &locals), vec![1, 0, 0, 1]);
    }

    #[test]
    fn single_cap_keeps_best() {
        let locals = LaminarProfile::single(4, 2);
        assert_eq!(solve(&[0.5, 3.0, 1.0, 2.0], &locals), vec![0, 1, 0, 1]);
    }

    #[test]
    fn hierarchy_c223() {
        // halves {0,1,2} cap2 / {3,4,5} cap2, root cap3
        let locals = LaminarProfile::scenario_c223(6);
        let x = solve(&[5.0, 4.0, 3.0, 2.0, 1.0, 0.5], &locals);
        // half1 keeps 5,4; half2 keeps 2,1; root keeps top-3 = {5,4,2}
        assert_eq!(x, vec![1, 1, 0, 1, 0, 0]);
    }

    #[test]
    fn nested_chain() {
        // {0,1} ≤ 1 nested in {0,1,2,3} ≤ 2
        let locals = LaminarProfile::new(vec![
            LocalConstraint::new(vec![0, 1], 1),
            LocalConstraint::new(vec![0, 1, 2, 3], 2),
        ])
        .unwrap();
        let x = solve(&[3.0, 2.5, 1.0, 0.5], &locals);
        // child keeps item0 only; root keeps {0, 2}
        assert_eq!(x, vec![1, 0, 1, 0]);
    }

    #[test]
    fn negative_profits_never_selected_even_under_loose_caps() {
        let locals = LaminarProfile::single(3, 3);
        assert_eq!(solve(&[-1.0, -2.0, -3.0], &locals), vec![0, 0, 0]);
    }

    #[test]
    fn tie_break_is_lowest_index() {
        let locals = LaminarProfile::single(3, 1);
        assert_eq!(solve(&[1.0, 1.0, 1.0], &locals), vec![1, 0, 0]);
    }

    #[test]
    fn no_locals_means_threshold_rule() {
        let locals = LaminarProfile::new(vec![]).unwrap();
        assert_eq!(solve(&[1.0, -1.0], &locals), vec![1, 0]);
    }

    #[test]
    fn selection_value_matches() {
        let locals = LaminarProfile::single(3, 2);
        let mut s = GroupScratch::new(3);
        s.ptilde.copy_from_slice(&[2.0, 1.0, 3.0]);
        greedy_select(&locals, &mut s);
        assert_eq!(s.x, vec![1, 0, 1]);
        assert!((selection_value(&s) - 5.0).abs() < 1e-12);
    }
}

/// Seed `scratch.order` with the identity permutation (the deterministic
/// starting point for a warm walk over one group's candidates).
pub fn reset_order(scratch: &mut GroupScratch) {
    let m = scratch.ptilde.len();
    scratch.order.clear();
    scratch.order.extend(0..m as u32);
}
