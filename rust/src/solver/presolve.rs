//! §5.3 — pre-solving by sampling.
//!
//! Sample `n ≪ N` groups, scale every budget by `n/N`, solve the sampled
//! problem to convergence, and use its `λ` to warm-start the full solve.
//! The paper reports 40–75% fewer SCD iterations (Table 2) — and that the
//! sampled `λ` *alone* violates constraints when applied to the full data,
//! which is why it is a warm start and not a solver.

use crate::error::Result;
use crate::instance::laminar::LaminarProfile;
use crate::instance::problem::{Dims, GroupBuf, GroupSource};
use crate::mapreduce::Cluster;
use crate::rng::Xoshiro256pp;
use crate::solver::config::{PresolveConfig, SolverConfig};

/// A uniformly-sampled sub-instance with proportionally scaled budgets.
pub struct SampledSource<'a, S: GroupSource + ?Sized> {
    inner: &'a S,
    ids: Vec<usize>,
    budgets: Vec<f64>,
}

impl<'a, S: GroupSource + ?Sized> SampledSource<'a, S> {
    /// Sample `n` distinct groups (all of them when `n ≥ N`).
    pub fn sample(inner: &'a S, n: usize, seed: u64) -> Self {
        let total = inner.dims().n_groups;
        let n = n.min(total);
        let ids = if n == total {
            (0..total).collect()
        } else {
            let mut rng = Xoshiro256pp::new(seed);
            rng.sample_distinct(total, n)
        };
        let scale = n as f64 / total as f64;
        let budgets = inner.budgets().iter().map(|b| b * scale).collect();
        Self { inner, ids, budgets }
    }

    /// The sampled group ids.
    pub fn ids(&self) -> &[usize] {
        &self.ids
    }
}

impl<S: GroupSource + ?Sized> GroupSource for SampledSource<'_, S> {
    fn dims(&self) -> Dims {
        Dims { n_groups: self.ids.len(), ..self.inner.dims() }
    }
    fn is_dense(&self) -> bool {
        self.inner.is_dense()
    }
    fn locals(&self) -> &LaminarProfile {
        self.inner.locals()
    }
    fn budgets(&self) -> &[f64] {
        &self.budgets
    }
    fn fill_group(&self, i: usize, buf: &mut GroupBuf) {
        self.inner.fill_group(self.ids[i], buf)
    }
}

/// Produce a warm-start `λ⁰` by solving the sampled instance with SCD.
pub fn presolve_lambda<S: GroupSource + ?Sized>(
    source: &S,
    pcfg: &PresolveConfig,
    parent: &SolverConfig,
    cluster: &Cluster,
) -> Result<Vec<f64>> {
    let sampled = SampledSource::sample(source, pcfg.sample, pcfg.seed);
    let cfg = SolverConfig {
        max_iters: pcfg.max_iters,
        presolve: None, // no recursion
        postprocess: false,
        track_history: false,
        shard_size: None,
        ..parent.clone()
    };
    // type-erase the sampled source: keeps the compiler from instantiating
    // solve_scd::<SampledSource<SampledSource<...>>> recursively
    let erased: &dyn GroupSource = &sampled;
    let report = crate::solver::scd::solve_scd(erased, &cfg, cluster)?;
    Ok(report.lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};
    use crate::solver::scd::solve_scd;

    #[test]
    fn sampled_source_shape_and_budget_scaling() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(10_000, 10, 10).with_seed(1));
        let s = SampledSource::sample(&p, 100, 7);
        assert_eq!(s.dims().n_groups, 100);
        assert_eq!(s.ids().len(), 100);
        for (sb, fb) in s.budgets().iter().zip(p.budgets()) {
            assert!((sb / fb - 0.01).abs() < 1e-12);
        }
        // sampling more than N clamps
        let s = SampledSource::sample(&p, 1 << 30, 7);
        assert_eq!(s.dims().n_groups, 10_000);
    }

    #[test]
    fn sampled_groups_match_inner_data() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_000, 5, 5).with_seed(2));
        let s = SampledSource::sample(&p, 10, 3);
        let mut a = GroupBuf::new(s.dims(), false);
        let mut b = GroupBuf::new(p.dims(), false);
        for (si, &gi) in s.ids().iter().enumerate() {
            s.fill_group(si, &mut a);
            p.fill_group(gi, &mut b);
            assert_eq!(a.profits, b.profits);
        }
    }

    #[test]
    fn presolve_lambda_is_near_full_solution() {
        // the sampled multipliers should be in the ballpark of the full
        // solve's multipliers (that is the whole point of §5.3)
        let p = SyntheticProblem::new(GeneratorConfig::sparse(20_000, 10, 10).with_seed(3));
        let cfg = SolverConfig::default();
        let warm = presolve_lambda(
            &p,
            &PresolveConfig { sample: 2_000, max_iters: 40, seed: 1 },
            &cfg,
            &Cluster::new(4),
        )
        .unwrap();
        let full = solve_scd(&p, &cfg, &Cluster::new(4)).unwrap();
        for (w, f) in warm.iter().zip(&full.lambda) {
            assert!(
                (w - f).abs() < 0.25 * f.abs().max(0.1),
                "warm {w} vs full {f} (all: warm={warm:?} full={:?})",
                full.lambda
            );
        }
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(20_000, 10, 10).with_seed(5));
        let cold_cfg = SolverConfig { track_history: false, ..Default::default() };
        let cold = solve_scd(&p, &cold_cfg, &Cluster::new(4)).unwrap();
        let warm_cfg = SolverConfig {
            presolve: Some(PresolveConfig { sample: 2_000, max_iters: 40, seed: 1 }),
            track_history: false,
            ..Default::default()
        };
        let warm = solve_scd(&p, &warm_cfg, &Cluster::new(4)).unwrap();
        assert!(
            warm.iterations <= cold.iterations,
            "warm {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
    }
}
