//! Coordinate-scheduling variants (§4.3.2): synchronous (all coordinates
//! each round — the paper's best performer), cyclic (one at a time) and
//! block (a fixed-size block per round).

use crate::solver::config::CdMode;

/// The coordinates updated at iteration `t` for `k` total coordinates.
pub fn active_coords(mode: CdMode, t: usize, k: usize) -> Vec<usize> {
    match mode {
        CdMode::Synchronous => (0..k).collect(),
        CdMode::Cyclic => vec![t % k],
        CdMode::Block { block_size } => {
            let bs = block_size.min(k).max(1);
            let n_blocks = k.div_ceil(bs);
            let b = t % n_blocks;
            (b * bs..((b + 1) * bs).min(k)).collect()
        }
    }
}

/// Number of iterations forming one full sweep over all coordinates
/// (convergence is only declared on sweep boundaries).
pub fn sweep_len(mode: CdMode, k: usize) -> usize {
    match mode {
        CdMode::Synchronous => 1,
        CdMode::Cyclic => k,
        CdMode::Block { block_size } => k.div_ceil(block_size.min(k).max(1)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synchronous_covers_all() {
        assert_eq!(active_coords(CdMode::Synchronous, 3, 4), vec![0, 1, 2, 3]);
        assert_eq!(sweep_len(CdMode::Synchronous, 4), 1);
    }

    #[test]
    fn cyclic_round_robin() {
        assert_eq!(active_coords(CdMode::Cyclic, 0, 3), vec![0]);
        assert_eq!(active_coords(CdMode::Cyclic, 4, 3), vec![1]);
        assert_eq!(sweep_len(CdMode::Cyclic, 3), 3);
    }

    #[test]
    fn block_partitions() {
        let m = CdMode::Block { block_size: 2 };
        assert_eq!(active_coords(m, 0, 5), vec![0, 1]);
        assert_eq!(active_coords(m, 1, 5), vec![2, 3]);
        assert_eq!(active_coords(m, 2, 5), vec![4]);
        assert_eq!(active_coords(m, 3, 5), vec![0, 1]);
        assert_eq!(sweep_len(m, 5), 3);
    }

    #[test]
    fn every_coord_covered_within_a_sweep() {
        for mode in [CdMode::Synchronous, CdMode::Cyclic, CdMode::Block { block_size: 3 }] {
            let k = 7;
            let mut seen = vec![false; k];
            for t in 0..sweep_len(mode, k) {
                for c in active_coords(mode, t, k) {
                    seen[c] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "{mode:?}");
        }
    }

    #[test]
    fn oversized_block_behaves_like_synchronous() {
        let m = CdMode::Block { block_size: 99 };
        assert_eq!(active_coords(m, 0, 4), vec![0, 1, 2, 3]);
        assert_eq!(sweep_len(m, 4), 1);
    }
}
