//! §5.2 — fine-tuned bucketing for the SCD reducer.
//!
//! The exact reducer keeps every emitted `(v1, v2)` pair; at `N` in the
//! hundreds of millions that is too much state. The paper's fix: a
//! fixed-size histogram whose buckets are finest *around the previous
//! iterate* `λ_k^t` (the best available estimate of the new `λ_k`) and grow
//! exponentially away from it:
//!
//! ```text
//! bucket_id(λ) = sign(λ − λ_t) · ⌊log(|λ − λ_t| / Δ)⌋
//! ```
//!
//! The reducer walks buckets from high λ to low, accumulating consumption,
//! and interpolates inside the bucket where the budget is crossed (we use
//! the consumption-weighted mean of the bucket's candidates, which equals
//! the exact answer when the bucket is a single candidate).

/// Number of exponential buckets per side. 2^96 of dynamic range around Δ
/// covers any f64 candidate the solver can produce.
const HALF: usize = 96;

/// One side's bucket: total consumption, consumption-weighted λ mass, and
/// the observed candidate range (for in-bucket interpolation).
#[derive(Debug, Clone, Copy)]
struct Bucket {
    w: f64,  // Σ v2
    wv: f64, // Σ v1·v2
    lo: f64, // min v1 observed
    hi: f64, // max v1 observed
}

impl Default for Bucket {
    fn default() -> Self {
        Self { w: 0.0, wv: 0.0, lo: f64::INFINITY, hi: f64::NEG_INFINITY }
    }
}

/// Exponential histogram centred on `center = λ_k^t`.
#[derive(Debug, Clone)]
pub struct BucketHist {
    center: f64,
    delta: f64,
    /// `λ ≥ center`: index grows with distance above.
    pos: Vec<Bucket>,
    /// `λ < center`: index grows with distance below.
    neg: Vec<Bucket>,
}

impl BucketHist {
    /// New histogram around `center` with finest width `delta`.
    pub fn new(center: f64, delta: f64) -> Self {
        assert!(delta > 0.0);
        Self { center, delta, pos: vec![Bucket::default(); HALF], neg: vec![Bucket::default(); HALF] }
    }

    #[inline]
    fn side_index(&self, dist: f64) -> usize {
        // dist ≥ 0; buckets: [0,Δ) → 0, [Δ,2Δ) → 1, [2Δ,4Δ) → 2, ...
        if dist < self.delta {
            0
        } else {
            let e = (dist / self.delta).log2().floor() as i64 + 1;
            (e.max(0) as usize).min(HALF - 1)
        }
    }

    /// Add one `(v1, v2)` emission.
    #[inline]
    pub fn add(&mut self, v1: f64, v2: f64) {
        let d = v1 - self.center;
        let b = if d >= 0.0 {
            let idx = self.side_index(d);
            &mut self.pos[idx]
        } else {
            let idx = self.side_index(-d);
            &mut self.neg[idx]
        };
        b.w += v2;
        b.wv += v1 * v2;
        b.lo = b.lo.min(v1);
        b.hi = b.hi.max(v1);
    }

    /// Merge a compatible histogram (same center/delta).
    pub fn merge(&mut self, other: &BucketHist) {
        debug_assert_eq!(self.center.to_bits(), other.center.to_bits());
        debug_assert_eq!(self.delta.to_bits(), other.delta.to_bits());
        let fold = |a: &mut Bucket, b: &Bucket| {
            a.w += b.w;
            a.wv += b.wv;
            a.lo = a.lo.min(b.lo);
            a.hi = a.hi.max(b.hi);
        };
        for (a, b) in self.pos.iter_mut().zip(&other.pos) {
            fold(a, b);
        }
        for (a, b) in self.neg.iter_mut().zip(&other.neg) {
            fold(a, b);
        }
    }

    /// Total emitted consumption.
    pub fn total(&self) -> f64 {
        self.pos.iter().chain(&self.neg).map(|b| b.w).sum()
    }

    /// Number of `f64` words [`BucketHist::to_wire`] emits: center, delta,
    /// then `(w, wv, lo, hi)` for every positive- and negative-side bucket.
    pub(crate) const fn wire_len() -> usize {
        2 + 2 * HALF * 4
    }

    /// Flatten the histogram into `out` for the cluster wire protocol
    /// (exactly [`BucketHist::wire_len`] words, bit-preserving).
    pub(crate) fn to_wire(&self, out: &mut Vec<f64>) {
        out.push(self.center);
        out.push(self.delta);
        for b in self.pos.iter().chain(&self.neg) {
            out.extend_from_slice(&[b.w, b.wv, b.lo, b.hi]);
        }
    }

    /// Rebuild a histogram from [`BucketHist::to_wire`] words. Returns
    /// `None` when `v` is shorter than [`BucketHist::wire_len`] or the
    /// delta is not positive (corrupt or hostile frame).
    pub(crate) fn from_wire(v: &[f64]) -> Option<Self> {
        if v.len() < Self::wire_len() || !(v[1] > 0.0) {
            return None;
        }
        let mut h = BucketHist::new(v[0], v[1]);
        for (i, b) in h.pos.iter_mut().chain(&mut h.neg).enumerate() {
            let at = 2 + i * 4;
            *b = Bucket { w: v[at], wv: v[at + 1], lo: v[at + 2], hi: v[at + 3] };
        }
        Some(h)
    }

    /// The §5.2 reduce: walk buckets from the highest λ down; when the
    /// cumulative consumption would cross the budget inside a bucket,
    /// *interpolate within that bucket* (paper: "approximate the value of
    /// v ... by interpolating within the bucket"): the fraction
    /// `f = (budget − cum)/w` of the bucket's consumption still fits, so
    /// return `hi − f·(hi − lo)`. Returns 0 when everything fits.
    pub fn reduce(&self, budget: f64) -> f64 {
        let mut cum = 0.0f64;
        // descending λ: far-above buckets first, then near-above, then below
        for b in self.pos.iter().rev().chain(self.neg.iter()) {
            if b.w == 0.0 {
                continue;
            }
            if cum + b.w > budget {
                let f = ((budget - cum) / b.w).clamp(0.0, 1.0);
                return (b.hi - f * (b.hi - b.lo)).max(0.0);
            }
            cum += b.w;
        }
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::solver::scd::exact_threshold_reduce;

    #[test]
    fn single_candidate_is_exact() {
        let mut h = BucketHist::new(1.0, 1e-6);
        h.add(2.5, 3.0);
        assert_eq!(h.reduce(1.0), 2.5); // 3.0 > budget → crossing bucket
        assert_eq!(h.reduce(3.0), 0.0); // fits → λ = 0
    }

    #[test]
    fn picks_crossing_bucket_top_down() {
        let mut h = BucketHist::new(0.0, 1e-3);
        h.add(10.0, 5.0); // far above
        h.add(0.5, 5.0); // nearer
        h.add(0.1, 5.0);
        // budget 7: 5 (λ=10) fits, adding λ=0.5 bucket crosses → ≈0.5
        let v = h.reduce(7.0);
        assert!((v - 0.5).abs() < 0.2, "got {v}");
        // budget 20: everything fits → 0
        assert_eq!(h.reduce(20.0), 0.0);
    }

    #[test]
    fn negative_side_order() {
        let mut h = BucketHist::new(5.0, 1e-2);
        h.add(4.0, 1.0); // below center
        h.add(6.0, 1.0); // above center
        // budget 0.5: the λ=6 bucket crosses first
        assert!((h.reduce(0.5) - 6.0).abs() < 1e-9);
        // budget 1.5: 6 fits, 4 crosses
        assert!((h.reduce(1.5) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn merge_equals_combined_adds() {
        let mut a = BucketHist::new(1.0, 1e-4);
        let mut b = BucketHist::new(1.0, 1e-4);
        let mut c = BucketHist::new(1.0, 1e-4);
        for (i, (v1, v2)) in [(0.9, 1.0), (1.1, 2.0), (3.0, 1.5), (0.2, 0.5)].iter().enumerate() {
            c.add(*v1, *v2);
            if i % 2 == 0 {
                a.add(*v1, *v2)
            } else {
                b.add(*v1, *v2)
            }
        }
        a.merge(&b);
        assert!((a.total() - c.total()).abs() < 1e-12);
        assert_eq!(a.reduce(2.0), c.reduce(2.0));
    }

    #[test]
    fn approximates_exact_reduce_when_centered_well() {
        // center the histogram at the true answer: buckets are finest there
        let mut rng = Xoshiro256pp::new(123);
        for _ in 0..50 {
            let n = 200;
            let pairs: Vec<(f64, f64)> =
                (0..n).map(|_| (rng.uniform(0.0, 2.0), rng.uniform(0.0, 1.0))).collect();
            let budget = rng.uniform(5.0, 40.0);
            let exact = exact_threshold_reduce(&mut pairs.clone(), budget);
            let mut h = BucketHist::new(exact, 1e-5);
            for &(v1, v2) in &pairs {
                h.add(v1, v2);
            }
            let approx = h.reduce(budget);
            assert!(
                (approx - exact).abs() <= 0.05 * exact.max(0.05),
                "exact {exact} vs bucketed {approx}"
            );
        }
    }

    #[test]
    fn wire_roundtrip_preserves_reduce_bits() {
        let mut h = BucketHist::new(1.25, 1e-5);
        for (v1, v2) in [(0.9, 1.0), (1.2500001, 2.0), (7.0, 1.5), (0.0, 0.5)] {
            h.add(v1, v2);
        }
        let mut words = Vec::new();
        h.to_wire(&mut words);
        assert_eq!(words.len(), BucketHist::wire_len());
        let back = BucketHist::from_wire(&words).expect("valid wire form");
        assert_eq!(back.total().to_bits(), h.total().to_bits());
        for budget in [0.1, 1.0, 2.4, 10.0] {
            assert_eq!(back.reduce(budget).to_bits(), h.reduce(budget).to_bits());
        }
        // truncated and corrupt forms are rejected
        assert!(BucketHist::from_wire(&words[..words.len() - 1]).is_none());
        let mut bad = words.clone();
        bad[1] = 0.0; // delta must stay positive
        assert!(BucketHist::from_wire(&bad).is_none());
    }

    #[test]
    fn extreme_values_clamp_into_range() {
        let mut h = BucketHist::new(1.0, 1e-9);
        h.add(1e30, 1.0);
        h.add(1e-30, 1.0);
        assert!((h.total() - 2.0).abs() < 1e-12);
        let v = h.reduce(0.5);
        assert!(v > 1e20); // the huge candidate crosses first
    }
}
