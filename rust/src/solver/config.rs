//! Solver configuration.

use crate::error::{Error, Result};

/// How the SCD reducer aggregates `(v1, v2)` threshold emissions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReduceMode {
    /// Keep every emission and sort — exact Algorithm 4 reduce. Memory is
    /// O(total emissions); right for `N` up to a few million.
    Exact,
    /// §5.2 fine-tuned bucketing: fixed-size exponential histogram centred
    /// on `λ_k^t`, `delta` is the finest bucket width. O(1) memory per
    /// knapsack; the update is interpolated inside the crossing bucket.
    Bucketed {
        /// Finest bucket width `Δ`.
        delta: f64,
    },
}

/// Coordinate-descent scheduling (paper §4.3.2: synchronous performs best;
/// cyclic and block are also supported "in our implementation").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CdMode {
    /// Update every `λ_k` simultaneously each round (Algorithm 4).
    Synchronous,
    /// One coordinate per round, round-robin.
    Cyclic,
    /// `block_size` coordinates per round, round-robin blocks.
    Block {
        /// Coordinates updated per round.
        block_size: usize,
    },
}

/// Pre-solving (§5.3) settings.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PresolveConfig {
    /// Number of sampled groups `n` (paper: 10,000).
    pub sample: usize,
    /// Iteration cap for the sampled solve.
    pub max_iters: usize,
    /// Sampling seed.
    pub seed: u64,
}

impl Default for PresolveConfig {
    fn default() -> Self {
        Self { sample: 10_000, max_iters: 50, seed: 0x9e37 }
    }
}

/// Full solver configuration shared by DD and SCD.
#[derive(Debug, Clone, PartialEq)]
pub struct SolverConfig {
    /// Iteration cap `T`.
    pub max_iters: usize,
    /// Convergence: stop when `max_k |Δλ_k| / max(1,|λ_k|) <` this.
    pub tol: f64,
    /// Initial multiplier value (paper §6.3 starts at 1.0).
    pub lambda0: f64,
    /// DD learning rate `α` (ignored by SCD).
    pub dd_alpha: f64,
    /// SCD reduce mode.
    pub reduce: ReduceMode,
    /// Coordinate scheduling.
    pub cd: CdMode,
    /// Optional §5.3 pre-solve.
    pub presolve: Option<PresolveConfig>,
    /// Run §5.4 post-processing when the converged solution violates a
    /// global constraint.
    pub postprocess: bool,
    /// Shard size override (default: derived from worker count).
    pub shard_size: Option<usize>,
    /// Use Algorithm 5 on eligible sparse instances (on by default;
    /// disable to benchmark the general Algorithm 3 path — Fig 4).
    pub use_sparse_fast_path: bool,
    /// λ-stability skipping: cache each group's Algorithm-3 emissions per
    /// coordinate and replay them while no *other* coordinate's multiplier
    /// has moved bit-wise (on by default; in-process executor only, memory
    /// gated by `PALLAS_SKIP_CACHE_MB`). Replays are exact, so results are
    /// bit-identical either way — this knob only trades memory for work.
    pub lambda_skip: bool,
    /// Under-relaxation β for the synchronous λ update:
    /// `λ^{t+1} = λ^t + β(reduce − λ^t)`. `None` = auto (1.0 on sparse
    /// instances, 0.5 on dense ones, whose coordinates couple strongly and
    /// make the undamped Jacobi-style update 2-cycle between extremes).
    pub damping: Option<f64>,
    /// Record per-iteration stats (primal/dual/violation) in the report.
    /// Kept for the thin `solve_scd`/`solve_dd` wrappers; the session API
    /// expresses the same thing (and more) through
    /// [`crate::solver::stats::SolveObserver`] — history recording is the
    /// built-in [`crate::solver::stats::HistoryObserver`].
    pub track_history: bool,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self {
            max_iters: 60,
            tol: 1e-4,
            lambda0: 1.0,
            dd_alpha: 1e-3,
            reduce: ReduceMode::Exact,
            cd: CdMode::Synchronous,
            presolve: None,
            postprocess: true,
            shard_size: None,
            use_sparse_fast_path: true,
            lambda_skip: true,
            damping: None,
            track_history: true,
        }
    }
}

impl SolverConfig {
    /// Validate parameter ranges.
    pub fn validate(&self) -> Result<()> {
        if self.max_iters == 0 {
            return Err(Error::InvalidConfig("max_iters must be ≥ 1".into()));
        }
        if !(self.tol > 0.0) {
            return Err(Error::InvalidConfig("tol must be > 0".into()));
        }
        if self.lambda0 < 0.0 {
            return Err(Error::InvalidConfig("lambda0 must be ≥ 0".into()));
        }
        if !(self.dd_alpha > 0.0) {
            return Err(Error::InvalidConfig("dd_alpha must be > 0".into()));
        }
        if let ReduceMode::Bucketed { delta } = self.reduce {
            if !(delta > 0.0) {
                return Err(Error::InvalidConfig("bucketing delta must be > 0".into()));
            }
        }
        if let CdMode::Block { block_size } = self.cd {
            if block_size == 0 {
                return Err(Error::InvalidConfig("block_size must be ≥ 1".into()));
            }
        }
        if let Some(p) = &self.presolve {
            if p.sample == 0 || p.max_iters == 0 {
                return Err(Error::InvalidConfig("presolve sample/max_iters must be ≥ 1".into()));
            }
        }
        if let Some(b) = self.damping {
            if !(b > 0.0 && b <= 1.0) {
                return Err(Error::InvalidConfig("damping must be in (0, 1]".into()));
            }
        }
        Ok(())
    }

    /// Builder-style setters (the common knobs).
    pub fn with_max_iters(mut self, t: usize) -> Self {
        self.max_iters = t;
        self
    }
    /// Set the convergence tolerance.
    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }
    /// Set DD's learning rate.
    pub fn with_dd_alpha(mut self, a: f64) -> Self {
        self.dd_alpha = a;
        self
    }
    /// Enable §5.3 pre-solving.
    pub fn with_presolve(mut self, p: PresolveConfig) -> Self {
        self.presolve = Some(p);
        self
    }
    /// Set the SCD reduce mode.
    pub fn with_reduce(mut self, r: ReduceMode) -> Self {
        self.reduce = r;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SolverConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_values() {
        assert!(SolverConfig { max_iters: 0, ..Default::default() }.validate().is_err());
        assert!(SolverConfig { tol: 0.0, ..Default::default() }.validate().is_err());
        assert!(SolverConfig { lambda0: -1.0, ..Default::default() }.validate().is_err());
        assert!(SolverConfig { dd_alpha: 0.0, ..Default::default() }.validate().is_err());
        assert!(SolverConfig {
            reduce: ReduceMode::Bucketed { delta: 0.0 },
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(SolverConfig { cd: CdMode::Block { block_size: 0 }, ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn builders_apply() {
        let c = SolverConfig::default()
            .with_max_iters(7)
            .with_tol(1e-2)
            .with_dd_alpha(0.5)
            .with_reduce(ReduceMode::Bucketed { delta: 1e-3 });
        assert_eq!(c.max_iters, 7);
        assert_eq!(c.tol, 1e-2);
        assert_eq!(c.dd_alpha, 0.5);
        c.validate().unwrap();
    }
}
