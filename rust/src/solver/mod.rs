//! The paper's solvers and speedups.
//!
//! | Paper | Module |
//! |---|---|
//! | Algorithm 1 (greedy per-group IP, laminar locals) | [`greedy`] |
//! | Algorithm 2 (distributed dual descent)            | [`dd`] |
//! | Algorithm 3 (candidate λ values, general)         | [`candidates`] |
//! | Algorithm 4 (synchronous coordinate descent)      | [`scd`] |
//! | Algorithm 5 (linear-time candidates, sparse)      | [`sparse_q`] |
//! | §5.2 fine-tuned bucketing                         | [`bucketing`] |
//! | §5.3 pre-solving by sampling                      | [`presolve`] |
//! | §5.4 post-processing for feasibility              | [`postprocess`] |
//! | cyclic / block coordinate descent variants        | [`cd_modes`] |
//!
//! Every solver consumes a [`crate::instance::GroupSource`], so the same
//! code runs against in-memory, synthetic-on-the-fly, or out-of-core
//! memory-mapped instances ([`crate::instance::store`]) — the latter is
//! how instances bigger than RAM are solved, mirroring the paper's mappers
//! streaming groups from a sharded distributed store.

pub mod adjusted;
pub mod bucketing;
pub mod candidates;
pub mod cd_modes;
pub mod config;
pub mod dd;
pub mod greedy;
pub mod pointquery;
pub mod postprocess;
pub mod presolve;
pub mod rounds;
pub mod scd;
pub mod sparse_q;
pub(crate) mod stability;
pub mod stats;

pub use config::{CdMode, ReduceMode, SolverConfig};
pub use stats::{IterStat, PhaseTimings, SolveReport};
