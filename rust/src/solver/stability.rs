//! λ-stability skipping for the SCD map phase.
//!
//! Algorithm 3's candidate walk for coordinate `k` never reads `λ_k`
//! itself: the line coefficients are `a_j = p_j − Σ_{k'≠k} λ_{k'} b_jk'`,
//! `s_j = b_jk`, and the walk enumerates *every* positive candidate from
//! high `λ_k` to low. A group's emitted `(v1, v2)` set for coordinate `k`
//! is therefore a pure function of the group data and `λ_{-k}` — it is
//! provably unchanged on the whole interval `λ_k ∈ [0, ∞)` as long as no
//! *other* coordinate moved, and is invalidated the moment one does (the
//! interval collapses to empty). This is the flip side of the paper's
//! observation that each exact line-search update moves one coordinate
//! while most group decisions stay fixed: once coordinates freeze (the
//! convergence tail, cyclic sweeps over a quiet region, or the ubiquitous
//! single-global-constraint case `K = 1`, where `λ_{-k}` is empty and the
//! cache never invalidates), the O(M²·K) walk is pure recomputation.
//!
//! [`ScdStability`] caches each group's emissions per coordinate and
//! *replays* them — same values, same order — when the validity rule
//! holds, so the reduce receives bit-identical inputs whether a walk was
//! skipped or recomputed. Bit-equality of multipliers is tracked with
//! round tags (`last_change[k]` = last round whose broadcast λ_k differed
//! bit-wise from the previous round's), which makes the validity check
//! O(1) per (group, coordinate): `other_change[k] ≤ computed_round`.
//! The tag rule is deliberately one-sided: a coordinate that oscillates
//! A→B→A is treated as changed even though its bits match the cache
//! round again, so an occasional valid replay is conservatively
//! recomputed — never the other way around (a stale replay is
//! impossible; the invariant was brute-force checked against bitwise
//! λ-history equality over randomized histories).
//!
//! Capturing walks has a cost of its own, so it is gated per coordinate
//! by the same signal ([`ShardGuard::store_useful`]): a walk for `k` is
//! cached only when the *other* coordinates were already quiet entering
//! the round — mid-descent churn (synchronous or cyclic) pays no capture
//! overhead, while `K = 1` and quiet tails capture and replay from the
//! next round on.
//!
//! The cache lives on the leader's in-process executor only — remote
//! workers are stateless between task frames by design — and is memory-
//! gated: it engages only when the instance is small enough for the
//! bookkeeping to fit `PALLAS_SKIP_CACHE_MB` (default 512), and stops
//! inserting when the stored emissions would exceed the budget. Skipping
//! never changes results, only work: everything here is an exact replay.

use crate::instance::shard::Shards;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Default cache budget in MiB (override with `PALLAS_SKIP_CACHE_MB`).
const DEFAULT_CACHE_MB: usize = 512;

/// Per-(group, coordinate) cached emissions for one group.
#[derive(Debug)]
struct GroupCache {
    /// Round at which coordinate `k`'s walk was cached (0 = never).
    computed: Vec<u32>,
    /// The cached `(v1, v2)` emissions, per coordinate, in walk order.
    emits: Vec<Vec<(f64, f64)>>,
}

impl GroupCache {
    fn new(kk: usize) -> Self {
        Self { computed: vec![0; kk], emits: vec![Vec::new(); kk] }
    }
}

/// Approximate resident bytes of one empty [`GroupCache`] (headers +
/// per-coordinate bookkeeping), used for the memory gate.
fn group_overhead(kk: usize) -> usize {
    std::mem::size_of::<GroupCache>() + kk * (4 + std::mem::size_of::<Vec<(f64, f64)>>()) + 16
}

/// The solve-lifetime λ-stability cache. One per in-process SCD solve;
/// shared read-only across map workers (each shard is processed by exactly
/// one worker per round, so the per-shard mutexes are uncontended).
pub(crate) struct ScdStability {
    shards: Shards,
    kk: usize,
    /// Current round, 1-based (0 = before the first `begin_round`).
    round: u32,
    /// Per coordinate: last round whose broadcast λ_k changed bit-wise.
    last_change: Vec<u32>,
    /// Per coordinate: `max_{k'≠k} last_change[k']` for the current round.
    other_change: Vec<u32>,
    caches: Vec<Mutex<Vec<Option<Box<GroupCache>>>>>,
    walks_total: AtomicU64,
    walks_skipped: AtomicU64,
    mem_used: AtomicUsize,
    mem_cap: usize,
}

impl ScdStability {
    /// Build a cache for the solve's shard partition, or `None` when the
    /// bookkeeping alone would blow the memory budget (billion-scale
    /// instances simply run uncached).
    pub(crate) fn try_new(shards: Shards, kk: usize) -> Option<Self> {
        let mem_cap = std::env::var("PALLAS_SKIP_CACHE_MB")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_CACHE_MB)
            .saturating_mul(1 << 20);
        // upfront floor: one Option slot per group plus per-shard mutexes;
        // require the fully-populated overhead (no emissions yet) to fit
        // half the budget, leaving room for the emissions themselves
        let n = shards.n_total();
        let floor = n.checked_mul(8 + group_overhead(kk))?;
        if mem_cap == 0 || floor > mem_cap / 2 {
            return None;
        }
        Some(Self {
            shards,
            kk,
            round: 0,
            last_change: vec![0; kk],
            other_change: vec![0; kk],
            caches: (0..shards.count()).map(|_| Mutex::new(Vec::new())).collect(),
            walks_total: AtomicU64::new(0),
            walks_skipped: AtomicU64::new(0),
            mem_used: AtomicUsize::new(0),
            mem_cap,
        })
    }

    /// Advance to the next round with its broadcast multipliers. `prev` is
    /// the previous round's broadcast λ (None before the first round —
    /// every coordinate counts as changed).
    pub(crate) fn begin_round(&mut self, prev: Option<&[f64]>, lambda: &[f64]) {
        debug_assert_eq!(lambda.len(), self.kk);
        self.round += 1;
        match prev {
            None => self.last_change.iter_mut().for_each(|c| *c = self.round),
            Some(p) => {
                for (c, (a, b)) in self.last_change.iter_mut().zip(p.iter().zip(lambda)) {
                    if a.to_bits() != b.to_bits() {
                        *c = self.round;
                    }
                }
            }
        }
        // other_change[k] = max over k'≠k of last_change[k'] — computed
        // with the (max, second-max) trick so a round costs O(K), not O(K²)
        let (mut max1, mut max2, mut argmax) = (0u32, 0u32, usize::MAX);
        for (k, &c) in self.last_change.iter().enumerate() {
            if c > max1 {
                max2 = max1;
                max1 = c;
                argmax = k;
            } else if c > max2 {
                max2 = c;
            }
        }
        for (k, o) in self.other_change.iter_mut().enumerate() {
            *o = if k == argmax { max2 } else { max1 };
        }
    }

    /// Lock shard `idx`'s cache for this round's map pass.
    pub(crate) fn shard(&self, idx: usize) -> ShardGuard<'_> {
        let shard = self.shards.get(idx);
        let mut groups = self.caches[idx].lock().unwrap();
        if groups.len() != shard.len() {
            groups.resize_with(shard.len(), || None);
        }
        ShardGuard { st: self, groups, base: shard.start, total: 0, skipped: 0 }
    }

    /// Drain the per-round walk counters `(total, skipped)`.
    pub(crate) fn take_counts(&self) -> (u64, u64) {
        (self.walks_total.swap(0, Ordering::Relaxed), self.walks_skipped.swap(0, Ordering::Relaxed))
    }
}

/// One worker's exclusive view of a shard's caches during a map pass.
pub(crate) struct ShardGuard<'a> {
    st: &'a ScdStability,
    groups: MutexGuard<'a, Vec<Option<Box<GroupCache>>>>,
    base: usize,
    total: u64,
    skipped: u64,
}

impl ShardGuard<'_> {
    /// Replay group `i`'s cached emissions for coordinate `k` when they
    /// are provably current (no *other* coordinate's λ changed bit-wise
    /// since they were computed). Returns true when the walk was skipped;
    /// the caller must recompute (and [`ShardGuard::store`]) otherwise.
    #[inline]
    pub(crate) fn replay<F: FnMut(f64, f64)>(&mut self, i: usize, k: usize, mut emit: F) -> bool {
        self.total += 1;
        let Some(g) = self.groups[i - self.base].as_deref() else {
            return false;
        };
        let at = g.computed[k];
        if at == 0 || self.st.other_change[k] > at {
            return false; // never cached, or the stability interval collapsed
        }
        for &(v1, v2) in &g.emits[k] {
            emit(v1, v2);
        }
        self.skipped += 1;
        true
    }

    /// Whether caching coordinate `k`'s walk this round can ever pay off:
    /// a cache written now stays valid only while `λ_{-k}` holds still, so
    /// capturing is useful exactly when the *other* coordinates were
    /// already quiet entering this round (`other_change[k] < round`).
    /// This single predicate covers every schedule — synchronous churn
    /// (all coordinates moving ⇒ capture nothing), cyclic sweeps (the
    /// round-robin mover keeps invalidating everyone else ⇒ capture
    /// nothing until the region quiets), `K = 1` (no other coordinates ⇒
    /// always capture), and the convergence tail (quiet ⇒ capture, replay
    /// from the next round on). Callers use it to skip the
    /// emission-capture bookkeeping, not just the store.
    #[inline]
    pub(crate) fn store_useful(&self, k: usize) -> bool {
        self.st.other_change[k] < self.st.round
    }

    /// Record a freshly computed walk for `(i, k)`; a no-op when capturing
    /// cannot pay off ([`ShardGuard::store_useful`]) or once the cache
    /// budget is exhausted (the group then simply keeps recomputing).
    pub(crate) fn store(&mut self, i: usize, k: usize, emits: &[(f64, f64)]) {
        if !self.store_useful(k) {
            return;
        }
        let round = self.st.round;
        let slot = &mut self.groups[i - self.base];
        if slot.is_none() {
            let overhead = group_overhead(self.st.kk);
            if self.st.mem_used.fetch_add(overhead, Ordering::Relaxed) + overhead
                > self.st.mem_cap
            {
                self.st.mem_used.fetch_sub(overhead, Ordering::Relaxed);
                return;
            }
            *slot = Some(Box::new(GroupCache::new(self.st.kk)));
        }
        let g = slot.as_deref_mut().unwrap();
        let stored = &mut g.emits[k];
        stored.clear();
        // grow with reserve_exact so the charged bytes equal the real
        // allocation (extend_from_slice's amortized doubling would let the
        // cache silently overshoot the budget by ~2×)
        let grow = emits.len().saturating_sub(stored.capacity())
            * std::mem::size_of::<(f64, f64)>();
        if grow > 0
            && self.st.mem_used.fetch_add(grow, Ordering::Relaxed) + grow > self.st.mem_cap
        {
            self.st.mem_used.fetch_sub(grow, Ordering::Relaxed);
            g.computed[k] = 0;
            return;
        }
        stored.reserve_exact(emits.len());
        stored.extend_from_slice(emits);
        g.computed[k] = round;
    }
}

impl Drop for ShardGuard<'_> {
    fn drop(&mut self) {
        self.st.walks_total.fetch_add(self.total, Ordering::Relaxed);
        self.st.walks_skipped.fetch_add(self.skipped, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(guard: &mut ShardGuard<'_>, i: usize, k: usize) -> Option<Vec<(f64, f64)>> {
        let mut out = Vec::new();
        guard.replay(i, k, |v1, v2| out.push((v1, v2))).then_some(out)
    }

    #[test]
    fn replays_only_while_other_coordinates_hold_still() {
        let mut st = ScdStability::try_new(Shards::new(10, 4), 2).unwrap();
        // round 1: everything counts as changed → capturing cannot pay off
        st.begin_round(None, &[1.0, 1.0]);
        {
            let mut g = st.shard(0);
            assert!(collect(&mut g, 2, 0).is_none(), "nothing cached yet");
            assert!(!g.store_useful(0) && !g.store_useful(1));
            g.store(2, 0, &[(9.0, 9.0)]); // gated no-op
        }
        // round 2: only λ_0 moved → λ_1 (coordinate 0's dependency) is
        // quiet, so coordinate 0 captures; coordinate 1 cannot pay off
        st.begin_round(Some(&[1.0, 1.0]), &[0.5, 1.0]);
        {
            let mut g = st.shard(0);
            assert!(collect(&mut g, 2, 0).is_none(), "round-1 store was gated off");
            assert!(g.store_useful(0));
            assert!(!g.store_useful(1));
            g.store(2, 0, &[(3.0, 0.5), (1.0, 0.25)]);
        }
        // round 3: λ_0 moved again — its own movement never invalidates
        // its interval, so the cached walk replays
        st.begin_round(Some(&[0.5, 1.0]), &[0.25, 1.0]);
        {
            let mut g = st.shard(0);
            assert_eq!(collect(&mut g, 2, 0), Some(vec![(3.0, 0.5), (1.0, 0.25)]));
        }
        // round 4: λ_1 moved → interval invalidated, must recompute
        st.begin_round(Some(&[0.25, 1.0]), &[0.25, 0.75]);
        {
            let mut g = st.shard(0);
            assert!(collect(&mut g, 2, 0).is_none(), "other-coordinate movement must invalidate");
        }
        // round 5 (frozen): capture again; round 6 replays it
        st.begin_round(Some(&[0.25, 0.75]), &[0.25, 0.75]);
        st.shard(0).store(2, 0, &[(2.0, 0.5)]);
        st.begin_round(Some(&[0.25, 0.75]), &[0.25, 0.75]);
        {
            let mut g = st.shard(0);
            assert_eq!(collect(&mut g, 2, 0), Some(vec![(2.0, 0.5)]));
        }
        let (total, skipped) = st.take_counts();
        assert_eq!(total, 5);
        assert_eq!(skipped, 2);
        assert_eq!(st.take_counts(), (0, 0), "counters drain per round");
    }

    #[test]
    fn single_constraint_never_invalidates() {
        // K = 1: λ_{-k} is empty, so a cached walk stays valid forever
        let mut st = ScdStability::try_new(Shards::new(4, 4), 1).unwrap();
        st.begin_round(None, &[2.0]);
        st.shard(0).store(0, 0, &[(1.0, 1.0)]);
        for l in [1.5, 0.7, 0.1] {
            let prev = [2.0 * l]; // arbitrary moving λ_0
            st.begin_round(Some(&prev), &[l]);
            let mut g = st.shard(0);
            assert_eq!(collect(&mut g, 0, 0), Some(vec![(1.0, 1.0)]));
        }
    }

    #[test]
    fn empty_emission_sets_replay_too() {
        let mut st = ScdStability::try_new(Shards::new(4, 2), 2).unwrap();
        st.begin_round(None, &[1.0, 1.0]);
        // round 2 (quiet): capturing pays off → an *empty* walk is cached
        st.begin_round(Some(&[1.0, 1.0]), &[1.0, 1.0]);
        st.shard(1).store(3, 1, &[]);
        st.begin_round(Some(&[1.0, 1.0]), &[1.0, 1.0]);
        let mut g = st.shard(1);
        assert_eq!(collect(&mut g, 3, 1), Some(vec![]));
    }

    #[test]
    fn churning_schedules_never_pay_capture_cost() {
        // synchronous churn: both coordinates move every round → no store
        // can pay off, and none happens (mem_used stays untouched)
        let mut st = ScdStability::try_new(Shards::new(4, 4), 2).unwrap();
        let mut prev: Option<Vec<f64>> = None;
        for r in 1..=5u32 {
            let cur = vec![r as f64, r as f64 + 0.5];
            st.begin_round(prev.as_deref(), &cur);
            let mut g = st.shard(0);
            assert!(!g.store_useful(0) && !g.store_useful(1), "round {r}");
            g.store(0, 0, &[(9.0, 9.0)]);
            g.store(0, 1, &[(9.0, 9.0)]);
        }
        assert_eq!(st.mem_used.load(Ordering::Relaxed), 0, "gated stores must not allocate");
        // cyclic churn: each round updates one coordinate round-robin, and
        // only the *active* coordinate's walk runs. Mid-churn the previous
        // round's mover always invalidates the current active coordinate,
        // so the gate is false exactly where a store would otherwise happen
        let mut st = ScdStability::try_new(Shards::new(4, 4), 3).unwrap();
        let mut lam = vec![1.0, 1.0, 1.0];
        st.begin_round(None, &lam); // round 1 ↔ t = 0, active coordinate 0
        for t in 1..=6usize {
            let prev = lam.clone();
            lam[(t - 1) % 3] += 0.25; // last round's active coordinate moved
            st.begin_round(Some(&prev), &lam);
            let active = t % 3;
            assert!(!st.shard(0).store_useful(active), "cyclic churn, t={t}");
        }
        // ...until the sweep goes quiet: then capture resumes and replays
        let frozen = lam.clone();
        st.begin_round(Some(&frozen), &lam);
        st.shard(0).store(2, 1, &[(1.0, 1.0)]);
        st.begin_round(Some(&frozen), &lam);
        assert_eq!(collect(&mut st.shard(0), 2, 1), Some(vec![(1.0, 1.0)]));
    }

    #[test]
    fn memory_gate_refuses_oversized_instances() {
        // a billion groups would need ~GBs of Option slots alone
        assert!(ScdStability::try_new(Shards::new(1_000_000_000, 1 << 20), 10).is_none());
        assert!(ScdStability::try_new(Shards::new(100_000, 4_096), 10).is_some());
    }
}
