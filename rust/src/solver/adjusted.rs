//! Cost-adjusted profits — the quantity everything else is built on:
//!
//! ```text
//! p̃_ij = p_ij − Σ_k λ_k b_ijk            (per item; §4.2)
//! p̃_i  = Σ_j (p_ij − Σ_k λ_k b_ijk) x_ij  (per group; §5.4)
//! ```
//!
//! The kernels consume [`GroupRow`] slices straight out of a
//! [`crate::instance::problem::GroupBlock`] — zero-copy on block-capable
//! sources — and are written as flat slice passes (no per-item branching
//! on layout) so the compiler can unroll and vectorize the inner loops.
//! The [`GroupBuf`] entry points are thin wrappers over the same code, so
//! the two paths cannot drift numerically.

use crate::instance::problem::{CostsBuf, GroupBuf, GroupRow, RowCosts};

/// Compute `p̃_j` for one group row into `out` (len `M`).
///
/// Dense: a length-`K` dot product per item (this is exactly the
/// contraction the L1 Pallas kernel performs batched on the MXU).
/// Sparse: one multiply per item.
#[inline]
pub fn adjusted_profits_row(row: GroupRow<'_>, lambda: &[f64], out: &mut [f64]) {
    let m = row.profits.len();
    debug_assert_eq!(out.len(), m);
    match row.costs {
        RowCosts::Dense(b) => {
            let k = lambda.len();
            debug_assert_eq!(b.len(), m * k);
            for (j, (o, &p)) in out.iter_mut().zip(row.profits).enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut dot = 0.0f64;
                for (lam, &bc) in lambda.iter().zip(brow) {
                    dot += lam * bc as f64;
                }
                *o = p as f64 - dot;
            }
        }
        RowCosts::Sparse { knap, cost } => {
            for (((o, &p), &kn), &c) in out.iter_mut().zip(row.profits).zip(knap).zip(cost) {
                *o = p as f64 - lambda[kn as usize] * c as f64;
            }
        }
    }
}

/// [`adjusted_profits_row`] through the per-group buffer API.
#[inline]
pub fn adjusted_profits(buf: &GroupBuf, lambda: &[f64], out: &mut [f64]) {
    adjusted_profits_row(buf.row(), lambda, out)
}

/// Add the selected items' consumption `Σ_j b_jk x_j` into `acc[k]`,
/// and return `(primal, dual)` group contributions:
/// `primal = Σ p_j x_j`, `dual = Σ p̃_j x_j`.
#[inline]
pub fn accumulate_selection_row(
    row: GroupRow<'_>,
    ptilde: &[f64],
    x: &[u8],
    acc: &mut [f64],
) -> (f64, f64) {
    let m = row.profits.len();
    let mut primal = 0.0f64;
    let mut dual = 0.0f64;
    match row.costs {
        RowCosts::Dense(b) => {
            let k = acc.len();
            for j in 0..m {
                if x[j] != 0 {
                    primal += row.profits[j] as f64;
                    dual += ptilde[j];
                    let brow = &b[j * k..(j + 1) * k];
                    for (a, &bc) in acc.iter_mut().zip(brow) {
                        *a += bc as f64;
                    }
                }
            }
        }
        RowCosts::Sparse { knap, cost } => {
            for j in 0..m {
                if x[j] != 0 {
                    primal += row.profits[j] as f64;
                    dual += ptilde[j];
                    acc[knap[j] as usize] += cost[j] as f64;
                }
            }
        }
    }
    (primal, dual)
}

/// [`accumulate_selection_row`] through the per-group buffer API.
#[inline]
pub fn accumulate_selection(
    buf: &GroupBuf,
    ptilde: &[f64],
    x: &[u8],
    acc: &mut [f64],
) -> (f64, f64) {
    accumulate_selection_row(buf.row(), ptilde, x, acc)
}

/// Consumption of a single knapsack `k` by the selection (used by the SCD
/// candidate walk, which only tracks the coordinate being updated).
#[inline]
pub fn consumption_of(buf: &GroupBuf, x: &[u8], k: usize) -> f64 {
    let m = buf.profits.len();
    match &buf.costs {
        CostsBuf::Dense(b) => {
            let kk = b.len() / m;
            (0..m)
                .filter(|&j| x[j] != 0)
                .map(|j| b[j * kk + k] as f64)
                .sum()
        }
        CostsBuf::Sparse { knap, cost } => (0..m)
            .filter(|&j| x[j] != 0 && knap[j] as usize == k)
            .map(|j| cost[j] as f64)
            .sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::problem::{Dims, GroupBuf};

    fn dense_buf() -> GroupBuf {
        let mut buf = GroupBuf::new(Dims { n_groups: 1, n_items: 2, n_global: 2 }, true);
        buf.profits.copy_from_slice(&[1.0, 2.0]);
        match &mut buf.costs {
            CostsBuf::Dense(b) => b.copy_from_slice(&[0.5, 0.0, 0.25, 1.0]),
            _ => unreachable!(),
        }
        buf
    }

    #[test]
    fn dense_adjusted() {
        let buf = dense_buf();
        let mut out = [0.0; 2];
        adjusted_profits(&buf, &[2.0, 4.0], &mut out);
        // j0: 1 − (2·0.5 + 4·0) = 0; j1: 2 − (2·0.25 + 4·1) = −2.5
        assert!((out[0] - 0.0).abs() < 1e-9);
        assert!((out[1] + 2.5).abs() < 1e-9);
    }

    #[test]
    fn sparse_adjusted() {
        let mut buf = GroupBuf::new(Dims { n_groups: 1, n_items: 2, n_global: 3 }, false);
        buf.profits.copy_from_slice(&[1.0, 2.0]);
        match &mut buf.costs {
            CostsBuf::Sparse { knap, cost } => {
                knap.copy_from_slice(&[2, 0]);
                cost.copy_from_slice(&[0.5, 1.0]);
            }
            _ => unreachable!(),
        }
        let mut out = [0.0; 2];
        adjusted_profits(&buf, &[3.0, 9.0, 2.0], &mut out);
        assert!((out[0] - (1.0 - 2.0 * 0.5)).abs() < 1e-9);
        assert!((out[1] - (2.0 - 3.0 * 1.0)).abs() < 1e-9);
    }

    #[test]
    fn row_and_buf_paths_agree_bitwise() {
        let buf = dense_buf();
        let lambda = [0.3, 1.7];
        let (mut a, mut b) = ([0.0; 2], [0.0; 2]);
        adjusted_profits(&buf, &lambda, &mut a);
        adjusted_profits_row(buf.row(), &lambda, &mut b);
        assert_eq!(a, b);
        let mut acc_a = [0.0; 2];
        let mut acc_b = [0.0; 2];
        let ra = accumulate_selection(&buf, &a, &[1, 1], &mut acc_a);
        let rb = accumulate_selection_row(buf.row(), &b, &[1, 1], &mut acc_b);
        assert_eq!(ra, rb);
        assert_eq!(acc_a, acc_b);
    }

    #[test]
    fn accumulate_and_consumption() {
        let buf = dense_buf();
        let ptilde = [0.7, 1.5];
        let mut acc = [0.0; 2];
        let (primal, dual) = accumulate_selection(&buf, &ptilde, &[1, 1], &mut acc);
        assert!((primal - 3.0).abs() < 1e-9);
        assert!((dual - 2.2).abs() < 1e-9);
        assert!((acc[0] - 0.75).abs() < 1e-9);
        assert!((acc[1] - 1.0).abs() < 1e-9);
        assert!((consumption_of(&buf, &[1, 0], 0) - 0.5).abs() < 1e-9);
        assert!((consumption_of(&buf, &[0, 1], 1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn nothing_selected() {
        let buf = dense_buf();
        let mut acc = [0.0; 2];
        let (p, d) = accumulate_selection(&buf, &[0.0, 0.0], &[0, 0], &mut acc);
        assert_eq!((p, d), (0.0, 0.0));
        assert_eq!(acc, [0.0, 0.0]);
    }
}
