//! **Algorithm 5** — linear-time candidate generation for the sparse
//! special case (§5.1):
//!
//! * each item consumes from exactly one knapsack (one-to-one when `M = K`,
//!   or an injective per-group mapping in general), and
//! * one local constraint caps the number of chosen items at `Q`.
//!
//! Then for each item there is *at most one* candidate for its knapsack's
//! multiplier: the value at which the item's adjusted profit crosses the
//! top-`Q` threshold. Quickselect finds the `Q`-th / `(Q+1)`-th largest
//! adjusted profits in O(M), independent of `Q`.

use crate::instance::laminar::LaminarProfile;
use crate::instance::problem::{CostsBuf, GroupBuf, GroupSource};
use crate::util::top_k_threshold;

/// Scratch for the Algorithm-5 map step.
#[derive(Debug, Clone, Default)]
pub struct SparseQScratch {
    ap: Vec<f64>,
    sel: Vec<f64>,
}

/// Whether `source` satisfies Algorithm 5's structural preconditions:
/// sparse costs and a single all-items local constraint. (The injectivity
/// of each group's item→knapsack mapping is the generator's contract and is
/// property-tested, not checked per group.)
pub fn eligible<S: GroupSource + ?Sized>(source: &S) -> Option<u32> {
    if source.is_dense() {
        return None;
    }
    let locals: &LaminarProfile = source.locals();
    if locals.len() != 1 {
        return None;
    }
    let c = &locals.constraints()[0];
    if c.items.len() != source.dims().n_items {
        return None;
    }
    Some(c.cap)
}

/// Whether `source` is the shape the `scd_sparse` XLA artifact compiles
/// for: Algorithm-5 eligible *and* identity-mapped (`M = K`). The single
/// gate shared by the session planner and the legacy `Coordinator` so the
/// two dispatch paths can never drift.
pub fn xla_identity_eligible<S: GroupSource + ?Sized>(source: &S) -> bool {
    let dims = source.dims();
    eligible(source).is_some() && dims.n_items == dims.n_global
}

/// The Algorithm-5 map step for one group row: emit `(k, v1, v2)`
/// candidate triples via `emit`. `q` is the local cap. The slices come
/// straight out of a [`crate::instance::problem::GroupBlock`] — zero-copy
/// on block-capable sources.
///
/// `v1` is the critical multiplier below which item `j` (consuming from
/// knapsack `knap[j]`) is selected; `v2 = b_j` is the consumption it then
/// adds.
pub fn emit_candidates_row<F: FnMut(usize, f64, f64)>(
    profits: &[f32],
    knap: &[u32],
    cost: &[f32],
    lambda: &[f64],
    q: u32,
    scratch: &mut SparseQScratch,
    mut emit: F,
) {
    let m = profits.len();
    scratch.ap.clear();
    scratch.ap.reserve(m);
    for j in 0..m {
        // f64 end-to-end: the same arithmetic as Algorithm 3's line
        // coefficients, so the two candidate paths agree bit-exactly
        let ap = profits[j] as f64 - lambda[knap[j] as usize] * cost[j] as f64;
        scratch.ap.push(ap.max(0.0));
    }
    let q = q as usize;
    // Q-th and (Q+1)-th largest adjusted profits; beyond the array they
    // fall back to 0 (profits are clamped at 0, so 0 is the no-op threshold)
    let (q_th, q1_th) = if q >= m {
        (0.0f64, 0.0f64)
    } else {
        let (a, b) = top_k_threshold(&scratch.ap, q, &mut scratch.sel);
        (a, b.max(0.0))
    };
    for j in 0..m {
        if cost[j] <= 0.0 {
            continue; // zero-cost item: λ never changes its status
        }
        let p_bar = if scratch.ap[j] >= q_th { q1_th } else { q_th };
        let p = profits[j] as f64;
        if p > p_bar {
            let v1 = (p - p_bar) / cost[j] as f64;
            emit(knap[j] as usize, v1, cost[j] as f64);
        }
    }
}

/// [`emit_candidates_row`] through the per-group buffer API. Panics on a
/// dense buffer (Algorithm 5's precondition).
pub fn emit_candidates<F: FnMut(usize, f64, f64)>(
    buf: &GroupBuf,
    lambda: &[f64],
    q: u32,
    scratch: &mut SparseQScratch,
    emit: F,
) {
    let (knap, cost) = match &buf.costs {
        CostsBuf::Sparse { knap, cost } => (knap, cost),
        CostsBuf::Dense(_) => panic!("Algorithm 5 requires the sparse layout"),
    };
    emit_candidates_row(&buf.profits, knap, cost, lambda, q, scratch, emit)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};
    use crate::instance::laminar::LaminarProfile;
    use crate::instance::problem::{Dims, GroupBuf};

    fn sparse_buf(p: &[f32], knap: &[u32], cost: &[f32], k: usize) -> GroupBuf {
        let m = p.len();
        let mut buf = GroupBuf::new(Dims { n_groups: 1, n_items: m, n_global: k }, false);
        buf.profits.copy_from_slice(p);
        match &mut buf.costs {
            CostsBuf::Sparse { knap: dk, cost: dc } => {
                dk.copy_from_slice(knap);
                dc.copy_from_slice(cost);
            }
            _ => unreachable!(),
        }
        buf
    }

    fn collect(buf: &GroupBuf, lambda: &[f64], q: u32) -> Vec<(usize, f64, f64)> {
        let mut out = Vec::new();
        let mut scratch = SparseQScratch::default();
        emit_candidates(buf, lambda, q, &mut scratch, |k, v1, v2| out.push((k, v1, v2)));
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    #[test]
    fn identity_mapping_emits_per_item_thresholds() {
        // M = K = 3, λ = 0, Q = 1: ap = p = [3, 2, 1]
        // item0 (in top-1): p̄ = Q1th = 2 → v1 = (3−2)/1 = 1
        // item1 (out):      p̄ = Qth = 3 → 2 > 3? no emit
        // item2 (out):      p̄ = 3 → no emit
        let buf = sparse_buf(&[3.0, 2.0, 1.0], &[0, 1, 2], &[1.0, 1.0, 1.0], 3);
        let got = collect(&buf, &[0.0; 3], 1);
        assert_eq!(got, vec![(0, 1.0, 1.0)]);
    }

    #[test]
    fn out_of_top_item_can_emit_when_profit_beats_threshold() {
        // λ = [5, 0]: ap = [max(3−5,0), 2] = [0, 2]; Q=1
        // item0 out of top-1: p̄ = Qth = 2; p_0 = 3 > 2 → v1 = (3−2)/1 = 1
        // item1 in top-1: p̄ = Q1th = 0; p_1 = 2 > 0 → v1 = 2/1 = 2
        let buf = sparse_buf(&[3.0, 2.0], &[0, 1], &[1.0, 1.0], 2);
        let got = collect(&buf, &[5.0, 0.0], 1);
        assert_eq!(got, vec![(0, 1.0, 1.0), (1, 2.0, 1.0)]);
    }

    #[test]
    fn q_at_least_m_uses_zero_threshold() {
        let buf = sparse_buf(&[3.0, 2.0], &[0, 1], &[0.5, 2.0], 2);
        let got = collect(&buf, &[0.0, 0.0], 5);
        // every positive-profit item emits its axis crossing p/b
        assert_eq!(got, vec![(0, 6.0, 0.5), (1, 1.0, 2.0)]);
    }

    #[test]
    fn zero_cost_items_do_not_emit() {
        let buf = sparse_buf(&[3.0], &[0], &[0.0], 1);
        assert!(collect(&buf, &[0.0], 1).is_empty());
    }

    #[test]
    fn eligibility() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(10, 5, 5));
        assert_eq!(eligible(&p), Some(1));
        let p = SyntheticProblem::new(GeneratorConfig::dense(10, 5, 5));
        assert_eq!(eligible(&p), None);
        let p = SyntheticProblem::new(
            GeneratorConfig::sparse(10, 6, 6).with_locals(LaminarProfile::scenario_c223(6)),
        );
        assert_eq!(eligible(&p), None);
        // single constraint over a strict subset: not eligible
        let p = SyntheticProblem::new(GeneratorConfig::sparse(10, 6, 6).with_locals(
            LaminarProfile::new(vec![crate::instance::laminar::LocalConstraint::new(
                vec![0, 1, 2],
                1,
            )])
            .unwrap(),
        ));
        assert_eq!(eligible(&p), None);
    }
}
