//! Point queries: per-group allocations under a *fixed* λ.
//!
//! A converged solve pins the multipliers; after that, "what does group
//! `i` get?" is a single Algorithm-1 greedy pass over that group — no
//! rounds, no reduce. This is the read side of a hosted solve
//! ([`crate::serve`]): the daemon answers batched allocation queries at
//! its current warm λ in microseconds per group, through exactly the
//! same row kernels the map phase runs ([`adjusted_profits_row`] →
//! [`greedy_select`] → [`accumulate_selection_row`]), so a point query
//! can never drift from what a full evaluation round would select.

use crate::error::{Error, Result};
use crate::instance::problem::{for_each_row, BlockBuf, GroupSource};
use crate::solver::adjusted::{accumulate_selection_row, adjusted_profits_row};
use crate::solver::greedy::{greedy_select, GroupScratch};
use crate::util::KahanSum;

/// One group's allocation under a fixed λ.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupAllocation {
    /// Group id (as queried).
    pub group: u64,
    /// Selection `x_j ∈ {0,1}` per item.
    pub x: Vec<u8>,
    /// `Σ_j p_j x_j` — the group's primal contribution.
    pub primal: f64,
    /// `Σ_j p̃_j x_j` — the group's inner dual contribution (dual
    /// objective minus the `Σ λ_k B_k` term).
    pub dual_inner: f64,
    /// `Σ_j b_jk x_j` per knapsack — the group's consumption.
    pub consumption: Vec<f64>,
}

/// Evaluate the greedy allocation of each queried group at fixed λ.
///
/// Groups may repeat and arrive in any order; the answer for a given
/// `(group, λ)` is a pure function of the instance, so batching and
/// ordering are presentation choices. Errors on a λ that fails the warm
/// validator (wrong length, negative or non-finite entries) and on group
/// ids out of range — both are caller data errors, reported before any
/// evaluation work happens.
pub fn allocations_at(
    source: &dyn GroupSource,
    lambda: &[f64],
    groups: &[u64],
) -> Result<Vec<GroupAllocation>> {
    let dims = source.dims();
    if let Err(m) = crate::solver::scd::check_warm_lambda(lambda, dims.n_global) {
        return Err(Error::InvalidConfig(format!("point query λ {m}")));
    }
    if let Some(&bad) = groups.iter().find(|&&g| g >= dims.n_groups as u64) {
        return Err(Error::InvalidConfig(format!(
            "point query asks for group {bad} but the instance has {} groups",
            dims.n_groups
        )));
    }
    let locals = source.locals();
    let mut block = BlockBuf::new();
    let mut scratch = GroupScratch::new(dims.n_items);
    let mut out = Vec::with_capacity(groups.len());
    for &g in groups {
        let mut acc = vec![0.0f64; dims.n_global];
        let mut got: Option<GroupAllocation> = None;
        for_each_row(source, g as usize, g as usize + 1, &mut block, |_, row| {
            adjusted_profits_row(row, lambda, &mut scratch.ptilde);
            greedy_select(locals, &mut scratch);
            let (primal, dual_inner) =
                accumulate_selection_row(row, &scratch.ptilde, &scratch.x, &mut acc);
            got = Some(GroupAllocation {
                group: g,
                x: scratch.x.clone(),
                primal,
                dual_inner,
                consumption: std::mem::take(&mut acc),
            });
        });
        out.push(got.expect("for_each_row visits exactly the requested group"));
    }
    Ok(out)
}

/// Whole-query aggregate, for bracketing a batch against a full round.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryAggregate {
    /// `Σ` primal over the queried groups.
    pub primal: f64,
    /// `Σ` dual_inner + `Σ_k λ_k B_k` — when the query covers *all*
    /// groups this is the dual objective `g(λ)`, an upper bound on the
    /// exact optimum for any λ ≥ 0 (weak duality).
    pub dual: f64,
    /// Summed consumption per knapsack.
    pub consumption: Vec<f64>,
    /// Total selected items.
    pub n_selected: u64,
}

/// Aggregate a batch of allocations (Kahan-compensated, ascending input
/// order — callers wanting the solver's bit pattern pass groups in
/// ascending id order, matching the single-chunk evaluation sum).
pub fn aggregate(allocs: &[GroupAllocation], lambda: &[f64], budgets: &[f64]) -> QueryAggregate {
    let k = budgets.len();
    let mut consumption = vec![KahanSum::new(); k];
    let mut primal = KahanSum::new();
    let mut dual = KahanSum::new();
    let mut n_selected = 0u64;
    for a in allocs {
        primal.add(a.primal);
        dual.add(a.dual_inner);
        for (s, &c) in consumption.iter_mut().zip(&a.consumption) {
            s.add(c);
        }
        n_selected += a.x.iter().map(|&x| x as u64).sum::<u64>();
    }
    let mut g = KahanSum::new();
    g.add(dual.value());
    for (l, b) in lambda.iter().zip(budgets) {
        g.add(l * b);
    }
    QueryAggregate {
        primal: primal.value(),
        dual: g.value(),
        consumption: consumption.iter().map(|s| s.value()).collect(),
        n_selected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};
    use crate::instance::shard::Shards;
    use crate::mapreduce::Cluster;
    use crate::solver::rounds::{evaluation_round, RustEvaluator};

    #[test]
    fn full_query_matches_evaluation_round_exactly() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(300, 8, 8).with_seed(3));
        let dims = p.dims();
        let lambda: Vec<f64> = (0..dims.n_global).map(|k| 0.3 + 0.1 * k as f64).collect();
        let groups: Vec<u64> = (0..dims.n_groups as u64).collect();
        let allocs = allocations_at(&p, &lambda, &groups).unwrap();
        let agg = aggregate(&allocs, &lambda, p.budgets());

        let cluster = Cluster::new(1);
        let round = evaluation_round(
            &RustEvaluator::new(&p),
            Shards::new(dims.n_groups, dims.n_groups),
            dims.n_global,
            &lambda,
            &cluster,
        );
        // one chunk, ascending group order on both sides ⇒ identical
        // Kahan summation order ⇒ bit-identical aggregates
        assert_eq!(agg.primal.to_bits(), round.primal.value().to_bits());
        assert_eq!(agg.n_selected, round.n_selected);
        for (a, b) in agg.consumption.iter().zip(round.consumption_values()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(
            agg.dual.to_bits(),
            round.dual_value(&lambda, p.budgets()).to_bits()
        );
    }

    #[test]
    fn repeats_and_order_are_pure() {
        let p = SyntheticProblem::new(GeneratorConfig::dense(50, 5, 4).with_seed(5));
        let lambda = vec![0.5; p.dims().n_global];
        let a = allocations_at(&p, &lambda, &[7, 3, 7]).unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0], a[2]);
        let b = allocations_at(&p, &lambda, &[3]).unwrap();
        assert_eq!(a[1], b[0]);
    }

    #[test]
    fn rejects_bad_lambda_and_bad_group() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(10, 3, 3).with_seed(1));
        assert!(allocations_at(&p, &[0.1; 2], &[0]).is_err());
        assert!(allocations_at(&p, &[-1.0, 0.0, 0.0], &[0]).is_err());
        assert!(allocations_at(&p, &[0.1; 3], &[10]).is_err());
        assert!(allocations_at(&p, &[0.1; 3], &[]).unwrap().is_empty());
    }
}
