//! **Algorithm 2** — distributed dual descent (DD).
//!
//! Each iteration: mappers solve the per-group subproblems at `λ^t` and emit
//! per-knapsack consumption; reducers aggregate `R_k`; the leader updates
//!
//! ```text
//! λ_k^{t+1} = max(0, λ_k^t + α (R_k − B_k))
//! ```
//!
//! The paper's critique (§4.3.2) — α must be tuned and the iterates are
//! prone to constraint violations — is reproduced by the Fig 5/6 bench.

use crate::cluster::{Clock, Exec, SystemClock};
use crate::error::Result;
use crate::instance::problem::GroupSource;
use crate::instance::shard::Shards;
use crate::mapreduce::Cluster;
use crate::metrics::ClockStopwatch;
use crate::obs::{self, names, Track};
use crate::solver::config::SolverConfig;
use crate::solver::postprocess;
use crate::solver::rounds::{evaluation_round, RoundAgg, RustEvaluator, ShardEvaluator};
use crate::solver::stats::{
    max_violation_ratio, ObserverControl, PhaseTimings, RoundEvent, SolveObserver, SolveReport,
};
use crate::util::rel_change;

/// Solve with dual descent using the pure-rust evaluator.
pub fn solve_dd<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
) -> Result<SolveReport> {
    let eval = RustEvaluator::new(source);
    solve_dd_with(source, &eval, config, cluster)
}

/// [`solve_dd`] with the session-API hooks: an optional warm-start λ
/// (overrides `lambda0` *and* pre-solving) and an optional per-round
/// [`SolveObserver`] (progress, checkpoints, cancellation).
pub fn solve_dd_driven<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    cluster: &Cluster,
    init: Option<&[f64]>,
    observer: Option<&mut dyn SolveObserver>,
) -> Result<SolveReport> {
    let eval = RustEvaluator::new(source);
    solve_dd_with_driven(source, &eval, config, cluster, init, observer)
}

/// Solve with dual descent using a caller-supplied evaluator (e.g. the
/// XLA-backed dense path).
pub fn solve_dd_with<S: GroupSource + ?Sized, E: ShardEvaluator>(
    source: &S,
    evaluator: &E,
    config: &SolverConfig,
    cluster: &Cluster,
) -> Result<SolveReport> {
    solve_dd_with_driven(source, evaluator, config, cluster, None, None)
}

/// The full dual-descent driver: caller-supplied evaluator, optional
/// warm-start λ and optional per-round observer.
pub fn solve_dd_with_driven<S: GroupSource + ?Sized, E: ShardEvaluator>(
    source: &S,
    evaluator: &E,
    config: &SolverConfig,
    cluster: &Cluster,
    init: Option<&[f64]>,
    observer: Option<&mut dyn SolveObserver>,
) -> Result<SolveReport> {
    solve_dd_with_driven_clocked(source, evaluator, config, cluster, init, observer, &SystemClock)
}

/// [`solve_dd_with_driven`] with the phase timings read through an
/// explicit [`Clock`] — how a daemon-hosted solve stays fully
/// virtual-time testable under the deterministic simulator.
#[allow(clippy::too_many_arguments)]
pub fn solve_dd_with_driven_clocked<S: GroupSource + ?Sized, E: ShardEvaluator>(
    source: &S,
    evaluator: &E,
    config: &SolverConfig,
    cluster: &Cluster,
    init: Option<&[f64]>,
    observer: Option<&mut dyn SolveObserver>,
    clock: &dyn Clock,
) -> Result<SolveReport> {
    let k = source.dims().n_global;
    dd_drive(
        source,
        config,
        &Exec::Local(cluster),
        &|shards, lambda| Ok(evaluation_round(evaluator, shards, k, lambda, cluster)),
        init,
        observer,
        clock,
    )
}

/// Dual descent on the executor abstraction: the pure-rust map phase runs
/// on the in-process pool ([`Exec::Local`]) or a TCP worker fleet
/// ([`Exec::Remote`]) — leader-side update and reporting are identical.
/// (The XLA-evaluator path stays on [`solve_dd_with_driven`]: custom
/// evaluators cannot cross a process boundary.)
pub fn solve_dd_exec<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    exec: &Exec<'_>,
    init: Option<&[f64]>,
    observer: Option<&mut dyn SolveObserver>,
) -> Result<SolveReport> {
    solve_dd_exec_clocked(source, config, exec, init, observer, &SystemClock)
}

/// [`solve_dd_exec`] with the phase timings read through an explicit
/// [`Clock`]: under [`SystemClock`] the behavior is byte-for-byte the
/// production one, under a virtual clock the reported `wall_ms`/phases
/// are virtual-time — nothing in the driver touches `Instant` directly.
pub fn solve_dd_exec_clocked<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    exec: &Exec<'_>,
    init: Option<&[f64]>,
    observer: Option<&mut dyn SolveObserver>,
    clock: &dyn Clock,
) -> Result<SolveReport> {
    let k = source.dims().n_global;
    dd_drive(
        source,
        config,
        exec,
        &|shards, lambda| exec.eval_round(source, shards, k, lambda),
        init,
        observer,
        clock,
    )
}

/// Shared Algorithm-2 loop; `round` evaluates one map round at fixed λ.
#[allow(clippy::too_many_arguments)]
fn dd_drive<S: GroupSource + ?Sized>(
    source: &S,
    config: &SolverConfig,
    exec: &Exec<'_>,
    round: &dyn Fn(Shards, &[f64]) -> Result<RoundAgg>,
    init: Option<&[f64]>,
    mut observer: Option<&mut dyn SolveObserver>,
    clock: &dyn Clock,
) -> Result<SolveReport> {
    config.validate()?;
    source.validate()?;
    let t0 = ClockStopwatch::start(clock);
    let dims = source.dims();
    let budgets = source.budgets().to_vec();
    // align map shards with the source's storage shards (no-op for
    // in-memory sources) so out-of-core workers touch whole files
    let shards = Shards::plan(
        dims.n_groups,
        exec.map_parallelism(),
        source.preferred_shard_size(),
        config.shard_size,
    );

    let mut lambda =
        crate::solver::scd::initial_lambda(source, config, exec.local_pool(), init)?;

    let mut history = Vec::new();
    let mut last_agg: Option<RoundAgg> = None;
    let mut converged = false;
    let mut stopped = false;
    let mut iterations = 0;
    let mut phases = PhaseTimings::default();

    for t in 0..config.max_iters {
        let it0 = ClockStopwatch::start(clock);
        let agg = round(shards, &lambda)?;
        let map_ns = it0.elapsed_ns();
        let map_ms = map_ns as f64 / 1e6;
        phases.map_ms += map_ms;
        obs::complete(Track::Leader, names::MAP, it0.start_ns(), map_ns, t as u64, 0);
        let r0 = ClockStopwatch::start(clock);
        let consumption = agg.consumption_values();

        // leader-side dual-descent update
        let mut new_lambda = lambda.clone();
        for k in 0..dims.n_global {
            new_lambda[k] = (lambda[k] + config.dd_alpha * (consumption[k] - budgets[k])).max(0.0);
        }
        let reduce_ns = r0.elapsed_ns();
        let reduce_ms = reduce_ns as f64 / 1e6;
        phases.reduce_ms += reduce_ms;
        obs::complete(Track::Leader, names::REDUCE, r0.start_ns(), reduce_ns, t as u64, 0);
        let residual = rel_change(&new_lambda, &lambda);
        iterations = t + 1;
        let round_ns = it0.elapsed_ns();
        obs::complete(Track::Leader, names::ROUND, it0.start_ns(), round_ns, t as u64, 0);
        let event = RoundEvent {
            iter: t,
            primal: agg.primal.value(),
            dual: agg.dual_value(&lambda, &budgets),
            max_violation_ratio: max_violation_ratio(&consumption, &budgets),
            lambda_change: residual,
            wall_ms: round_ns as f64 / 1e6,
            map_ms,
            reduce_ms,
            skip_rate: 0.0,
            lambda: &new_lambda,
        };
        if config.track_history {
            history.push(event.to_iter_stat());
        }
        last_agg = Some(agg);
        let stop = match observer.as_mut() {
            Some(obs) => obs.on_round(&event) == ObserverControl::Stop,
            None => false,
        };
        lambda = new_lambda;
        if stop {
            stopped = true;
            break;
        }
        if residual < config.tol {
            converged = true;
            break;
        }
    }

    // DD's recorded aggregate is for the λ the round *started* from; on
    // cancellation re-evaluate at the adopted λ so the report (and the
    // feasibility decision post-processing makes) match report.lambda —
    // the same self-consistency contract the SCD drivers keep
    let agg = if stopped {
        let e0 = ClockStopwatch::start(clock);
        let agg = round(shards, &lambda)?;
        let final_ns = e0.elapsed_ns();
        phases.final_eval_ms = final_ns as f64 / 1e6;
        let it = iterations as u64;
        obs::complete(Track::Leader, names::FINAL_EVAL, e0.start_ns(), final_ns, it, 0);
        agg
    } else {
        last_agg.expect("max_iters ≥ 1 ran at least one round")
    };
    let mut report = SolveReport {
        dual_value: agg.dual_value(&lambda, &budgets),
        primal_value: agg.primal.value(),
        consumption: agg.consumption_values(),
        lambda,
        iterations,
        converged,
        budgets,
        n_selected: agg.n_selected,
        dropped_groups: 0,
        history,
        wall_ms: 0.0,
        phases,
        membership: Vec::new(),
    };
    if config.postprocess && !report.is_feasible() {
        let p0 = ClockStopwatch::start(clock);
        postprocess::enforce_feasibility(source, &mut report, exec)?;
        let post_ns = p0.elapsed_ns();
        report.phases.postprocess_ms = post_ns as f64 / 1e6;
        obs::complete(Track::Leader, names::POSTPROCESS, p0.start_ns(), post_ns, 0, 0);
    }
    let wall_ns = t0.elapsed_ns();
    report.wall_ms = wall_ns as f64 / 1e6;
    obs::complete(Track::Leader, names::SESSION, t0.start_ns(), wall_ns, iterations as u64, 0);
    crate::metrics::record_phase_timings(&report.phases);
    if let Some(obs) = observer.as_mut() {
        obs.on_complete(&report);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};

    #[test]
    fn dd_reduces_violation_over_iterations() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(2_000, 10, 10).with_seed(1));
        let cfg = SolverConfig {
            max_iters: 40,
            dd_alpha: 2e-3,
            postprocess: false,
            ..Default::default()
        };
        let r = solve_dd(&p, &cfg, &Cluster::new(4)).unwrap();
        assert!(r.iterations >= 2);
        let first = &r.history[0];
        let last = r.history.last().unwrap();
        // starting at λ=1 with tight budgets, DD must move towards
        // feasibility or at least reduce the violation dramatically
        assert!(
            last.max_violation_ratio < first.max_violation_ratio.max(0.5) + 1.0,
            "violation did not behave: first={} last={}",
            first.max_violation_ratio,
            last.max_violation_ratio
        );
        assert!(r.primal_value > 0.0);
        // weak duality holds against the *feasible* primal: if the final
        // iterate is feasible the gap must be non-negative
        if r.is_feasible() {
            assert!(r.dual_value >= r.primal_value - 1e-6);
        }
    }

    #[test]
    fn dd_with_postprocess_is_feasible() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(1_000, 10, 10).with_seed(2));
        let cfg = SolverConfig { max_iters: 15, dd_alpha: 1e-3, ..Default::default() };
        let r = solve_dd(&p, &cfg, &Cluster::new(4)).unwrap();
        assert!(r.is_feasible(), "postprocess must enforce feasibility");
    }

    #[test]
    fn dd_deterministic_across_workers() {
        let p = SyntheticProblem::new(GeneratorConfig::dense(500, 5, 3).with_seed(7));
        let cfg = SolverConfig { max_iters: 5, postprocess: false, ..Default::default() };
        let a = solve_dd(&p, &cfg, &Cluster::new(1)).unwrap();
        let b = solve_dd(&p, &cfg, &Cluster::new(7)).unwrap();
        assert_eq!(a.lambda, b.lambda);
        assert_eq!(a.primal_value, b.primal_value);
    }

    #[test]
    fn rejects_invalid_config() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(10, 2, 2));
        let cfg = SolverConfig { max_iters: 0, ..Default::default() };
        assert!(solve_dd(&p, &cfg, &Cluster::single()).is_err());
    }

    #[test]
    fn zero_group_instance_is_refused_typed_by_both_solvers() {
        // a degenerate instance with no groups maps over zero shards;
        // both drivers must refuse it with a typed error up front — the
        // reduce path underneath must never panic on an empty round
        use crate::instance::laminar::LaminarProfile;
        use crate::instance::problem::{Dims, MaterializedProblem};
        let p = MaterializedProblem::zeroed_dense(
            Dims { n_groups: 0, n_items: 2, n_global: 1 },
            vec![1.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        let cfg = SolverConfig::default();
        for r in [
            solve_dd(&p, &cfg, &Cluster::single()),
            crate::solver::scd::solve_scd(&p, &cfg, &Cluster::single()),
        ] {
            match r {
                Err(crate::Error::InvalidProblem(msg)) => {
                    assert!(msg.contains("positive"), "unexpected message: {msg}")
                }
                other => panic!("expected InvalidProblem, got {other:?}"),
            }
        }
    }
}
