//! Shared map rounds: evaluate the greedy solution at fixed `λ` over all
//! groups, aggregating consumption / primal / dual — the body of every DD
//! iteration (Algorithm 2's `Map` + `Reduce`) and of SCD's bookkeeping.

use crate::instance::problem::{for_each_row, BlockBuf, GroupSource};
use crate::instance::shard::{ShardRange, Shards};
use crate::mapreduce::Cluster;
use crate::solver::adjusted::{accumulate_selection_row, adjusted_profits_row};
use crate::solver::greedy::{greedy_select, GroupScratch};
use crate::util::KahanSum;

/// Aggregate emitted by an evaluation round.
#[derive(Debug, Clone)]
pub struct RoundAgg {
    /// `R_k = Σ_i Σ_j b_ijk x_ij` per knapsack.
    pub consumption: Vec<KahanSum>,
    /// `Σ p_ij x_ij`.
    pub primal: KahanSum,
    /// `Σ_i Σ_j p̃_ij x_ij` (dual objective minus the `Σ λ_k B_k` term).
    pub dual_inner: KahanSum,
    /// Total selected items.
    pub n_selected: u64,
}

impl RoundAgg {
    /// Zeroed aggregate for `k` knapsacks.
    pub fn new(k: usize) -> Self {
        Self {
            consumption: vec![KahanSum::new(); k],
            primal: KahanSum::new(),
            dual_inner: KahanSum::new(),
            n_selected: 0,
        }
    }

    /// Merge another aggregate (worker-rank order for determinism).
    pub fn merge(mut self, other: RoundAgg) -> Self {
        for (a, b) in self.consumption.iter_mut().zip(&other.consumption) {
            a.merge(b);
        }
        self.primal.merge(&other.primal);
        self.dual_inner.merge(&other.dual_inner);
        self.n_selected += other.n_selected;
        self
    }

    /// Materialize consumption as plain f64s.
    pub fn consumption_values(&self) -> Vec<f64> {
        self.consumption.iter().map(|k| k.value()).collect()
    }

    /// The dual objective `g(λ) = Σ_i max(...) + Σ_k λ_k B_k`.
    pub fn dual_value(&self, lambda: &[f64], budgets: &[f64]) -> f64 {
        let mut g = KahanSum::new();
        g.add(self.dual_inner.value());
        for (l, b) in lambda.iter().zip(budgets) {
            g.add(l * b);
        }
        g.value()
    }
}

/// Evaluates shards at fixed `λ`. The default implementation is the pure
/// rust path; [`crate::runtime`] provides an XLA-backed one for the dense
/// single-level case.
pub trait ShardEvaluator: Sync {
    /// Accumulate the shard's groups into `agg`.
    fn eval_shard(&self, shard: ShardRange, lambda: &[f64], agg: &mut RoundAgg);
}

/// Pure-rust evaluator: stream groups through [`greedy_select`].
pub struct RustEvaluator<'a, S: GroupSource + ?Sized> {
    source: &'a S,
}

impl<'a, S: GroupSource + ?Sized> RustEvaluator<'a, S> {
    /// Wrap a group source.
    pub fn new(source: &'a S) -> Self {
        Self { source }
    }
}

impl<S: GroupSource + ?Sized> ShardEvaluator for RustEvaluator<'_, S> {
    fn eval_shard(&self, shard: ShardRange, lambda: &[f64], agg: &mut RoundAgg) {
        let dims = self.source.dims();
        let locals = self.source.locals();
        // thread-local reusable buffers (one set per worker-held call);
        // groups stream through the zero-copy block path
        thread_local! {
            static BUFS: std::cell::RefCell<Option<(BlockBuf, GroupScratch, Vec<f64>)>> =
                const { std::cell::RefCell::new(None) };
        }
        BUFS.with(|cell| {
            let mut slot = cell.borrow_mut();
            let needs_new = match slot.as_ref() {
                Some((_, s, acc)) => {
                    s.ptilde.len() != dims.n_items || acc.len() != dims.n_global
                }
                None => true,
            };
            if needs_new {
                let acc = vec![0.0; dims.n_global];
                *slot = Some((BlockBuf::new(), GroupScratch::new(dims.n_items), acc));
            }
            let (block, scratch, acc) = slot.as_mut().unwrap();
            for_each_row(self.source, shard.start, shard.end, block, |_, row| {
                adjusted_profits_row(row, lambda, &mut scratch.ptilde);
                greedy_select(locals, scratch);
                acc.iter_mut().for_each(|a| *a = 0.0);
                let (primal, dual) =
                    accumulate_selection_row(row, &scratch.ptilde, &scratch.x, acc);
                for (sum, &a) in agg.consumption.iter_mut().zip(acc.iter()) {
                    sum.add(a);
                }
                agg.primal.add(primal);
                agg.dual_inner.add(dual);
                agg.n_selected += scratch.x.iter().map(|&x| x as u64).sum::<u64>();
            });
        });
    }
}

/// Run one full evaluation round over `n_groups` via the cluster.
pub fn evaluation_round<E: ShardEvaluator>(
    evaluator: &E,
    shards: Shards,
    n_global: usize,
    lambda: &[f64],
    cluster: &Cluster,
) -> RoundAgg {
    evaluation_chunk(evaluator, shards, 0, shards.count(), n_global, lambda, cluster)
}

/// Evaluate the contiguous shard chunk `[lo, hi)` of the global partition —
/// the unit a cluster worker executes for one evaluation task frame. The
/// full-round case is `lo = 0, hi = shards.count()` ([`evaluation_round`]).
pub(crate) fn evaluation_chunk<E: ShardEvaluator>(
    evaluator: &E,
    shards: Shards,
    lo: usize,
    hi: usize,
    n_global: usize,
    lambda: &[f64],
    cluster: &Cluster,
) -> RoundAgg {
    cluster.map_combine(
        hi.saturating_sub(lo),
        || RoundAgg::new(n_global),
        |agg, idx| evaluator.eval_shard(shards.get(lo + idx), lambda, agg),
        RoundAgg::merge,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::generator::{GeneratorConfig, SyntheticProblem};
    use crate::instance::problem::{Dims, GroupSource, MaterializedProblem};
    use crate::instance::laminar::LaminarProfile;

    #[test]
    fn tiny_hand_checked_round() {
        // 2 groups, 2 items, 1 knapsack, cap 1 per group, λ=0:
        // both groups select their best item.
        let dims = Dims { n_groups: 2, n_items: 2, n_global: 1 };
        let mut p = MaterializedProblem::zeroed_dense(
            dims,
            vec![10.0],
            LaminarProfile::single(2, 1),
        )
        .unwrap();
        p.set_profit(0, 0, 1.0);
        p.set_profit(0, 1, 2.0);
        p.set_profit(1, 0, 3.0);
        p.set_profit(1, 1, 1.0);
        for (i, j) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
            p.set_cost(i, j, 0, 1.0);
        }
        let eval = RustEvaluator::new(&p);
        let agg = evaluation_round(
            &eval,
            Shards::new(2, 1),
            1,
            &[0.0],
            &Cluster::new(2),
        );
        assert_eq!(agg.n_selected, 2);
        assert!((agg.primal.value() - 5.0).abs() < 1e-9);
        assert!((agg.consumption_values()[0] - 2.0).abs() < 1e-9);
        // λ=0 ⇒ dual_inner == primal, and dual_value adds λ·B = 0
        assert!((agg.dual_value(&[0.0], &[10.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn empty_chunk_reduces_to_the_identity_aggregate() {
        // a zero-shard chunk (lo == hi, the shape a relay or worker sees
        // when its dealt range is empty) must produce the zeroed
        // aggregate, not panic in the reduce
        let p = SyntheticProblem::new(GeneratorConfig::sparse(100, 4, 3).with_seed(7));
        let eval = RustEvaluator::new(&p);
        let agg = evaluation_chunk(&eval, Shards::new(100, 10), 4, 4, 3, &[0.5; 3], &Cluster::new(4));
        assert_eq!(agg.n_selected, 0);
        assert_eq!(agg.primal.value(), 0.0);
        assert_eq!(agg.consumption_values(), vec![0.0; 3]);
    }

    #[test]
    fn deterministic_across_cluster_sizes_and_shard_sizes() {
        let p = SyntheticProblem::new(GeneratorConfig::sparse(5_000, 10, 10).with_seed(3));
        let lambda = vec![0.7; 10];
        let eval = RustEvaluator::new(&p);
        let base = evaluation_round(&eval, Shards::new(5_000, 512), 10, &lambda, &Cluster::new(1));
        for (w, sh) in [(4, 512), (8, 100), (3, 4999)] {
            let agg =
                evaluation_round(&eval, Shards::new(5_000, sh), 10, &lambda, &Cluster::new(w));
            assert_eq!(agg.n_selected, base.n_selected);
            assert!((agg.primal.value() - base.primal.value()).abs() < 1e-9);
            for (a, b) in agg.consumption_values().iter().zip(base.consumption_values()) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn higher_lambda_never_increases_consumption_much() {
        // monotonicity sanity: raising all multipliers shrinks selection
        let p = SyntheticProblem::new(GeneratorConfig::dense(2_000, 8, 4).with_seed(9));
        let eval = RustEvaluator::new(&p);
        let sh = Shards::new(2_000, 256);
        let low = evaluation_round(&eval, sh, 4, &[0.01; 4], &Cluster::new(4));
        let high = evaluation_round(&eval, sh, 4, &[5.0; 4], &Cluster::new(4));
        assert!(high.n_selected <= low.n_selected);
        let (lc, hc) = (low.consumption_values(), high.consumption_values());
        for k in 0..4 {
            assert!(hc[k] <= lc[k] + 1e-9);
        }
    }
}
