//! Solve reports: the quantities the paper evaluates (primal value, duality
//! gap, constraint-violation ratios, iteration counts) — plus the
//! [`SolveObserver`] trait the iterative solvers report per-round events
//! through (the session API's progress/cancellation/checkpoint hook).

/// One iteration's tracked statistics (Figures 5 & 6 plot these series).
#[derive(Debug, Clone)]
pub struct IterStat {
    /// Iteration index (0-based).
    pub iter: usize,
    /// Primal objective `Σ p x` at this iteration's `λ`.
    pub primal: f64,
    /// Dual objective `g(λ)`.
    pub dual: f64,
    /// `max_k max(0, R_k − B_k) / B_k` (paper §6: "max constraint
    /// violation ratio").
    pub max_violation_ratio: f64,
    /// Convergence residual `max_k |Δλ_k| / max(1, |λ_k|)`.
    pub lambda_change: f64,
    /// Wall time of the iteration (map + reduce + update), milliseconds.
    pub wall_ms: f64,
    /// Map-phase wall time (dispatch + per-group kernels + combine;
    /// includes the λ broadcast on a distributed executor), milliseconds.
    pub map_ms: f64,
    /// Leader-side reduce + λ-update wall time, milliseconds.
    pub reduce_ms: f64,
    /// Fraction of candidate walks served from the λ-stability cache this
    /// round (0 when the cache is off or the round had no walks).
    pub skip_rate: f64,
}

/// Cumulative per-phase breakdown of a solve — what `solve --json`
/// surfaces so speedups and λ-stability skipping are observable in
/// production runs, not just in benches.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseTimings {
    /// Leader-side round preparation (active-coordinate mask + round spec
    /// construction — the broadcast payload), milliseconds.
    pub broadcast_ms: f64,
    /// Total map-phase wall time across rounds, milliseconds.
    pub map_ms: f64,
    /// Total leader-side reduce + λ-update wall time, milliseconds.
    pub reduce_ms: f64,
    /// Closing evaluation at the final λ, milliseconds.
    pub final_eval_ms: f64,
    /// §5.4 feasibility projection, milliseconds (0 when it didn't run).
    pub postprocess_ms: f64,
    /// Candidate walks requested across all rounds (Algorithm-3 path).
    pub walks_total: u64,
    /// Walks served by replaying the λ-stability cache.
    pub walks_skipped: u64,
    /// Time spent inside shard reads by the async I/O subsystem,
    /// milliseconds — overlappable work on the backend's threads, not the
    /// map workers' (0 when serving from memory or borrow-only mmap).
    pub io_read_ms: f64,
    /// Time map workers were *blocked* waiting for shard data,
    /// milliseconds — the compute-visible I/O stall. Prefetch is working
    /// when this stays far below `io_read_ms`.
    pub io_wait_ms: f64,
    /// Bytes read by the async I/O subsystem.
    pub io_bytes: u64,
    /// Shards whose read was already in flight (or done) when first
    /// needed.
    pub io_prefetch_hits: u64,
    /// Shards that had to be read synchronously on demand.
    pub io_prefetch_misses: u64,
}

impl PhaseTimings {
    /// Overall fraction of candidate walks skipped.
    pub fn skip_rate(&self) -> f64 {
        if self.walks_total == 0 {
            0.0
        } else {
            self.walks_skipped as f64 / self.walks_total as f64
        }
    }
}

impl IterStat {
    /// Duality gap `g(λ) − primal` (paper footnote 5).
    pub fn duality_gap(&self) -> f64 {
        self.dual - self.primal
    }
}

/// What happened to cluster membership, as recorded in
/// [`SolveReport::membership`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipChange {
    /// A worker died (wire error / timeout) or a joiner was refused.
    Lost,
    /// A transiently-dead worker was re-dialed and re-handshaken back
    /// into the deal.
    Redialed,
    /// A fresh worker was admitted mid-solve through the join listener.
    Admitted,
    /// The solve continued below full strength (one note per strength
    /// transition, not per round).
    Degraded,
}

impl MembershipChange {
    /// Stable lowercase label (JSON reports, logs).
    pub fn label(&self) -> &'static str {
        match self {
            MembershipChange::Lost => "lost",
            MembershipChange::Redialed => "redialed",
            MembershipChange::Admitted => "admitted",
            MembershipChange::Degraded => "degraded",
        }
    }
}

/// One cluster membership change during a distributed solve — losses,
/// redials, mid-solve admissions, degradations — in occurrence order.
/// Empty for in-process solves.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipEvent {
    /// Gather round (the leader's round ordinal) the change landed in.
    pub round: u64,
    /// Worker slot affected; `None` for fleet-wide notes (degradation,
    /// refused joins that never got a slot).
    pub worker: Option<usize>,
    /// What changed.
    pub change: MembershipChange,
    /// Human-readable detail (address, cause).
    pub detail: String,
}

/// Final report of a DD/SCD solve.
#[derive(Debug, Clone)]
pub struct SolveReport {
    /// Final multipliers `λ*`.
    pub lambda: Vec<f64>,
    /// Iterations executed.
    pub iterations: usize,
    /// Whether the λ residual fell below tolerance.
    pub converged: bool,
    /// Final primal objective.
    pub primal_value: f64,
    /// Final dual objective `g(λ*)` (an upper bound on the IP optimum).
    pub dual_value: f64,
    /// Final per-knapsack consumption `R_k`.
    pub consumption: Vec<f64>,
    /// Budgets `B_k` (copied for ratio reporting).
    pub budgets: Vec<f64>,
    /// Total selected items.
    pub n_selected: u64,
    /// Groups zeroed by §5.4 post-processing (0 when it didn't run).
    pub dropped_groups: u64,
    /// Per-iteration series (empty when `track_history` is off).
    pub history: Vec<IterStat>,
    /// Total wall time, milliseconds.
    pub wall_ms: f64,
    /// Per-phase timing breakdown and λ-stability skip counters.
    pub phases: PhaseTimings,
    /// Cluster membership changes during the solve (losses, redials,
    /// admissions, degradations), in occurrence order; empty for
    /// in-process solves.
    pub membership: Vec<MembershipEvent>,
}

impl SolveReport {
    /// Duality gap `dual − primal` (≥ 0 up to numerical noise at
    /// convergence; Table 1's third column).
    pub fn duality_gap(&self) -> f64 {
        self.dual_value - self.primal_value
    }

    /// `max_k max(0, R_k − B_k)/B_k`.
    pub fn max_violation_ratio(&self) -> f64 {
        max_violation_ratio(&self.consumption, &self.budgets)
    }

    /// Number of violated global constraints.
    pub fn n_violations(&self) -> usize {
        self.consumption
            .iter()
            .zip(&self.budgets)
            .filter(|(r, b)| violates(**r, **b))
            .count()
    }

    /// True when every global constraint holds (up to relative epsilon).
    pub fn is_feasible(&self) -> bool {
        self.n_violations() == 0
    }
}

/// One round of an iterative solve, as reported to a [`SolveObserver`].
///
/// `primal`/`dual`/`max_violation_ratio` are evaluated at the multipliers
/// the round *started* from (`λ^t`); [`RoundEvent::lambda`] is the updated
/// vector the solver is about to adopt (`λ^{t+1}`) — the right thing to
/// checkpoint, and what a warm start should resume from.
#[derive(Debug)]
pub struct RoundEvent<'a> {
    /// Iteration index (0-based).
    pub iter: usize,
    /// Primal objective at `λ^t`.
    pub primal: f64,
    /// Dual objective `g(λ^t)`.
    pub dual: f64,
    /// `max_k max(0, R_k − B_k)/B_k` at `λ^t`.
    pub max_violation_ratio: f64,
    /// Convergence residual `max_k |Δλ_k| / max(1, |λ_k|)`.
    pub lambda_change: f64,
    /// Wall time of the round, milliseconds.
    pub wall_ms: f64,
    /// Map-phase wall time of the round, milliseconds.
    pub map_ms: f64,
    /// Leader-side reduce + λ-update wall time of the round, milliseconds.
    pub reduce_ms: f64,
    /// Fraction of candidate walks served from the λ-stability cache.
    pub skip_rate: f64,
    /// The updated multipliers `λ^{t+1}`.
    pub lambda: &'a [f64],
}

impl RoundEvent<'_> {
    /// Copy the round into an owned [`IterStat`] (what history recording
    /// stores).
    pub fn to_iter_stat(&self) -> IterStat {
        IterStat {
            iter: self.iter,
            primal: self.primal,
            dual: self.dual,
            max_violation_ratio: self.max_violation_ratio,
            lambda_change: self.lambda_change,
            wall_ms: self.wall_ms,
            map_ms: self.map_ms,
            reduce_ms: self.reduce_ms,
            skip_rate: self.skip_rate,
        }
    }
}

/// What an observer tells the solver to do after a round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObserverControl {
    /// Keep iterating.
    Continue,
    /// Stop after this round. The solver adopts the round's `λ^{t+1}`,
    /// reports `converged = false`, and still runs its final evaluation
    /// (and post-processing) so the returned report is self-consistent.
    Stop,
}

/// Per-round hook into an iterative solve (DD, SCD, or the XLA-backed SCD).
///
/// Observers subsume the old `track_history` bool: history recording is
/// just [`HistoryObserver`], and the same mechanism carries progress
/// display, periodic λ checkpointing
/// ([`crate::solve::CheckpointObserver`]) and cooperative cancellation.
pub trait SolveObserver {
    /// Called once per iteration, after the leader computed `λ^{t+1}` but
    /// before the next map round. Return [`ObserverControl::Stop`] to
    /// cancel the solve.
    fn on_round(&mut self, event: &RoundEvent<'_>) -> ObserverControl {
        let _ = event;
        ObserverControl::Continue
    }

    /// Called once with the final report (after the closing evaluation and
    /// any §5.4 post-processing), whether the solve converged, hit its
    /// iteration cap, or was cancelled.
    fn on_complete(&mut self, report: &SolveReport) {
        let _ = report;
    }
}

/// Built-in observer that records the per-iteration series — the observer
/// form of `SolverConfig::track_history`.
#[derive(Debug, Default)]
pub struct HistoryObserver {
    /// The recorded series, one entry per round.
    pub history: Vec<IterStat>,
}

impl HistoryObserver {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }
}

impl SolveObserver for HistoryObserver {
    fn on_round(&mut self, event: &RoundEvent<'_>) -> ObserverControl {
        self.history.push(event.to_iter_stat());
        ObserverControl::Continue
    }
}

/// Relative violation tolerance: consumption within `1 + 1e-9` of budget
/// counts as feasible (guards f32-accumulation noise at N=1e8 scale).
const REL_EPS: f64 = 1e-9;

fn violates(r: f64, b: f64) -> bool {
    r > b * (1.0 + REL_EPS)
}

/// `max_k max(0, R_k − B_k)/B_k` over all knapsacks.
pub fn max_violation_ratio(consumption: &[f64], budgets: &[f64]) -> f64 {
    consumption
        .iter()
        .zip(budgets)
        .map(|(&r, &b)| ((r - b) / b).max(0.0))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> SolveReport {
        SolveReport {
            lambda: vec![0.5, 0.0],
            iterations: 10,
            converged: true,
            primal_value: 100.0,
            dual_value: 101.5,
            consumption: vec![9.0, 12.0],
            budgets: vec![10.0, 10.0],
            n_selected: 42,
            dropped_groups: 0,
            history: vec![],
            wall_ms: 1.0,
            phases: PhaseTimings::default(),
            membership: Vec::new(),
        }
    }

    #[test]
    fn gap_and_violations() {
        let r = report();
        assert!((r.duality_gap() - 1.5).abs() < 1e-12);
        assert_eq!(r.n_violations(), 1);
        assert!(!r.is_feasible());
        assert!((r.max_violation_ratio() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn feasible_when_under_budget() {
        let mut r = report();
        r.consumption = vec![10.0, 9.9999];
        assert!(r.is_feasible());
        assert_eq!(r.max_violation_ratio(), 0.0);
    }

    #[test]
    fn history_observer_records_rounds() {
        let mut obs = HistoryObserver::new();
        let lambda = vec![0.5, 0.25];
        for t in 0..3 {
            let ev = RoundEvent {
                iter: t,
                primal: t as f64,
                dual: t as f64 + 1.0,
                max_violation_ratio: 0.0,
                lambda_change: 0.1,
                wall_ms: 1.0,
                map_ms: 0.8,
                reduce_ms: 0.1,
                skip_rate: 0.0,
                lambda: &lambda,
            };
            assert_eq!(obs.on_round(&ev), ObserverControl::Continue);
        }
        assert_eq!(obs.history.len(), 3);
        assert_eq!(obs.history[2].iter, 2);
        assert!((obs.history[1].duality_gap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn iter_stat_gap() {
        let s = IterStat {
            iter: 0,
            primal: 5.0,
            dual: 7.0,
            max_violation_ratio: 0.0,
            lambda_change: 1.0,
            wall_ms: 0.0,
            map_ms: 0.0,
            reduce_ms: 0.0,
            skip_rate: 0.0,
        };
        assert!((s.duality_gap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn phase_skip_rate() {
        let mut p = PhaseTimings::default();
        assert_eq!(p.skip_rate(), 0.0);
        p.walks_total = 8;
        p.walks_skipped = 2;
        assert!((p.skip_rate() - 0.25).abs() < 1e-12);
    }
}
