//! **Algorithm 3** — candidate values for `λ_k` (general case).
//!
//! For group `i` and coordinate `k`, each item defines a line
//! `z_j(λ_k) = a_j − λ_k b_jk` with `a_j = p_j − Σ_{k'≠k} λ_{k'} b_jk'`.
//! The greedy solution depends only on the *relative order* of the `z_j`
//! and their signs, so the solution can only change at:
//!
//! 1. pairwise intersections of the `M` lines, and
//! 2. intersections with the horizontal axis.
//!
//! Screening those O(M²) positive values is exhaustive.

use crate::instance::problem::{GroupBuf, GroupRow, RowCosts};

/// Per-coordinate line coefficients `(a_j, s_j)` with `s_j = b_jk`,
/// consuming a zero-copy [`GroupRow`] — the block-path kernel.
pub fn line_coefficients_row(
    row: GroupRow<'_>,
    lambda: &[f64],
    k: usize,
    a: &mut [f64],
    s: &mut [f64],
) {
    let m = row.profits.len();
    match row.costs {
        RowCosts::Dense(b) => {
            let kk = lambda.len();
            for j in 0..m {
                let brow = &b[j * kk..(j + 1) * kk];
                let mut dot = 0.0f64;
                for (kp, (&lam, &bc)) in lambda.iter().zip(brow).enumerate() {
                    if kp != k {
                        dot += lam * bc as f64;
                    }
                }
                a[j] = row.profits[j] as f64 - dot;
                s[j] = brow[k] as f64;
            }
        }
        RowCosts::Sparse { knap, cost } => {
            for j in 0..m {
                if knap[j] as usize == k {
                    a[j] = row.profits[j] as f64;
                    s[j] = cost[j] as f64;
                } else {
                    a[j] = row.profits[j] as f64 - lambda[knap[j] as usize] * cost[j] as f64;
                    s[j] = 0.0;
                }
            }
        }
    }
}

/// [`line_coefficients_row`] through the per-group buffer API.
pub fn line_coefficients(buf: &GroupBuf, lambda: &[f64], k: usize, a: &mut [f64], s: &mut [f64]) {
    line_coefficients_row(buf.row(), lambda, k, a, s)
}

/// Collect the positive candidate values for `λ_k` into `out`
/// (deduplicated, sorted **descending** — the order Algorithm 4's walk
/// needs). `a`/`s` are the line coefficients from [`line_coefficients`].
pub fn candidate_lambdas(a: &[f64], s: &[f64], out: &mut Vec<f64>) {
    out.clear();
    let m = a.len();
    for j in 0..m {
        // axis crossing: z_j(λ) = 0
        if s[j] > 0.0 {
            let lam = a[j] / s[j];
            if lam > 0.0 {
                out.push(lam);
            }
        }
        // pairwise intersections
        for jp in (j + 1)..m {
            let ds = s[j] - s[jp];
            if ds != 0.0 {
                let lam = (a[j] - a[jp]) / ds;
                if lam > 0.0 && lam.is_finite() {
                    out.push(lam);
                }
            }
        }
    }
    out.sort_unstable_by(|x, y| y.partial_cmp(x).unwrap());
    out.dedup();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::problem::{CostsBuf, Dims, GroupBuf};

    fn dense_buf(p: &[f32], b: &[f32], k: usize) -> GroupBuf {
        let m = p.len();
        let mut buf = GroupBuf::new(Dims { n_groups: 1, n_items: m, n_global: k }, true);
        buf.profits.copy_from_slice(p);
        match &mut buf.costs {
            CostsBuf::Dense(d) => d.copy_from_slice(b),
            _ => unreachable!(),
        }
        buf
    }

    #[test]
    fn two_lines_one_knapsack() {
        // z_0 = 3 − λ, z_1 = 2 − 0.5λ ⇒ intersection λ = 2, axes at 3 and 4
        let buf = dense_buf(&[3.0, 2.0], &[1.0, 0.5], 1);
        let (mut a, mut s) = (vec![0.0; 2], vec![0.0; 2]);
        line_coefficients(&buf, &[0.0], 0, &mut a, &mut s);
        assert_eq!(a, vec![3.0, 2.0]);
        assert_eq!(s, vec![1.0, 0.5]);
        let mut out = Vec::new();
        candidate_lambdas(&a, &s, &mut out);
        assert_eq!(out, vec![4.0, 3.0, 2.0]);
    }

    #[test]
    fn other_coordinates_shift_intercepts() {
        // K=2: a_j must subtract λ_1 b_j1 when screening k=0
        let buf = dense_buf(&[3.0, 2.0], &[1.0, 2.0, 0.5, 0.0], 2);
        let (mut a, mut s) = (vec![0.0; 2], vec![0.0; 2]);
        line_coefficients(&buf, &[9.0, 0.5], 0, &mut a, &mut s);
        assert_eq!(a, vec![3.0 - 0.5 * 2.0, 2.0]);
        assert_eq!(s, vec![1.0, 0.5]);
    }

    #[test]
    fn sparse_lines() {
        let mut buf = GroupBuf::new(Dims { n_groups: 1, n_items: 2, n_global: 2 }, false);
        buf.profits.copy_from_slice(&[3.0, 2.0]);
        match &mut buf.costs {
            CostsBuf::Sparse { knap, cost } => {
                knap.copy_from_slice(&[0, 1]);
                cost.copy_from_slice(&[1.5, 2.0]);
            }
            _ => unreachable!(),
        }
        let (mut a, mut s) = (vec![0.0; 2], vec![0.0; 2]);
        line_coefficients(&buf, &[0.7, 0.3], 0, &mut a, &mut s);
        // item0 maps to k=0: slope 1.5, intercept p=3
        assert_eq!(a[0], 3.0);
        assert_eq!(s[0], 1.5);
        // item1 maps elsewhere: slope 0, intercept p − λ_1 b = 2 − 0.6
        assert!((a[1] - 1.4).abs() < 1e-12);
        assert_eq!(s[1], 0.0);
    }

    #[test]
    fn negative_candidates_are_dropped() {
        // parallel lines produce no intersection; negative axis crossing dropped
        let (a, s) = (vec![-1.0, -2.0], vec![1.0, 1.0]);
        let mut out = Vec::new();
        candidate_lambdas(&a, &s, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn duplicates_removed_and_sorted_desc() {
        // three identical axis crossings at λ=2
        let (a, s) = (vec![2.0, 4.0, 6.0], vec![1.0, 2.0, 3.0]);
        let mut out = Vec::new();
        candidate_lambdas(&a, &s, &mut out);
        assert_eq!(out, vec![2.0]);
    }

    #[test]
    fn candidate_count_is_at_most_m_choose_2_plus_m() {
        let m = 8;
        let a: Vec<f64> = (0..m).map(|j| 1.0 + j as f64 * 0.37).collect();
        let s: Vec<f64> = (0..m).map(|j| 0.1 + j as f64 * 0.11).collect();
        let mut out = Vec::new();
        candidate_lambdas(&a, &s, &mut out);
        assert!(out.len() <= m * (m - 1) / 2 + m);
        // sorted descending
        for w in out.windows(2) {
            assert!(w[0] > w[1]);
        }
    }
}
